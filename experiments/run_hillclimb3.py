"""MoE iteration 2: tokens constrained on data axes only."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from pathlib import Path
sys.path.insert(0, "src")
from repro.launch import dryrun as dr

OUT = Path("experiments/hillclimb"); OUT.mkdir(exist_ok=True)

def run(tag, arch, shape, mb=1):
    if (OUT / f"{tag}.json").exists():
        print(f"{tag}: cached"); return
    dr.MICROBATCHES = mb
    try:
        rec = dr.dryrun_lm_cell(arch, shape, multi_pod=False)
    except Exception as e:
        import traceback
        rec = {"status": "error", "error": str(e), "traceback": traceback.format_exc()[-2500:]}
    finally:
        dr.MICROBATCHES = 1
    (OUT / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    m = rec.get("memory", {}).get("approx_peak_bytes_per_device", 0)/1e9
    rl = rec.get("roofline", {})
    print(f"{tag}: {rec['status']} mem={m:.1f}GB c={rl.get('compute_s',0):.2f} "
          f"m={rl.get('memory_s',0):.2f} x={rl.get('collective_s',0):.2f}", flush=True)

run("deepseek-moe-16b__train_4k__single__moefix2", "deepseek-moe-16b", "train_4k")
run("llama4-scout-17b-a16e__train_4k__single__moefix2", "llama4-scout-17b-a16e", "train_4k")
run("deepseek-moe-16b__train_4k__single__moefix2_mb4", "deepseek-moe-16b", "train_4k", mb=4)
run("deepseek-moe-16b__prefill_32k__single__moefix2", "deepseek-moe-16b", "prefill_32k")
print("hillclimb3 complete")
