"""Second hillclimb batch: gradient-accumulation microbatching for the
dense-train cells that exceed HBM."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from pathlib import Path
sys.path.insert(0, "src")

from repro.launch import dryrun as dr

OUT = Path("experiments/hillclimb"); OUT.mkdir(parents=True, exist_ok=True)

def run(tag, arch, shape, mb, multi=False):
    if (OUT / f"{tag}.json").exists():
        print(f"{tag}: cached"); return
    dr.MICROBATCHES = mb
    try:
        rec = dr.dryrun_lm_cell(arch, shape, multi_pod=multi)
    except Exception as e:
        import traceback
        rec = {"status": "error", "error": str(e),
               "traceback": traceback.format_exc()[-3000:]}
    finally:
        dr.MICROBATCHES = 1
    (OUT / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    m = rec.get("memory", {}).get("approx_peak_bytes_per_device", 0)/1e9
    print(f"{tag}: {rec['status']} mem={m:.1f}GB", flush=True)

run("command-r-plus-104b__train_4k__single__mb4", "command-r-plus-104b", "train_4k", 4)
run("qwen1.5-32b__train_4k__single__mb2", "qwen1.5-32b", "train_4k", 2)
run("gemma3-27b__train_4k__single__mb2", "gemma3-27b", "train_4k", 2)
print("hillclimb2 complete")
