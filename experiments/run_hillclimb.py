"""Hillclimb measurements for the three selected (arch x shape) pairs.

Baselines live in experiments/dryrun_baseline/; this script produces the
optimized counterparts into experiments/hillclimb/.  Run AFTER the main
sweep finishes (single process owns the 512 fake devices).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")

from repro.configs.registry import get_arch
from repro.launch.dryrun import dryrun_lm_cell, dryrun_maxflow

OUT = Path("experiments/hillclimb")
OUT.mkdir(parents=True, exist_ok=True)


def save(tag, rec):
    (OUT / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    rl = rec.get("roofline", {})
    mem = rec.get("memory", {}).get("approx_peak_bytes_per_device", 0) / 1e9
    print(f"{tag}: {rec['status']} mem={mem:.1f}GB "
          f"c={rl.get('compute_s', 0):.3f} m={rl.get('memory_s', 0):.3f} "
          f"x={rl.get('collective_s', 0):.3f} "
          f"useful={rl.get('useful_ratio', 0):.3f}", flush=True)


def run(tag, fn, *a, **kw):
    if (OUT / f"{tag}.json").exists():
        print(f"{tag}: cached", flush=True)
        return
    try:
        rec = fn(*a, **kw)
    except Exception as e:
        import traceback
        rec = {"status": "error", "error": str(e),
               "traceback": traceback.format_exc()[-3000:]}
    save(tag, rec)


# Pair 1 (worst roofline fraction): deepseek-moe-16b train_4k —
# MoE dispatch sharding constraints (code change in models/moe.py)
run("deepseek-moe-16b__train_4k__single__moefix",
    dryrun_lm_cell, "deepseek-moe-16b", "train_4k", multi_pod=False)

# Pair 2 (most collective-bound): deepseek prefill + xlstm train —
# (a) same MoE fix on the prefill cell, (b) pure-DP parallelism for xlstm
run("deepseek-moe-16b__prefill_32k__single__moefix",
    dryrun_lm_cell, "deepseek-moe-16b", "prefill_32k", multi_pod=False)
xl = dataclasses.replace(get_arch("xlstm-350m"), sharding="dp")
run("xlstm-350m__train_4k__single__dp",
    dryrun_lm_cell, "xlstm-350m", "train_4k", multi_pod=False,
    cfg_override=xl)

# Pair 3 (paper-representative): distributed P-ARD sweep —
# boundary-only label/flow exchange vs full all-gather
run("maxflow__sweep__single__full", dryrun_maxflow, multi_pod=False,
    exchange="full")
run("maxflow__sweep__single__boundary", dryrun_maxflow, multi_pod=False,
    exchange="boundary")
run("maxflow__sweep__multi__boundary", dryrun_maxflow, multi_pod=True,
    exchange="boundary")

# Bonus: llama4 MoE cells with the fix; xlstm probes now unroll the chunk
# scan (flops-exactness fix)
run("llama4-scout-17b-a16e__train_4k__single__moefix",
    dryrun_lm_cell, "llama4-scout-17b-a16e", "train_4k", multi_pod=False)
run("xlstm-350m__train_4k__single__exactprobe",
    dryrun_lm_cell, "xlstm-350m", "train_4k", multi_pod=False)
print("hillclimb measurements complete")
