"""End-to-end behaviour tests for the paper's system."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import SweepConfig, grid_partition, solve_mincut
from repro.data.grids import segmentation_grid
from repro.kernels.ref import maxflow_oracle


def test_end_to_end_segmentation():
    """The paper's motivating application: solve a vision segmentation
    instance with the distributed solver and check the cut recovers the
    planted foreground disk."""
    h = w = 24
    p = segmentation_grid(h, w, seed=0)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, part=grid_partition((h, w), (2, 2)),
                       config=SweepConfig(method="ard"))
    assert res.flow_value == want

    yy, xx = np.mgrid[:h, :w]
    disk = ((yy - h / 2) ** 2 + (xx - w / 2) ** 2
            < (min(h, w) / 3) ** 2)
    # the planted disk should be mostly labelled foreground (source side)
    agreement = (res.source_side.reshape(h, w) == disk).mean()
    assert agreement > 0.9, agreement


def test_end_to_end_training_and_generation():
    """Train a tiny LM on a deterministic stream, then greedily generate —
    the full train->serve arc in one test."""
    import dataclasses

    from repro.configs import ARCHS
    from repro.data.pipeline import MarkovSpec, markov_batch
    from repro.models.model import init_params
    from repro.train import optimizer as opt_lib
    from repro.train import train_loop as tl
    from repro.train.serve import greedy_generate

    cfg = dataclasses.replace(ARCHS["phi3-mini-3.8b"].smoke(),
                              num_layers=2, vocab_size=32)
    spec = MarkovSpec(vocab=32, branching=2, seed=0)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    step = jax.jit(tl.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=3e-3), jnp.float32))
    first = last = None
    for i in range(30):
        b = jax.tree.map(jnp.asarray, markov_batch(spec, i, 8, 64))
        state, m = step(state, b)
        if first is None:
            first = float(m["ce"])
        last = float(m["ce"])
    assert last < first

    prompts = jnp.asarray(markov_batch(spec, 999, 2, 16)["tokens"])
    out = greedy_generate(cfg, state.params, prompts, steps=8, max_seq=40,
                          dtype=jnp.float32)
    assert out.shape == (2, 8)
