"""Backward-compat shims: the legacy one-shot entry points are thin
wrappers over the ``Solver`` session and must behave BIT-IDENTICALLY to
the pre-session (PR-4) implementation — same flow, labels, cut and stats —
so downstream callers and all existing tests run unmodified.

The PR-4 reference behavior is reconstructed here from the primitives the
old front-ends composed (``build`` + ``init_labels`` + ``sweep.solve`` +
``extract_cut``/``cut_value``; ``pack_instances`` + ``batch.solve_batch``)
rather than from a pinned snapshot — those primitives are themselves
covered by the driver test suites.
"""

import numpy as np
import pytest

from repro.core import (BatchedSolver, SweepConfig, build, cut_value,
                        extract_cut, grid_partition, init_labels,
                        pack_instances, solve, solve_mincut,
                        solve_mincut_batch)
from repro.core import batch as batch_mod
from repro.data.grids import random_sparse, synthetic_grid


def _instance(g=10, seed=0):
    p = synthetic_grid(g, g, connectivity=8, strength=150, seed=seed)
    return p, grid_partition((g, g), (2, 2))


@pytest.mark.parametrize("cfg", [SweepConfig(method="ard"),
                                 SweepConfig(method="prd"),
                                 SweepConfig(device_resident=True)],
                         ids=["ard", "prd", "ard-dr"])
def test_solve_mincut_matches_pr4_composition(cfg):
    """solve_mincut == the old build/init_labels/sweep.solve/extract_cut
    pipeline, bit for bit (flow, labels, residuals, cut, stats)."""
    p, part = _instance()
    # --- the PR-4 front-end, reconstructed ---
    meta, state, layout = build(p, np.asarray(part))
    state0 = state
    st, stats = solve(meta, init_labels(meta, state), cfg)
    sink_side = extract_cut(meta, st)
    flow = int(st.flow_to_t)
    assert int(cut_value(meta, state0, sink_side)) == flow
    source_ref = ~layout.to_flat(np.asarray(sink_side))
    # --- the shim ---
    res = solve_mincut(p, part=part, config=cfg)
    assert res.flow_value == flow
    np.testing.assert_array_equal(res.source_side, source_ref)
    np.testing.assert_array_equal(np.asarray(res.state.d), np.asarray(st.d))
    np.testing.assert_array_equal(np.asarray(res.state.cf),
                                  np.asarray(st.cf))
    assert (res.stats.sweeps, res.stats.engine_iters,
            res.stats.engine_launches, res.stats.host_syncs,
            res.stats.boundary_bytes, res.stats.page_bytes,
            res.stats.regions_discharged) == \
           (stats.sweeps, stats.engine_iters, stats.engine_launches,
            stats.host_syncs, stats.boundary_bytes, stats.page_bytes,
            stats.regions_discharged)
    assert res.stats.flow_curve == stats.flow_curve
    assert res.stats.active_curve == stats.active_curve
    assert res.stats.scope == "instance"


def test_batched_shims_match_pr4_composition():
    """solve_mincut_batch/BatchedSolver == pack_instances + solve_batch,
    per instance, with the batched stats globals surfaced unchanged (now
    explicitly marked scope="batch")."""
    probs = [synthetic_grid(8, 8, seed=1), synthetic_grid(8, 8, seed=2),
             random_sparse(14, 28, seed=3)]
    cfg = SweepConfig(method="ard")
    # --- the PR-4 composition ---
    packs = pack_instances(probs, num_regions=4)
    ref = {}
    for packed in packs:
        bstate, bstats = batch_mod.solve_batch(packed, cfg)
        for b, idx in enumerate(packed.indices):
            meta = packed.metas[b]
            K, V, E = meta.num_regions, meta.region_size, meta.max_degree
            ref[idx] = (int(bstate.flow_to_t[b]),
                        np.asarray(bstate.d[b, :K, :V]),
                        int(bstats.sweeps[b]), int(bstats.engine_iters[b]),
                        bstats.engine_launches, bstats.host_syncs)
    # --- the shims ---
    solver = BatchedSolver(cfg, num_regions=4)
    res = solver.solve(probs)
    res2 = solve_mincut_batch(probs, num_regions=4, config=cfg)
    for i, r in enumerate(res):
        flow, d, sweeps, iters, launches, syncs = ref[i]
        assert r.flow_value == flow == res2[i].flow_value
        np.testing.assert_array_equal(np.asarray(r.state.d), d)
        assert r.stats.sweeps == sweeps
        assert r.stats.engine_iters == iters
        assert r.stats.engine_launches == launches   # the batch's global
        assert r.stats.host_syncs == syncs           # counters, verbatim
        assert r.stats.scope == "batch"
    assert len(solver.last_batch_stats) == len(packs)


def test_batched_solver_legacy_surface():
    """The knobs and failure modes of the old BatchedSolver survive."""
    with pytest.raises(ValueError):
        BatchedSolver(SweepConfig(parallel=False))
    with pytest.raises(ValueError):
        BatchedSolver(SweepConfig(use_boundary_relabel=True))
    solver = BatchedSolver(num_regions=4, check=True)
    solver.solve([synthetic_grid(8, 8, seed=5)])
    info = solver.cache_info()
    assert info.misses >= 0 and info.hits >= 0
    solver.solve([synthetic_grid(8, 8, seed=6)])
    assert solver.cache_info().hits >= 1


def test_legacy_import_surface():
    """Names downstream code imports keep resolving."""
    from repro.core.api import (BatchCacheInfo, MincutResult,  # noqa: F401
                                solve_mincut as _sm)
    from repro.core import MincutResult as _mr                 # noqa: F401
