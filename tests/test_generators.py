"""GENRMF / Washington-RLG generators: validity, determinism, oracle flow."""

import numpy as np
import pytest

from repro.core import SweepConfig, build, solve_mincut
from repro.core.graph import validate_problem
from repro.core.partition import block_partition
from repro.data.generators import genrmf, pipeline_levels, washington_rlg
from repro.kernels.ref import maxflow_oracle

from invariants import assert_sweep_bound

CASES = [
    ("genrmf", lambda seed: genrmf(a=3, b=5, c1=1, c2=40, seed=seed)),
    ("rlg", lambda seed: washington_rlg(rows=5, levels=8, degree=3,
                                        max_cap=50, seed=seed)),
]


@pytest.mark.parametrize("name,gen", CASES, ids=[c[0] for c in CASES])
def test_generated_instances_are_valid_and_deterministic(name, gen):
    p = gen(11)
    validate_problem(p, context=name)
    q = gen(11)
    np.testing.assert_array_equal(p.edges, q.edges)
    np.testing.assert_array_equal(p.cap_fwd, q.cap_fwd)
    np.testing.assert_array_equal(p.excess, q.excess)
    r = gen(12)
    assert not (len(p.cap_fwd) == len(r.cap_fwd)
                and np.array_equal(p.cap_fwd, r.cap_fwd))


@pytest.mark.parametrize("name,gen", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("method", ["ard", "prd"])
def test_generated_instances_solve_to_oracle_flow(name, gen, method):
    p = gen(seed=4)
    want, _ = maxflow_oracle(p)
    assert want > 0
    part = block_partition(p.num_vertices, 4)
    res = solve_mincut(p, part, config=SweepConfig(method=method))
    assert res.flow_value == want
    assert_sweep_bound(res.meta, res.stats, ard=method == "ard", where=name)


def test_pipeline_levels_absorbs_all_supply():
    # the bench instance's defining property: no stuck excess, so the
    # maxflow equals the injected supply exactly and the sequential
    # sweep drains it in a handful of passes
    p = pipeline_levels(rows=16, levels=12, supply=100)
    validate_problem(p, context="pipeline")
    want, _ = maxflow_oracle(p)
    assert want == 100 * 16
    part = np.arange(p.num_vertices) // (16 * 4)
    res = solve_mincut(p, part, config=SweepConfig(
        method="ard", parallel=False, use_global_gap=False))
    assert res.flow_value == want
    assert res.stats.sweeps <= 4


def test_genrmf_flow_percolates_every_frame():
    # flow must cross all b-1 random inter-frame cuts: the maxflow is
    # bounded by the narrowest of them, and the sweep count grows with b
    p_short = genrmf(a=3, b=3, seed=9)
    p_long = genrmf(a=3, b=9, seed=9)
    s_short = solve_mincut(p_short, num_regions=3,
                           config=SweepConfig(method="ard")).stats
    s_long = solve_mincut(p_long, num_regions=3,
                          config=SweepConfig(method="ard")).stats
    assert s_long.sweeps >= s_short.sweeps


def test_rlg_source_column_feeds_everything():
    p = washington_rlg(rows=4, levels=6, seed=0)
    vid = np.arange(p.num_vertices).reshape(6, 4)
    assert (p.excess[vid[0]] > 0).all()
    # random in-degree can leave a last-column vertex unfed, but the
    # column as a whole is the only drain
    assert p.sink_cap[vid[-1]].sum() > 0
    assert p.excess[vid[1:]].sum() == 0 and p.sink_cap[vid[:-1]].sum() == 0
    meta, _, _ = build(p, block_partition(p.num_vertices, 3))
    assert meta.num_boundary > 0
