"""Multi-device behaviour, run in subprocesses with 8 forced host devices
(XLA locks the device count at first init, so these cannot share the main
test process).  Covers: shard_map P-ARD vs oracle, sharded train step vs
single-device reference, elastic checkpoint restore across mesh sizes."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_pard_matches_oracle():
    out = _run("""
        import jax, numpy as np
        from repro.data.grids import synthetic_grid
        from repro.core.graph import build, init_labels
        from repro.core import partition
        from repro.core.distributed import solve_sharded
        from repro.core.sweep import SweepConfig, extract_cut, cut_value
        from repro.kernels.ref import maxflow_oracle

        p = synthetic_grid(24, 24, connectivity=8, strength=120, seed=4)
        want, _ = maxflow_oracle(p)
        part = partition.grid_partition((24, 24), (2, 4))
        meta, state, _ = build(p, part)
        state0 = state
        state = init_labels(meta, state)
        mesh = jax.make_mesh((8,), ('regions',))
        st, sweeps = solve_sharded(meta, state, mesh,
                                   SweepConfig(method='ard'), max_sweeps=500)
        assert int(st.flow_to_t) == want, (int(st.flow_to_t), want)
        side = extract_cut(meta, st)
        assert int(cut_value(meta, state0, side)) == want
        print('OK sweeps', sweeps)
    """)
    assert "OK" in out


def test_sharded_device_resident_matches_host_loop():
    """solve_sharded(device_resident=True) — the lax.while_loop-under-
    shard_map driver — must match the per-sweep host loop bit-exactly
    (flow, labels, sweep count) at every sync cadence, and still report one
    (no-op) sweep on an already-converged input like the host loop does."""
    out = _run("""
        import jax, numpy as np
        from repro.data.grids import synthetic_grid
        from repro.core.graph import build, init_labels
        from repro.core import partition
        from repro.core.distributed import solve_sharded
        from repro.core.sweep import SweepConfig
        from repro.kernels.ref import maxflow_oracle

        p = synthetic_grid(16, 16, connectivity=8, strength=120, seed=4)
        want, _ = maxflow_oracle(p)
        part = partition.grid_partition((16, 16), (2, 4))
        meta, state0, _ = build(p, part)
        cfg = SweepConfig(method='ard')
        mesh = jax.make_mesh((8,), ('regions',))
        st, sweeps = solve_sharded(meta, init_labels(meta, state0), mesh,
                                   cfg, max_sweeps=500)
        assert int(st.flow_to_t) == want
        for hse in (None, 2):
            st2, sweeps2 = solve_sharded(meta, init_labels(meta, state0),
                                         mesh, cfg, max_sweeps=500,
                                         device_resident=True,
                                         host_sync_every=hse)
            assert int(st2.flow_to_t) == want, hse
            assert sweeps2 == sweeps, (hse, sweeps2, sweeps)
            np.testing.assert_array_equal(np.asarray(st.d),
                                          np.asarray(st2.d))
        # converged-at-entry: both drivers run exactly one no-op sweep
        for dr in (False, True):
            st3, s3 = solve_sharded(meta, st, mesh, cfg, max_sweeps=500,
                                    device_resident=dr)
            assert s3 == 1, (dr, s3)
            assert int(st3.flow_to_t) == want
        print('OK sweeps', sweeps)
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import dataclasses, jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.launch.mesh import make_host_mesh
        from repro.models.model import init_params
        from repro.train import optimizer as opt_lib
        from repro.train import train_loop as tl
        from repro.data.pipeline import MarkovSpec, markov_batch

        cfg = dataclasses.replace(ARCHS['phi3-mini-3.8b'].smoke(),
                                  num_layers=2, vocab_size=64,
                                  num_kv_heads=2)
        spec = MarkovSpec(vocab=64, branching=2)
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        batch = jax.tree.map(jnp.asarray, markov_batch(spec, 0, 8, 64))

        # single-device reference
        state = tl.TrainState(params=params,
                              opt=opt_lib.init_opt_state(params))
        ref_step = jax.jit(tl.make_train_step(
            cfg, opt_lib.AdamWConfig(lr=1e-3), jnp.float32))
        _, ref_m = ref_step(state, batch)

        # sharded on a 2x4 mesh
        mesh = make_host_mesh((2, 4), ('data', 'model'))
        step, state_sh, bspec = tl.make_sharded_train_step(
            cfg, mesh, opt_lib.AdamWConfig(lr=1e-3), jnp.float32,
            donate=False, seq_len=64)
        state2 = tl.TrainState(params=params,
                               opt=opt_lib.init_opt_state(params))
        state2 = jax.device_put(state2, state_sh)
        batch2 = jax.device_put(batch, bspec)
        _, m = step(state2, batch2)
        a, b = float(ref_m['loss']), float(m['loss'])
        assert abs(a - b) < 5e-4 * max(1, abs(a)), (a, b)
        print('OK', a, b)
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    out = _run(f"""
        import dataclasses, jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.launch.mesh import make_host_mesh
        from repro.launch import shardings as shd
        from repro.models.model import init_params
        from repro.train import checkpoint as ckpt
        from repro.train import optimizer as opt_lib
        from repro.train import train_loop as tl

        cfg = dataclasses.replace(ARCHS['phi3-mini-3.8b'].smoke(),
                                  num_layers=2, vocab_size=64,
                                  num_kv_heads=2)
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        mesh_a = make_host_mesh((2, 4), ('data', 'model'))
        shapes = jax.eval_shape(lambda: params)
        sh_a = shd.param_shardings(cfg, mesh_a, shapes)
        pa = jax.device_put(params, sh_a)
        ckpt.save({str(tmp_path)!r}, 3, pa)

        # restore onto a DIFFERENT mesh (4x2): elastic re-layout
        mesh_b = make_host_mesh((4, 2), ('data', 'model'))
        sh_b = shd.param_shardings(cfg, mesh_b, shapes)
        pb = ckpt.restore({str(tmp_path)!r}, 3, shapes, sh_b)
        for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print('OK elastic')
    """)
    assert "OK elastic" in out


def test_production_mesh_constructors():
    out = _run("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh(multi_pod=False)
        assert m1.axis_names == ('data', 'model') and m1.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ('pod', 'data', 'model') and m2.size == 512
        print('OK mesh')
    """, devices=512)
    assert "OK mesh" in out
