"""Statement 9 (ARD) / Statement 1 (PRD) discharge properties, checked
directly on the discharge operators — these are the properties the
2|B|^2+1 and O(n^2) sweep-bound proofs rest on.  The labeling-validity
condition itself lives in tests/invariants.py
(``assert_region_labeling_valid``), shared with the conformance suite's
state-level checkers."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import invariants
from repro.core.ard import ard_discharge_one
from repro.core.graph import build, init_labels, intra_mask
from repro.core.labels import gather_ghost_labels, region_relabel
from repro.core.prd import prd_discharge_one
from repro.data.grids import random_sparse
from repro.core.partition import block_partition


def _region_view(meta, state, k):
    intra = intra_mask(state)
    ghost_d = gather_ghost_labels(state)
    sl = lambda a: a[k]
    return dict(cf=sl(state.cf), sink_cf=sl(state.sink_cf),
                excess=sl(state.excess), d=sl(state.d), ghost=sl(ghost_d),
                nbr_local=sl(state.nbr_local), rev_slot=sl(state.rev_slot),
                intra=sl(intra), emask=sl(state.emask),
                vmask=sl(state.vmask))


@pytest.mark.parametrize("seed", range(5))
def test_ard_discharge_properties(seed):
    p = random_sparse(16, 30, seed=seed)
    part = block_partition(16, 3)
    meta, state, _ = build(p, part)
    state = init_labels(meta, state)
    # give it a nontrivial valid labeling first
    state = region_relabel(meta, state, ard=True)
    v = _region_view(meta, state, 0)
    res = ard_discharge_one(
        v["cf"], v["sink_cf"], v["excess"], v["ghost"],
        nbr_local=v["nbr_local"], rev_slot=v["rev_slot"], intra=v["intra"],
        emask=v["emask"], vmask=v["vmask"], d_inf=meta.d_inf_ard,
        stage_cap=meta.d_inf_ard)

    # 1. optimality: no active vertices left w.r.t. (f', d')
    active = (np.asarray(res.excess) > 0) & \
        (np.asarray(res.d) < meta.d_inf_ard) & np.asarray(v["vmask"])
    assert not active.any()

    # 2. monotony: d' >= d
    assert (np.asarray(res.d) >= np.asarray(v["d"]))[
        np.asarray(v["vmask"])].all()

    # 3. validity in the region network: residual intra arc (u,v) =>
    #    d'(u) <= d'(v); residual cross arc => d'(u) <= d(ghost) + 1;
    #    sink-residual => d'(u) <= 0
    invariants.assert_region_labeling_valid(
        res.d, res.cf, res.sink_cf, intra=v["intra"], emask=v["emask"],
        vmask=v["vmask"], nbr_local=v["nbr_local"], ghost=v["ghost"],
        d_inf=meta.d_inf_ard, ard=True)

    # 4. flow direction: cross pushes only into ghosts with label < d'(u)...
    #    out_push(u, e) > 0 => d'(u) > d(ghost(e))
    d = np.asarray(res.d)
    ghost = np.asarray(v["ghost"])
    out = np.asarray(res.out_push)
    for u, e in zip(*np.nonzero(out > 0)):
        assert d[u] > ghost[u, e]

    # conservation: excess in + nothing lost
    before = int(np.asarray(v["excess"]).sum())
    after = int(np.asarray(res.excess).sum()) + int(res.sink_pushed) + \
        int(out.sum())
    assert before == after


@pytest.mark.parametrize("seed", range(3))
def test_prd_discharge_properties(seed):
    p = random_sparse(14, 26, seed=seed + 50)
    part = block_partition(14, 2)
    meta, state, _ = build(p, part)
    state = init_labels(meta, state)
    v = _region_view(meta, state, 0)
    res = prd_discharge_one(
        v["cf"], v["sink_cf"], v["excess"], v["d"], v["ghost"],
        nbr_local=v["nbr_local"], rev_slot=v["rev_slot"], intra=v["intra"],
        emask=v["emask"], vmask=v["vmask"], d_inf=meta.d_inf_prd)
    vm = np.asarray(v["vmask"])
    active = (np.asarray(res.excess) > 0) & \
        (np.asarray(res.d) < meta.d_inf_prd) & vm
    assert not active.any()
    assert (np.asarray(res.d) >= np.asarray(v["d"]))[vm].all()
    # validity (PRD): residual arc (u,v) => d'(u) <= d'(v)+1, and
    # sink-residual => d'(u) <= 1
    invariants.assert_region_labeling_valid(
        res.d, res.cf, res.sink_cf, intra=v["intra"], emask=v["emask"],
        vmask=v["vmask"], nbr_local=v["nbr_local"], ghost=v["ghost"],
        d_inf=meta.d_inf_prd, ard=False)
