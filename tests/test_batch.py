"""Pinned regressions of the batched multi-instance driver.

The batched-vs-single bit-exactness MATRIX (ard/prd × engine backend,
plus the shared launch/sync stream accounting) lives in
tests/test_executor_conformance.py.  This file keeps the batch-specific
edge cases: heuristic variants flowing through the packed state, the
per-instance ``max_sweeps`` budget and ``host_sync_every`` hatch,
shape-bucket packing/padding, the zero-retrace compile cache, and the
fail-fast config validation.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (BatchedSolver, SweepConfig, bucket_shape_for,
                        pack_instances, solve_mincut, solve_mincut_batch)
from repro.core import batch as batch_mod
from repro.core import grid_partition
from repro.data.grids import random_sparse, synthetic_grid
from repro.kernels.ref import maxflow_oracle


def _mixed_batch():
    """Mixed shapes and partitioners: two buckets, one with padded K/V."""
    probs = [synthetic_grid(8, 8, connectivity=8, strength=150, seed=0),
             synthetic_grid(8, 8, connectivity=8, strength=150, seed=1),
             random_sparse(14, 28, seed=2),
             synthetic_grid(10, 10, connectivity=8, strength=120, seed=3)]
    parts = [grid_partition((8, 8), (2, 2)), grid_partition((8, 8), (2, 2)),
             None, grid_partition((10, 10), (2, 2))]
    return probs, parts


def test_batch_heuristic_variants_match_single():
    """partial-discharge / gap-off / engine caps flow through the batched
    driver with per-instance bit-exactness preserved."""
    probs, parts = _mixed_batch()
    for cfg in [SweepConfig(method="ard", partial_discharge=True),
                SweepConfig(method="ard", use_global_gap=False),
                SweepConfig(method="prd", engine_max_iters=7)]:
        singles = [solve_mincut(p, part=pt, num_regions=4, config=cfg)
                   for p, pt in zip(probs, parts)]
        batched = solve_mincut_batch(probs, parts, num_regions=4, config=cfg)
        for i, (s, b) in enumerate(zip(singles, batched)):
            assert b.flow_value == s.flow_value, (cfg, i)
            np.testing.assert_array_equal(np.asarray(s.state.d),
                                          np.asarray(b.state.d))
            assert b.stats.sweeps == s.stats.sweeps, (cfg, i)
            assert b.stats.engine_iters == s.stats.engine_iters, (cfg, i)


def test_batch_max_sweeps_cap_and_sync_hatch():
    """A mid-solve sweep cap freezes each instance at its own budget, and
    the host_sync_every hatch syncs per m sweeps without changing state."""
    probs, parts = _mixed_batch()
    base = SweepConfig(method="prd")
    full = [solve_mincut(p, part=pt, num_regions=4, config=base)
            for p, pt in zip(probs, parts)]
    cap = max(1, min(r.stats.sweeps for r in full) - 1)
    cfg = dataclasses.replace(base, max_sweeps=cap)
    singles = [solve_mincut(p, part=pt, num_regions=4, config=cfg,
                            check=False)
               for p, pt in zip(probs, parts)]
    for hse in (None, 2):
        cfg2 = dataclasses.replace(cfg, host_sync_every=hse)
        batched = solve_mincut_batch(probs, parts, num_regions=4,
                                     config=cfg2, check=False)
        for s, b in zip(singles, batched):
            assert b.stats.sweeps == s.stats.sweeps <= cap
            assert b.flow_value == s.flow_value
            np.testing.assert_array_equal(np.asarray(s.state.d),
                                          np.asarray(b.state.d))


def test_pack_instances_buckets_and_padding():
    probs, parts = _mixed_batch()
    packs = pack_instances(probs, parts, num_regions=4)
    assert sum(p.num_real for p in packs) == len(probs)
    assert sorted(i for p in packs for i in p.indices) == [0, 1, 2, 3]
    for p in packs:
        B, K, V, E, X = p.meta.bucket_shape
        # bucket dims are powers of two and cover every member instance
        for d in (B, K, V, E, X):
            assert d & (d - 1) == 0
        assert p.state.cf.shape == (B, K, V, E)
        for m in p.metas:
            assert bucket_shape_for(m) == (K, V, E, X)
            assert m.num_regions <= K and m.region_size <= V
        # padding slots (instances beyond num_real) are inert
        pad = np.asarray(p.state.vmask[p.num_real:])
        assert not pad.any()
        assert not np.asarray(p.state.excess[p.num_real:]).any()


def test_batched_solver_compile_cache(fresh_compile_cache):
    """A second batch landing in a known bucket shape must not retrace the
    batched device program, even with a different real instance count.
    (fresh_compile_cache makes the first solve deterministically a miss
    under any test ordering.)"""
    cfg = SweepConfig(method="ard")
    solver = BatchedSolver(cfg, num_regions=4)
    first = [synthetic_grid(8, 8, seed=s) for s in range(3)]
    r1 = solver.solve(first)
    info1 = solver.cache_info()
    assert info1.misses == 1 and info1.hits == 0
    before = batch_mod.trace_count()
    second = [synthetic_grid(8, 8, seed=s) for s in (11, 12, 13, 14)]
    r2 = solver.solve(second)
    assert batch_mod.trace_count() == before, "bucket re-solve retraced"
    assert solver.cache_info().hits >= 1
    for p, r in zip(first + second, r1 + r2):
        assert r.flow_value == maxflow_oracle(p)[0]


def test_batched_solver_rejects_unsupported_configs():
    with pytest.raises(ValueError):
        BatchedSolver(SweepConfig(parallel=False))
    with pytest.raises(ValueError):
        BatchedSolver(SweepConfig(use_boundary_relabel=True))


def test_solve_mincut_check_flag():
    """check=False must skip the cut==flow assertion without changing the
    result (the serving-path knob)."""
    p = synthetic_grid(8, 8, seed=4)
    a = solve_mincut(p, num_regions=4)
    b = solve_mincut(p, num_regions=4, check=False)
    assert a.flow_value == b.flow_value == maxflow_oracle(p)[0]
    np.testing.assert_array_equal(a.source_side, b.source_side)
