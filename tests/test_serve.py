"""Serving-tier robustness matrix (deterministic: fake clock, injected
faults, no wall time).

Every scenario asserts BOTH the typed error a client sees and the
``ServiceStats`` counter it increments: deadline expiry mid-solve and in
the queue, queue-full shedding (per tenant), LRU handle eviction + warm
resume, circuit-breaker trip/cooldown/recovery over the kernel ladder,
supervised retry of injected batch faults — plus the overload acceptance
scenario: bursty load over capacity with tight deadlines and a kernel
fault mid-stream keeps the service up and bounded (every request resolves
to a result or a typed error, queue depth never exceeds its bound,
in-flight requests survive the fault via degradation).
"""

import numpy as np
import pytest

from repro.core import FaultPlan, Solver, SolverOptions, fault_injection
from repro.data.grids import synthetic_grid
from repro.kernels.ref import maxflow_oracle
from repro.serve import (DeadlineExceeded, ERROR_TAXONOMY, MaxflowService,
                         RequestFailed, ServiceClosed, ServiceConfig,
                         ServiceError, ServiceOverloaded, SolveRequest,
                         solve_with_deadline)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _grid(seed=0, n=6):
    return synthetic_grid(n, n, seed=seed)


OPTS = SolverOptions(num_regions=4)


# --------------------------------------------------------------------------
# baseline: continuous batching matches the oracle
# --------------------------------------------------------------------------

def test_service_mixed_stream_matches_oracle():
    """Heterogeneous shapes through the continuous-batching loop give
    per-instance oracle flows; the liveness invariant holds throughout."""
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=2, sync_every=2),
                         clock=FakeClock())
    probs = [_grid(seed=s) for s in range(3)] + [_grid(seed=7, n=8)]
    tickets = [svc.submit(SolveRequest(problem=p)) for p in probs]
    svc.run_until_idle()
    for p, t in zip(probs, tickets):
        assert t.outcome().flow_value == maxflow_oracle(p)[0]
    assert svc.stats.completed == len(probs)
    assert svc.stats.swaps == len(probs)
    assert svc.healthy() and svc.ready()
    rep = svc.report()
    assert rep["completed"] == len(probs) and rep["healthy"]
    assert set(rep["breaker"]) == {"pallas-fused", "xla-fused",
                                   "xla-unfused"}


def test_service_slot_swap_admits_into_live_batch():
    """With one slot per bucket, a second same-shape request must wait
    for the slot and then swap into the LIVE batch (no new bucket)."""
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=1, sync_every=1),
                         clock=FakeClock())
    p1, p2 = _grid(seed=0), _grid(seed=1)
    t1 = svc.submit(SolveRequest(problem=p1))
    t2 = svc.submit(SolveRequest(problem=p2))
    svc.step()
    assert svc.stats.in_flight == 1 and svc.stats.queue_depth == 1
    svc.run_until_idle()
    assert t1.outcome().flow_value == maxflow_oracle(p1)[0]
    assert t2.outcome().flow_value == maxflow_oracle(p2)[0]
    assert len(svc._buckets) == 1
    assert svc.stats.swaps == 2


def test_warm_session_recut_through_service():
    """A session request re-cuts warm: the prepared handle is reused and
    the updated problem's flow matches a cold oracle solve."""
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=2),
                         clock=FakeClock())
    p = _grid(seed=3)
    t1 = svc.submit(SolveRequest(problem=p, session="cam"))
    svc.run_until_idle()
    assert t1.outcome().flow_value == maxflow_oracle(p)[0]
    arcs = np.arange(4)
    t2 = svc.submit(SolveRequest(
        session="cam",
        update={"arcs": arcs, "cap_fwd": p.cap_fwd[arcs] + 70}))
    svc.run_until_idle()
    updated = svc._sessions["cam"].problem
    assert t2.outcome().flow_value == maxflow_oracle(updated)[0]
    assert svc.stats.completed == 2


# --------------------------------------------------------------------------
# deadlines
# --------------------------------------------------------------------------

def test_deadline_expiry_mid_solve():
    """A deadline crossing mid-solve kills the request at the next sweep
    boundary with sweeps-completed + partial-flow diagnostics."""
    p = _grid(seed=0, n=10)
    base = Solver(OPTS).solve(p)
    assert base.stats.sweeps >= 3, "instance too easy to expire mid-solve"
    clk = FakeClock()
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=1, sync_every=1),
                         clock=clk)
    t = svc.submit(SolveRequest(problem=p, timeout=5.0))
    svc.step()                       # admitted; one sweep run
    assert svc.stats.in_flight == 1
    clk.advance(10.0)                # deadline passes mid-solve
    svc.step()
    assert t.done
    with pytest.raises(DeadlineExceeded) as ei:
        t.outcome()
    err = ei.value
    assert err.stage == "running"
    assert err.sweeps_completed >= 1
    assert isinstance(err.partial_flow, int)
    assert 0 <= err.partial_flow <= base.flow_value  # a valid preflow's
    assert err.code == "deadline_exceeded" and not err.retriable
    assert svc.stats.deadline_misses == 1
    assert svc.healthy()
    # the freed slot serves the next request normally
    t2 = svc.submit(SolveRequest(problem=_grid(seed=2)))
    svc.run_until_idle()
    assert t2.outcome().flow_value == maxflow_oracle(_grid(seed=2))[0]


def test_deadline_expiry_in_queue():
    """A request whose deadline passes before admission dies in the queue
    (stage="queued", zero sweeps)."""
    clk = FakeClock()
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=1, sync_every=1),
                         clock=clk)
    # same shape: t2 must wait for t1's (only) slot in the shared bucket
    t1 = svc.submit(SolveRequest(problem=_grid(seed=0, n=10)))
    t2 = svc.submit(SolveRequest(problem=_grid(seed=1, n=10), timeout=2.0))
    svc.step()                       # t1 takes the only slot; t2 queued
    clk.advance(5.0)
    svc.step()
    assert t2.done
    with pytest.raises(DeadlineExceeded) as ei:
        t2.outcome()
    assert ei.value.stage == "queued"
    assert ei.value.sweeps_completed == 0
    assert svc.stats.deadline_misses == 1
    svc.run_until_idle()
    assert t1.outcome().converged


def test_solve_with_deadline_single_handle_routes():
    """The single-handle deadline route: aborts at a sweep boundary with
    diagnostics; the handle survives and re-solves cleanly after."""
    p = _grid(seed=0, n=10)
    for opts in (OPTS,
                 SolverOptions(num_regions=4, device_resident=True,
                               host_sync_every=1)):
        base = Solver(opts).solve(p)
        assert base.stats.sweeps >= 3
        clk = FakeClock()

        def ticking():
            clk.advance(1.0)
            return clk.t

        h = Solver(opts).prepare(p)
        with pytest.raises(DeadlineExceeded) as ei:
            solve_with_deadline(h, timeout=2.5, clock=ticking)
        err = ei.value
        assert err.stage == "running" and err.sweeps_completed >= 1
        assert err.sweeps_completed < base.stats.sweeps
        assert 0 <= err.partial_flow <= base.flow_value
        assert h.solve().flow_value == base.flow_value  # handle intact


# --------------------------------------------------------------------------
# admission control
# --------------------------------------------------------------------------

def test_queue_full_sheds_per_tenant():
    svc = MaxflowService(OPTS, ServiceConfig(max_queue=2, retry_after=1.5),
                         clock=FakeClock())
    probs = [_grid(seed=s) for s in range(5)]
    tenants = ["a", "a", "b", "a", "b"]
    tickets = [svc.submit(SolveRequest(problem=p, tenant=tn))
               for p, tn in zip(probs, tenants)]
    shed = [t for t in tickets if t.done]
    assert len(shed) == 3            # queue bound 2: requests 3-5 shed
    for t in shed:
        with pytest.raises(ServiceOverloaded) as ei:
            t.outcome()
        err = ei.value
        assert err.retriable and err.retry_after == 1.5
        assert err.queue_depth == 2 and err.bound == 2
    assert svc.stats.sheds == 3
    assert svc.stats.sheds_by_tenant == {"a": 1, "b": 2}
    assert not svc.ready()           # full queue: not ready
    svc.run_until_idle()
    assert svc.ready() and svc.healthy()
    assert svc.stats.completed == 2  # the admitted two completed
    # shedding is immediate and typed, never an unbounded queue
    assert svc.stats.max_queue_depth <= 2


def test_closed_service_rejects_typed():
    svc = MaxflowService(OPTS, clock=FakeClock())
    svc.close()
    t = svc.submit(SolveRequest(problem=_grid()))
    with pytest.raises(ServiceClosed):
        t.outcome()
    assert svc.stats.submitted == 0  # never entered


def test_malformed_request_fails_typed_and_service_survives():
    """A re-cut against a session the service never saw (e.g. its create
    request was shed) must fail THAT request typed, not crash the loop."""
    svc = MaxflowService(OPTS, clock=FakeClock())
    bad = svc.submit(SolveRequest(session="never-created",
                                  update=dict(arcs=np.array([0]))))
    good = svc.submit(SolveRequest(problem=_grid()))
    svc.run_until_idle()
    with pytest.raises(RequestFailed) as ei:
        bad.outcome()
    assert "never-created" in str(ei.value) and ei.value.attempts == 0
    assert svc.stats.failed == 1
    assert good.outcome().flow_value == maxflow_oracle(_grid())[0]
    assert svc.healthy()


# --------------------------------------------------------------------------
# handle LRU + eviction-to-checkpoint + warm resume
# --------------------------------------------------------------------------

def test_lru_eviction_and_warm_resume(tmp_path):
    p = _grid(seed=0)
    probe = Solver(OPTS).prepare(p)
    one = MaxflowService._handle_bytes(probe)
    svc = MaxflowService(
        OPTS,
        ServiceConfig(max_batch=1, handle_budget_bytes=int(1.5 * one),
                      eviction_dir=str(tmp_path)),
        clock=FakeClock())
    ta = svc.submit(SolveRequest(problem=p, session="a"))
    svc.run_until_idle()
    tb = svc.submit(SolveRequest(problem=_grid(seed=1), session="b"))
    svc.run_until_idle()
    # budget fits ~1.5 handles: LRU session "a" was checkpointed off
    assert svc.stats.evictions == 1
    assert "a" in svc._evicted and "a" not in svc._sessions
    assert "b" in svc._sessions
    assert any(tmp_path.glob("a/step_*")), "no eviction snapshot on disk"
    assert svc.stats.resident_bytes <= int(1.5 * one)

    # next request for "a" resumes it warm: zero sweeps, same flow
    ta2 = svc.submit(SolveRequest(session="a"))
    svc.run_until_idle()
    assert svc.stats.warm_resumes == 1
    assert ta2.outcome().flow_value == ta.outcome().flow_value
    assert ta2.outcome().stats.sweeps == 0, "resumed session was not warm"
    assert "a" in svc._sessions and "a" not in svc._evicted
    assert svc.healthy()


# --------------------------------------------------------------------------
# circuit breaker over the degradation ladder
# --------------------------------------------------------------------------

PALLAS_OPTS = SolverOptions(num_regions=4, engine_backend="pallas",
                            engine_chunk_iters=8)


def test_breaker_trip_cooldown_recovery():
    """A kernel fault degrades the chunk down the ladder WITHOUT failing
    the in-flight request, trips the rung's breaker (threshold 1), which
    is then skipped at entry until the cooldown's half-open probe closes
    it again."""
    clk = FakeClock()
    svc = MaxflowService(
        PALLAS_OPTS,
        ServiceConfig(max_batch=1, sync_every=4, breaker_threshold=1,
                      breaker_cooldown=30.0),
        clock=clk)
    p1 = _grid(seed=0)
    with fault_injection(FaultPlan("vmem_overflow", at_sweep=1, times=1,
                                   route="device")):
        t1 = svc.submit(SolveRequest(problem=p1))
        svc.run_until_idle()
    # the in-flight request survived the fault via the ladder
    assert t1.outcome().flow_value == maxflow_oracle(p1)[0]
    assert svc.stats.faults == 1
    assert svc.stats.degradations == 1
    assert svc.stats.breaker_trips == 1
    assert svc.board["pallas-fused"].state == "open"

    # while open: chunks enter one rung down, skipping the broken rung
    p2 = _grid(seed=1)
    t2 = svc.submit(SolveRequest(problem=p2))
    svc.run_until_idle()
    assert t2.outcome().flow_value == maxflow_oracle(p2)[0]
    assert svc.stats.breaker_skips >= 1
    assert svc.stats.faults == 1     # no new fault: the rung was skipped

    # cooldown elapses: half-open lets one probe through; success closes
    clk.advance(31.0)
    assert svc.board["pallas-fused"].state == "half-open"
    p3 = _grid(seed=2)
    t3 = svc.submit(SolveRequest(problem=p3))
    svc.run_until_idle()
    assert t3.outcome().flow_value == maxflow_oracle(p3)[0]
    assert svc.board["pallas-fused"].state == "closed"
    assert svc.report()["breaker"]["pallas-fused"] == "closed"


# --------------------------------------------------------------------------
# supervised retries of faulted batches
# --------------------------------------------------------------------------

def test_supervisor_retries_injected_fault():
    """A non-kernel injected fault re-runs the chunk from the intact
    boundary; the request completes with the oracle flow."""
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=1, max_retries=2),
                         clock=FakeClock())
    p = _grid(seed=0)
    with fault_injection(FaultPlan("raise", at_sweep=1, times=1,
                                   route="device")):
        t = svc.submit(SolveRequest(problem=p))
        svc.run_until_idle()
    assert t.outcome().flow_value == maxflow_oracle(p)[0]
    assert svc.stats.faults == 1 and svc.stats.retries == 1
    assert svc.stats.failed == 0


def test_supervisor_exhaustion_fails_typed():
    """Retries exhausted: the batch's requests resolve to RequestFailed;
    the service stays up and serves the next request."""
    svc = MaxflowService(OPTS, ServiceConfig(max_batch=1, max_retries=1),
                         clock=FakeClock())
    p = _grid(seed=0)
    with fault_injection(FaultPlan("raise", at_sweep=1, times=-1,
                                   route="device")):
        t = svc.submit(SolveRequest(problem=p))
        svc.run_until_idle()
    with pytest.raises(RequestFailed) as ei:
        t.outcome()
    assert ei.value.attempts == 2    # first run + 1 retry
    assert "InjectedFault" in ei.value.cause
    assert svc.stats.failed == 1 and svc.stats.retries == 1
    assert svc.healthy()
    t2 = svc.submit(SolveRequest(problem=p))
    svc.run_until_idle()
    assert t2.outcome().flow_value == maxflow_oracle(p)[0]


# --------------------------------------------------------------------------
# the acceptance scenario: overload + tight deadlines + mid-stream fault
# --------------------------------------------------------------------------

def test_overload_with_tight_deadlines_and_fault_stays_bounded():
    """Offered load beyond capacity with 25% tight deadlines and a kernel
    fault mid-stream: the service stays up, every request resolves to a
    result or a typed error, and the queue never exceeds its bound."""
    clk = FakeClock()
    svc = MaxflowService(
        PALLAS_OPTS,
        ServiceConfig(max_queue=4, max_batch=2, sync_every=1,
                      breaker_threshold=1),
        clock=clk)
    probs = [_grid(seed=s) for s in range(12)]
    tickets = []
    with fault_injection(FaultPlan("vmem_overflow", at_sweep=2, times=1,
                                   route="device")):
        for i, p in enumerate(probs):
            timeout = 0.5 if i % 4 == 0 else None     # 25% tight
            tickets.append(svc.submit(SolveRequest(
                problem=p, timeout=timeout, tenant=f"t{i % 2}")))
            if i % 3 == 2:           # bursty: 3 submits per service step
                svc.step()
                clk.advance(0.4)
            assert svc.stats.queue_depth <= 4, "queue bound violated"
        svc.run_until_idle()

    for t in tickets:               # every request reached a terminal,
        assert t.done               # typed outcome — none vanished
        if t.error is not None:
            assert isinstance(t.error, ServiceError)
            assert t.error.code in ERROR_TAXONOMY
        else:
            assert t.result.flow_value >= 0
    s = svc.stats
    assert s.completed + s.deadline_misses + s.sheds + s.failed \
        == s.submitted == len(probs)
    assert s.max_queue_depth <= 4
    assert s.failed == 0            # the kernel fault degraded, not failed
    assert s.faults >= 1 and s.degradations >= 1
    assert s.completed >= 1
    assert svc.healthy()
    # completed requests are CORRECT under overload, not just resolved
    for p, t in zip(probs, tickets):
        if t.error is None:
            assert t.result.flow_value == maxflow_oracle(p)[0]
