"""Warm-start incremental re-solves (``handle.update`` + ``handle.solve``).

The acceptance bar: after ANY capacity perturbation, a warm re-solve must
reach exactly the flow value (and a valid mincut) of a cold solve on the
perturbed problem — the Kohli-Torr reparameterization of
``graph.apply_update`` plus the ``warm_labels`` policy are pure
performance devices.  Checked across perturbation classes
(increase-only / decrease-only / mixed; p in {1%, 10%}) on 16^2/24^2
grids, across ard/prd x xla/pallas x host-loop/device-resident drivers,
and on the 64^2 interactive-segmentation instance where the warm solve
must also use strictly fewer sweeps than the cold one.  The preflow and
label invariants of ``test_discharge_invariants.py`` are asserted
directly on the reparameterized state.
"""

import numpy as np
import pytest

from repro.core import Solver, SolverOptions, solve_mincut, grid_partition
from repro.core.graph import intra_mask
from repro.core.labels import gather_ghost_labels
from repro.data.grids import segmentation_seeds_grid, synthetic_grid

# every solve in this module runs check=True: the cut-cost == flow
# assertion inside the solver prices the extracted cut in the CURRENT
# (perturbed, un-reparameterized) initial network, so each warm solve
# already proves its cut is a mincut of the perturbed problem.


def _perturb_kwargs(problem, rng, kind, p):
    m = len(problem.edges)
    k = max(1, int(round(p * m)))
    idx = rng.choice(m, size=k, replace=False)
    if kind == "increase":
        new_f = problem.cap_fwd[idx] + rng.randint(1, 151, size=k)
        new_b = problem.cap_bwd[idx] + rng.randint(1, 151, size=k)
    elif kind == "decrease":
        new_f = problem.cap_fwd[idx] // rng.randint(2, 5, size=k)
        new_b = problem.cap_bwd[idx] // rng.randint(2, 5, size=k)
    else:                                   # mixed: re-randomize
        new_f = rng.randint(0, 301, size=k)
        new_b = rng.randint(0, 301, size=k)
    return dict(arcs=idx, cap_fwd=new_f.astype(np.int32),
                cap_bwd=new_b.astype(np.int32))


def _assert_warm_matches_cold(handle, solver, part, opts):
    """Warm re-solve == cold solve of the (updated) problem, exactly."""
    warm = handle.solve()
    cold = solve_mincut(handle.problem, part=part,
                        config=opts.sweep_config())
    assert warm.flow_value == cold.flow_value
    # both cuts already passed the cut-cost == flow check; they need not be
    # the identical partition (mincuts are not unique), so compare values
    return warm, cold


@pytest.mark.parametrize("g", [16, 24])
@pytest.mark.parametrize("kind", ["increase", "decrease", "mixed"])
@pytest.mark.parametrize("p", [0.01, 0.1], ids=["p1", "p10"])
def test_warm_resolve_matches_cold(g, kind, p):
    prob = synthetic_grid(g, g, connectivity=8, strength=150, seed=g)
    part = grid_partition((g, g), (2, 2))
    opts = SolverOptions()
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()
    rng = np.random.RandomState(hash((g, kind, p)) % (2**31))
    handle.update(**_perturb_kwargs(handle.problem, rng, kind, p))
    _assert_warm_matches_cold(handle, solver, part, opts)


DRIVER_MATRIX = [
    ("ard", "xla", None, False),
    ("ard", "xla", None, True),
    ("ard", "pallas", 8, False),
    ("ard", "pallas", 8, True),
    ("prd", "xla", None, False),
    ("prd", "xla", None, True),
    ("prd", "pallas", 8, False),
    ("prd", "pallas", 8, True),
]
DRIVER_IDS = [f"{m}-{b}{'-fused' if c else ''}-{'dr' if d else 'host'}"
              for m, b, c, d in DRIVER_MATRIX]


@pytest.mark.parametrize("method,backend,chunk,dr", DRIVER_MATRIX,
                         ids=DRIVER_IDS)
def test_warm_resolve_across_drivers(method, backend, chunk, dr):
    prob = synthetic_grid(16, 16, connectivity=8, strength=150, seed=1)
    part = grid_partition((16, 16), (2, 2))
    opts = SolverOptions(method=method, engine_backend=backend,
                         engine_chunk_iters=chunk, device_resident=dr)
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()
    rng = np.random.RandomState(3)
    handle.update(**_perturb_kwargs(handle.problem, rng, "mixed", 0.1))
    _assert_warm_matches_cold(handle, solver, part, opts)


def test_warm_host_loop_and_device_resident_bitexact():
    """The two single-instance drivers must agree bit-exactly on the SAME
    warm entry state (labels, flow, counters) — warmth is driver-
    independent."""
    import dataclasses

    from repro.core import build, solve

    prob = synthetic_grid(16, 16, connectivity=8, strength=150, seed=2)
    part = grid_partition((16, 16), (2, 2))
    opts = SolverOptions()
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()
    rng = np.random.RandomState(5)
    handle.update(**_perturb_kwargs(handle.problem, rng, "mixed", 0.05))
    entry = handle._entry_state()
    cfg = opts.sweep_config()
    st_h, stats_h = solve(handle.meta, entry, cfg, warm=True)
    st_d, stats_d = solve(handle.meta, entry,
                          dataclasses.replace(cfg, device_resident=True),
                          warm=True)
    assert int(st_h.flow_to_t) == int(st_d.flow_to_t)
    np.testing.assert_array_equal(np.asarray(st_h.d), np.asarray(st_d.d))
    np.testing.assert_array_equal(np.asarray(st_h.cf), np.asarray(st_d.cf))
    assert stats_h.sweeps == stats_d.sweeps
    assert stats_h.engine_iters == stats_d.engine_iters
    assert stats_h.engine_launches == stats_d.engine_launches


def test_terminal_updates_match_cold():
    """excess / sink_cap deltas (incl. decreases below drained flow) warm-
    resolve to the cold flow."""
    prob = synthetic_grid(16, 16, connectivity=8, strength=150, seed=7)
    part = grid_partition((16, 16), (2, 2))
    opts = SolverOptions()
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()
    rng = np.random.RandomState(9)
    snk = handle.problem.sink_cap.copy()
    exc = handle.problem.excess.copy()
    nz = np.nonzero(snk)[0]
    snk[nz[: len(nz) // 2]] = 0             # drop t-links below their flow
    ez = np.nonzero(exc)[0]
    exc[ez[: len(ez) // 3]] //= 4           # retract source mass
    exc[ez[len(ez) // 3:]] += rng.randint(0, 100, size=len(ez)
                                          - len(ez) // 3)
    handle.update(excess=exc, sink_cap=snk)
    _assert_warm_matches_cold(handle, solver, part, opts)


def test_stacked_updates_before_one_solve():
    """Several updates may accumulate before the next solve; offsets and
    deltas compose."""
    prob = synthetic_grid(16, 16, connectivity=8, strength=150, seed=11)
    part = grid_partition((16, 16), (2, 2))
    opts = SolverOptions()
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()
    rng = np.random.RandomState(13)
    for kind in ("decrease", "increase", "mixed"):
        handle.update(**_perturb_kwargs(handle.problem, rng, kind, 0.03))
    _assert_warm_matches_cold(handle, solver, part, opts)


def test_update_before_first_solve_is_plain_edit():
    """Updating a cold handle is just a capacity edit — the first solve
    equals a cold solve of the edited problem."""
    prob = synthetic_grid(16, 16, connectivity=8, strength=150, seed=17)
    part = grid_partition((16, 16), (2, 2))
    opts = SolverOptions()
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    rng = np.random.RandomState(19)
    handle.update(**_perturb_kwargs(handle.problem, rng, "mixed", 0.1))
    res = handle.solve()
    cold = solve_mincut(handle.problem, part=part)
    assert res.flow_value == cold.flow_value
    assert int(handle._flow_offset) == 0    # zero flow: nothing to clamp


# --------------------------------------------------------------------------
# Invariants of the reparameterized state (test_discharge_invariants.py's
# properties, checked right after ``update`` + the label policy).
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["increase", "decrease", "mixed"])
def test_reparameterized_state_invariants(kind):
    prob = synthetic_grid(16, 16, connectivity=8, strength=150, seed=23)
    part = grid_partition((16, 16), (2, 2))
    opts = SolverOptions()
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()
    rng = np.random.RandomState(29)
    handle.update(**_perturb_kwargs(handle.problem, rng, kind, 0.1))
    meta = handle.meta
    st = handle._entry_state()              # warm_labels policy applied
    lay = handle.layout
    p = handle.problem

    cf = np.asarray(st.cf)
    sink_cf = np.asarray(st.sink_cf)
    excess = np.asarray(st.excess)
    d = np.asarray(st.d)
    vmask = np.asarray(st.vmask)

    # preflow validity: nonnegative residuals and excess everywhere
    assert (cf >= 0).all() and (sink_cf >= 0).all()
    assert (excess[vmask] >= 0).all()

    # residual pair invariant: cf(u,v) + cf(v,u) == c'(u,v) + c'(v,u)
    flat = cf.reshape(-1)
    pair = flat[lay.edge_arc_u] + flat[lay.edge_arc_v]
    np.testing.assert_array_equal(
        pair, p.cap_fwd.astype(np.int64) + p.cap_bwd)

    # t-links cover the reparameterization: sink_cf >= sink_cap - drained,
    # and padding slots stay untouched
    assert not sink_cf[~vmask].any() and not excess[~vmask].any()

    # label validity (ARD, cf. test_discharge_invariants): for d(u) < d_inf
    # a residual intra arc needs d(u) <= d(v), a residual cross arc
    # d(u) <= d(ghost) + 1, and an open t-link d(u) == 0
    intra = np.asarray(intra_mask(st))
    emask = np.asarray(st.emask)
    nbr = np.asarray(st.nbr_local)
    ghost = np.asarray(gather_ghost_labels(st))
    K, V, E = cf.shape
    for r in range(K):
        for u in range(V):
            if not vmask[r, u] or d[r, u] >= meta.d_inf_ard:
                continue
            if sink_cf[r, u] > 0:
                assert d[r, u] == 0, (r, u)
            for e in range(E):
                if not emask[r, u, e] or cf[r, u, e] <= 0:
                    continue
                if intra[r, u, e]:
                    assert d[r, u] <= d[r, nbr[r, u, e]], (r, u, e)
                elif ghost[r, u, e] < meta.d_inf_ard:
                    assert d[r, u] <= ghost[r, u, e] + 1, (r, u, e)


# --------------------------------------------------------------------------
# The 64^2 acceptance instance: bit-exact flow, strictly fewer sweeps.
# --------------------------------------------------------------------------

def test_warm_start_64x64_acceptance():
    """On the 64^2 interactive-segmentation instance, a warm re-solve after
    a 1% capacity perturbation reaches the cold flow value bit-exactly in
    strictly fewer sweeps, and the same-shape re-solve cycle retraces
    nothing."""
    prob = segmentation_seeds_grid(64, 64, seed=0)
    part = grid_partition((64, 64), (4, 4))
    opts = SolverOptions(num_regions=16)
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()

    rng = np.random.RandomState(0)
    handle.update(**_perturb_kwargs(handle.problem, rng, "mixed", 0.01))
    warm, cold = _assert_warm_matches_cold(handle, solver, part, opts)
    assert warm.stats.sweeps < cold.stats.sweeps
    assert warm.stats.engine_launches < cold.stats.engine_launches

    # second same-shape cycle: the session retraces nothing.  (warm2's
    # optimality is certified by the in-solve cut-cost == flow check: a cut
    # whose cost in the perturbed initial network equals the flow value
    # proves both are optimal, no cold reference needed.)
    traces = solver.cache_info().traces
    handle.update(**_perturb_kwargs(handle.problem, rng, "mixed", 0.01))
    handle.solve()
    assert solver.cache_info().traces == traces
