"""Pallas kernels vs pure-jnp oracles across shape/dtype sweeps
(interpret mode on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.engine import push_relabel, push_relabel_batched
from repro.kernels.flash_attention import flash_attention
from repro.kernels.push_relabel import (engine_phase, fused_engine_run,
                                        fused_engine_run_batched,
                                        push_relabel_phase)
from repro.kernels.ref import (attention_ref, fused_iteration_ref,
                               push_relabel_iteration_ref)

ATTN_SHAPES = [
    # B, H, Hkv, Sq, Sk, D
    (1, 2, 2, 64, 64, 32),
    (2, 4, 2, 128, 128, 64),
    (1, 4, 1, 96, 96, 32),      # MQA
    (1, 2, 1, 1, 128, 32),      # decode: one query against a cache
    (1, 1, 1, 37, 53, 16),      # ragged (padding path)
    (1, 2, 2, 200, 200, 128),   # head_dim 128 (lane width)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", ATTN_SHAPES,
                         ids=[str(s) for s in ATTN_SHAPES])
def test_flash_attention_matches_ref(shape, dtype):
    B, H, Hkv, Sq, Sk, D = shape
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, Sq, D), dtype)
    k = jnp.asarray(rng.randn(B, Hkv, Sk, D), dtype)
    v = jnp.asarray(rng.randn(B, Hkv, Sk, D), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=64,
                          interpret=True)
    kk = jnp.repeat(k, H // Hkv, 1)
    vv = jnp.repeat(v, H // Hkv, 1)
    want = attention_ref(q, kk, vv, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (64, 128)])
def test_flash_attention_block_shape_independence(block_q, block_k):
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 2, 128, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 128, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 128, 32), jnp.float32)
    a = flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                        interpret=True)
    b = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


PR_SHAPES = [(16, 4), (33, 5), (64, 8), (128, 3)]


@pytest.mark.parametrize("V,E", PR_SHAPES, ids=[str(s) for s in PR_SHAPES])
@pytest.mark.parametrize("block_v", [8, 32])
def test_push_relabel_phase_matches_ref(V, E, block_v):
    rng = np.random.RandomState(V + E)
    cf = jnp.asarray(rng.randint(0, 50, (V, E)), jnp.int32)
    nbr = jnp.asarray(rng.randint(0, V, (V, E)), jnp.int32)
    intra = jnp.asarray((rng.rand(V, E) < 0.8), jnp.int32)
    pushable = jnp.ones((V, E), jnp.int32)
    cross_lab = jnp.asarray(rng.randint(0, 6, (V, E)), jnp.int32)
    lab = jnp.asarray(rng.randint(0, 8, (V,)), jnp.int32)
    excess = jnp.asarray(rng.randint(0, 40, (V,)), jnp.int32)
    sink_cf = jnp.asarray(rng.randint(0, 20, (V,)), jnp.int32)
    d_inf = 64
    got_d, got_l = push_relabel_phase(
        lab, cf, sink_cf, excess, nbr, intra, pushable, cross_lab, d_inf,
        block_v=block_v, interpret=True)
    want_d, want_l = push_relabel_iteration_ref(
        cf, sink_cf, excess, lab, nbr, None, intra != 0,
        jnp.ones((V, E), bool), jnp.ones((V,), bool), cross_lab,
        pushable != 0, d_inf)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(want_l))


def _random_region(V, E, seed):
    """Random (not necessarily consistent) region network: enough for
    bit-parity checks, which only need both backends to see the same bits."""
    rng = np.random.RandomState(seed)
    return dict(
        cf=jnp.asarray(rng.randint(0, 50, (V, E)), jnp.int32),
        sink_cf=jnp.asarray(rng.randint(0, 20, (V,)), jnp.int32),
        excess=jnp.asarray(rng.randint(0, 40, (V,)), jnp.int32),
        lab=jnp.asarray(rng.randint(0, 8, (V,)), jnp.int32),
        nbr_local=jnp.asarray(rng.randint(0, V, (V, E)), jnp.int32),
        rev_slot=jnp.asarray(rng.randint(0, E, (V, E)), jnp.int32),
        intra=jnp.asarray(rng.rand(V, E) < 0.8),
        emask=jnp.asarray(rng.rand(V, E) < 0.9),
        vmask=jnp.asarray(rng.rand(V) < 0.95),
        cross_pushable=jnp.asarray(rng.rand(V, E) < 0.5),
        cross_lab=jnp.asarray(rng.randint(0, 6, (V, E)), jnp.int32),
    )


@pytest.mark.parametrize("V,E", PR_SHAPES, ids=[str(s) for s in PR_SHAPES])
@pytest.mark.parametrize("sink_open", [True, False])
def test_engine_phase_matches_xla_phase(V, E, sink_open):
    """kernels.engine_phase (pallas adapter) == engine._phase_xla, bit-exact,
    under the engine's cross_pushable/emask/vmask/sink_open gating."""
    from repro.core.engine import make_phase

    r = _random_region(V, E, seed=3 * V + E)
    kw = dict(nbr_local=r["nbr_local"], intra=r["intra"], emask=r["emask"],
              vmask=r["vmask"], cross_pushable=r["cross_pushable"],
              cross_lab=r["cross_lab"], d_inf=V + 2, sink_open=sink_open)
    want = make_phase("xla", **kw)(r["lab"], r["cf"], r["sink_cf"],
                                   r["excess"])
    got = engine_phase(r["lab"], r["cf"], r["sink_cf"], r["excess"],
                       block_v=8, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


@pytest.mark.parametrize("V,E", PR_SHAPES, ids=[str(s) for s in PR_SHAPES])
def test_engine_backend_parity(V, E):
    """Full engine runs (while_loop of push+apply+relabel) are bit-identical
    between the XLA and Pallas compute-phase backends."""
    r = _random_region(V, E, seed=7 * V + E)
    kw = dict(nbr_local=r["nbr_local"], rev_slot=r["rev_slot"],
              intra=r["intra"], emask=r["emask"], vmask=r["vmask"],
              cross_pushable=r["cross_pushable"], cross_lab=r["cross_lab"],
              d_inf=V + 2, sink_open=True, max_iters=40)
    a = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend="xla", **kw)
    b = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend="pallas", block_v=8, **kw)
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"field {name}")


def _engine_kwargs(r, V, **over):
    kw = dict(nbr_local=r["nbr_local"], rev_slot=r["rev_slot"],
              intra=r["intra"], emask=r["emask"], vmask=r["vmask"],
              cross_pushable=r["cross_pushable"], cross_lab=r["cross_lab"],
              d_inf=V + 2, sink_open=True)
    kw.update(over)
    return kw


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("V,E", [(16, 4), (33, 5)],
                         ids=["(16,4)", "(33,5)"])
def test_fused_iteration_matches_ref_oracle(V, E, backend):
    """One fused engine iteration (push + intra scatter + post-push relabel)
    is bit-equal to the kernels/ref.py fused-iteration oracle."""
    r = _random_region(V, E, seed=11 * V + E)
    es = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                      backend=backend, chunk_iters=1, max_iters=1,
                      **_engine_kwargs(r, V))
    want = fused_iteration_ref(
        r["cf"], r["sink_cf"], r["excess"], r["lab"], r["nbr_local"],
        r["rev_slot"], r["intra"], r["emask"], r["vmask"], r["cross_lab"],
        r["cross_pushable"], V + 2)
    got = (es.cf, es.sink_cf, es.excess, es.lab, es.out_push,
           es.sink_pushed, es.relabel_sum)
    names = ("cf", "sink_cf", "excess", "lab", "out_push", "sink_pushed",
             "relabel_sum")
    for name, x, y in zip(names, got, want):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"field {name}")
    assert int(es.iters) == 1


@pytest.mark.parametrize("chunk", [1, 8])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("V,E", PR_SHAPES, ids=[str(s) for s in PR_SHAPES])
def test_fused_engine_matches_unfused(V, E, backend, chunk):
    """The chunked fused driver (k iterations per launch) is bit-identical
    to the unfused two-phase engine — every state field including iteration
    counts — on both backends.  max_iters=16 is a whole number of chunks
    at chunk=8 (the mid-chunk early exit is covered on a consistent network
    by test_fused_early_exit_convergence; random regions need an iteration
    cap because their labeling can be permanently invalid)."""
    r = _random_region(V, E, seed=7 * V + E)
    kw = _engine_kwargs(r, V, max_iters=16)
    a = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend="xla", **kw)
    b = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend=backend, chunk_iters=chunk, **kw)
    for name, x, y in zip(a._fields, a, b):
        if name == "launches":
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"field {name}")
    # launch accounting: unfused = 2 phase programs per iteration; fused
    # pallas = exactly ceil(iters / chunk) kernel launches (the early exit
    # never pays for an extra empty launch); fused xla = one traced
    # compute body per iteration (2x fewer programs, not chunked)
    iters = int(a.iters)
    assert int(a.launches) == 2 * iters
    want = -(-iters // chunk) if backend == "pallas" else iters
    assert int(b.launches) == want


def _consistent_region(n, m, seed):
    """A *valid* single-region network (true reverse slots, zero labels) —
    the engine provably terminates on it, unlike on _random_region's
    arbitrary topology, so it can run to convergence."""
    from repro.core.graph import build, intra_mask
    from repro.data.grids import random_sparse

    p = random_sparse(n, m, seed=seed)
    meta, state, _ = build(p, np.zeros(n, np.int64))
    sq = lambda a: a[0]
    return dict(
        cf=sq(state.cf), sink_cf=sq(state.sink_cf), excess=sq(state.excess),
        lab=jnp.zeros_like(sq(state.sink_cf)),
        nbr_local=sq(state.nbr_local), rev_slot=sq(state.rev_slot),
        intra=sq(intra_mask(state)), emask=sq(state.emask),
        vmask=sq(state.vmask),
        cross_pushable=jnp.zeros_like(sq(state.emask)),
        cross_lab=jnp.zeros_like(sq(state.nbr_local)),
    ), meta.num_vertices


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fused_early_exit_convergence(backend):
    """On a consistent network the fused driver runs to convergence with an
    in-kernel early exit: identical final state and iteration count as the
    unfused engine, and exactly ceil(iters/chunk) launches — the early exit
    stops mid-chunk instead of padding to a chunk multiple."""
    r, n = _consistent_region(12, 24, seed=4)
    kw = dict(nbr_local=r["nbr_local"], rev_slot=r["rev_slot"],
              intra=r["intra"], emask=r["emask"], vmask=r["vmask"],
              cross_pushable=r["cross_pushable"], cross_lab=r["cross_lab"],
              d_inf=n, sink_open=True, max_iters=None)
    a = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend="xla", **kw)
    iters = int(a.iters)
    assert iters > 0
    # no active vertex left: the run converged rather than hitting a cap
    assert not bool(((a.excess > 0) & (a.lab < n) & r["vmask"]).any())
    for chunk in (8, iters + 5):     # mid-chunk exit / single-launch exit
        b = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                         backend=backend, chunk_iters=chunk, **kw)
        for name, x, y in zip(a._fields, a, b):
            if name == "launches":
                continue
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"field {name}")
        want = -(-iters // chunk) if backend == "pallas" else iters
        assert int(b.launches) == want


def test_fused_pallas_vmem_fallback():
    """A region over the VMEM budget must fall back to the blocked two-phase
    path (launch accounting shows 2/iteration) and stay bit-exact."""
    V, E = 33, 5
    r = _random_region(V, E, seed=7 * V + E)
    kw = _engine_kwargs(r, V, max_iters=16)
    a = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend="pallas", block_v=8, **kw)
    b = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                     backend="pallas", block_v=8, chunk_iters=8,
                     vmem_budget_bytes=1, **kw)
    for name, x, y in zip(a._fields, a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"field {name}")
    assert int(b.launches) == 2 * int(b.iters)


def test_fused_engine_run_batched_matches_scalar_kernel():
    """The grid-over-regions kernel (grid=(K,)) is bit-identical, region by
    region, to K separate single-region fused kernel launches — including a
    region whose per-region iteration budget is exhausted (limit 0)."""
    rng = np.random.RandomState(0)
    K, V, E = 3, 16, 4
    mk = lambda *s, hi=10: jnp.asarray(rng.randint(0, hi, s), jnp.int32)
    lab, cf = mk(K, V, hi=8), mk(K, V, E, hi=50)
    sink, exc = mk(K, V, hi=20), mk(K, V, hi=40)
    nbr, rev = mk(K, V, E, hi=V), mk(K, V, E, hi=E)
    intra = jnp.asarray(rng.rand(K, V, E) < 0.8, jnp.int32)
    pushable = jnp.asarray(rng.rand(K, V, E) < 0.9, jnp.int32)
    clab = mk(K, V, E, hi=6)
    vmask = jnp.asarray(rng.rand(K, V) < 0.95, jnp.int32)
    d_inf, limit = 18, jnp.asarray([5, 0, 9], jnp.int32)
    got = fused_engine_run_batched(lab, cf, sink, exc, nbr, rev, intra,
                                   pushable, clab, vmask, d_inf, limit,
                                   interpret=True)
    for k in range(K):
        want = fused_engine_run(lab[k], cf[k], sink[k], exc[k], nbr[k],
                                rev[k], intra[k], pushable[k], clab[k],
                                vmask[k], d_inf, limit[k], interpret=True)
        for i, (x, y) in enumerate(zip([o[k] for o in got], want)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"region {k} output {i}")


@pytest.mark.parametrize("backend,chunk",
                         [("xla", None), ("xla", 8), ("pallas", 8)],
                         ids=["xla-unfused", "xla-fused", "pallas-fused"])
def test_push_relabel_batched_matches_vmapped_scalar(backend, chunk):
    """The batched engine entry point is bit-identical, per region, to
    jax.vmap of the scalar engine on every state field; only the launch
    accounting becomes global (1 per chunk trip on fused pallas)."""
    rng = np.random.RandomState(5)
    K, V, E = 3, 16, 4
    regions = [_random_region(V, E, seed=100 + k) for k in range(K)]
    stack = lambda name: jnp.stack([r[name] for r in regions])
    kw = dict(nbr_local=stack("nbr_local"), rev_slot=stack("rev_slot"),
              intra=stack("intra"), emask=stack("emask"),
              vmask=stack("vmask"), cross_pushable=stack("cross_pushable"),
              cross_lab=stack("cross_lab"), d_inf=V + 2, sink_open=True,
              max_iters=16)
    got = push_relabel_batched(stack("cf"), stack("sink_cf"),
                               stack("excess"), stack("lab"),
                               backend=backend, chunk_iters=chunk, **kw)
    launches = 0
    for k, r in enumerate(regions):
        want = push_relabel(r["cf"], r["sink_cf"], r["excess"], r["lab"],
                            nbr_local=r["nbr_local"], rev_slot=r["rev_slot"],
                            intra=r["intra"], emask=r["emask"],
                            vmask=r["vmask"],
                            cross_pushable=r["cross_pushable"],
                            cross_lab=r["cross_lab"], d_inf=V + 2,
                            sink_open=True, max_iters=16, backend=backend,
                            chunk_iters=chunk)
        launches += int(want.launches)
        for name, x, y in zip(want._fields, got, want):
            if name == "launches":
                continue
            np.testing.assert_array_equal(np.asarray(x[k]), np.asarray(y),
                                          err_msg=f"region {k} field {name}")
    if backend == "pallas" and chunk:
        # grid-over-regions: one launch per chunk trip covers every region,
        # so the dispatch count is the busiest region's ceil(iters/chunk)
        # instead of the sum over regions
        want_trips = max(-(-int(it) // chunk)
                         for it in np.asarray(got.iters))
        assert int(got.launches) == want_trips
    else:
        assert int(got.launches) == launches


def test_push_relabel_phase_respects_blocking():
    """Cross arcs marked non-pushable must get no flow and no relabel use."""
    V, E = 8, 3
    rng = np.random.RandomState(0)
    cf = jnp.asarray(rng.randint(1, 10, (V, E)), jnp.int32)
    nbr = jnp.asarray(rng.randint(0, V, (V, E)), jnp.int32)
    intra = jnp.zeros((V, E), jnp.int32)          # all cross
    pushable = jnp.zeros((V, E), jnp.int32)       # all blocked
    cross_lab = jnp.zeros((V, E), jnp.int32)
    lab = jnp.ones((V,), jnp.int32)
    excess = jnp.full((V,), 5, jnp.int32)
    sink_cf = jnp.zeros((V,), jnp.int32)
    delta, new_lab = push_relabel_phase(
        lab, cf, sink_cf, excess, nbr, intra, pushable, cross_lab, 16,
        block_v=8, interpret=True)
    assert int(jnp.sum(delta)) == 0
    assert (np.asarray(new_lab) == 16).all()      # relabel straight to cap
