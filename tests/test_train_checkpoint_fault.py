"""Training substrate: loss convergence, chunked-CE equivalence, checkpoint
roundtrip + atomicity, fault-tolerant driver with injected failures,
gradient compression."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.pipeline import MarkovSpec, markov_batch
from repro.models.model import forward, init_params
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import fault as fault_lib
from repro.train import optimizer as opt_lib
from repro.train import train_loop as tl


def _small_cfg():
    return dataclasses.replace(ARCHS["phi3-mini-3.8b"].smoke(),
                               num_layers=2, vocab_size=64)


def test_loss_decreases_on_markov_stream():
    cfg = _small_cfg()
    spec = MarkovSpec(vocab=cfg.vocab_size, branching=2, seed=3)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    step = jax.jit(tl.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
        jnp.float32))
    losses = []
    for i in range(40):
        b = jax.tree.map(jnp.asarray, markov_batch(spec, i, 8, 64))
        state, m = step(state, b)
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0] * 0.7, losses[::8]
    # approaching the entropy floor log(2) from above
    assert losses[-1] > spec.entropy_floor() * 0.5


def test_chunked_ce_matches_dense_ce():
    cfg = _small_cfg()
    params = init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    B, S = 2, 64
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    hidden, _ = forward(cfg, params, {"tokens": toks}, mode="train",
                        dtype=jnp.float32, return_hidden=True)
    mask = jnp.ones((B, S), jnp.float32)
    got = tl.chunked_ce_loss(cfg, params, hidden, labels, mask)
    # dense reference
    logits, _ = forward(cfg, params, {"tokens": toks}, mode="train",
                        dtype=jnp.float32)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    want = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    cfg = _small_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    ckpt.save(tmp_path, 7, state)
    assert ckpt.latest_step(tmp_path) == 7
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = ckpt.restore(tmp_path, 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path):
    cfg = _small_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    ckpt.save(tmp_path, 1, state)
    # simulate a crashed writer: stale tmp dir must be ignored + recoverable
    crash = tmp_path / "step_00000002.tmp"
    crash.mkdir()
    (crash / "garbage").write_text("partial write")
    assert ckpt.latest_step(tmp_path) == 1
    ckpt.save(tmp_path, 2, state)        # overwrites the stale tmp cleanly
    assert ckpt.latest_step(tmp_path) == 2


def test_fault_driver_recovers_from_injected_failure(tmp_path):
    cfg = _small_cfg()
    spec = MarkovSpec(vocab=cfg.vocab_size, branching=2)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    step = jax.jit(tl.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=1e-3), jnp.float32))
    boom = {"armed": True}

    def inject(step_idx):
        if step_idx == 12 and boom["armed"]:
            boom["armed"] = False
            return RuntimeError("injected node failure")
        return None

    fcfg = fault_lib.FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                                 max_retries=2)
    state, stats = fault_lib.run_training(
        state=state, state_shardings=None, train_step=step,
        make_batch=lambda i: jax.tree.map(
            jnp.asarray, markov_batch(spec, i, 4, 32)),
        num_steps=20, cfg=fcfg, inject_fault=inject)
    assert stats.restarts >= 1
    assert stats.steps_replayed >= 1       # replayed from step 10 ckpt
    assert ckpt.latest_step(tmp_path) == 20


def test_compression_error_feedback():
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(16, 64) * 0.01, jnp.float32)
    q = comp.compress_int8(g)
    # block quantisation error is bounded by scale/2 per element
    scale = np.abs(np.asarray(g)).max(-1, keepdims=True) / 127.0
    assert (np.abs(np.asarray(q - g)) <= scale / 2 + 1e-9).all()
    # error feedback: accumulated compressed updates converge to the truth
    ef = jax.tree.map(lambda p: jnp.zeros_like(p), g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        cg, ef = comp.ef_compress(g, ef)
        total = total + cg
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=float(scale.max()) * 0.1)


def test_training_with_compression_converges():
    cfg = _small_cfg()
    spec = MarkovSpec(vocab=cfg.vocab_size, branching=2)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    step = jax.jit(tl.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=3e-3), jnp.float32,
        compress=comp.make_plain_compressor()))
    losses = []
    for i in range(30):
        b = jax.tree.map(jnp.asarray, markov_batch(spec, i, 8, 64))
        state, m = step(state, b)
        losses.append(float(m["ce"]))
    assert losses[-1] < losses[0] * 0.8


def test_data_pipeline_determinism():
    spec = MarkovSpec(vocab=97, branching=3)
    a = markov_batch(spec, 5, 8, 32, host_id=0, num_hosts=2)
    b = markov_batch(spec, 5, 8, 32, host_id=0, num_hosts=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = markov_batch(spec, 5, 8, 32, host_id=1, num_hosts=2)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels really are next tokens
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
