"""Label machinery: region-relabel (Alg. 3), gap heuristics, boundary
relabel (Sec. 6.1), region reduction (Alg. 5) on structured instances."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (SweepConfig, build, grid_partition, init_labels,
                        region_reduction, solve_mincut)
from repro.core.heuristics import boundary_relabel
from repro.core.labels import global_gap, region_relabel
from repro.data.grids import random_sparse, segmentation_grid, synthetic_grid
from repro.kernels.ref import maxflow_oracle


def _setup(seed=0, n=16, m=30, k=3):
    from repro.core.partition import block_partition

    p = random_sparse(n, m, seed=seed)
    part = block_partition(n, k)
    meta, state, layout = build(p, part)
    return p, meta, init_labels(meta, state), layout


@pytest.mark.parametrize("ard", [True, False])
def test_region_relabel_monotone_and_bounded(ard):
    p, meta, state, _ = _setup()
    st1 = region_relabel(meta, state, ard=ard)
    d0, d1 = np.asarray(state.d), np.asarray(st1.d)
    vm = np.asarray(state.vmask)
    assert (d1 >= d0)[vm].all()
    cap = meta.d_inf_ard if ard else meta.d_inf_prd
    assert (d1 <= cap)[vm].all()
    # repeated application keeps tightening the lower bound monotonically
    # (not idempotent: rising boundary labels feed back into neighbours)
    st2 = region_relabel(meta, st1, ard=ard)
    d2 = np.asarray(st2.d)
    assert (d2 >= d1)[vm].all()
    assert (d2 <= cap)[vm].all()


def test_global_gap_preserves_solution():
    p = synthetic_grid(12, 12, strength=100, seed=5)
    want, _ = maxflow_oracle(p)
    part = grid_partition((12, 12), (2, 2))
    for gap in (True, False):
        res = solve_mincut(p, part=part,
                           config=SweepConfig(method="ard",
                                              use_global_gap=gap))
        assert res.flow_value == want


def test_boundary_relabel_is_sound_lower_bound():
    """After boundary relabel the solver must still reach the optimum and
    labels must not decrease."""
    p, meta, state, _ = _setup(seed=3)
    st = region_relabel(meta, state, ard=True)
    st2 = boundary_relabel(meta, st)
    assert (np.asarray(st2.d) >= np.asarray(st.d)).all()


def test_reduction_on_segmentation():
    """Vision-style instances decide a large fraction (paper Table 3 shows
    70-85% for stereo-like problems; our coherent disk instance should
    decide well above the random-grid near-zero)."""
    p = segmentation_grid(24, 24, seed=1)
    part = grid_partition((24, 24), (2, 2))
    meta, state, layout = build(p, part)
    red = region_reduction(meta, state)
    frac = float(np.asarray(red.decided).sum()) / p.num_vertices
    assert frac > 0.5, frac
    # soundness vs the optimal cut
    res = solve_mincut(p, part=part)
    src = res.source_side
    sk = layout.to_flat(np.asarray(red.strong_sink))
    ws = layout.to_flat(np.asarray(red.weak_source))
    assert not (src & sk).any()
    # weak sources: there EXISTS an optimal cut with them on the source
    # side; the canonical minimal-sink-side cut is exactly that maximal cut,
    # so they must not be strictly required on the sink side — verify by
    # checking the cut we extracted keeps its cost when they sit source-side
    # (already guaranteed by construction; sanity only):
    assert ws.sum() >= 0


def test_reduction_random_grid_low_decided():
    p = synthetic_grid(16, 16, strength=150, seed=0)
    part = grid_partition((16, 16), (2, 2))
    meta, state, _ = build(p, part)
    red = region_reduction(meta, state)
    frac = float(np.asarray(red.decided).sum()) / p.num_vertices
    assert frac < 0.5   # paper: synthetic random grids decide very little


def test_reduction_regression_hypothesis_counterexample():
    """Pinned counterexample found by hypothesis: the single-scratch Alg. 5
    port classified a source-side vertex as strong sink (cross-region
    in-arc capacity corruption).  The two-phase Kovtun formulation must
    classify it correctly."""
    from repro.core import build, solve_mincut, region_reduction
    from repro.core.graph import Problem
    from repro.core.partition import block_partition

    p = Problem(
        num_vertices=5,
        edges=np.array([[1, 3], [3, 2], [4, 0], [4, 2]]),
        cap_fwd=np.array([36, 57, 6, 42], np.int32),
        cap_bwd=np.array([35, 37, 24, 37], np.int32),
        excess=np.array([8, 36, 31, 30, 23], np.int32),
        sink_cap=np.array([13, 3, 12, 39, 20], np.int32))
    part = block_partition(5, 2)
    meta, state, layout = build(p, part)
    red = region_reduction(meta, state)
    res = solve_mincut(p, part=part)
    src = res.source_side
    sk = layout.to_flat(np.asarray(red.strong_sink))
    ss = layout.to_flat(np.asarray(red.strong_source))
    assert not (src & sk).any()
    assert (src[ss]).all() or not ss.any()
