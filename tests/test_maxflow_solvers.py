"""Solver correctness: every variant must reach the oracle maxflow value and
produce a consistent minimum cut, within the paper's sweep bounds."""

import numpy as np
import pytest

from repro.core import (SweepConfig, build, cut_value, extract_cut,
                        grid_partition, solve_mincut)
from repro.core.sweep import sweep_bound
from repro.data.grids import random_sparse, segmentation_grid, synthetic_grid
from repro.kernels.ref import maxflow_oracle

VARIANTS = [
    SweepConfig(method="ard", parallel=True),
    SweepConfig(method="ard", parallel=False),
    SweepConfig(method="prd", parallel=True),
    SweepConfig(method="prd", parallel=False),
]


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("cfg", VARIANTS,
                         ids=["ard-par", "ard-seq", "prd-par", "prd-seq"])
def test_random_sparse_matches_oracle(seed, cfg):
    p = random_sparse(14, 28, seed=seed)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, num_regions=3, config=cfg)
    assert res.flow_value == want
    # cut consistency is asserted inside solve_mincut (cost == flow)
    assert res.stats.sweeps <= sweep_bound(res.meta, cfg)


@pytest.mark.parametrize("cfg", VARIANTS[:2], ids=["ard-par", "ard-seq"])
def test_grid_instance(cfg):
    p = synthetic_grid(16, 16, connectivity=8, strength=120, seed=1)
    want, _ = maxflow_oracle(p)
    part = grid_partition((16, 16), (2, 2))
    res = solve_mincut(p, part=part, config=cfg)
    assert res.flow_value == want


def test_heuristics_preserve_correctness():
    p = synthetic_grid(16, 16, connectivity=8, strength=150, seed=2)
    want, _ = maxflow_oracle(p)
    part = grid_partition((16, 16), (2, 2))
    for cfg in [
        SweepConfig(method="ard", use_boundary_relabel=True),
        SweepConfig(method="ard", partial_discharge=True),
        SweepConfig(method="ard", partial_discharge=True,
                    use_boundary_relabel=True),
        SweepConfig(method="ard", use_global_gap=False),
    ]:
        res = solve_mincut(p, part=part, config=cfg)
        assert res.flow_value == want, cfg


def test_ard_fewer_sweeps_than_prd():
    """The paper's headline experimental claim (Fig. 8, Table 1)."""
    p = synthetic_grid(20, 20, connectivity=8, strength=150, seed=3)
    part = grid_partition((20, 20), (2, 2))
    ard = solve_mincut(p, part=part, config=SweepConfig(method="ard"))
    prd = solve_mincut(p, part=part, config=SweepConfig(method="prd"))
    assert ard.flow_value == prd.flow_value
    assert ard.stats.sweeps <= prd.stats.sweeps


@pytest.mark.parametrize("method", ["ard", "prd"])
def test_bfs_partition_irregular_end_to_end(method):
    """An irregular (non-grid) instance solved through a BFS-grown
    partition — exercises partition.bfs_partition in a full solve, which
    the grid/block partition tests never reach."""
    from repro.core import bfs_partition

    p = random_sparse(24, 60, seed=7)
    want, _ = maxflow_oracle(p)
    part = bfs_partition(p.num_vertices, p.edges, 3, seed=1)
    assert part.min() >= 0 and part.max() <= 2 and len(part) == 24
    res = solve_mincut(p, part=part, config=SweepConfig(method=method))
    assert res.flow_value == want
    assert res.stats.sweeps <= sweep_bound(res.meta, SweepConfig(method=method))


def test_segmentation_instance():
    p = segmentation_grid(20, 20, seed=0)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, num_regions=4,
                       config=SweepConfig(method="ard"))
    assert res.flow_value == want


def test_source_side_is_minimal_cut():
    p = random_sparse(12, 24, seed=9)
    want, oracle_side = maxflow_oracle(p)
    res = solve_mincut(p, num_regions=2)
    # the extracted sink side T = {v -> t} is the canonical minimal sink
    # side; the oracle computes the minimal *source* side {s -> v}; both
    # cuts must have the same (optimal) cost.
    meta, state0, layout = build(p, np.zeros(p.num_vertices, np.int64))
    assert res.flow_value == want


@pytest.mark.parametrize("method", ["ard", "prd"])
def test_engine_backend_full_solve_parity(method):
    """The Pallas engine backend (interpret mode on CPU) must be a drop-in
    replacement: identical flow value, labels, and sweep count vs XLA."""
    instances = [
        (synthetic_grid(12, 12, connectivity=8, strength=120, seed=1),
         grid_partition((12, 12), (2, 2))),
        (random_sparse(14, 28, seed=2), None),
    ]
    for p, part in instances:
        want, _ = maxflow_oracle(p)
        res = {}
        for be in ("xla", "pallas"):
            cfg = SweepConfig(method=method, engine_backend=be)
            res[be] = solve_mincut(p, part=part, num_regions=3, config=cfg)
            assert res[be].flow_value == want
        assert res["xla"].flow_value == res["pallas"].flow_value
        np.testing.assert_array_equal(np.asarray(res["xla"].state.d),
                                      np.asarray(res["pallas"].state.d))
        assert res["xla"].stats.sweeps == res["pallas"].stats.sweeps


@pytest.mark.parametrize("method", ["ard", "prd"])
def test_fused_engine_full_solve_parity(method):
    """The region-resident fused engine (chunk_iters=k) must be a drop-in
    replacement on full solves: oracle flow value, identical labels and
    sweep counts vs the unfused path, on both backends, with the expected
    kernel-launch reduction."""
    p = random_sparse(16, 32, seed=5)
    want, _ = maxflow_oracle(p)
    base = solve_mincut(p, num_regions=3,
                        config=SweepConfig(method=method))
    assert base.flow_value == want
    for backend, chunk in [("xla", 1), ("xla", 8), ("pallas", 8)]:
        cfg = SweepConfig(method=method, engine_backend=backend,
                          engine_chunk_iters=chunk)
        res = solve_mincut(p, num_regions=3, config=cfg)
        assert res.flow_value == want, (backend, chunk)
        np.testing.assert_array_equal(np.asarray(res.state.d),
                                      np.asarray(base.state.d),
                                      err_msg=f"{backend} chunk={chunk}")
        assert res.stats.sweeps == base.stats.sweeps
        assert res.stats.engine_iters == base.stats.engine_iters
        # fused pallas: one kernel launch per chunk (vs 2 programs per
        # iteration) -> >= 4x fewer dispatches at chunk=8; fused xla: one
        # traced body per iteration -> exactly 2x fewer
        if backend == "pallas" and chunk > 1:
            assert res.stats.engine_launches * 4 <= base.stats.engine_launches
        elif backend == "xla":
            assert res.stats.engine_launches * 2 == base.stats.engine_launches


def test_trivial_cases():
    # no edges: flow = sum(min(excess, sink_cap)) per vertex
    p = random_sparse(5, 0, seed=0)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, num_regions=2)
    assert res.flow_value == want
    # single region (degenerate partition)
    p = random_sparse(10, 20, seed=3)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, num_regions=1)
    assert res.flow_value == want
