import os
import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses with
# their own XLA_FLAGS (tests/_subproc.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_compilation_cache_dir", "/tmp/repro_jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)


@pytest.fixture
def fresh_compile_cache():
    """Reset the process-global jit executable caches.

    The compile-cache accounting tests assert hit/miss counts derived
    from module-global trace counters, but jit caches are process-global:
    an identically-shaped solve in an EARLIER test warms the cache, so
    whether this test's first solve is a hit or a miss depends on pytest
    ordering.  Clearing the caches up front makes the first invocation
    deterministically a miss under any ordering (-p no:randomly not
    required, -k subsets safe)."""
    jax.clear_caches()
    yield
