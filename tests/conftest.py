import os
import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses with
# their own XLA_FLAGS (tests/_subproc.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

jax.config.update("jax_compilation_cache_dir", "/tmp/repro_jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
