"""DIMACS ``.max`` reader/writer roundtrip and solver integration."""

import numpy as np
import pytest

from repro.core import SweepConfig, solve_mincut
from repro.data.dimacs import read_dimacs, write_dimacs
from repro.data.grids import random_sparse, synthetic_grid
from repro.kernels.ref import maxflow_oracle


def _canonical_edges(p):
    """Undirected edge -> (cap_lo_to_hi, cap_hi_to_lo), zero edges dropped."""
    d = {}
    for (u, v), cf, cb in zip(p.edges, p.cap_fwd, p.cap_bwd):
        u, v, cf, cb = int(u), int(v), int(cf), int(cb)
        if u > v:
            u, v, cf, cb = v, u, cb, cf
        if cf or cb:
            a, b = d.get((u, v), (0, 0))
            d[(u, v)] = (a + cf, b + cb)
    return d


@pytest.mark.parametrize("p", [
    random_sparse(14, 28, seed=3),
    random_sparse(9, 14, seed=5),
    synthetic_grid(6, 6, connectivity=8, strength=120, seed=1),
], ids=["sparse14", "sparse9", "grid6"])
def test_write_read_roundtrip(p, tmp_path):
    path = tmp_path / "instance.max"
    write_dimacs(p, path)
    q = read_dimacs(path)
    assert q.num_vertices == p.num_vertices
    assert _canonical_edges(q) == _canonical_edges(p)
    np.testing.assert_array_equal(q.excess, p.excess)
    np.testing.assert_array_equal(q.sink_cap, p.sink_cap)
    assert maxflow_oracle(q)[0] == maxflow_oracle(p)[0]


def test_read_handles_text_comments_and_merges():
    text = """c tiny hand-written instance
p max 5 7
n 4 s
n 5 t
a 4 1 10
a 4 1 5
a 1 2 7
a 2 1 3
a 2 5 9
a 3 5 2
a 1 3 4
"""
    p = read_dimacs(text)
    assert p.num_vertices == 3              # nodes 1..3 (4=s, 5=t)
    np.testing.assert_array_equal(p.excess, [15, 0, 0])   # parallel s-arcs sum
    np.testing.assert_array_equal(p.sink_cap, [0, 9, 2])
    assert _canonical_edges(p) == {(0, 1): (7, 3), (0, 2): (4, 0)}
    # maxflow: s->1 (15) ; 1->2 (7) -> t (9-capped by 7), 1->3 (4) -> t (2)
    assert maxflow_oracle(p)[0] == 9


def test_read_errors_are_loud(tmp_path):
    # a missing path must raise FileNotFoundError, not parse as text
    with pytest.raises(FileNotFoundError):
        read_dimacs(tmp_path / "no_such_file.max")
    # a direct (s, t) arc has no slot in the excess/sink_cap form
    with pytest.raises(NotImplementedError):
        read_dimacs("c x\np max 3 1\nn 2 s\nn 3 t\na 2 3 5\n")


def test_dimacs_instance_solves_end_to_end(tmp_path):
    p = random_sparse(16, 30, seed=11)
    path = tmp_path / "solve.max"
    write_dimacs(p, path)
    q = read_dimacs(path)
    want, _ = maxflow_oracle(q)
    res = solve_mincut(q, num_regions=3, config=SweepConfig(method="ard"))
    assert res.flow_value == want
