"""The ``Solver`` session front-end: prepared handles, unified routes,
compile-cache accounting.

Everything here is about the session plumbing — warm-start semantics have
their own suite (tests/test_warmstart.py), legacy-shim equivalence its own
(tests/test_api_compat.py).
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.core import (Solver, SolverCacheInfo, SolverOptions, SweepConfig,
                        grid_partition, solve_mincut)
from repro.data.grids import random_sparse, synthetic_grid
from repro.kernels.ref import maxflow_oracle


def _instance(g=10, seed=0):
    p = synthetic_grid(g, g, connectivity=8, strength=150, seed=seed)
    return p, grid_partition((g, g), (2, 2))


def test_options_absorb_sweep_config():
    cfg = SweepConfig(method="prd", engine_backend="pallas",
                      engine_chunk_iters=4, device_resident=True,
                      host_sync_every=3)
    opts = SolverOptions.from_sweep_config(cfg, num_regions=9, check=False)
    assert opts.sweep_config() == cfg
    assert opts.num_regions == 9 and opts.check is False
    # every SweepConfig field exists on SolverOptions (nothing silently
    # dropped when new sweep knobs appear)
    sw = {f.name for f in dataclasses.fields(SweepConfig)}
    so = {f.name for f in dataclasses.fields(SolverOptions)}
    assert sw <= so


def test_options_validation():
    with pytest.raises(AssertionError):
        SolverOptions(warm_labels="sometimes")
    with pytest.raises(AssertionError):
        SolverOptions(exchange="psum")
    with pytest.raises(AssertionError):
        SolverOptions(method="bfs")


def test_prepare_solve_matches_one_shot():
    p, part = _instance()
    want, _ = maxflow_oracle(p)
    for opts in [SolverOptions(), SolverOptions(method="prd"),
                 SolverOptions(device_resident=True)]:
        legacy = solve_mincut(p, part=part, config=opts.sweep_config())
        res = Solver(opts).prepare(p, part).solve()
        assert res.flow_value == legacy.flow_value == want
        np.testing.assert_array_equal(res.source_side, legacy.source_side)
        np.testing.assert_array_equal(np.asarray(res.state.d),
                                      np.asarray(legacy.state.d))
        assert res.stats.sweeps == legacy.stats.sweeps
        assert res.stats.engine_iters == legacy.stats.engine_iters
        assert res.stats.engine_launches == legacy.stats.engine_launches
        assert res.stats.scope == "instance"


def test_solver_solve_is_prepare_solve():
    p, part = _instance(seed=3)
    s = Solver(SolverOptions())
    assert s.solve(p, part).flow_value == \
        s.prepare(p, part).solve().flow_value


def test_second_solved_handle_is_warm_noop():
    """Re-solving an untouched warm handle costs zero sweeps and returns
    the same flow."""
    p, part = _instance(seed=1)
    h = Solver(SolverOptions()).prepare(p, part)
    r1 = h.solve()
    r2 = h.solve()
    assert r2.flow_value == r1.flow_value
    assert r2.stats.sweeps == 0


def test_cache_info_zero_retrace_same_shape(fresh_compile_cache):
    """A second same-shape problem through the session reuses every
    compiled program.  (fresh_compile_cache clears the process-global jit
    caches, so the first solve is deterministically a miss under any test
    ordering.)"""
    s = Solver(SolverOptions())
    p1, part = _instance(seed=4)
    s.prepare(p1, part).solve()
    info1 = s.cache_info()
    assert info1.misses == 1 and info1.hits == 0
    p2, _ = _instance(seed=5)
    s.prepare(p2, part).solve()
    info2 = s.cache_info()
    assert info2.traces == info1.traces
    assert info2.hits == info1.hits + 1
    assert isinstance(info2, SolverCacheInfo)


def test_solve_many_handles_problems_and_scope():
    s = Solver(SolverOptions())
    probs = [synthetic_grid(8, 8, seed=i) for i in range(2)] \
        + [random_sparse(14, 28, seed=7)]
    handles = [s.prepare(probs[0]), probs[1], probs[2]]   # mixed input kinds
    res = s.solve_many(handles)
    for p, r in zip(probs, res):
        assert r.flow_value == maxflow_oracle(p)[0]
        assert r.stats.scope == "batch"
    # the prepared handle came back warm
    assert handles[0].warm
    # per-instance launch/sync fields carry the globals of their batch
    batch_launches = {bs.engine_launches for bs in s.last_batch_stats}
    assert all(r.stats.engine_launches in batch_launches for r in res)


def test_solve_many_keeps_handles_warm():
    s = Solver(SolverOptions())
    probs = [synthetic_grid(8, 8, seed=i) for i in (11, 12)]
    hs = [s.prepare(p) for p in probs]
    res1 = s.solve_many(hs)
    # untouched warm handles re-enter the batched driver converged
    res2 = s.solve_many(hs)
    for r1, r2 in zip(res1, res2):
        assert r2.flow_value == r1.flow_value
        assert r2.stats.sweeps == 0
    for h in hs:
        assert h.warm


def test_solve_many_warm_after_update_matches_cold():
    s = Solver(SolverOptions())
    probs = [synthetic_grid(8, 8, seed=i) for i in (21, 22, 23)]
    hs = [s.prepare(p) for p in probs]
    s.solve_many(hs)
    rng = np.random.RandomState(2)
    m = len(hs[1].problem.edges)
    idx = rng.choice(m, size=4, replace=False)
    hs[1].update(arcs=idx,
                 cap_fwd=rng.randint(0, 301, size=4).astype(np.int32))
    res = s.solve_many(hs)
    for h, r in zip(hs, res):
        cold = solve_mincut(h.problem, part=h.part)
        assert r.flow_value == cold.flow_value


def test_solve_many_rejections():
    s = Solver(SolverOptions(parallel=False))
    with pytest.raises(ValueError):
        s.solve_many([_instance()[0]])
    s2 = Solver(SolverOptions(use_boundary_relabel=True))
    with pytest.raises(ValueError):
        s2.solve_many([_instance()[0]])
    # a handle from another session is refused
    a, b = Solver(SolverOptions()), Solver(SolverOptions())
    h = a.prepare(_instance()[0])
    with pytest.raises(ValueError):
        b.solve_many([h])


def test_reset_returns_to_cold():
    p, part = _instance(seed=6)
    s = Solver(SolverOptions())
    h = s.prepare(p, part)
    h.solve()
    rng = np.random.RandomState(8)
    idx = rng.choice(len(p.edges), size=5, replace=False)
    h.update(arcs=idx, cap_fwd=rng.randint(0, 301, size=5).astype(np.int32))
    h.reset()
    assert not h.warm and int(h._flow_offset) == 0
    res = h.solve()
    cold = solve_mincut(h.problem, part=part)
    assert res.flow_value == cold.flow_value
    assert res.stats.sweeps == cold.stats.sweeps


def test_sharded_route_unified_result():
    """handle.solve(mesh=...) returns the same MincutResult shape with the
    sharded driver underneath (1-device mesh: plumbing, not scaling)."""
    p, part = _instance(seed=9)
    mesh = jax.make_mesh((1,), ("regions",))
    s = Solver(SolverOptions())
    h = s.prepare(p, part)
    res = h.solve(mesh=mesh)
    ref = solve_mincut(p, part=part)
    assert res.flow_value == ref.flow_value
    assert res.stats.scope == "instance"
    assert res.stats.sweeps >= 1 and res.stats.host_syncs >= 1
    # fields the sharded driver cannot observe are None, not fake zeros
    assert res.stats.engine_iters is None
    assert res.stats.engine_launches is None
    # second sharded solve through the session: memoized program, no trace
    traces = s.cache_info().traces
    h2 = s.prepare(_instance(seed=10)[0], part)
    h2.solve(mesh=mesh)
    assert s.cache_info().traces == traces
