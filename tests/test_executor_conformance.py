"""Cross-executor conformance: every route is THE SAME algorithm.

One parameterized matrix — executor/driver × ard/prd × engine backend
(xla-unfused / xla-fused / pallas-fused) — asserting bit-exact flow,
labels, residuals and statistics against the scalar host-loop oracle
(``sweep.solve``, the paper's Alg. 1/2 reference driver), which is itself
checked against the Edmonds–Karp oracle.  This replaces the per-driver
bit-exactness matrices that used to live in test_sweep_driver.py /
test_batch.py (their pinned driver regressions remain there).

Also here, because they are the executor interface's contract:

* the capability matrix — every (feature, executor) pair either validates
  or fails fast with one consistent ``UnsupportedFeatureError`` (a
  ``ValueError`` and a ``NotImplementedError``) at every front end;
* the mid-solve invariant check — the preflow/labeling/conservation
  invariants of ``tests/invariants.py`` hold at every sweep boundary,
  attached through ``sweep.solve``'s ``on_sweep`` stats hook.
"""

import dataclasses
from functools import lru_cache

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import invariants
from repro.core import (SweepConfig, Solver, SolverOptions, build,
                        grid_partition, solve_mincut, solve_mincut_batch)
from repro.core.executor import (BatchedExecutor, Capabilities,
                                 LocalExecutor, ShardedExecutor,
                                 StreamingExecutor,
                                 UnsupportedFeatureError, required_features)
from repro.core import sweep as sweep_mod
from repro.core.graph import init_labels
from repro.data.grids import synthetic_grid
from repro.kernels.ref import maxflow_oracle

P_GRID = (10, 10)
P_REGIONS = (2, 2)

# engine configurations every executor must agree under: the unfused
# two-phase engine, the fused chunked XLA engine, the fused pallas kernel
BACKENDS = [("xla", None), ("xla", 8), ("pallas", 8)]
BACKEND_IDS = ["xla-unfused", "xla-fused", "pallas-fused"]


@lru_cache(maxsize=None)
def _instance(seed=0):
    p = synthetic_grid(*P_GRID, connectivity=8, strength=150, seed=seed)
    part = grid_partition(P_GRID, P_REGIONS)
    return p, part


@lru_cache(maxsize=None)
def _cfg(method, backend, chunk, **kw) -> SweepConfig:
    return SweepConfig(method=method, engine_backend=backend,
                       engine_chunk_iters=chunk)


@lru_cache(maxsize=None)
def _oracle(method, backend, chunk, seed=0):
    """The scalar host-loop solve — the conformance reference — plus the
    Edmonds–Karp flow value it must (and does) reproduce."""
    p, part = _instance(seed)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, part=part, config=_cfg(method, backend, chunk))
    assert res.flow_value == want, "host-loop oracle off the true maxflow"
    assert res.stats.host_syncs == res.stats.sweeps + 1
    return res, want


def _assert_state_bitexact(ref, got, msg=""):
    assert got.flow_value == ref.flow_value, msg
    np.testing.assert_array_equal(np.asarray(ref.state.d),
                                  np.asarray(got.state.d), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(ref.state.cf),
                                  np.asarray(got.state.cf), err_msg=msg)


@pytest.mark.parametrize("backend,chunk", BACKENDS, ids=BACKEND_IDS)
@pytest.mark.parametrize("method", ["ard", "prd"])
def test_local_device_resident_conformance(method, backend, chunk):
    """LocalExecutor, device-resident driver: everything observable equals
    the host loop — state, counters, curves — with one host sync."""
    p, part = _instance()
    ref, _ = _oracle(method, backend, chunk)
    cfg = dataclasses.replace(_cfg(method, backend, chunk),
                              device_resident=True)
    got = solve_mincut(p, part=part, config=cfg)
    _assert_state_bitexact(ref, got, f"{method}/{backend}/{chunk}")
    s_ref, s_got = ref.stats, got.stats
    assert (s_got.sweeps, s_got.engine_iters, s_got.engine_launches,
            s_got.regions_discharged, s_got.page_bytes,
            s_got.boundary_bytes) == \
           (s_ref.sweeps, s_ref.engine_iters, s_ref.engine_launches,
            s_ref.regions_discharged, s_ref.page_bytes,
            s_ref.boundary_bytes)
    assert s_got.flow_curve == s_ref.flow_curve
    assert s_got.active_curve == s_ref.active_curve
    assert s_got.host_syncs == 1


@pytest.mark.parametrize("backend,chunk", BACKENDS, ids=BACKEND_IDS)
@pytest.mark.parametrize("method", ["ard", "prd"])
def test_batched_executor_conformance(method, backend, chunk):
    """BatchedExecutor: every instance of a 2-instance batch is bit-equal
    to its standalone solve; launch/sync counters are global (scope
    "batch"), with the fused pallas path sharing the launch stream."""
    p, part = _instance(0)
    p2, _ = _instance(1)
    ref, _ = _oracle(method, backend, chunk, seed=0)
    ref2, _ = _oracle(method, backend, chunk, seed=1)
    cfg = _cfg(method, backend, chunk)
    got = solve_mincut_batch([p, p2], parts=[part, part], config=cfg)
    for single, batched in ((ref, got[0]), (ref2, got[1])):
        _assert_state_bitexact(single, batched,
                               f"{method}/{backend}/{chunk}")
        bs, ss = batched.stats, single.stats
        assert bs.scope == "batch"
        assert bs.sweeps == ss.sweeps
        assert bs.engine_iters == ss.engine_iters
        assert bs.regions_discharged == ss.regions_discharged
        assert bs.page_bytes == ss.page_bytes
        assert bs.boundary_bytes == ss.boundary_bytes
        assert bs.host_syncs == 1
    if backend == "pallas":
        # the batch shares one grid=(B, K) launch stream: strictly fewer
        # kernel launches than the instances dispatched separately
        assert got[0].stats.engine_launches < \
            ref.stats.engine_launches + ref2.stats.engine_launches


@pytest.mark.parametrize("device_resident", [False, True],
                         ids=["host", "device"])
@pytest.mark.parametrize("backend,chunk", BACKENDS, ids=BACKEND_IDS)
@pytest.mark.parametrize("method", ["ard", "prd"])
def test_sharded_executor_conformance(method, backend, chunk,
                                      device_resident):
    """ShardedExecutor (1-device mesh: conformance, not scaling): flow,
    labels, residuals and sweep count equal the host-loop oracle; the
    multi-device regressions live in test_multidevice.py."""
    p, part = _instance()
    ref, _ = _oracle(method, backend, chunk)
    mesh = jax.make_mesh((1,), ("regions",))
    cfg = dataclasses.replace(_cfg(method, backend, chunk),
                              device_resident=device_resident)
    opts = SolverOptions.from_sweep_config(cfg)
    got = Solver(opts).prepare(p, part).solve(mesh=mesh)
    _assert_state_bitexact(ref, got,
                           f"{method}/{backend}/{chunk}/{device_resident}")
    assert got.stats.sweeps == ref.stats.sweeps
    # the sharded route does not observe engine dispatches
    assert got.stats.engine_iters is None
    assert got.stats.engine_launches is None
    assert got.stats.host_syncs == \
        (1 if device_resident else ref.stats.sweeps)


@pytest.mark.parametrize("backend,chunk", BACKENDS, ids=BACKEND_IDS)
@pytest.mark.parametrize("method", ["ard", "prd"])
def test_streaming_executor_conformance(method, backend, chunk):
    """StreamingExecutor: staging regions through the disk spill pool one
    at a time is bit-exact with the all-resident sequential host loop —
    flow, labels, residuals, sweep count and engine counters — while the
    stats additionally account the staged traffic and |B|."""
    p, part = _instance()
    want, _ = maxflow_oracle(p)
    cfg = dataclasses.replace(_cfg(method, backend, chunk),
                              parallel=False, use_global_gap=False)
    ref = solve_mincut(p, part=part, config=cfg)
    assert ref.flow_value == want
    got = Solver(SolverOptions.from_sweep_config(
        cfg, streaming=True)).prepare(p, part).solve()
    _assert_state_bitexact(ref, got, f"streaming/{method}/{backend}/{chunk}")
    s_ref, s_got = ref.stats, got.stats
    assert s_got.sweeps == s_ref.sweeps
    assert s_got.engine_iters == s_ref.engine_iters
    assert s_got.regions_discharged == s_ref.regions_discharged
    assert s_got.flow_curve == s_ref.flow_curve
    assert s_got.active_curve == s_ref.active_curve
    # comms accounting: |B| is on every route; the staged-bytes ledgers
    # are the streaming route's own contribution
    assert s_got.num_boundary == s_ref.num_boundary \
        == ref.meta.num_boundary
    assert s_got.staged_in_bytes > 0 and s_got.staged_out_bytes > 0
    assert s_ref.staged_in_bytes == 0 and s_ref.staged_out_bytes == 0
    invariants.assert_sweep_bound(ref.meta, s_got, ard=method == "ard")


# --------------------------------------------------------------------------
# capability matrix: one consistent fail-fast surface
# --------------------------------------------------------------------------

FEATURE_CFG = {
    "parallel": dict(parallel=True),
    "sequential": dict(parallel=False),
    "boundary_relabel": dict(use_boundary_relabel=True),
    "partial_discharge": dict(partial_discharge=True),
    "global_gap": dict(use_global_gap=True),
}
ALL_EXECUTORS = [LocalExecutor, BatchedExecutor, ShardedExecutor,
                 StreamingExecutor]


def test_required_features_maps_every_validated_flag():
    seq_all = SweepConfig(parallel=False, use_boundary_relabel=True,
                          partial_discharge=True, use_global_gap=True)
    assert set(required_features(seq_all)) == set(FEATURE_CFG) - {"parallel"}
    assert required_features(
        SweepConfig(use_global_gap=False)) == ("parallel",)
    assert required_features(
        SweepConfig(parallel=False, use_global_gap=False)) == ("sequential",)


@pytest.mark.parametrize("executor", ALL_EXECUTORS,
                         ids=lambda e: e.name)
@pytest.mark.parametrize("feature", sorted(FEATURE_CFG))
def test_capability_matrix(executor, feature):
    """Every (feature, executor) pair: supported configs validate,
    unsupported ones raise the one consistent error.

    A feature's probe config can require more than the probed feature
    (e.g. boundary_relabel rides the default parallel sweep), so the
    expected rejection is the FIRST flag of ``required_features`` the
    executor lacks — validate's documented fail-fast order."""
    cfg = SweepConfig(**{"use_global_gap": False, **FEATURE_CFG[feature]})
    req = required_features(cfg)
    assert feature in req
    unsupported = [f for f in req
                   if not getattr(executor.capabilities, f)]
    if not unsupported:
        executor.validate(cfg)          # must not raise
    else:
        with pytest.raises(UnsupportedFeatureError) as ei:
            executor.validate(cfg)
        err = ei.value
        # one consistent surface: executor name + feature in the message,
        # catchable as the historical ValueError AND as the precise
        # NotImplementedError
        assert isinstance(err, ValueError)
        assert isinstance(err, NotImplementedError)
        assert err.executor == executor.name
        assert err.feature == unsupported[0]
        assert executor.name in str(err) and err.feature in str(err)


def test_capability_declarations_pin_the_support_matrix():
    """The support matrix is part of the public contract — changing it is
    a deliberate act, not a refactor side effect."""
    assert LocalExecutor.capabilities == Capabilities(batched=False)
    assert BatchedExecutor.capabilities == Capabilities(
        sequential=False, boundary_relabel=False, batched=True,
        host_loop=False)
    assert ShardedExecutor.capabilities == Capabilities(
        sequential=False, boundary_relabel=False)
    assert StreamingExecutor.capabilities == Capabilities(
        parallel=False, boundary_relabel=False, global_gap=False,
        batched=False, device_resident=False)


def test_unsupported_combos_fail_fast_at_every_front_end():
    """The same config is rejected with the same error type no matter
    which entry point routes it to an incapable executor."""
    p, part = _instance()
    mesh = jax.make_mesh((1,), ("regions",))
    for bad in (SweepConfig(parallel=False),
                SweepConfig(use_boundary_relabel=True)):
        with pytest.raises(UnsupportedFeatureError):
            solve_mincut_batch([p], parts=[part], config=bad)
        with pytest.raises(UnsupportedFeatureError):
            Solver(SolverOptions.from_sweep_config(bad)).solve_many(
                [p], parts=[part])
        # the sharded route used to silently ignore these flags; now it
        # refuses them at the interface
        with pytest.raises(UnsupportedFeatureError):
            Solver(SolverOptions.from_sweep_config(bad)).prepare(
                p, part).solve(mesh=mesh)


# --------------------------------------------------------------------------
# mid-solve invariants at every sweep boundary (the stats hook)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["ard", "prd"])
def test_invariants_hold_at_every_sweep_boundary(method):
    """Preflow validity, labeling validity and flow conservation hold at
    every sweep boundary of the host-loop driver, via the on_sweep hook."""
    p, part = _instance()
    meta, state, _ = build(p, np.asarray(part))
    state = init_labels(meta, state)
    total0 = invariants.preflow_total(state)
    seen = []

    def on_sweep(st, sweeps_done):
        where = f"after sweep {sweeps_done} ({method})"
        invariants.assert_valid_preflow(meta, st, where)
        invariants.assert_valid_labeling(meta, st, ard=method == "ard",
                                         where=where)
        invariants.assert_flow_conservation(meta, st, total0, where)
        seen.append(sweeps_done)

    cfg = SweepConfig(method=method)
    _st, stats = sweep_mod.solve(meta, state, cfg, on_sweep=on_sweep)
    assert seen == list(range(1, stats.sweeps + 1))
    assert stats.sweeps >= 1


def test_on_sweep_needs_the_host_loop():
    p, part = _instance()
    meta, state, _ = build(p, np.asarray(part))
    with pytest.raises(ValueError):
        sweep_mod.solve(meta, init_labels(meta, state),
                        SweepConfig(device_resident=True),
                        on_sweep=lambda st, i: None)
