"""Property-based tests (hypothesis): solver == oracle on arbitrary sparse
networks; the paper's structural invariants hold after every sweep."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import SweepConfig, build, init_labels, solve_mincut
from repro.core.graph import Problem
from repro.core.labels import gather_ghost_labels
from repro.core.sweep import num_active, parallel_sweep
from repro.core.graph import intra_mask
from repro.kernels.ref import maxflow_oracle


@st.composite
def problems(draw):
    n = draw(st.integers(3, 12))
    m = draw(st.integers(0, min(20, n * (n - 1) // 2)))
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    pairs = set()
    while len(pairs) < m:
        u, v = rng.randint(0, n, 2)
        if u != v and (u, v) not in pairs and (v, u) not in pairs:
            pairs.add((u, v))
    edges = np.asarray(sorted(pairs), np.int64).reshape(-1, 2)
    return Problem(
        num_vertices=n, edges=edges,
        cap_fwd=rng.randint(0, 60, size=len(edges)).astype(np.int32),
        cap_bwd=rng.randint(0, 60, size=len(edges)).astype(np.int32),
        excess=rng.randint(0, 40, size=n).astype(np.int32),
        sink_cap=rng.randint(0, 40, size=n).astype(np.int32))


@settings(max_examples=25, deadline=None)
@given(problems(), st.integers(1, 4), st.booleans())
def test_flow_matches_oracle(p, k, use_ard):
    want, _ = maxflow_oracle(p)
    cfg = SweepConfig(method="ard" if use_ard else "prd")
    res = solve_mincut(p, num_regions=min(k, p.num_vertices), config=cfg)
    assert res.flow_value == want


def _labeling_valid_ard(meta, state):
    """Paper eq. (9)/(10): d(u) <= d(v) + [cross] on residual arcs, capped."""
    ghost_d = gather_ghost_labels(state)
    intra = intra_mask(state)
    d = state.d
    du = jnp.broadcast_to(d[:, :, None], state.cf.shape)
    resid = (state.cf > 0) & state.emask
    at_cap = du >= meta.d_inf_ard
    ok_intra = ~resid | ~intra | (du <= ghost_d) | at_cap
    cross = state.emask & ~intra
    ok_cross = ~resid | ~cross | (du <= ghost_d + 1) | at_cap
    # sink validity: sink residual => d(u) <= 1... for ARD: d(u) <= 0 + 0
    ok_sink = (state.sink_cf == 0) | (d <= 0) | (d >= meta.d_inf_ard) | \
        ~state.vmask
    return bool(jnp.all(ok_intra & ok_cross)) and bool(jnp.all(ok_sink))


@settings(max_examples=10, deadline=None)
@given(problems(), st.integers(2, 3))
def test_sweep_invariants(p, k):
    """After every parallel ARD sweep: labels valid, monotone; flow sane."""
    from repro.core.partition import block_partition

    part = block_partition(p.num_vertices, k)
    meta, state, _ = build(p, part)
    state = init_labels(meta, state)
    cfg = SweepConfig(method="ard", use_global_gap=False)
    prev_d = np.asarray(state.d)
    total0 = int(jnp.sum(jnp.where(state.vmask, state.excess, 0))) + \
        int(state.flow_to_t)
    for sweep in range(12):
        if int(num_active(meta, state, cfg)) == 0:
            break
        state, _, _ = parallel_sweep(meta, state, cfg,
                                     jnp.asarray(sweep, jnp.int32))
        d = np.asarray(state.d)
        assert (d >= prev_d).all(), "labels must be monotone"
        prev_d = d
        assert _labeling_valid_ard(meta, state), "labeling must stay valid"
        # conservation: excess + delivered flow is invariant
        total = int(jnp.sum(jnp.where(state.vmask, state.excess, 0))) + \
            int(state.flow_to_t)
        assert total == total0, "flow mass must be conserved"
        assert (np.asarray(state.cf) >= 0).all(), "residuals non-negative"


@settings(max_examples=10, deadline=None)
@given(problems())
def test_reduction_sound(p):
    from repro.core import region_reduction
    from repro.core.partition import block_partition

    part = block_partition(p.num_vertices, 2)
    meta, state, layout = build(p, part)
    red = region_reduction(meta, state)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, part=part)
    src = res.source_side
    sk = layout.to_flat(np.asarray(red.strong_sink))
    ss = layout.to_flat(np.asarray(red.strong_source))
    assert not (src & sk).any(), "strong sink on source side"
    assert (src[ss]).all() or not ss.any(), "strong source on sink side"
