"""Property-based tests (hypothesis): solver == oracle on arbitrary sparse
networks — through EVERY region executor — and the paper's structural
invariants hold after every sweep (via tests/invariants.py)."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

import invariants
from repro.core import (Solver, SolverOptions, SweepConfig, build,
                        init_labels, solve_mincut, solve_mincut_batch)
from repro.core.graph import Problem
from repro.core.partition import block_partition
from repro.core.sweep import num_active, parallel_sweep
from repro.kernels.ref import maxflow_oracle


@st.composite
def problems(draw, max_n=12, max_m=20, max_cap=60):
    n = draw(st.integers(3, max_n))
    m = draw(st.integers(0, min(max_m, n * (n - 1) // 2)))
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    pairs = set()
    while len(pairs) < m:
        u, v = rng.randint(0, n, 2)
        if u != v and (u, v) not in pairs and (v, u) not in pairs:
            pairs.add((u, v))
    edges = np.asarray(sorted(pairs), np.int64).reshape(-1, 2)
    return Problem(
        num_vertices=n, edges=edges,
        cap_fwd=rng.randint(0, max_cap, size=len(edges)).astype(np.int32),
        cap_bwd=rng.randint(0, max_cap, size=len(edges)).astype(np.int32),
        excess=rng.randint(0, 40, size=n).astype(np.int32),
        sink_cap=rng.randint(0, 40, size=n).astype(np.int32))


@settings(max_examples=25, deadline=None)
@given(problems(), st.integers(1, 4), st.booleans())
def test_flow_matches_oracle(p, k, use_ard):
    want, _ = maxflow_oracle(p)
    cfg = SweepConfig(method="ard" if use_ard else "prd")
    res = solve_mincut(p, num_regions=min(k, p.num_vertices), config=cfg)
    assert res.flow_value == want


# every route through the one generic executor loop: local host loop,
# local device-resident, batched (1-instance bucket), sharded (1-device
# mesh).  Shrinking-friendly small bounds: shapes stay tiny so a failing
# example minimizes fast.
EXECUTOR_ROUTES = ("host", "device", "batched", "sharded")


def _solve_via(route, p, part, cfg):
    if route == "batched":
        return solve_mincut_batch([p], parts=[part], config=cfg)[0]
    if route == "sharded":
        mesh = jax.make_mesh((1,), ("regions",))
        s = Solver(SolverOptions.from_sweep_config(cfg))
        return s.prepare(p, part).solve(mesh=mesh)
    if route == "device":
        cfg = SweepConfig(**{**cfg.__dict__, "device_resident": True})
    return solve_mincut(p, part=part, config=cfg)


@settings(max_examples=12, deadline=None)
@given(problems(max_n=9, max_m=14), st.sampled_from(EXECUTOR_ROUTES),
       st.booleans())
def test_flow_matches_oracle_every_executor(p, route, use_ard):
    want, _ = maxflow_oracle(p)
    cfg = SweepConfig(method="ard" if use_ard else "prd")
    part = block_partition(p.num_vertices, min(2, p.num_vertices))
    res = _solve_via(route, p, part, cfg)
    assert res.flow_value == want, route


@settings(max_examples=10, deadline=None)
@given(problems(max_n=8, max_m=12, max_cap=30), st.data())
def test_warm_resolve_after_delta_matches_oracle(p, data):
    """Warm-start re-solve after a random capacity delta: the session
    continues from the solved preflow and must land on the updated
    problem's true maxflow."""
    s = Solver(SolverOptions(num_regions=2))
    h = s.prepare(p)
    assert h.solve().flow_value == maxflow_oracle(p)[0]
    m, n = len(p.edges), p.num_vertices
    if m:
        h.update(cap_fwd=np.asarray(
            data.draw(st.lists(st.integers(0, 30), min_size=m, max_size=m)),
            np.int32))
    h.update(sink_cap=np.asarray(
        data.draw(st.lists(st.integers(0, 30), min_size=n, max_size=n)),
        np.int32))
    want, _ = maxflow_oracle(h.problem)
    assert h.solve().flow_value == want


@settings(max_examples=8, deadline=None)
@given(problems(max_n=8, max_m=12, max_cap=30),
       problems(max_n=8, max_m=12, max_cap=30), st.data())
def test_batched_warm_resolve_matches_oracle(p1, p2, data):
    """A 2-instance batch through the batched executor, then a random
    capacity delta on one instance and a warm batched re-solve: both
    instances must track their own oracle throughout."""
    s = Solver(SolverOptions(num_regions=2))
    h1, h2 = s.prepare(p1), s.prepare(p2)
    r = s.solve_many([h1, h2])
    assert r[0].flow_value == maxflow_oracle(p1)[0]
    assert r[1].flow_value == maxflow_oracle(p2)[0]
    m = len(p1.edges)
    if m:
        h1.update(cap_fwd=np.asarray(
            data.draw(st.lists(st.integers(0, 30), min_size=m, max_size=m)),
            np.int32))
    r2 = s.solve_many([h1, h2])       # h1 warm after the delta, h2 warm
    assert r2[0].flow_value == maxflow_oracle(h1.problem)[0]
    assert r2[1].flow_value == maxflow_oracle(p2)[0]


@settings(max_examples=10, deadline=None)
@given(problems(), st.integers(2, 3))
def test_sweep_invariants(p, k):
    """After every parallel ARD sweep: labels valid, monotone; flow sane
    (the checkers live in tests/invariants.py, shared with the
    conformance suite's sweep-boundary hook)."""
    part = block_partition(p.num_vertices, k)
    meta, state, _ = build(p, part)
    state = init_labels(meta, state)
    cfg = SweepConfig(method="ard", use_global_gap=False)
    prev_d = np.asarray(state.d)
    total0 = invariants.preflow_total(state)
    for sweep in range(12):
        if int(num_active(meta, state, cfg)) == 0:
            break
        state, _, _ = parallel_sweep(meta, state, cfg,
                                     jnp.asarray(sweep, jnp.int32))
        d = np.asarray(state.d)
        assert (d >= prev_d).all(), "labels must be monotone"
        prev_d = d
        invariants.assert_valid_preflow(meta, state)
        invariants.assert_valid_labeling(meta, state, ard=True)
        invariants.assert_flow_conservation(meta, state, total0)


@settings(max_examples=10, deadline=None)
@given(problems())
def test_reduction_sound(p):
    from repro.core import region_reduction

    part = block_partition(p.num_vertices, 2)
    meta, state, layout = build(p, part)
    red = region_reduction(meta, state)
    want, _ = maxflow_oracle(p)
    res = solve_mincut(p, part=part)
    src = res.source_side
    sk = layout.to_flat(np.asarray(red.strong_sink))
    ss = layout.to_flat(np.asarray(red.strong_source))
    assert not (src & sk).any(), "strong sink on source side"
    assert (src[ss]).all() or not ss.any(), "strong source on sink side"
