"""Pinned regressions of the device-resident sweep driver.

The host-vs-device bit-exactness MATRIX (executor × ard/prd × backend ×
fused/unfused × host/device) lives in tests/test_executor_conformance.py;
this file keeps the driver-specific edge cases: a mid-solve ``max_sweeps``
cap, the stats ring overflow path (where only the curve tails survive by
design), the one-launch-per-sweep acceptance headline, sequential sweeps
under both drivers, and the converged-at-entry degenerate solve.
"""

import dataclasses

import numpy as np

from repro.core import SweepConfig, build, grid_partition, init_labels, solve_mincut
from repro.core.sweep import solve
from repro.data.grids import synthetic_grid
from repro.kernels.ref import maxflow_oracle

P_GRID = (10, 10)
P_REGIONS = (2, 2)


def _instance():
    p = synthetic_grid(*P_GRID, connectivity=8, strength=150, seed=0)
    part = grid_partition(P_GRID, P_REGIONS)
    return p, part


def _stat_tuple(s):
    return (s.sweeps, s.engine_iters, s.engine_launches,
            s.regions_discharged, s.page_bytes, s.boundary_bytes)


def test_max_sweeps_cap_mid_solve():
    """A sweep cap that stops the solve before convergence must leave both
    drivers in the same (non-converged) state with the same curves."""
    p, part = _instance()
    meta, state, _ = build(p, np.asarray(
        grid_partition(P_GRID, P_REGIONS)))
    full = solve_mincut(p, part=part, config=SweepConfig(method="prd"))
    cap = max(1, full.stats.sweeps - 1)       # stops mid-solve
    base = SweepConfig(method="prd", max_sweeps=cap)
    st_h, stats_h = solve(meta, init_labels(meta, state), base)
    st_d, stats_d = solve(meta, init_labels(meta, state),
                          dataclasses.replace(base, device_resident=True))
    assert stats_h.sweeps == stats_d.sweeps == cap
    np.testing.assert_array_equal(np.asarray(st_h.d), np.asarray(st_d.d))
    np.testing.assert_array_equal(np.asarray(st_h.cf), np.asarray(st_d.cf))
    assert int(st_h.flow_to_t) == int(st_d.flow_to_t)
    assert _stat_tuple(stats_h) == _stat_tuple(stats_d)
    assert stats_h.flow_curve == stats_d.flow_curve
    # cap hit: no terminal 0 is recorded by either driver
    assert stats_h.active_curve == stats_d.active_curve
    assert len(stats_d.active_curve) == cap
    assert stats_d.host_syncs == 1


def test_stats_ring_overflow_keeps_tail():
    """When a solve runs longer than the ring, counters stay exact and the
    curves keep their last ``stats_ring_size`` entries."""
    p, part = _instance()
    host = solve_mincut(p, part=part, config=SweepConfig(method="prd"))
    sweeps = host.stats.sweeps
    assert sweeps >= 3, "instance too easy to exercise the ring"
    ring = 2
    cfg = SweepConfig(method="prd", device_resident=True,
                      stats_ring_size=ring)
    dev = solve_mincut(p, part=part, config=cfg)
    assert _stat_tuple(dev.stats) == _stat_tuple(host.stats)
    assert dev.stats.flow_curve == host.stats.flow_curve[-ring:]
    # active_curve: ring tail of the pre-sweep counts + the terminal 0
    assert dev.stats.active_curve == \
        host.stats.active_curve[sweeps - ring:sweeps] + [0]


def test_prd_pallas_single_launch_per_sweep():
    """The acceptance headline: with the grid-over-regions kernel and a
    chunk larger than any discharge, a device-resident PRD solve dispatches
    exactly ONE kernel launch per parallel sweep (vs K per-region launch
    chains) and syncs to the host exactly once."""
    p, part = _instance()
    want, _ = maxflow_oracle(p)
    cfg = SweepConfig(method="prd", engine_backend="pallas",
                      engine_chunk_iters=1 << 20, device_resident=True)
    res = solve_mincut(p, part=part, config=cfg)
    assert res.flow_value == want
    assert res.stats.engine_launches == res.stats.sweeps
    assert res.stats.host_syncs == 1


def test_device_resident_converged_at_entry():
    """A problem with no active vertex solves in zero sweeps and one sync,
    with the same degenerate curves as the host loop."""
    from repro.data.grids import random_sparse

    p = random_sparse(6, 0, seed=0)
    p = dataclasses.replace(p, excess=np.zeros(6, np.int32))
    host = solve_mincut(p, num_regions=2, config=SweepConfig())
    dev = solve_mincut(p, num_regions=2,
                       config=SweepConfig(device_resident=True))
    assert host.flow_value == dev.flow_value == 0
    assert dev.stats.sweeps == host.stats.sweeps == 0
    assert dev.stats.active_curve == host.stats.active_curve == [0]
    assert dev.stats.flow_curve == host.stats.flow_curve == []
    assert dev.stats.host_syncs == 1
