"""Out-of-core streaming executor: store, build, equivalence, resume.

The conformance suite (test_executor_conformance.py) already pins the
streaming route bit-exact against the resident reference across methods
and engine backends; this file covers the subsystem's own moving parts —
the spill pool's versioning/eviction/prefetch, the shard-wise build, the
sharded DIMACS ingest, checkpoint/resume at sweep boundaries, and the
capability surface of ``StreamingExecutor``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (Solver, SolverOptions, StreamingExecutor, SweepConfig,
                        build, solve_mincut)
from repro.core.executor import UnsupportedFeatureError
from repro.core.graph import (REGION_FLOW_FIELDS, REGION_TOPO_FIELDS,
                              extract_region)
from repro.core.partition import block_partition
from repro.core.resilience import CheckpointPolicy
from repro.data.dimacs import read_dimacs, read_dimacs_sharded, write_dimacs
from repro.data.grids import random_sparse, synthetic_grid
from repro.kernels.ref import maxflow_oracle
from repro.stream import build_stream, solve_stream
from repro.stream.store import StreamStore


def _cfg(**kw):
    kw.setdefault("method", "ard")
    kw.setdefault("parallel", False)
    kw.setdefault("use_global_gap", False)
    return SweepConfig(**kw)


def _problem():
    return synthetic_grid(10, 12, connectivity=8, strength=120, seed=7)


def _part(p, k=4):
    return block_partition(p.num_vertices, k)


# --------------------------------------------------------------------------
# spill pool unit behavior
# --------------------------------------------------------------------------

def _tiny(v):
    return {"cf": np.full((2, 2), v, np.int32)}, \
        {"excess": np.full(3, v, np.int32)}


def test_store_versioning_eviction_and_prune(tmp_path):
    st = StreamStore(3, tmp_path / "pool", max_resident=2, prefetch=False)
    for r in range(3):
        topo, flow = _tiny(r)
        st.put_region(r, topo, flow)
    assert st.staged_in_bytes == 0          # population is setup, not traffic

    t0, f0 = st.load(0)
    st.load(1)
    assert st.disk_loads == 2
    st.load(2)                              # evicts LRU region 0
    assert st.evictions == 1
    st.load(0)                              # back from disk
    assert st.disk_loads == 4 and st.staged_in_bytes > 0

    # write-through versioning: writeback publishes v1, prunes v0
    st.writeback(0, {"excess": np.full(3, 42, np.int32)})
    assert st.versions[0] == 1
    state_dir = tmp_path / "pool" / "region_00000" / "state"
    assert (state_dir / "step_00000001").exists()
    assert not (state_dir / "step_00000000").exists()
    _, f = st.load(0)                       # resident entry was refreshed
    assert f["excess"][0] == 42

    # protect pins the checkpointed version against pruning
    st.protect(st.versions.copy())
    st.writeback(0, {"excess": np.full(3, 43, np.int32)})
    assert (state_dir / "step_00000001").exists()    # pinned
    assert (state_dir / "step_00000002").exists()    # current

    # attach rewinds to the protected set (the resume entry)
    st.attach(np.array([1, 0, 0]))
    _, f = st.load(0)
    assert f["excess"][0] == 42
    st.close()
    assert (tmp_path / "pool").exists()     # caller-owned dir survives close


def test_store_prefetch_counters(tmp_path):
    st = StreamStore(3, tmp_path / "pool", max_resident=1, prefetch=True)
    for r in range(3):
        st.put_region(r, *_tiny(r))
    st.load(0)
    st.prefetch(1)
    st.load(1)
    assert st.prefetch_hits == 1
    # a mispredicted prefetch is consumed, counted wasted, and the
    # requested region is re-read synchronously
    st.prefetch(2)
    st.load(0)
    assert st.prefetch_wasted == 1
    _, f = st.load(0)                       # still correct data
    assert f["excess"][0] == 0
    st.close()


# --------------------------------------------------------------------------
# shard-wise build == resident build, slab for slab
# --------------------------------------------------------------------------

def test_build_stream_slabs_match_resident_build():
    p = _problem()
    part = _part(p)
    cfg = _cfg()
    meta, state, _ = build(p, part)
    ss = build_stream(p, part, cfg, prefetch=False)
    assert ss.meta == meta
    for r in range(meta.num_regions):
        topo = extract_region(state, r, REGION_TOPO_FIELDS)
        flow = extract_region(state, r, REGION_FLOW_FIELDS)
        got_t, got_f = ss.store.load(r)
        for f in REGION_TOPO_FIELDS:
            np.testing.assert_array_equal(got_t[f], np.asarray(topo[f]), f)
        for f in REGION_FLOW_FIELDS:
            np.testing.assert_array_equal(got_f[f], np.asarray(flow[f]), f)
    ss.store.close()


# --------------------------------------------------------------------------
# eviction / prefetch do not change the math
# --------------------------------------------------------------------------

def _run(p, part, cfg, **kw):
    ss = build_stream(p, part, cfg, **kw)
    try:
        ss, stats = solve_stream(ss)
        return ss.bnd.flow_to_t, stats, \
            (ss.bnd.d_B.copy(), ss.bnd.e_B.copy()), ss.store
    finally:
        ss.store.close()


@pytest.mark.parametrize("method", ["ard", "prd"])
def test_single_resident_region_is_bit_exact(method):
    p = _problem()
    part = _part(p)
    cfg = _cfg(method=method)
    want, _ = maxflow_oracle(p)
    flow_all, stats_all, bnd_all, store_all = _run(
        p, part, cfg, max_resident_regions=4, prefetch=False)
    flow_one, stats_one, bnd_one, store_one = _run(
        p, part, cfg, max_resident_regions=1, prefetch=False)
    assert flow_all == flow_one == want
    assert stats_all.sweeps == stats_one.sweeps
    assert stats_all.flow_curve == stats_one.flow_curve
    np.testing.assert_array_equal(bnd_all[0], bnd_one[0])
    np.testing.assert_array_equal(bnd_all[1], bnd_one[1])
    assert store_one.evictions > store_all.evictions
    # a 1-resident run re-reads every staged region from disk
    assert stats_one.staged_in_bytes > stats_all.staged_in_bytes


def test_prefetch_on_off_equivalence():
    p = _problem()
    part = _part(p)
    cfg = _cfg()
    flow_on, stats_on, bnd_on, store_on = _run(
        p, part, cfg, max_resident_regions=1, prefetch=True)
    flow_off, stats_off, bnd_off, _ = _run(
        p, part, cfg, max_resident_regions=1, prefetch=False)
    assert flow_on == flow_off
    assert stats_on.sweeps == stats_off.sweeps
    assert stats_on.flow_curve == stats_off.flow_curve
    np.testing.assert_array_equal(bnd_on[0], bnd_off[0])
    assert store_on.prefetch_hits > 0


# --------------------------------------------------------------------------
# checkpoint/resume at sweep boundaries
# --------------------------------------------------------------------------

def test_checkpoint_resume_is_bit_exact(tmp_path):
    p = _problem()
    part = _part(p)
    pool, ckdir = tmp_path / "pool", tmp_path / "ck"

    _, ref_stats, ref_bnd, _ = _run(p, part, _cfg(), prefetch=False)
    assert ref_stats.converged and ref_stats.sweeps > 4

    # interrupted run: sweep budget runs out mid-solve, checkpointing
    cut = _cfg(max_sweeps=3)
    ss = build_stream(p, part, cut, spill_dir=pool, prefetch=False)
    _, stats1 = solve_stream(
        ss, checkpoint=CheckpointPolicy(directory=ckdir, every=1))
    assert not stats1.converged and stats1.sweeps == 3
    ss.store.close()

    # resume with the full budget against the same durable pool
    ss2 = build_stream(p, part, _cfg(), spill_dir=pool, prefetch=False)
    ss2, stats2 = solve_stream(ss2, resume_from=ckdir)
    assert stats2.converged
    assert stats2.sweeps == ref_stats.sweeps
    assert stats2.flow_curve == ref_stats.flow_curve
    assert ss2.bnd.flow_to_t == ref_stats.flow_curve[-1]
    np.testing.assert_array_equal(ss2.bnd.d_B, ref_bnd[0])
    np.testing.assert_array_equal(ss2.bnd.e_B, ref_bnd[1])
    ss2.store.close()


# --------------------------------------------------------------------------
# sharded DIMACS ingest
# --------------------------------------------------------------------------

@pytest.mark.parametrize("p", [
    random_sparse(14, 28, seed=3),
    synthetic_grid(6, 6, connectivity=8, strength=120, seed=1),
], ids=["sparse14", "grid6"])
def test_sharded_reader_roundtrips_bit_exact(p, tmp_path):
    path = tmp_path / "instance.max"
    write_dimacs(p, path)
    ref = read_dimacs(path)
    for part in (3, block_partition(ref.num_vertices, 3),
                 lambda n: block_partition(n, 3)):
        sd = read_dimacs_sharded(path, part)
        q = sd.to_problem()
        assert q.num_vertices == ref.num_vertices
        np.testing.assert_array_equal(q.edges, ref.edges)
        np.testing.assert_array_equal(q.cap_fwd, ref.cap_fwd)
        np.testing.assert_array_equal(q.cap_bwd, ref.cap_bwd)
        np.testing.assert_array_equal(q.excess, ref.excess)
        np.testing.assert_array_equal(q.sink_cap, ref.sink_cap)
        sd.close()


def test_sharded_reader_to_stream_solves(tmp_path):
    p = synthetic_grid(8, 9, connectivity=4, strength=90, seed=5)
    path = tmp_path / "instance.max"
    write_dimacs(p, path)
    want, _ = maxflow_oracle(read_dimacs(path))
    sd = read_dimacs_sharded(path, 4)
    ss = sd.to_stream(_cfg(), prefetch=False)
    ss, stats = solve_stream(ss)
    assert stats.converged and ss.bnd.flow_to_t == want
    assert stats.num_boundary == ss.meta.num_boundary
    ss.store.close()
    sd.close()


def test_sharded_reader_errors_are_loud():
    with pytest.raises(NotImplementedError):
        read_dimacs_sharded("p max 3 1\nn 2 s\nn 3 t\na 2 3 5\n", 2)
    with pytest.raises(AssertionError, match="designators"):
        read_dimacs_sharded("p max 4 1\na 1 2 5\nn 3 s\nn 4 t\n", 2)


# --------------------------------------------------------------------------
# solver-session route and capability surface
# --------------------------------------------------------------------------

def test_streaming_executor_refuses_device_loop():
    p = _problem()
    cfg = _cfg()
    meta, _, _ = build(p, _part(p))
    ex = StreamingExecutor(meta, cfg)
    for call in (lambda: ex.init_carry(None),
                 lambda: ex.one_sweep(None, None, 1),
                 lambda: ex.keep_running(None, None, 1),
                 lambda: ex.progress(None, 1)):
        with pytest.raises(UnsupportedFeatureError) as ei:
            call()
        assert ei.value.feature == "device_resident"
    with pytest.raises(UnsupportedFeatureError):
        StreamingExecutor.validate(_cfg(parallel=True))


def test_streaming_and_batching_are_mutually_exclusive():
    opts = SolverOptions.from_sweep_config(_cfg(), streaming=True)
    ps = [random_sparse(10, 18, seed=s) for s in (1, 2)]
    with pytest.raises(ValueError, match="solve_many and streaming"):
        Solver(opts).solve_many(ps)


def test_streaming_session_reports_io_accounting():
    p = _problem()
    ref = solve_mincut(p, _part(p), config=_cfg())
    opts = SolverOptions.from_sweep_config(
        _cfg(), streaming=True, max_resident_regions=2)
    res = Solver(opts).prepare(p, _part(p)).solve()
    assert res.flow_value == ref.flow_value
    assert res.stats.staged_in_bytes > 0
    assert res.stats.staged_out_bytes > 0
    assert res.stats.num_boundary == ref.meta.num_boundary
