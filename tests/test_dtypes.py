"""Dtype-narrowing correctness: policy selection, validation guards,
byte accounting, bit-exactness vs the int32 oracle on every route, and
autotuner determinism (PR 9)."""

import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Solver, SolverOptions, grid_partition, solve_mincut)
from repro.core import autotune as _autotune
from repro.core import dtypes as _dt
from repro.core.graph import (ProblemValidationError, build,
                              validate_problem, validate_update_dtypes)
from repro.core.sweep import SweepConfig, _page_and_msg_bytes
from repro.data.grids import synthetic_grid
from repro.kernels.push_relabel import (FUSED_VMEM_BUDGET_BYTES,
                                        fused_region_vmem_bytes)


def _small_problem(seed=1):
    """A 10x10 grid whose capacity mass fits the int16 flow bound."""
    p = synthetic_grid(10, 10, connectivity=4, strength=3, seed=seed)
    assert _dt.flows_fit_narrow(_dt.flow_mass(p))
    return p, grid_partition((10, 10), (2, 2))


def _big_problem():
    """A 16x16 grid whose capacity mass exceeds the int16 flow bound."""
    p = synthetic_grid(16, 16, connectivity=8, strength=150, seed=0)
    assert not _dt.flows_fit_narrow(_dt.flow_mass(p))
    return p, grid_partition((16, 16), (2, 2))


def _map_narrow_labels(d16):
    """Narrow labels -> the wide value space (sentinel classes map by a
    monotone offset), for exact comparison against an int32 solve."""
    d = np.asarray(d16).astype(np.int64)
    return np.where(d >= _dt.NARROW_INF_LABEL,
                    d - _dt.NARROW_INF_LABEL + _dt.INF_LABEL_WIDE, d)


# ---------------------------------------------------------------- policy

class TestPolicySelection:
    def test_int32_default_everywhere(self):
        p, part = _small_problem()
        meta, state, _ = build(p, part)
        assert meta.kernel_dtypes == _dt.WIDE
        assert state.cf.dtype == jnp.int32 and state.d.dtype == jnp.int32

    def test_auto_narrows_when_bounds_fit(self):
        p, part = _small_problem()
        meta, state, _ = build(p, part, dtype_policy="auto")
        assert meta.kernel_dtypes == _dt.NARROW
        assert state.cf.dtype == jnp.int16 and state.d.dtype == jnp.int16
        assert state.excess.dtype == jnp.int16

    def test_auto_falls_back_per_family(self):
        p, part = _big_problem()
        meta, _, _ = build(p, part, dtype_policy="auto")
        kd = meta.kernel_dtypes
        assert kd.flow == "int32"          # mass over the int16 bound
        assert kd.label == "int16"         # labels still fit
        assert kd.mask == "int8"           # any narrow family -> int8 masks

    def test_narrow_policy_raises_typed_error_naming_bound(self):
        p, part = _big_problem()
        with pytest.raises(ProblemValidationError) as e:
            validate_problem(p, context="problem", dtype_policy="narrow")
        msg = str(e.value)
        assert "int16" in msg and str(_dt.NARROW_FLOW_LIMIT) in msg

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            SolverOptions(dtype_policy="float16")
        p, part = _small_problem()
        with pytest.raises(ValueError):
            build(p, part, dtype_policy="int8")

    def test_sentinels_order_like_wide(self):
        assert _dt.inf_label_for("int16") == _dt.NARROW_INF_LABEL
        assert _dt.inf_label_for("int32") == _dt.INF_LABEL_WIDE
        # every representable narrow label sits strictly below the sentinel
        assert _dt.NARROW_LABEL_LIMIT + 1 < _dt.NARROW_INF_LABEL + 1 \
            < np.iinfo(np.int16).max


# ------------------------------------------------------------ validation

class TestUpdateGuard:
    def test_update_rejects_mass_growth_past_bound(self):
        p, part = _small_problem()
        s = Solver(SolverOptions(dtype_policy="narrow"))
        h = s.prepare(p, part)
        h.solve()
        m = len(p.edges)
        with pytest.raises(ProblemValidationError) as e:
            h.update(arcs=np.arange(m),
                     cap_fwd=np.full(m, 2000, np.int32))
        assert "int16" in str(e.value) and "re-prepare" in str(e.value)

    def test_update_within_bound_stays_narrow_and_exact(self):
        p, part = _small_problem()
        s16 = Solver(SolverOptions(dtype_policy="narrow"))
        s32 = Solver(SolverOptions(dtype_policy="int32"))
        h16, h32 = s16.prepare(p, part), s32.prepare(p, part)
        h16.solve(), h32.solve()
        idx = np.arange(6)
        caps = np.full(6, 5, np.int32)
        r16 = h16.update(arcs=idx, cap_fwd=caps).solve()
        r32 = h32.update(arcs=idx, cap_fwd=caps).solve()
        assert r16.flow_value == r32.flow_value
        assert h16.state.cf.dtype == jnp.int16

    def test_validate_update_dtypes_noop_for_wide(self):
        p, part = _small_problem()
        meta, _, _ = build(p, part)                  # wide build
        big, _ = _big_problem()
        validate_update_dtypes(meta, big)            # must not raise


# ------------------------------------------------------- byte accounting

class TestByteAccounting:
    def test_wide_vmem_matches_historical_formula(self):
        for V, E in [(64, 4), (256, 8), (1024, 8), (4096, 16)]:
            assert fused_region_vmem_bytes(V, E) \
                == fused_region_vmem_bytes(V, E, _dt.WIDE) \
                == 4 * (9 * V * E + 2 * V * (E + 1) + 8 * V)

    def test_narrow_vmem_cut_at_least_40_percent_for_32sq_region(self):
        V, E = 32 * 32, 8
        wide = fused_region_vmem_bytes(V, E, _dt.WIDE)
        narrow = fused_region_vmem_bytes(V, E, _dt.NARROW)
        assert narrow <= 0.60 * wide, (narrow, wide)

    def test_page_and_msg_bytes_wide_matches_historical(self):
        p, part = _small_problem()
        meta, state, _ = build(p, part)
        V, E = meta.region_size, meta.max_degree
        page, msg = _page_and_msg_bytes(meta)
        assert page == 16 * V * E + 16 * V
        assert msg == 8 * meta.num_cross_arcs

    def test_page_bytes_shrink_under_narrowing(self):
        p, part = _small_problem()
        meta_w, _, _ = build(p, part)
        meta_n, _, _ = build(p, part, dtype_policy="narrow")
        page_w, msg_w = _page_and_msg_bytes(meta_w)
        page_n, msg_n = _page_and_msg_bytes(meta_n)
        # the int32 topology slabs (nbr/rev) never narrow, so the page
        # shrinks less than the value-only fused VMEM does (~36% here)
        assert page_n < 0.70 * page_w
        assert msg_n == msg_w // 2        # (4+4) -> (2+2) bytes per arc


# ---------------------------------------------------------- bit-exactness

class TestBitExactMatrix:
    @pytest.mark.parametrize("method", ["ard", "prd"])
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    @pytest.mark.parametrize("device_resident", [False, True])
    def test_narrow_matches_int32_oracle(self, method, backend,
                                         device_resident):
        p, part = _small_problem()
        out = {}
        for policy in ("int32", "narrow"):
            s = Solver(SolverOptions(
                method=method, engine_backend=backend,
                device_resident=device_resident, dtype_policy=policy))
            h = s.prepare(p, part)
            r = h.solve()
            out[policy] = r
        r32, r16 = out["int32"], out["narrow"]
        assert r16.flow_value == r32.flow_value
        assert r16.stats.sweeps == r32.stats.sweeps
        assert r16.stats.engine_iters == r32.stats.engine_iters
        assert (r16.source_side == r32.source_side).all()
        assert (np.asarray(r16.state.cf)
                == np.asarray(r32.state.cf)).all()
        assert (_map_narrow_labels(r16.state.d)
                == np.asarray(r32.state.d)).all()

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_narrow_matches_int32_batched(self, backend):
        probs = [synthetic_grid(10, 10, connectivity=4, strength=3, seed=s)
                 for s in range(3)]
        part = grid_partition((10, 10), (2, 2))
        out = {}
        for policy in ("int32", "narrow"):
            s = Solver(SolverOptions(engine_backend=backend,
                                     dtype_policy=policy))
            rs = s.solve_many(probs, [part] * 3)
            out[policy] = [(r.flow_value, r.stats.sweeps,
                            r.stats.engine_iters) for r in rs]
        assert out["int32"] == out["narrow"]

    def test_narrow_matches_int32_sharded_one_device(self):
        p, part = _small_problem()
        mesh = jax.make_mesh((1,), ("regions",))
        out = {}
        for policy in ("int32", "narrow"):
            s = Solver(SolverOptions(dtype_policy=policy))
            h = s.prepare(p, part)
            r = h.solve(mesh=mesh)
            out[policy] = r
        r32, r16 = out["int32"], out["narrow"]
        assert r16.flow_value == r32.flow_value
        assert r16.stats.sweeps == r32.stats.sweeps
        assert (r16.source_side == r32.source_side).all()
        assert r16.state.cf.dtype == jnp.int16      # narrowed back at exit
        assert (_map_narrow_labels(r16.state.d)
                == np.asarray(r32.state.d)).all()

    def test_oracle_flow_on_narrow(self):
        from repro.kernels.ref import maxflow_oracle

        p, part = _small_problem()
        want, _ = maxflow_oracle(p)
        r = Solver(SolverOptions(dtype_policy="narrow")) \
            .prepare(p, part).solve()
        assert r.flow_value == want


# --------------------------------------------------------- compile cache

class TestCompileCacheKeys:
    def test_dtypes_in_meta_split_jit_keys(self):
        p, part = _small_problem()
        meta_w, _, _ = build(p, part)
        meta_n, _, _ = build(p, part, dtype_policy="narrow")
        assert meta_w != meta_n           # frozen metadata IS the jit key
        assert meta_w.kernel_dtypes != meta_n.kernel_dtypes

    def test_pack_built_separates_dtype_buckets(self):
        from repro.core.graph import pack_built

        p, part = _small_problem()
        builds = []
        for i, policy in enumerate(("int32", "narrow")):
            meta, state, layout = build(p, part, dtype_policy=policy)
            builds.append((i, meta, state, layout, state))
        packs = pack_built(builds)
        assert len(packs) == 2            # same dims, different dtypes


# -------------------------------------------------------------- autotune

class TestAutotuner:
    def test_same_key_same_config_and_cache_persistence(self, tmp_path):
        cache = tmp_path / "at.json"
        kd = _dt.NARROW
        tc1 = _autotune.tune(256, 8, backend="pallas", dtypes=kd,
                             cache=cache)
        tc2 = _autotune.tune(256, 8, backend="pallas", dtypes=kd,
                             cache=cache)
        assert tc1 == tc2
        stored = json.loads(cache.read_text())
        key = _autotune.tune_key(256, 8, "pallas", kd)
        assert key in stored
        assert stored[key]["engine_chunk_iters"] == tc1.engine_chunk_iters

    def test_tuned_config_within_budget(self, tmp_path):
        for kd in (_dt.WIDE, _dt.NARROW):
            tc = _autotune.tune(1024, 8, backend="pallas", dtypes=kd,
                                cache=tmp_path / "at.json")
            if tc.fused:
                assert tc.vmem_bytes <= FUSED_VMEM_BUDGET_BYTES

    def test_dtype_narrowing_extends_fused_range(self, tmp_path):
        # a region over budget wide but in budget narrow must flip fused
        V, E = 8192, 16
        budget = fused_region_vmem_bytes(V, E, _dt.NARROW) + 1
        tw = _autotune.tune(V, E, backend="pallas", dtypes=_dt.WIDE,
                            vmem_budget_bytes=budget,
                            cache=tmp_path / "a.json")
        tn = _autotune.tune(V, E, backend="pallas", dtypes=_dt.NARROW,
                            vmem_budget_bytes=budget,
                            cache=tmp_path / "a.json")
        assert not tw.fused and tn.fused

    def test_user_pin_beats_tuner(self, tmp_path, monkeypatch):
        monkeypatch.setenv(_autotune.CACHE_ENV,
                           str(tmp_path / "at.json"))
        cfg = SweepConfig(engine_chunk_iters=3, engine_backend="pallas")
        p, part = _small_problem()
        meta, _, _ = build(p, part)
        assert _autotune.tuned_sweep_config(cfg, meta) is cfg

    def test_zero_retrace_on_repeat_key(self, monkeypatch, tmp_path,
                                        fresh_compile_cache):
        monkeypatch.setenv(_autotune.CACHE_ENV,
                           str(tmp_path / "at.json"))
        p, part = _small_problem()
        s = Solver(SolverOptions(autotune=True, engine_backend="pallas",
                                 dtype_policy="narrow"))
        h1 = s.prepare(p, part)
        r1 = h1.solve()
        traces_after_first = s.cache_info().traces
        h2 = s.prepare(p, part)
        r2 = h2.solve()
        assert s.cache_info().traces == traces_after_first
        assert r1.flow_value == r2.flow_value

    def test_solve_results_unchanged_by_autotune(self, monkeypatch,
                                                 tmp_path):
        monkeypatch.setenv(_autotune.CACHE_ENV,
                           str(tmp_path / "at.json"))
        p, part = _small_problem()
        base = Solver(SolverOptions()).prepare(p, part).solve()
        tuned = Solver(SolverOptions(autotune=True)) \
            .prepare(p, part).solve()
        assert tuned.flow_value == base.flow_value
        assert tuned.stats.sweeps == base.stats.sweeps
        assert tuned.stats.engine_iters == base.stats.engine_iters


# --------------------------------------------------------------- CLI/API

class TestFrontEnds:
    def test_solve_mincut_unchanged_default(self):
        p, part = _small_problem()
        res = solve_mincut(p, part=part, config=SweepConfig())
        assert res.meta.kernel_dtypes == _dt.WIDE

    def test_options_roundtrip(self):
        o = SolverOptions(dtype_policy="auto", autotune=True)
        assert o.sweep_config() == SweepConfig()     # session knobs only
        o2 = dataclasses.replace(o, dtype_policy="int32")
        assert o2.autotune is True
