"""Resilience layer: checkpoint/resume bit-exactness, supervised retry
under deterministic fault injection, the degradation ladder, input
validation, and structured non-convergence.

The headline matrix: an interrupted-then-resumed solve must match the
uninterrupted one BIT-EXACTLY — flow, labels, residuals, sweep count,
engine iterations and the per-sweep curves — at EVERY sweep boundary, on
every route (host loop, device-resident, batched, sharded), cold and
warm.  The routes are bit-identical to each other by the repo's executor
conformance suite, so cross-route resume (a device checkpoint continued
on the host loop) must be exact too.
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.core import (CertificateError, CheckpointMismatchError,
                        CheckpointPolicy, FaultPlan, ProblemValidationError,
                        Solver, SolverOptions, SweepConfig, build,
                        fault_injection, grid_partition, init_labels)
from repro.core import resilience as res
from repro.core.sweep import solve
from repro.data.dimacs import read_dimacs
from repro.data.grids import synthetic_grid
from repro.kernels.ref import maxflow_oracle

P_GRID = (10, 10)
P_REGIONS = (2, 2)


def _instance():
    p = synthetic_grid(*P_GRID, connectivity=8, strength=150, seed=0)
    part = np.asarray(grid_partition(P_GRID, P_REGIONS))
    return p, part


def _built():
    p, part = _instance()
    meta, state, _ = build(p, part)
    return p, part, meta, state


def _steps(directory):
    return sorted(int(d.name[5:]) for d in directory.iterdir()
                  if d.is_dir() and d.name.startswith("step_")
                  and not d.name.endswith(".tmp"))


def _assert_same_solve(st_a, stats_a, st_b, stats_b):
    """Bit-exactness on everything the ISSUE pins (host_syncs excepted:
    a resumed solve legitimately pays extra host re-entries)."""
    np.testing.assert_array_equal(np.asarray(st_a.d), np.asarray(st_b.d))
    np.testing.assert_array_equal(np.asarray(st_a.cf), np.asarray(st_b.cf))
    np.testing.assert_array_equal(np.asarray(st_a.excess),
                                  np.asarray(st_b.excess))
    assert int(st_a.flow_to_t) == int(st_b.flow_to_t)
    for k in ("sweeps", "engine_iters", "engine_launches",
              "regions_discharged", "flow_curve", "active_curve",
              "converged"):
        assert getattr(stats_a, k) == getattr(stats_b, k), k


# --------------------------------------------------------------------------
# checkpoint/resume bit-exactness
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["prd", "ard"])
def test_host_resume_every_boundary_bit_exact(tmp_path, method):
    """Host route: resuming from EVERY sweep boundary reproduces the
    uninterrupted solve bit-exactly (state, counters and curves)."""
    _p, _part, meta, state = _built()
    cfg = SweepConfig(method=method)
    base_st, base_stats = solve(meta, init_labels(meta, state), cfg)
    assert base_stats.sweeps >= 3, "instance too easy for a boundary matrix"

    ckdir = tmp_path / method
    solve(meta, init_labels(meta, state), cfg,
          checkpoint=CheckpointPolicy(directory=ckdir, every=1))
    steps = _steps(ckdir)
    assert steps == list(range(1, base_stats.sweeps + 1))

    for step in steps:
        ck = res.load_checkpoint(ckdir, step)
        assert ck.sweeps == step and ck.route == "host"
        st_r, stats_r = solve(meta, init_labels(meta, state), cfg,
                              resume_from=ck)
        _assert_same_solve(st_r, stats_r, base_st, base_stats)


def test_device_resume_every_boundary_and_cross_route(tmp_path):
    """Device-resident route (host_sync_every=1: a checkpointable boundary
    per sweep): every boundary resumes bit-exactly — on the device route
    AND on the host loop (checkpoints are route-portable by design)."""
    _p, _part, meta, state = _built()
    cfg_d = SweepConfig(method="prd", device_resident=True,
                        host_sync_every=1)
    cfg_h = SweepConfig(method="prd")
    base_st, base_stats = solve(meta, init_labels(meta, state), cfg_d)

    solve(meta, init_labels(meta, state), cfg_d,
          checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
    steps = _steps(tmp_path)
    assert steps and steps[-1] == base_stats.sweeps

    for step in steps:
        ck = res.load_checkpoint(tmp_path, step)
        assert ck.route == "device"
        st_r, stats_r = solve(meta, init_labels(meta, state), cfg_d,
                              resume_from=ck)
        _assert_same_solve(st_r, stats_r, base_st, base_stats)
        # cross-route: the same checkpoint continued on the host loop
        st_x, stats_x = solve(meta, init_labels(meta, state), cfg_h,
                              resume_from=ck)
        _assert_same_solve(st_x, stats_x, base_st, base_stats)


def test_preempted_solve_resumes_bit_exact(tmp_path):
    """The deployment story end to end: a checkpointed solve is preempted
    mid-solve, then resumed from the latest on-disk checkpoint."""
    _p, _part, meta, state = _built()
    cfg = SweepConfig(method="ard")
    base_st, base_stats = solve(meta, init_labels(meta, state), cfg)
    assert base_stats.sweeps >= 4

    with fault_injection(FaultPlan("preempt", at_sweep=3)):
        with pytest.raises(res.PreemptionError):
            solve(meta, init_labels(meta, state), cfg,
                  checkpoint=CheckpointPolicy(directory=tmp_path, every=2))
    latest = res.latest_checkpoint(tmp_path)
    assert latest is not None and 2 <= latest.sweeps <= 3

    st_r, stats_r = solve(meta, init_labels(meta, state), cfg,
                          resume_from=tmp_path)     # directory form
    _assert_same_solve(st_r, stats_r, base_st, base_stats)


def test_batched_route_resume_matches(tmp_path):
    """Batched route: one checkpoint stream for the whole shape bucket;
    preempt at a sync boundary, re-pack the same fleet, resume."""
    probs = [synthetic_grid(8, 8, seed=s) for s in range(3)]
    want = [maxflow_oracle(p)[0] for p in probs]
    opts = SolverOptions(method="ard", num_regions=4, host_sync_every=2)
    base = Solver(opts).solve_many(list(probs))

    with fault_injection(FaultPlan("preempt", at_sweep=2)):
        with pytest.raises(res.PreemptionError):
            Solver(opts).solve_many(
                list(probs),
                checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
    assert _steps(tmp_path), "no checkpoint published before the preempt"
    assert res.latest_checkpoint(tmp_path).route == "batch"

    got = Solver(opts).solve_many(list(probs), resume_from=tmp_path)
    for r, b, w in zip(got, base, want):
        assert r.flow_value == b.flow_value == w
        assert r.converged and b.converged
        assert r.stats.sweeps == b.stats.sweeps
        assert r.stats.engine_iters == b.stats.engine_iters
        np.testing.assert_array_equal(r.source_side, b.source_side)
        np.testing.assert_array_equal(np.asarray(r.state.d),
                                      np.asarray(b.state.d))


def test_sharded_route_resume_matches(tmp_path):
    """Sharded route (1-device mesh: plumbing, not scaling): preempt at a
    mid-solve boundary, resume from disk through a fresh handle."""
    p, part = _instance()
    mesh = jax.make_mesh((1,), ("regions",))
    opts = SolverOptions(method="prd")
    base = Solver(opts).prepare(p, part).solve(mesh=mesh)
    assert base.stats.sweeps >= 3

    h = Solver(opts).prepare(p, part)
    with fault_injection(FaultPlan("preempt", at_sweep=2)):
        with pytest.raises(res.PreemptionError):
            h.solve(mesh=mesh,
                    checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
    latest = res.latest_checkpoint(tmp_path)
    assert latest is not None and latest.route == "sharded"
    assert latest.sweeps < base.stats.sweeps

    got = Solver(opts).prepare(p, part).solve(mesh=mesh,
                                              resume_from=tmp_path)
    assert got.flow_value == base.flow_value
    assert got.converged and got.stats.sweeps == base.stats.sweeps
    np.testing.assert_array_equal(got.source_side, base.source_side)
    np.testing.assert_array_equal(np.asarray(got.state.d),
                                  np.asarray(base.state.d))
    np.testing.assert_array_equal(np.asarray(got.state.cf),
                                  np.asarray(base.state.cf))


def test_warm_handle_resume_matches(tmp_path):
    """Warm leg of the matrix: a warm re-solve after an update checkpoints,
    preempts and resumes to the same result as its uninterrupted twin (the
    handle's flow-offset bookkeeping riding in the checkpoint)."""
    p, part = _instance()
    n = p.num_vertices

    def warm_handle():
        h = Solver(SolverOptions(method="ard")).prepare(p, part)
        h.solve()
        # zero half the t-links, widen the rest, double the source mass:
        # the warm re-solve has multi-sweep work to do
        sink = np.where(np.arange(n) % 2 == 0, 0,
                        2 * p.sink_cap).astype(np.int32)
        return h.update(excess=2 * p.excess, sink_cap=sink)

    a = warm_handle()
    base = a.solve()
    assert base.stats.sweeps >= 2

    b = warm_handle()
    assert int(b._flow_offset) == int(a._flow_offset)
    with fault_injection(FaultPlan("preempt", at_sweep=1)):
        with pytest.raises(res.PreemptionError):
            b.solve(checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
    assert res.latest_checkpoint(tmp_path).flow_offset == int(a._flow_offset)
    got = b.solve(resume_from=tmp_path)
    assert got.flow_value == base.flow_value
    assert got.stats.sweeps == base.stats.sweeps
    np.testing.assert_array_equal(np.asarray(got.state.d),
                                  np.asarray(base.state.d))
    np.testing.assert_array_equal(np.asarray(got.state.cf),
                                  np.asarray(base.state.cf))


def test_checkpoint_fingerprint_guards_resume(tmp_path):
    """A checkpoint from different math (prd vs ard) must refuse to
    resume; so must a snapshot that is not a solve checkpoint at all."""
    _p, _part, meta, state = _built()
    solve(meta, init_labels(meta, state), SweepConfig(method="prd"),
          checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
    with pytest.raises(CheckpointMismatchError):
        solve(meta, init_labels(meta, state), SweepConfig(method="ard"),
              resume_from=tmp_path)
    # a plain (training-style) snapshot is not a solve checkpoint
    other = tmp_path / "train"
    res.snapshot_save(other, 7, {"w": np.zeros(3)})
    with pytest.raises(CheckpointMismatchError):
        res.load_checkpoint(other)


def test_snapshot_atomicity_and_latest(tmp_path):
    """Crashed-writer debris (.tmp dirs) is invisible; restore is a
    bit-exact inverse of save; empty dirs answer None/FileNotFoundError."""
    tree = {"a": np.arange(12, dtype=np.int32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int64)}}
    res.snapshot_save(tmp_path, 1, tree)
    res.snapshot_save(tmp_path, 3, tree)
    (tmp_path / "step_00000002.tmp").mkdir()       # a crashed writer
    assert res.snapshot_latest(tmp_path) == 3
    back = res.snapshot_restore(tmp_path, 3, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), tree["a"])
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]), tree["b"]["c"])

    empty = tmp_path / "none"
    assert res.latest_checkpoint(empty) is None
    with pytest.raises(FileNotFoundError):
        res.load_checkpoint(empty)


# --------------------------------------------------------------------------
# the solve supervisor under the fault matrix
# --------------------------------------------------------------------------

def test_supervisor_retries_resumes_and_backs_off(tmp_path):
    p, part = _instance()
    base = Solver(SolverOptions(method="prd")).prepare(p, part).solve()

    delays: list[float] = []
    h = Solver(SolverOptions(method="prd")).prepare(p, part)
    sup = res.SolveSupervisor.for_handle(
        h, checkpoint_dir=tmp_path, checkpoint_every=1,
        retry=res.RetryPolicy(max_retries=3, sleep=delays.append))
    with fault_injection(FaultPlan("raise", at_sweep=2, times=2)):
        got = sup.solve(resume=False)
    assert got.flow_value == base.flow_value and got.converged
    assert sup.report.attempts == 3
    assert sup.report.resumes == 2
    assert len(sup.report.failures) == 2
    assert delays == [0.05, 0.1]                  # base * factor**(i-1)
    np.testing.assert_array_equal(got.source_side, base.source_side)


def test_supervisor_exhausts_retries(tmp_path):
    p, part = _instance()
    h = Solver(SolverOptions(method="prd")).prepare(p, part)
    sup = res.SolveSupervisor.for_handle(
        h, checkpoint_dir=tmp_path, checkpoint_every=1,
        retry=res.RetryPolicy(max_retries=2, sleep=lambda s: None))
    with fault_injection(FaultPlan("raise", at_sweep=1, times=-1)):
        with pytest.raises(res.InjectedFault):
            sup.solve(resume=False)
    assert sup.report.attempts == 3               # 1 + max_retries
    assert len(sup.report.failures) == 3


def test_supervisor_batch_route(tmp_path):
    probs = [synthetic_grid(8, 8, seed=s) for s in (0, 1)]
    want = [maxflow_oracle(p)[0] for p in probs]
    solver = Solver(SolverOptions(method="ard", num_regions=4,
                                  host_sync_every=1))
    sup = res.SolveSupervisor.for_batch(
        solver, probs, checkpoint_dir=tmp_path, checkpoint_every=1,
        retry=res.RetryPolicy(sleep=lambda s: None))
    with fault_injection(FaultPlan("preempt", at_sweep=1)):
        got = sup.solve(resume=False)
    assert [r.flow_value for r in got] == want
    assert all(r.converged for r in got)
    assert sup.report.attempts == 2 and sup.report.resumes == 1


def test_corrupt_labels_caught_by_certificate():
    """Boundary-exchange corruption makes the solve 'converge' to a wrong
    answer; check=True must refuse to certify it, with a diagnosis."""
    p, part = _instance()
    want = maxflow_oracle(p)[0]
    h = Solver(SolverOptions(method="prd")).prepare(p, part)
    with fault_injection(FaultPlan("corrupt_labels", at_sweep=1, times=-1)):
        with pytest.raises(CertificateError) as ei:
            h.solve()
    diag = ei.value.diagnosis
    assert diag.reason == "certificate"
    assert diag.cut_cost is not None and diag.flow_value != diag.cut_cost
    assert diag.flow_value < want                 # the corruption lost flow
    assert "cut cost" in str(ei.value)
    # CertificateError still IS the historical AssertionError
    assert isinstance(ei.value, AssertionError)


# --------------------------------------------------------------------------
# degradation ladder
# --------------------------------------------------------------------------

def test_degrade_config_walks_the_ladder():
    top = SweepConfig(engine_backend="pallas", engine_chunk_iters=64)
    assert res.config_rung(top) == "pallas-fused"
    mid = res.degrade_config(top)
    assert res.config_rung(mid) == "xla-fused"
    bot = res.degrade_config(mid)
    assert res.config_rung(bot) == "xla-unfused"
    assert res.degrade_config(bot) is None
    assert res.is_kernel_failure(res.VmemOverflowError("x"))
    assert res.is_kernel_failure(ValueError("RESOURCE_EXHAUSTED: vmem"))
    assert not res.is_kernel_failure(res.InjectedFault("x"))
    assert not res.is_kernel_failure(KeyError("unrelated"))


def test_vmem_overflow_degrades_one_rung():
    """A kernel-class failure mid-solve re-runs one rung down; the result
    is bit-correct and the degradation is recorded, never silent."""
    p, part = _instance()
    want = maxflow_oracle(p)[0]
    h = Solver(SolverOptions(method="prd", engine_chunk_iters=64)).prepare(
        p, part)
    with fault_injection(FaultPlan("vmem_overflow", at_sweep=1)):
        got = h.solve()
    assert got.flow_value == want and got.converged
    assert len(got.stats.degraded) == 1
    assert got.stats.degraded[0].startswith("xla-fused -> xla-unfused")


def test_ladder_bottoms_out():
    p, part = _instance()
    h = Solver(SolverOptions(method="prd")).prepare(p, part)   # xla-unfused
    with fault_injection(FaultPlan("vmem_overflow", at_sweep=1)):
        with pytest.raises(res.VmemOverflowError):
            h.solve()


# --------------------------------------------------------------------------
# input validation + structured non-convergence
# --------------------------------------------------------------------------

def test_validate_problem_rejects_bad_inputs():
    p, _part = _instance()
    neg = dataclasses.replace(
        p, cap_fwd=np.where(np.arange(len(p.cap_fwd)) == 0, -1,
                            p.cap_fwd).astype(np.int32))
    with pytest.raises(ProblemValidationError, match="negative cap_fwd"):
        Solver().prepare(neg)

    pair = dataclasses.replace(
        p,
        cap_fwd=np.where(np.arange(len(p.cap_fwd)) == 0, 1 << 29,
                         p.cap_fwd).astype(np.int32),
        cap_bwd=np.where(np.arange(len(p.cap_bwd)) == 0, 1 << 29,
                         p.cap_bwd).astype(np.int32))
    with pytest.raises(ProblemValidationError, match="INF_CAP"):
        Solver().prepare(pair)

    term = dataclasses.replace(
        p, excess=np.where(np.arange(p.num_vertices) == 0, 1 << 30,
                           p.excess).astype(np.int64))
    with pytest.raises(ProblemValidationError):
        Solver().prepare(term)


def test_update_guard_and_opt_out():
    p, part = _instance()
    h = Solver(SolverOptions()).prepare(p, part)
    h.solve()
    with pytest.raises(ProblemValidationError, match="update"):
        h.update(cap_fwd=np.full(len(p.cap_fwd), -3, np.int32))
    # the rejected update must not have touched the handle's problem
    np.testing.assert_array_equal(h.problem.cap_fwd, p.cap_fwd)
    # opt-out: check=False skips the overflow screens (serving path)
    risky = dataclasses.replace(
        p,
        cap_fwd=np.where(np.arange(len(p.cap_fwd)) == 0, 1 << 29,
                         p.cap_fwd).astype(np.int32),
        cap_bwd=np.where(np.arange(len(p.cap_bwd)) == 0, 1 << 29,
                         p.cap_bwd).astype(np.int32))
    Solver(SolverOptions(check=False)).prepare(risky)   # does not raise


def test_dimacs_rejects_overflow_risk():
    text = ("p max 4 3\n" "n 1 s\n" "n 4 t\n"
            f"a 1 2 {1 << 30}\n" "a 2 3 5\n" "a 3 4 5\n")
    with pytest.raises(ProblemValidationError, match="DIMACS input"):
        read_dimacs(text)


def test_max_sweeps_yields_structured_nonconvergence():
    p, part = _instance()
    full = Solver(SolverOptions(method="prd")).prepare(p, part).solve()
    assert full.converged and full.diagnosis is None
    assert full.stats.sweeps >= 2

    capped = Solver(SolverOptions(method="prd", max_sweeps=1)).prepare(
        p, part).solve()                          # check=True must NOT raise
    assert capped.converged is False
    assert capped.stats.converged is False
    d = capped.diagnosis
    assert d is not None and d.reason == "max_sweeps"
    assert d.sweeps == 1 and d.max_sweeps == 1
    assert d.active_vertices > 0
    assert d.violations == []                     # intact, just unfinished
    assert "max_sweeps" in d.summary()
    assert capped.flow_value <= full.flow_value


# --------------------------------------------------------------------------
# converged-checkpoint short-circuit (no extra no-op sweep on resume)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("device_resident", [False, True])
def test_sharded_converged_checkpoint_resumes_without_extra_sweep(
        tmp_path, device_resident):
    """Sharded resume from the CONVERGED final-boundary checkpoint must
    return the finished result without re-entering the sweep loop — the
    legacy converged-entry semantics (ShardedExecutor.keep_running's
    ``idx == start`` term) would otherwise run one extra no-op sweep."""
    p, part = _instance()
    mesh = jax.make_mesh((1,), ("regions",))
    opts = SolverOptions(method="prd", device_resident=device_resident,
                         host_sync_every=1 if device_resident else None)
    base = Solver(opts).prepare(p, part).solve(
        mesh=mesh, checkpoint=CheckpointPolicy(directory=tmp_path, every=1))
    assert base.converged and base.stats.sweeps >= 2

    latest = res.latest_checkpoint(tmp_path)
    assert latest.sweeps == base.stats.sweeps
    assert res.checkpoint_converged(latest)

    got = Solver(opts).prepare(p, part).solve(mesh=mesh,
                                              resume_from=tmp_path)
    assert got.converged
    assert got.flow_value == base.flow_value
    assert got.stats.sweeps == base.stats.sweeps, \
        "converged-checkpoint resume ran extra sweeps"
    np.testing.assert_array_equal(got.source_side, base.source_side)
    np.testing.assert_array_equal(np.asarray(got.state.d),
                                  np.asarray(base.state.d))

    # a NON-converged mid-solve checkpoint must not short-circuit
    mid = res.load_checkpoint(tmp_path, step=1)
    assert not res.checkpoint_converged(mid)
