"""Per-architecture smoke tests (required deliverable f): every assigned
arch instantiates a reduced config of the same family and runs one forward
and one train step on CPU, asserting output shapes and no NaNs; decoder
archs additionally check prefill+decode consistency with train logits."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, shape_skip_reason
from repro.models.model import (build_plan, forward, init_cache, init_params,
                                param_count)
from repro.train import optimizer as opt_lib
from repro.train import train_loop as tl

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, key, B, S):
    if cfg.frontend == "audio_frames":
        return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim)),
                "labels": jnp.zeros((B, S), jnp.int32),
                "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "vision_patches":
        st = S - cfg.num_patches
        return {"tokens": jnp.ones((B, st), jnp.int32),
                "patches": jax.random.normal(
                    key, (B, cfg.num_patches, cfg.frontend_dim)),
                "labels": jnp.zeros((B, st), jnp.int32),
                "mask": jnp.ones((B, st), jnp.float32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(name):
    cfg = ARCHS[name].smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, dtype=jnp.float32)
    B, S = 2, 32
    batch = _batch_for(cfg, key, B, S)
    logits, aux = forward(cfg, params, batch, mode="train",
                          dtype=jnp.float32)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    cfg = ARCHS[name].smoke()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, dtype=jnp.float32)
    state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
    step = jax.jit(tl.make_train_step(
        cfg, opt_lib.AdamWConfig(lr=1e-3), jnp.float32))
    batch = _batch_for(cfg, key, 2, 32)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


DECODER_ARCHS = [n for n in ALL_ARCHS if not ARCHS[n].encoder_only]


@pytest.mark.parametrize("name", DECODER_ARCHS)
def test_smoke_serving_consistency(name):
    cfg = ARCHS[name].smoke()
    if cfg.moe is not None:   # avoid capacity-dropping train/serve mismatch
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, dtype=jnp.float32)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    tlog, _ = forward(cfg, params, {"tokens": toks}, mode="train",
                      dtype=jnp.float32)
    cache = init_cache(cfg, B, S + 8, dtype=jnp.float32)
    plog, cache = forward(cfg, params, {"tokens": toks[:, :S - 1]},
                          mode="prefill", cache=cache, dtype=jnp.float32)
    dlog, cache = forward(cfg, params, {"tokens": toks[:, S - 1:]},
                          mode="decode", cache=cache, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(plog), np.asarray(tlog[:, S - 2]),
                               atol=3e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(dlog), np.asarray(tlog[:, S - 1]),
                               atol=3e-4, rtol=1e-3)


def test_all_archs_registered_with_exact_configs():
    """Pin the assigned architecture table."""
    expect = {
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    assert set(expect) == set(ARCHS)
    for name, (L, d, h, kv, ff, v) in expect.items():
        c = ARCHS[name]
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), name


def test_moe_configs():
    m = ARCHS["deepseek-moe-16b"].moe
    assert (m.num_experts, m.top_k, m.num_shared) == (64, 6, 2)
    m = ARCHS["llama4-scout-17b-a16e"].moe
    assert (m.num_experts, m.top_k) == (16, 1)


def test_shape_skips_documented():
    skips = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            if shape_skip_reason(a, s):
                skips.append((a.name, s.name))
    # encoder-only decode skips + long_500k for non-sub-quadratic archs
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("gemma3-27b", "long_500k") in skips
    assert ("xlstm-350m", "long_500k") not in skips
    assert ("recurrentgemma-9b", "long_500k") not in skips
    live = 40 - len(skips)
    assert live == 31


def test_param_counts_plausible():
    """Parameter counts should be in the ballpark of the arch names."""
    approx = {
        "phi3-mini-3.8b": (3.0e9, 5.0e9),
        "command-r-plus-104b": (90e9, 120e9),
        "deepseek-moe-16b": (14e9, 21e9),
        "xlstm-350m": (0.2e9, 0.6e9),
        "recurrentgemma-9b": (7e9, 12e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
    }
    for name, (lo, hi) in approx.items():
        n = param_count(ARCHS[name])
        assert lo <= n <= hi, (name, n)
