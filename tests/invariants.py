"""Assert-style invariant checkers (test fixture module).

The state-level checkers were promoted into ``repro.core.invariants`` in
the robustness PR so the solver can *report* violations structurally
(``MincutResult.diagnosis``); this module keeps the historical assert
surface the tests call — each ``assert_*`` delegates to the corresponding
report-returning ``check_*`` and fails with the violation summary.

The region-level scalar-loop checker
(:func:`assert_region_labeling_valid`) stays here on purpose: it is an
*independent re-implementation* of the validity condition used as an
oracle by the discharge-operator tests, and folding it into the solver
package would make the oracle share code with the thing it checks.
"""

import numpy as np

from repro.core import invariants as _inv
from repro.core.invariants import preflow_total  # re-export  # noqa: F401


def _fail(violations, where: str):
    assert not violations, \
        f"invariants broken {where}: " + "; ".join(
            f"{v.kind} (x{v.count}): {v.detail}" for v in violations)


def assert_valid_preflow(meta, state, where=""):
    """Residuals and excess of a preflow are non-negative everywhere."""
    _fail(_inv.check_valid_preflow(meta, state), where)


def assert_valid_labeling(meta, state, *, ard: bool, where=""):
    """Paper eqs. (9)/(10): d() lower-bounds residual distance-to-sink."""
    _fail(_inv.check_valid_labeling(meta, state, ard=ard), where)


def assert_flow_conservation(meta, state, total0: int, where=""):
    """No flow mass appears or vanishes: excess + flow_to_t == total0."""
    _fail(_inv.check_flow_conservation(meta, state, total0), where)


def assert_sweep_bound(meta, stats, *, ard: bool, where=""):
    """Paper complexity bound: a converged solve took at most 2|B|^2 + 1
    sweeps (ARD) / 2n^2 + 1 (PRD)."""
    _fail(_inv.check_sweep_bound(meta, stats, ard=ard), where)


def assert_region_labeling_valid(d, cf, sink_cf, *, intra, emask, vmask,
                                 nbr_local, ghost, d_inf, ard: bool):
    """Validity on one region's [V, E] view, by scalar loops.

    The discharge-operator tests use this as an independent oracle for the
    condition the vectorized checkers verify on whole states: residual
    intra arc (u, v) => d(u) <= d(v) + w_intra, residual cross arc =>
    d(u) <= ghost + 1, sink-residual => d(u) <= sink bound.
    """
    d = np.asarray(d)
    cf = np.asarray(cf)
    intra = np.asarray(intra)
    emask = np.asarray(emask)
    vmask = np.asarray(vmask)
    nbr = np.asarray(nbr_local)
    ghost = np.asarray(ghost)
    intra_w = 0 if ard else 1
    V, E = cf.shape
    for u in range(V):
        if not vmask[u] or d[u] >= d_inf:
            continue
        for e in range(E):
            if not emask[u, e] or cf[u, e] <= 0:
                continue
            if intra[u, e]:
                assert d[u] <= d[nbr[u, e]] + intra_w, (u, e)
            elif ghost[u, e] < d_inf:
                assert d[u] <= ghost[u, e] + 1, (u, e)
    sink_w = 0 if ard else 1
    sink_cf = np.asarray(sink_cf)
    ok = (sink_cf == 0) | (d <= sink_w) | (d >= d_inf) | ~vmask
    assert ok.all(), "sink validity"
