"""Reusable preflow/labeling invariant checkers (test fixture module).

The properties the paper's correctness and sweep-bound proofs rest on
(Statements 1/9, eqs. (9)/(10)), factored out of the per-operator tests so
they can be asserted on ANY mid-solve ``FlowState`` — in particular at
every sweep boundary through ``sweep.solve``'s ``on_sweep`` hook (see
test_executor_conformance.py) and inside the hypothesis property tests.

State-level checkers (vectorized over the whole [K, V(, E)] state):

* :func:`assert_valid_preflow`      — residuals/excess non-negative.
* :func:`assert_valid_labeling`     — d() is a valid distance labeling of
  the residual network: every residual arc (u, v) satisfies
  ``d(u) <= d(v) + w`` with w = 0 for ARD intra-region arcs, 1 for ARD
  cross arcs, 1 for every PRD arc; sink-residual vertices are bounded by
  the terminal distance (0 for ARD, 1 for PRD), all capped at d_inf.
* :func:`assert_flow_conservation`  — excess mass + delivered flow is the
  invariant ``total0`` computed from the entry state.

Region-level checker (scalar loops — an independent re-implementation the
discharge-operator tests deliberately keep separate from the vectorized
solver code):

* :func:`assert_region_labeling_valid` — the same validity condition on
  one region's [V, E] view with ghost labels, used by
  test_discharge_invariants.py.
"""

import numpy as np

import jax.numpy as jnp

from repro.core.graph import intra_mask
from repro.core.labels import gather_ghost_labels


def preflow_total(state) -> int:
    """The conserved quantity: live excess + flow already delivered to t."""
    return int(jnp.sum(jnp.where(state.vmask, state.excess, 0))) + \
        int(state.flow_to_t)


def assert_valid_preflow(meta, state, where=""):
    """Residuals and excess of a preflow are non-negative everywhere."""
    cf = np.asarray(state.cf)
    sink_cf = np.asarray(state.sink_cf)
    excess = np.asarray(state.excess)
    vm = np.asarray(state.vmask)
    assert (cf >= 0).all(), f"negative residual {where}"
    assert (sink_cf >= 0).all(), f"negative sink residual {where}"
    assert (excess[vm] >= 0).all(), f"negative excess {where}"


def assert_valid_labeling(meta, state, *, ard: bool, where=""):
    """Paper eqs. (9)/(10): d() lower-bounds residual distance-to-sink.

    ARD labels count boundary crossings (intra arcs cost 0, cross arcs 1,
    the sink is at distance 0); PRD labels count hops (every arc costs 1,
    the sink is one hop away).  Vertices at the ceiling d_inf are exempt
    (they are declared unreachable), as are arcs into ghosts already at
    the ceiling — ``d(u) <= d_inf <= ghost`` holds trivially there.
    """
    ghost_d = gather_ghost_labels(state)
    intra = intra_mask(state)
    d_inf = meta.d_inf_ard if ard else meta.d_inf_prd
    d = state.d
    du = jnp.broadcast_to(d[:, :, None], state.cf.shape)
    resid = (state.cf > 0) & state.emask
    at_cap = du >= d_inf
    intra_w = 0 if ard else 1
    ok_intra = ~resid | ~intra | (du <= ghost_d + intra_w) | at_cap
    cross = state.emask & ~intra
    ok_cross = ~resid | ~cross | (du <= ghost_d + 1) | at_cap
    sink_w = 0 if ard else 1
    ok_sink = (state.sink_cf == 0) | (d <= sink_w) | (d >= d_inf) | \
        ~state.vmask
    assert bool(jnp.all(ok_intra)), f"intra-arc validity broken {where}"
    assert bool(jnp.all(ok_cross)), f"cross-arc validity broken {where}"
    assert bool(jnp.all(ok_sink)), f"sink validity broken {where}"


def assert_flow_conservation(meta, state, total0: int, where=""):
    """No flow mass appears or vanishes: excess + flow_to_t == total0."""
    total = preflow_total(state)
    assert total == total0, \
        f"flow mass not conserved {where}: {total} != {total0}"


def assert_region_labeling_valid(d, cf, sink_cf, *, intra, emask, vmask,
                                 nbr_local, ghost, d_inf, ard: bool):
    """Validity on one region's [V, E] view, by scalar loops.

    The discharge-operator tests use this as an independent oracle for the
    condition the vectorized :func:`assert_valid_labeling` checks on whole
    states: residual intra arc (u, v) => d(u) <= d(v) + w_intra, residual
    cross arc => d(u) <= ghost + 1, sink-residual => d(u) <= sink bound.
    """
    d = np.asarray(d)
    cf = np.asarray(cf)
    intra = np.asarray(intra)
    emask = np.asarray(emask)
    vmask = np.asarray(vmask)
    nbr = np.asarray(nbr_local)
    ghost = np.asarray(ghost)
    intra_w = 0 if ard else 1
    V, E = cf.shape
    for u in range(V):
        if not vmask[u] or d[u] >= d_inf:
            continue
        for e in range(E):
            if not emask[u, e] or cf[u, e] <= 0:
                continue
            if intra[u, e]:
                assert d[u] <= d[nbr[u, e]] + intra_w, (u, e)
            elif ghost[u, e] < d_inf:
                assert d[u] <= ghost[u, e] + 1, (u, e)
    sink_w = 0 if ard else 1
    sink_cf = np.asarray(sink_cf)
    ok = (sink_cf == 0) | (d <= sink_w) | (d >= d_inf) | ~vmask
    assert ok.all(), "sink validity"
