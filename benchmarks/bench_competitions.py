"""Paper Tables 1-3: sequential competition (streaming I/O accounting),
parallel competition, and region-reduction percentages — on CPU-sized
instances of the same families."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit_csv
from repro.core import (SweepConfig, build, grid_partition, region_reduction,
                        solve_mincut)
from repro.data.grids import random_sparse, segmentation_grid, synthetic_grid


def _instances(quick=False):
    s = 20 if quick else 28
    out = [
        ("seg2d", segmentation_grid(s, s, seed=0),
         grid_partition((s, s), (2, 2))),
        ("synth-easy", synthetic_grid(s, s, strength=30, seed=1),
         grid_partition((s, s), (2, 2))),
        ("synth-hard", synthetic_grid(s, s, strength=150, seed=1),
         grid_partition((s, s), (2, 2))),
    ]
    if not quick:
        out.append(("seg3d", segmentation_grid(12, 12, depth=6, seed=2),
                    None))
    return out


def table1_sequential(emit=emit_csv, quick=False):
    """S-ARD vs S-PRD: sweeps and streaming I/O (page + boundary bytes) —
    the paper's Table 1 criterion (ARD needs far less disk traffic)."""
    for name, p, part in _instances(quick):
        row = {}
        for m in ("ard", "prd"):
            t0 = time.perf_counter()
            res = solve_mincut(p, part=part, num_regions=4,
                               config=SweepConfig(method=m, parallel=False))
            us = (time.perf_counter() - t0) * 1e6
            s = res.stats
            emit(f"table1/S-{m.upper()}/{name}", us,
                 f"sweeps={s.sweeps};io_bytes={s.page_bytes};"
                 f"boundary_bytes={s.boundary_bytes};flow={res.flow_value}")
            row[m] = s.sweeps


def table2_parallel(emit=emit_csv, quick=False):
    """P-ARD vs P-PRD (all regions concurrently + fusion)."""
    for name, p, part in _instances(quick):
        for m in ("ard", "prd"):
            t0 = time.perf_counter()
            res = solve_mincut(p, part=part, num_regions=4,
                               config=SweepConfig(method=m, parallel=True))
            us = (time.perf_counter() - t0) * 1e6
            emit(f"table2/P-{m.upper()}/{name}", us,
                 f"sweeps={res.stats.sweeps};flow={res.flow_value}")


def table3_reduction(emit=emit_csv, quick=False):
    """Percentage of vertices decided by Alg. 5 preprocessing."""
    for name, p, part in _instances(quick):
        if part is None:
            from repro.core.partition import block_partition
            part = block_partition(p.num_vertices, 4)
        t0 = time.perf_counter()
        meta, state, _ = build(p, part)
        red = region_reduction(meta, state)
        us = (time.perf_counter() - t0) * 1e6
        frac = float(np.asarray(red.decided).sum()) / p.num_vertices
        emit(f"table3/reduction/{name}", us, f"decided={frac * 100:.1f}%")


def run(emit=emit_csv, quick=False):
    table1_sequential(emit, quick)
    table2_parallel(emit, quick)
    table3_reduction(emit, quick)
