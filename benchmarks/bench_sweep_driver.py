"""Sweep-driver benchmark: host-loop vs device-resident multi-sweep solve.

PR 2 collapsed the intra-region engine to one kernel launch per k
iterations; this benchmark measures the next level up — one grid-over-
regions kernel launch per engine chunk of a *whole parallel sweep*
(``grid=(K,)`` instead of K per-region launch chains), and one host sync
per *solve* instead of one per sweep (``SweepConfig(device_resident=True)``,
``host_sync_every``).  Per instance, driver and backend it records:

  * ``solve_s``           — full-solve wall time (post-warmup);
  * ``kernel_launches``   — compute-program dispatches per solve
                            (``SweepStats.engine_launches``);
  * ``launches_per_sweep``— the headline: K-free on the batched pallas
                            path, and exactly 1.0 for the PRD
                            single-engine-run row with a chunk larger than
                            any discharge;
  * ``host_syncs``        — device->host transfers per solve
                            (``SweepStats.host_syncs``): host loop pays
                            1 + 1/sweep, device-resident pays 1.

All drivers/backends must agree bit-exactly on flow, sweeps and engine
iterations (asserted here), so every column is a pure performance knob.
Results go to ``BENCH_sweep.json``; on this CPU-only container the Pallas
kernel runs in interpret mode, so absolute times measure correctness-path
overhead, not TPU speed (the JSON records platform + interpret mode).

    PYTHONPATH=src python benchmarks/bench_sweep_driver.py [--quick]
        [--smoke] [--out BENCH_sweep.json]

``--smoke`` runs one tiny instance through every driver × backend pair
plus the PRD 1-launch-per-sweep configuration and asserts the flow against
the Edmonds-Karp oracle — the CI guard for the sweep-driver plumbing.

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv  # noqa: E402

BACKENDS = ("xla", "pallas")
FUSED_CHUNK_ITERS = 8
PRD_BIG_CHUNK = 1 << 20      # larger than any discharge: the in-kernel
#                              early exit makes an oversized chunk free, so
#                              every engine run is exactly one launch


def _configs():
    """(label, SweepConfig) pairs: host vs device × backend, + the PRD
    single-launch-per-sweep demonstration row."""
    from repro.core import SweepConfig

    for backend in BACKENDS:
        base = SweepConfig(method="ard", engine_backend=backend,
                           engine_chunk_iters=FUSED_CHUNK_ITERS)
        yield f"host/{backend}", base
        yield f"device/{backend}", dataclasses.replace(
            base, device_resident=True)
    yield "device/pallas-prd-1launch", SweepConfig(
        method="prd", engine_backend="pallas",
        engine_chunk_iters=PRD_BIG_CHUNK, device_resident=True)


def _bench_instance(size, regions, label, cfg, quick):
    from repro.core import grid_partition, solve_mincut
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(size, size, connectivity=8, strength=150, seed=0)
    part = grid_partition((size, size), regions)

    # warm-up run first so solve_s measures execution, not trace/compile
    solve_mincut(p, part=part, config=cfg)
    t0 = time.perf_counter()
    res = solve_mincut(p, part=part, config=cfg)
    solve_s = time.perf_counter() - t0
    s = res.stats
    return dict(
        instance=f"grid{size}x{size}_r{regions[0]}x{regions[1]}",
        driver=label.split("/")[0],
        config=label,
        backend=cfg.engine_backend,
        method=cfg.method,
        device_resident=cfg.device_resident,
        chunk_iters=cfg.engine_chunk_iters,
        solve_s=round(solve_s, 3),
        sweeps=s.sweeps,
        engine_iters=s.engine_iters,
        kernel_launches=s.engine_launches,
        launches_per_sweep=round(s.engine_launches / max(1, s.sweeps), 2),
        host_syncs=s.host_syncs,
        flow=res.flow_value,
    )


def collect(quick: bool = False) -> dict:
    import jax

    sizes = ([(12, (2, 2))] if quick
             else [(16, (2, 2)), (24, (2, 2)), (32, (2, 2)),
                   (48, (2, 2))])
    rows = []
    for size, regions in sizes:
        per = {}
        for label, cfg in _configs():
            row = _bench_instance(size, regions, label, cfg, quick)
            per[label] = row
            rows.append(row)
        flows = {r["flow"] for r in per.values()}
        assert len(flows) == 1, "driver/backend parity violated in bench"
        for backend in BACKENDS:
            h, d = per[f"host/{backend}"], per[f"device/{backend}"]
            # device-resident must be bit-exact with the host loop
            assert (h["sweeps"], h["engine_iters"], h["kernel_launches"]) \
                == (d["sweeps"], d["engine_iters"], d["kernel_launches"])
            d["sync_reduction"] = round(
                h["host_syncs"] / max(1, d["host_syncs"]), 2)
        one = per["device/pallas-prd-1launch"]
        assert one["kernel_launches"] == one["sweeps"], \
            "PRD big-chunk pallas must launch exactly once per sweep"
    return dict(
        bench="sweep_driver",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        pallas_interpret=jax.default_backend() != "tpu",
        fused_chunk_iters=FUSED_CHUNK_ITERS,
        prd_big_chunk=PRD_BIG_CHUNK,
        results=rows,
    )


def smoke() -> None:
    """CI guard: tiny instance, every driver configuration, oracle flow."""
    from repro.core import grid_partition, solve_mincut
    from repro.data.grids import synthetic_grid
    from repro.kernels.ref import maxflow_oracle

    p = synthetic_grid(8, 8, connectivity=8, strength=150, seed=0)
    part = grid_partition((8, 8), (2, 2))
    want, _ = maxflow_oracle(p)
    stats = {}
    for label, cfg in _configs():
        res = solve_mincut(p, part=part, config=cfg)
        assert res.flow_value == want, (
            f"{label}: flow {res.flow_value} != oracle {want}")
        stats[label] = res.stats
        print(f"smoke ok: {label} flow={res.flow_value} "
              f"sweeps={res.stats.sweeps} "
              f"launches={res.stats.engine_launches} "
              f"host_syncs={res.stats.host_syncs}")
    for backend in BACKENDS:
        h, d = stats[f"host/{backend}"], stats[f"device/{backend}"]
        assert (h.sweeps, h.engine_iters, h.engine_launches) == \
            (d.sweeps, d.engine_iters, d.engine_launches), backend
        assert d.host_syncs == 1, backend
        assert h.host_syncs == h.sweeps + 1, backend
    one = stats["device/pallas-prd-1launch"]
    assert one.engine_launches == one.sweeps and one.host_syncs == 1
    print(f"smoke passed: oracle flow {want}; device-resident bit-exact "
          f"with host loop; 1 launch/sweep on the PRD big-chunk row")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        emit(f"sweep/{row['config']}/{row['instance']}",
             row["solve_s"] * 1e6,
             f"sweeps={row['sweeps']};launches={row['kernel_launches']};"
             f"launches_per_sweep={row['launches_per_sweep']};"
             f"host_syncs={row['host_syncs']};flow={row['flow']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-instance oracle check (CI), no JSON output")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_sweep.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
