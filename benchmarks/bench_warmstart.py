"""Warm-start re-solve benchmark: cold solve vs incremental warm re-solve.

The serving workload of the session front-end (PR 5): prepare a problem
once, then repeatedly perturb a p=1% fraction of its edge capacities and
re-solve through ``handle.update`` + warm ``handle.solve()``.  Per
instance and configuration it records:

  * ``cold_sweeps`` / ``cold_launches`` / ``cold_s``   — a from-scratch
    solve of the perturbed problem (the pre-session serving cost);
  * ``warm_sweeps`` / ``warm_launches`` / ``warm_s``   — the warm re-solve
    from the previous optimum (Kohli-Torr reparameterization + exact
    global relabel + the same sweep drivers);
  * ``sweep_reduction`` / ``launch_reduction``         — cold / warm;
  * ``flow_equal``                                     — warm flow ==
    cold flow, asserted (bit-exact ints) before any column is emitted;
  * ``retraces_second_cycle``                          — session traces
    incurred by a second same-sized update+solve cycle: must be 0 (the
    update program is bucketed by padded edit size, the sweep programs by
    problem shape).

Results go to ``BENCH_warmstart.json``; on this CPU-only container the
Pallas kernel runs in interpret mode, so absolute times measure
correctness-path overhead, not TPU speed (the JSON records platform +
interpret mode).

    PYTHONPATH=src python benchmarks/bench_warmstart.py [--quick]
        [--smoke] [--out BENCH_warmstart.json]

``--smoke`` runs a tiny instance through every configuration, asserts the
warm flow against the cold solve AND the Edmonds-Karp oracle, warm sweeps
<= cold sweeps, and the zero-retrace steady state — the CI guard for the
warm-start plumbing.

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.common import emit_csv  # noqa: E402

PERTURB = 0.01          # the acceptance perturbation: 1% of the edges


def _configs(big: bool):
    """(label, SolverOptions).  The 64^2 headline row runs the default
    engine; smaller rows add the device-resident and fused-pallas
    (interpret off-TPU) variants."""
    from repro.core import SolverOptions

    yield "ard/xla", SolverOptions()
    if not big:
        yield "ard/xla-dr", SolverOptions(device_resident=True)
        yield "ard/pallas-fused-dr", SolverOptions(
            engine_backend="pallas", engine_chunk_iters=8,
            device_resident=True)
        yield "prd/xla", SolverOptions(method="prd")


def _instances(quick: bool):
    """(label, problem, part, regions, big).  The interactive-segmentation
    seeds instance (sparse scribble terminals) is the headline: all flow
    crosses region boundaries, so cold solves genuinely need sweeps."""
    from repro.core import grid_partition
    from repro.data.grids import segmentation_seeds_grid, synthetic_grid

    g = 24 if quick else 32
    yield (f"seg{g}_seeds", segmentation_seeds_grid(g, g, seed=0),
           grid_partition((g, g), (4, 4)), 16, False)
    yield ("syn16", synthetic_grid(16, 16, connectivity=8, strength=150,
                                   seed=0),
           grid_partition((16, 16), (2, 2)), 4, False)
    if not quick:
        yield ("seg64_seeds", segmentation_seeds_grid(64, 64, seed=0),
               grid_partition((64, 64), (4, 4)), 16, True)


def _perturb_kwargs(problem, rng):
    m = len(problem.edges)
    k = max(1, int(round(PERTURB * m)))
    idx = rng.choice(m, size=k, replace=False)
    hi = int(max(problem.cap_fwd.max(), problem.cap_bwd.max())) * 2 + 1
    return dict(arcs=idx,
                cap_fwd=rng.randint(0, hi, size=k).astype(np.int32),
                cap_bwd=rng.randint(0, hi, size=k).astype(np.int32))


def _bench(label, opts, prob, part, regions):
    import dataclasses

    from repro.core import Solver, solve_mincut

    opts = dataclasses.replace(opts, num_regions=regions, check=False)
    solver = Solver(opts)
    handle = solver.prepare(prob, part)
    handle.solve()                           # initial optimum (+ warm-up)

    rng = np.random.RandomState(0)
    handle.update(**_perturb_kwargs(handle.problem, rng))
    t0 = time.perf_counter()
    warm = handle.solve()
    warm_s = time.perf_counter() - t0

    cfg = opts.sweep_config()
    solve_mincut(prob, part=part, config=cfg, check=False)   # warm-up jit
    t0 = time.perf_counter()
    cold = solve_mincut(handle.problem, part=part, config=cfg, check=False)
    cold_s = time.perf_counter() - t0
    assert warm.flow_value == cold.flow_value, (label, warm.flow_value,
                                                cold.flow_value)

    # steady state: a second same-sized cycle must retrace nothing
    traces = solver.cache_info().traces
    handle.update(**_perturb_kwargs(handle.problem, rng))
    warm2 = handle.solve()
    retraces = solver.cache_info().traces - traces
    cold2 = solve_mincut(handle.problem, part=part, config=cfg, check=False)
    assert warm2.flow_value == cold2.flow_value, label

    return dict(
        config=label,
        method=opts.method,
        backend=opts.engine_backend,
        device_resident=opts.device_resident,
        perturb=PERTURB,
        flow=warm.flow_value,
        flow_equal=True,
        cold_sweeps=cold.stats.sweeps,
        warm_sweeps=warm.stats.sweeps,
        sweep_reduction=round(cold.stats.sweeps / max(1, warm.stats.sweeps),
                              2),
        cold_launches=cold.stats.engine_launches,
        warm_launches=warm.stats.engine_launches,
        launch_reduction=round(cold.stats.engine_launches
                               / max(1, warm.stats.engine_launches), 2),
        cold_s=round(cold_s, 3),
        warm_s=round(warm_s, 3),
        speedup=round(cold_s / max(1e-9, warm_s), 2),
        retraces_second_cycle=retraces,
    )


def collect(quick: bool = False) -> dict:
    import jax

    rows = []
    for ilabel, prob, part, regions, big in _instances(quick):
        for clabel, opts in _configs(big):
            row = _bench(clabel, opts, prob, part, regions)
            row["instance"] = ilabel
            rows.append(row)
            assert row["retraces_second_cycle"] == 0, (ilabel, clabel)
    return dict(
        bench="warmstart",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        pallas_interpret=jax.default_backend() != "tpu",
        perturb=PERTURB,
        results=rows,
    )


def smoke() -> None:
    """CI guard: tiny instances, every configuration, warm == cold ==
    oracle flows, warm sweeps <= cold sweeps, zero retraces."""
    import dataclasses

    from repro.core import Solver, grid_partition, solve_mincut
    from repro.data.grids import segmentation_seeds_grid
    from repro.kernels.ref import maxflow_oracle

    g = 16
    prob = segmentation_seeds_grid(g, g, seed=0)
    part = grid_partition((g, g), (2, 2))
    for clabel, opts in _configs(big=False):
        opts = dataclasses.replace(opts, num_regions=4, check=True)
        solver = Solver(opts)
        handle = solver.prepare(prob, part)
        handle.solve()
        rng = np.random.RandomState(1)
        handle.update(**_perturb_kwargs(handle.problem, rng))
        warm = handle.solve()
        cold = solve_mincut(handle.problem, part=part,
                            config=opts.sweep_config())
        want, _ = maxflow_oracle(handle.problem)
        assert warm.flow_value == cold.flow_value == want, clabel
        assert warm.stats.sweeps <= cold.stats.sweeps, clabel
        traces = solver.cache_info().traces
        handle.update(**_perturb_kwargs(handle.problem, rng))
        handle.solve()
        assert solver.cache_info().traces == traces, clabel
        print(f"smoke ok: {clabel} flow={warm.flow_value} "
              f"warm_sweeps={warm.stats.sweeps} "
              f"cold_sweeps={cold.stats.sweeps} retraces=0")
    print("smoke passed: warm == cold == oracle flows, warm <= cold "
          "sweeps, zero retraces on the second update+solve cycle")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        emit(f"warmstart/{row['config']}/{row['instance']}",
             row["warm_s"] * 1e6,
             f"sweep_reduction={row['sweep_reduction']};"
             f"launch_reduction={row['launch_reduction']};"
             f"speedup={row['speedup']};"
             f"retraces={row['retraces_second_cycle']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-instance warm-vs-cold oracle check (CI), "
                         "no JSON output")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_warmstart.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
