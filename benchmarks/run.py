"""Benchmark entry point — one block per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks instance
sizes; full runs feed EXPERIMENTS.md §Paper-validation.  Roofline numbers
come from the dry-run artifacts (benchmarks/roofline_table formats them),
not from CPU timing.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_batch, bench_competitions,
                            bench_engine_backend, bench_lm, bench_memory,
                            bench_resilience, bench_service,
                            bench_sweep_driver, bench_synthetic,
                            bench_warmstart)

    mods = [("synthetic", bench_synthetic),
            ("engine_backend", bench_engine_backend),
            ("sweep_driver", bench_sweep_driver),
            ("batch", bench_batch),
            ("warmstart", bench_warmstart),
            ("resilience", bench_resilience),
            ("service", bench_service),
            ("competitions", bench_competitions),
            ("lm", bench_lm),
            ("memory", bench_memory)]
    print("name,us_per_call,derived")
    for name, mod in mods:
        if args.only and args.only not in name:
            continue
        mod.run(emit_csv, quick=args.quick)


if __name__ == "__main__":
    main()
