"""Paper Sec. 7.1 synthetic sweeps — Figures 6b, 7, 8, 9, 10.

Each figure becomes a CSV block: sweeps + CPU time for S-ARD vs S-PRD as a
function of one generator parameter, on CPU-sized grids (the paper's
qualitative claims — ARD's sweep count is flat where PRD's grows — are the
assertions checked by EXPERIMENTS.md §Paper-validation).
"""

from __future__ import annotations

import time

from benchmarks.common import emit_csv
from repro.core import SweepConfig, grid_partition, solve_mincut
from repro.data.grids import synthetic_grid


def _solve(p, part, method, **kw):
    t0 = time.perf_counter()
    res = solve_mincut(p, part=part,
                       config=SweepConfig(method=method, **kw))
    dt = (time.perf_counter() - t0) * 1e6
    return res, dt


def fig6b_strength(emit=emit_csv, quick=False):
    size = 20 if quick else 28
    part = grid_partition((size, size), (2, 2))
    strengths = [10, 150, 1000] if quick else [10, 50, 150, 500, 1000]
    for s in strengths:
        p = synthetic_grid(size, size, connectivity=8, strength=s, seed=0)
        for m in ("ard", "prd"):
            res, us = _solve(p, part, m)
            emit(f"fig6b/{m}/strength={s}", us,
                 f"sweeps={res.stats.sweeps};flow={res.flow_value}")


def fig7_regions(emit=emit_csv, quick=False):
    size = 24 if quick else 32
    splits = [(1, 2), (2, 2)] if quick else [(1, 2), (2, 2), (2, 4), (4, 4)]
    p = synthetic_grid(size, size, connectivity=8, strength=150, seed=0)
    for sy, sx in splits:
        part = grid_partition((size, size), (sy, sx))
        for m in ("ard", "prd"):
            res, us = _solve(p, part, m)
            emit(f"fig7/{m}/regions={sy * sx}", us,
                 f"sweeps={res.stats.sweeps}")


def fig8_size(emit=emit_csv, quick=False):
    sizes = [16, 24] if quick else [16, 24, 32, 40]
    for size in sizes:
        p = synthetic_grid(size, size, connectivity=8, strength=150, seed=0)
        part = grid_partition((size, size), (2, 2))
        for m in ("ard", "prd"):
            res, us = _solve(p, part, m)
            emit(f"fig8/{m}/n={size * size}", us,
                 f"sweeps={res.stats.sweeps}")


def fig9_connectivity(emit=emit_csv, quick=False):
    size = 20 if quick else 24
    conns = [4, 8] if quick else [4, 8, 16, 24]
    part = grid_partition((size, size), (2, 2))
    for c in conns:
        strength = max(1, (150 * 8) // c)       # paper's normalisation
        p = synthetic_grid(size, size, connectivity=c, strength=strength,
                           seed=0)
        for m in ("ard", "prd"):
            res, us = _solve(p, part, m)
            emit(f"fig9/{m}/conn={c}", us, f"sweeps={res.stats.sweeps}")


def fig10_workload(emit=emit_csv, quick=False):
    """Workload split proxy: engine iterations vs sweeps vs boundary bytes
    (the paper's msg/discharge/relabel/gap split maps to engine iterations
    [discharge], boundary bytes [msg] and sweeps [gap+relabel overhead])."""
    size = 20 if quick else 28
    p = synthetic_grid(size, size, connectivity=8, strength=150, seed=0)
    part = grid_partition((size, size), (2, 2))
    for m in ("ard", "prd"):
        res, us = _solve(p, part, m)
        s = res.stats
        emit(f"fig10/{m}/workload", us,
             f"sweeps={s.sweeps};engine_iters={s.engine_iters};"
             f"boundary_bytes={s.boundary_bytes};page_bytes={s.page_bytes}")


def run(emit=emit_csv, quick=False):
    fig6b_strength(emit, quick)
    fig7_regions(emit, quick)
    fig8_size(emit, quick)
    fig9_connectivity(emit, quick)
    fig10_workload(emit, quick)
