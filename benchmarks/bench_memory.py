"""Memory benchmark: dtype-narrowed storage, VMEM headroom, DMA overlap.

Measures what the memory-lean kernel work actually buys, per dtype policy
(``int32`` baseline vs ``auto``/forced-``narrow``):

  * **bytes/vertex** of the region page (the sweep drivers' per-region
    HBM round trip, ``sweep._page_and_msg_bytes``) and bytes per boundary
    message arc;
  * **fused-kernel VMEM** for reference region shapes
    (``kernels.push_relabel.fused_region_vmem_bytes``) and the largest
    region that stays VMEM-resident under the budget, before/after
    narrowing;
  * **launch accounting** of the DMA-overlap path: engine launches per
    solve for unfused / fused-xla / fused-pallas, with the PR-3/4
    invariants asserted (2 per iteration unfused, 1 per iteration
    fused-xla, 1 per chunk trip fused-pallas), plus whether the
    double-buffered HBM->VMEM stream is active (TPU) or the grid
    fallback runs (interpret mode on this container);
  * **roofline terms** (``roofline.analysis.analyze``) of the
    AOT-compiled parallel-sweep program for at least two kernel configs,
    so EXPERIMENTS.md gets compute/memory/collective seconds per config
    alongside the byte counts.

Writes ``BENCH_memory.json``.

    PYTHONPATH=src python benchmarks/bench_memory.py [--quick]
        [--smoke] [--out BENCH_memory.json]

``--smoke`` (the CI guard) asserts on a tiny instance that: narrowed
solves match the wide flow bit-exactly; the autotuner's decision for the
instance's key fits the VMEM budget; the launch/sync counters obey the
engine invariants; and the roofline analysis of one AOT-compiled 16x16
sweep returns finite, classified terms.

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv  # noqa: E402

POLICIES = ("int32", "auto")
FUSED_CHUNK_ITERS = 8


def _page_rows(size, regions):
    """bytes/vertex + msg bytes/arc per dtype policy for one instance."""
    from repro.core import grid_partition
    from repro.core.graph import build
    from repro.core.sweep import _page_and_msg_bytes
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(size, size, connectivity=4, strength=3, seed=0)
    part = grid_partition((size, size), regions)
    rows = []
    for policy in POLICIES:
        meta, state, _ = build(p, part, dtype_policy=policy)
        page, msg = _page_and_msg_bytes(meta)
        kd = meta.kernel_dtypes
        rows.append(dict(
            instance=f"grid{size}x{size}",
            policy=policy,
            dtypes=f"{kd.label}/{kd.flow}/{kd.mask}",
            page_bytes=page,
            page_bytes_per_vertex=round(page / meta.region_size, 2),
            msg_bytes_per_arc=round(msg / max(1, meta.num_cross_arcs), 2),
        ))
    wide = rows[0]["page_bytes"]
    for r in rows[1:]:
        r["page_reduction"] = round(1 - r["page_bytes"] / wide, 3)
    return rows


def _vmem_rows():
    """Fused-kernel VMEM for reference shapes + max resident region."""
    from repro.core import dtypes as _dt
    from repro.kernels.push_relabel import (FUSED_VMEM_BUDGET_BYTES,
                                            fused_region_vmem_bytes)

    shapes = [(256, 8), (1024, 8), (4096, 8)]   # 16^2 / 32^2 / 64^2 regions
    rows = []
    for V, E in shapes:
        wide = fused_region_vmem_bytes(V, E, _dt.WIDE)
        narrow = fused_region_vmem_bytes(V, E, _dt.NARROW)
        rows.append(dict(
            region=f"V={V},E={E}",
            vmem_bytes_int32=wide,
            vmem_bytes_narrow=narrow,
            vmem_reduction=round(1 - narrow / wide, 3),
        ))

    def max_resident(kd, E=8):
        v = 1
        while fused_region_vmem_bytes(2 * v, E, kd) \
                <= FUSED_VMEM_BUDGET_BYTES:
            v *= 2
        return v

    return rows, dict(
        budget_bytes=FUSED_VMEM_BUDGET_BYTES,
        max_resident_vertices_int32=max_resident(_dt.WIDE),
        max_resident_vertices_narrow=max_resident(_dt.NARROW),
    )


def _launch_rows(size, regions):
    """Engine-launch accounting per mode, invariants asserted."""
    from repro.core import SweepConfig, grid_partition, solve_mincut
    from repro.data.grids import synthetic_grid
    from repro.kernels.push_relabel import dma_overlap_supported

    p = synthetic_grid(size, size, connectivity=4, strength=3, seed=0)
    part = grid_partition((size, size), regions)
    rows = []
    for backend, chunk, mode in (("xla", None, "unfused"),
                                 ("xla", FUSED_CHUNK_ITERS, "fused-xla"),
                                 ("pallas", FUSED_CHUNK_ITERS,
                                  "fused-pallas")):
        cfg = SweepConfig(method="ard", engine_backend=backend,
                          engine_chunk_iters=chunk)
        res = solve_mincut(p, part=part, config=cfg)
        iters, launches = res.stats.engine_iters, res.stats.engine_launches
        if mode == "unfused":
            assert launches == 2 * iters, (launches, iters)
        elif mode == "fused-xla":
            assert launches == iters, (launches, iters)
        else:                         # fused-pallas: one launch per trip
            assert launches <= iters, (launches, iters)
        rows.append(dict(mode=mode, engine_iters=iters,
                         engine_launches=launches, flow=res.flow_value))
    flows = {r["flow"] for r in rows}
    assert len(flows) == 1, "mode parity violated in bench"
    return rows, dma_overlap_supported()


def _roofline_rows(size, regions):
    """Roofline terms of the AOT-compiled parallel sweep per config."""
    import jax.numpy as jnp

    from repro.core import SweepConfig, grid_partition
    from repro.core.graph import build, init_labels
    from repro.core.sweep import parallel_sweep
    from repro.data.grids import synthetic_grid
    from repro.roofline import analysis as _ra

    p = synthetic_grid(size, size, connectivity=4, strength=3, seed=0)
    part = grid_partition((size, size), regions)
    rows = []
    for policy in POLICIES:
        meta, state, _ = build(p, part, dtype_policy=policy)
        state = init_labels(meta, state)
        for backend, chunk in (("xla", None),
                               ("pallas", FUSED_CHUNK_ITERS)):
            cfg = SweepConfig(method="ard", engine_backend=backend,
                              engine_chunk_iters=chunk)
            compiled = parallel_sweep.lower(
                meta, state, cfg, jnp.asarray(0, jnp.int32)).compile()
            rl = _ra.analyze(compiled, n_chips=1)
            mem = _ra.memory_summary(compiled)
            rows.append(dict(
                config=f"{backend}/"
                       f"{'fused' if chunk else 'unfused'}/{policy}",
                flops=rl.flops,
                bytes_accessed=rl.bytes_accessed,
                compute_s=rl.compute_s,
                memory_s=rl.memory_s,
                collective_s=rl.collective_s,
                bottleneck=rl.bottleneck,
                peak_bytes_per_device=mem.get(
                    "approx_peak_bytes_per_device"),
            ))
    return rows


def collect(quick: bool = False) -> dict:
    import jax

    size, regions = (8, (2, 2)) if quick else (16, (2, 2))
    vmem_rows, resident = _vmem_rows()
    launch_rows, dma = _launch_rows(size, regions)
    return dict(
        bench="memory",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        pallas_interpret=jax.default_backend() != "tpu",
        dma_overlap_active=dma,
        page_bytes=_page_rows(size, regions),
        fused_vmem=vmem_rows,
        vmem_resident=resident,
        launch_accounting=launch_rows,
        roofline=_roofline_rows(size, regions),
    )


def smoke() -> None:
    """CI guard: narrowing is bit-exact, the autotuner stays in budget,
    launch/sync counters obey the engine invariants, and the roofline
    analysis of one AOT-compiled sweep classifies its terms."""
    import tempfile

    from repro.core import Solver, SolverOptions, grid_partition
    from repro.core.autotune import tune
    from repro.data.grids import synthetic_grid
    from repro.kernels.push_relabel import FUSED_VMEM_BUDGET_BYTES
    from repro.kernels.ref import maxflow_oracle

    p = synthetic_grid(8, 8, connectivity=4, strength=3, seed=0)
    part = grid_partition((8, 8), (2, 2))
    want, _ = maxflow_oracle(p)
    flows = {}
    for policy in ("int32", "narrow"):
        s = Solver(SolverOptions(dtype_policy=policy))
        h = s.prepare(p, part)
        res = h.solve()
        flows[policy] = (res.flow_value, res.stats.sweeps,
                         res.stats.engine_iters)
        assert res.flow_value == want, (policy, res.flow_value, want)
        if policy == "narrow":
            assert h.meta.kernel_dtypes.flow == "int16", h.meta.kernel_dtypes
    assert flows["int32"] == flows["narrow"], flows
    print(f"smoke ok: narrow == int32 == oracle "
          f"(flow={want}, sweeps={flows['int32'][1]}, "
          f"iters={flows['int32'][2]})")

    with tempfile.TemporaryDirectory() as d:
        meta = Solver(SolverOptions(dtype_policy="auto")) \
            .prepare(p, part).meta
        tc = tune(meta.region_size, meta.max_degree, backend="pallas",
                  dtypes=meta.kernel_dtypes, cache=Path(d) / "at.json")
        assert (not tc.fused) or tc.vmem_bytes <= FUSED_VMEM_BUDGET_BYTES, \
            tc
        tc2 = tune(meta.region_size, meta.max_degree, backend="pallas",
                   dtypes=meta.kernel_dtypes, cache=Path(d) / "at.json")
        assert tc == tc2, "autotune cache not deterministic"
    print(f"smoke ok: autotuned config in budget "
          f"(fused={tc.fused}, vmem={tc.vmem_bytes}B, "
          f"chunk_iters={tc.engine_chunk_iters})")

    rows, dma = _launch_rows(8, (2, 2))
    counts = ", ".join("{}={}".format(r["mode"], r["engine_launches"])
                       for r in rows)
    print(f"smoke ok: launch invariants hold ({counts}, dma_overlap={dma})")

    rl = _roofline_rows(16, (2, 2))
    assert len(rl) >= 2
    for r in rl:
        assert r["bytes_accessed"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective"), r
    print(f"smoke ok: roofline terms on {len(rl)} AOT-compiled configs "
          f"(bottleneck={rl[0]['bottleneck']})")
    print("smoke passed: memory/dtype plumbing verified")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["page_bytes"]:
        emit(f"memory/page/{row['instance']}/{row['policy']}",
             row["page_bytes_per_vertex"],
             f"dtypes={row['dtypes']};msg_per_arc={row['msg_bytes_per_arc']}")
    for row in data["fused_vmem"]:
        emit(f"memory/vmem/{row['region']}", row["vmem_bytes_narrow"],
             f"int32={row['vmem_bytes_int32']};"
             f"reduction={row['vmem_reduction']}")
    for row in data["roofline"]:
        emit(f"memory/roofline/{row['config']}", row["bytes_accessed"],
             f"bottleneck={row['bottleneck']};flops={row['flops']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-instance invariants check (CI), no JSON")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_memory.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    print(json.dumps(data["vmem_resident"], indent=2))
    for row in data["fused_vmem"] + data["roofline"]:
        print(row)


if __name__ == "__main__":
    main()
