"""Serving-tier benchmark: throughput and tail latency under offered load.

The service question the robustness layer must answer: what happens when
offered load crosses capacity?  Below capacity the bounded queue never
fills (sheds == 0, p99 ~ service time); above it, admission control
sheds the overflow with typed ``ServiceOverloaded`` errors while the p99
of ADMITTED requests stays bounded by the queue depth — the service
degrades by shedding, never by queueing unboundedly or falling over.

Method: calibrate the sustainable completion rate with a compiled-warm
burst, then replay paced request streams (25% carrying tight deadlines)
at offered loads below (0.5x) and above (3x) that rate, plus a stream
with a kernel fault injected mid-way (the degradation ladder + breaker
absorb it without failing in-flight requests).  Asserted on every run:

  * below capacity: ``sheds == 0``;
  * above capacity: ``sheds > 0``, ``max_queue_depth <= max_queue`` and
    ``p99 <= BOUND_SLACK * (max_queue + max_batch) / sustainable`` (the
    structural queue-delay bound, with CPU-jitter slack);
  * fault stream: every request resolves (result or typed error),
    ``failed == 0``, ``degradations >= 1``.

Results go to ``BENCH_service.json``; on this CPU-only container the
absolute rates measure correctness-path behavior, not TPU speed (the
JSON records the platform).

    PYTHONPATH=src python benchmarks/bench_service.py [--quick]
        [--smoke] [--out BENCH_service.json]

``--smoke`` runs the same three scenarios at tiny N with the same
assertions — the CI guard for the serving tier.

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv  # noqa: E402

TIGHT_FRAC = 0.25        # fraction of requests with tight deadlines
TIGHT_TIMEOUT = 0.002    # s — well under a CPU solve: guaranteed misses
#                          when the queue backs up
BOUND_SLACK = 5.0        # CPU-jitter slack on the structural p99 bound
OVER_FACTOR = 3.0        # above-capacity offered-load multiple — enough
#                          excess rate to overflow the queue within even
#                          the smoke-sized stream


def _service(max_queue=4, max_batch=2, pallas=False, threshold=3):
    from repro.core.solver import SolverOptions
    from repro.serve import MaxflowService, ServiceConfig

    opts = SolverOptions(num_regions=4, check=True,
                         engine_backend="pallas" if pallas else "xla",
                         engine_chunk_iters=8 if pallas else None)
    return MaxflowService(opts, ServiceConfig(
        max_queue=max_queue, max_batch=max_batch, sync_every=2,
        breaker_threshold=threshold))


def _requests(n: int, tight: bool):
    from repro.data.grids import synthetic_grid
    from repro.serve import SolveRequest

    shapes = [(6, 6), (8, 8)]
    out = []
    for i in range(n):
        h, w = shapes[i % len(shapes)]
        timeout = TIGHT_TIMEOUT \
            if tight and (i % int(1 / TIGHT_FRAC)) == 0 else None
        out.append(SolveRequest(problem=synthetic_grid(h, w, seed=i % 8),
                                timeout=timeout, tenant=f"t{i % 2}"))
    return out


def _calibrate(n: int) -> float:
    """Sustainable completion rate (req/s) of a compiled-warm burst."""
    from repro.serve import replay_stream

    for attempt in range(2):          # first pass pays compiles; time 2nd
        svc = _service(max_queue=n)
        t0 = time.perf_counter()
        replay_stream(svc, _requests(n, tight=False))
        elapsed = time.perf_counter() - t0
    assert svc.stats.completed == n and svc.stats.sheds == 0
    return n / elapsed


def _replay(n: int, rate: float, *, pallas=False, fault=False,
            threshold=3) -> dict:
    import contextlib

    from repro.core import FaultPlan, fault_injection
    from repro.serve import ServiceError, replay_stream

    svc = _service(pallas=pallas, threshold=threshold)
    reqs = _requests(n, tight=True)
    ctx = fault_injection(FaultPlan(
        "vmem_overflow", at_sweep=1, times=1, route="device")) \
        if fault else contextlib.nullcontext()
    t0 = time.perf_counter()
    with ctx:
        tickets = replay_stream(svc, reqs, rate=rate)
    elapsed = time.perf_counter() - t0
    for t in tickets:                 # liveness: every request resolved,
        assert t.done                 # errors all typed
        assert t.error is None or isinstance(t.error, ServiceError)
    s = svc.stats
    assert s.completed + s.deadline_misses + s.sheds + s.failed == n
    assert svc.healthy()
    q = s.latency_quantiles()
    return dict(
        requests=n, offered_rate=round(rate, 2),
        completed=s.completed, sheds=s.sheds,
        deadline_misses=s.deadline_misses, failed=s.failed,
        faults=s.faults, degradations=s.degradations,
        breaker_trips=s.breaker_trips,
        max_queue_depth=s.max_queue_depth,
        queue_bound=svc.config.max_queue,
        p50_s=round(q["p50"], 4), p99_s=round(q["p99"], 4),
        throughput=round(s.completed / elapsed, 2),
        elapsed_s=round(elapsed, 3),
    )


def _scenarios(n: int, sustainable: float):
    cfg = _service().config
    bound = BOUND_SLACK * (cfg.max_queue + cfg.max_batch) / sustainable

    below = _replay(n, 0.5 * sustainable)
    assert below["sheds"] == 0, \
        f"shed below capacity: {below}"

    above = _replay(n, OVER_FACTOR * sustainable)
    assert above["sheds"] > 0, \
        f"no shedding at {OVER_FACTOR}x capacity: {above}"
    assert above["max_queue_depth"] <= above["queue_bound"], above
    assert above["p99_s"] <= bound, \
        f"p99 {above['p99_s']}s above structural bound {bound:.3f}s"

    faulted = _replay(n, OVER_FACTOR * sustainable, pallas=True, fault=True,
                      threshold=1)
    assert faulted["failed"] == 0, \
        f"kernel fault failed in-flight requests: {faulted}"
    assert faulted["faults"] >= 1 and faulted["degradations"] >= 1, faulted

    below["scenario"], above["scenario"] = "below_capacity", "above_capacity"
    faulted["scenario"] = "above_capacity_vmem_fault"
    above["p99_bound_s"] = round(bound, 4)
    return [below, above, faulted]


def collect(quick: bool = False) -> dict:
    import jax

    n = 24 if quick else 64
    sustainable = _calibrate(16 if quick else 32)
    rows = _scenarios(n, sustainable)
    return dict(
        bench="service",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        sustainable_rate=round(sustainable, 2),
        tight_deadline_frac=TIGHT_FRAC,
        results=rows,
    )


def smoke() -> None:
    """CI guard: the three scenarios at tiny N, same assertions."""
    sustainable = _calibrate(8)
    rows = _scenarios(16, sustainable)
    for row in rows:
        print(f"smoke ok: {row['scenario']} completed={row['completed']} "
              f"sheds={row['sheds']} misses={row['deadline_misses']} "
              f"failed={row['failed']} p99={row['p99_s']}s "
              f"qmax={row['max_queue_depth']}/{row['queue_bound']}")
    print("smoke passed: bounded below/above capacity, kernel fault "
          "degraded without failing in-flight requests")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        emit(f"service/{row['scenario']}",
             row["p99_s"] * 1e6,
             f"throughput={row['throughput']};sheds={row['sheds']};"
             f"misses={row['deadline_misses']};"
             f"qmax={row['max_queue_depth']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny three-scenario run with the same "
                         "assertions (CI), no JSON output")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_service.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
