"""LM-side microbenchmarks: smoke-scale train/decode step timings per
architecture family + kernel timings (CPU interpret — correctness-scale
numbers; the TPU numbers come from the dry-run roofline)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_csv, time_call
from repro.configs import ARCHS
from repro.models.model import forward, init_cache, init_params
from repro.train import optimizer as opt_lib
from repro.train import train_loop as tl

FAMILIES = ["phi3-mini-3.8b", "deepseek-moe-16b", "xlstm-350m",
            "recurrentgemma-9b", "hubert-xlarge"]


def _batch(cfg, key, B, S):
    if cfg.frontend == "audio_frames":
        return {"frames": jax.random.normal(key, (B, S, cfg.frontend_dim)),
                "labels": jnp.zeros((B, S), jnp.int32),
                "mask": jnp.ones((B, S), jnp.float32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32)}


def train_step_bench(emit=emit_csv, quick=False):
    B, S = (2, 32) if quick else (4, 64)
    for name in (FAMILIES[:3] if quick else FAMILIES):
        cfg = ARCHS[name].smoke()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        state = tl.TrainState(params=params,
                              opt=opt_lib.init_opt_state(params))
        step = jax.jit(tl.make_train_step(
            cfg, opt_lib.AdamWConfig(), jnp.float32))
        batch = _batch(cfg, jax.random.PRNGKey(1), B, S)
        us, _ = time_call(lambda: step(state, batch), repeats=3)
        emit(f"lm/train_step/{name}", us,
             f"tok_per_s={B * S / (us / 1e6):.0f}")


def decode_step_bench(emit=emit_csv, quick=False):
    B, T = (2, 64) if quick else (4, 128)
    for name in (["phi3-mini-3.8b"] if quick
                 else ["phi3-mini-3.8b", "xlstm-350m", "recurrentgemma-9b"]):
        cfg = ARCHS[name].smoke()
        params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        cache = init_cache(cfg, B, T, dtype=jnp.float32)
        toks = jnp.ones((B, 8), jnp.int32)
        _, cache = forward(cfg, params, {"tokens": toks}, mode="prefill",
                           cache=cache, dtype=jnp.float32)
        step = jax.jit(lambda p, t, c: forward(
            cfg, p, {"tokens": t}, mode="decode", cache=c,
            dtype=jnp.float32))
        tok = jnp.ones((B, 1), jnp.int32)
        us, _ = time_call(lambda: step(params, tok, cache), repeats=3)
        emit(f"lm/decode_step/{name}", us,
             f"tok_per_s={B / (us / 1e6):.0f}")


def kernel_bench(emit=emit_csv, quick=False):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import attention_ref

    B, H, S, D = 1, 2, 128, 64
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
    us_k, _ = time_call(
        lambda: flash_attention(q, q, q, block_q=64, block_k=64,
                                interpret=True), repeats=2)
    emit("kernel/flash_attention_interp", us_k, f"S={S}")
    us_r, _ = time_call(lambda: attention_ref(q, q, q), repeats=2)
    emit("kernel/attention_ref", us_r, f"S={S}")


def run(emit=emit_csv, quick=False):
    train_step_bench(emit, quick)
    decode_step_bench(emit, quick)
    kernel_bench(emit, quick)
