"""Resilience overhead benchmark: what fault tolerance costs, what a
resume saves.

Two questions, answered per instance x route x cadence:

  * ``overhead_pct`` — wall-time cost of capturing sweep-boundary
    checkpoints during a solve, vs the identical un-checkpointed solve
    (one host fetch + one atomic npz publish per boundary).  The
    acceptance bar: at cadence >= 5 sweeps the overhead stays under 10%
    of wall (asserted here for the full run's headline rows).
  * ``resume_savings_pct`` — wall time saved by resuming from a mid-solve
    checkpoint (at roughly half the sweeps) instead of re-solving cold:
    the value a preempted worker recovers.  The resumed flow is asserted
    bit-equal to the cold solve's before any row is emitted.

Routes: the host loop (a checkpoint opportunity at every sweep boundary)
and the device-resident driver (boundaries at ``host_sync_every``).
Results go to ``BENCH_resilience.json``; on this CPU-only container the
absolute times measure correctness-path overhead, not TPU speed (the
JSON records the platform).

    PYTHONPATH=src python benchmarks/bench_resilience.py [--quick]
        [--smoke] [--out BENCH_resilience.json]

``--smoke`` runs a tiny instance through both routes: checkpoints appear
on disk, the resumed solve matches the uninterrupted one and the
Edmonds-Karp oracle bit-exactly — the CI guard for the resilience
plumbing (wall-clock assertions need the full run's instance sizes).

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from benchmarks.common import emit_csv  # noqa: E402

REPEATS = 3


def _routes():
    from repro.core.sweep import SweepConfig

    yield "host", SweepConfig(method="ard")
    yield "device-sync5", SweepConfig(method="ard", device_resident=True,
                                      host_sync_every=5)


def _instances(quick: bool):
    from repro.core import grid_partition
    from repro.data.grids import synthetic_grid

    g = 32 if quick else 64
    yield (f"syn{g}", synthetic_grid(g, g, connectivity=8, strength=150,
                                     seed=0),
           grid_partition((g, g), (2, 2)))


def _median_wall(fn, repeats: int = REPEATS) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _bench(ilabel, prob, part, rlabel, cfg, cadence: int, workdir: Path):
    from repro.core import build, init_labels
    from repro.core import resilience as res
    from repro.core.sweep import solve

    meta, state, _ = build(prob, part)
    st0 = init_labels(meta, state)

    base_st, base_stats = solve(meta, st0, cfg)     # warm-up jit + baseline
    plain_s = _median_wall(lambda: solve(meta, st0, cfg))

    def checkpointed(d):
        return solve(meta, st0, cfg, checkpoint=res.CheckpointPolicy(
            directory=d, every=cadence))

    # fresh dir per repeat: every run pays the full publish stream
    def one_ck():
        with tempfile.TemporaryDirectory(dir=workdir) as d:
            checkpointed(Path(d) / "ck")

    ck_s = _median_wall(one_ck)

    ckdir = workdir / f"{ilabel}_{rlabel}_c{cadence}"
    _st, _stats = checkpointed(ckdir)
    steps = sorted(int(p.name[5:]) for p in ckdir.iterdir()
                   if p.is_dir() and not p.name.endswith(".tmp"))
    assert steps, "no checkpoint published"

    # resume-vs-cold: continue from the boundary nearest half the sweeps
    mid = min(steps, key=lambda s: abs(s - base_stats.sweeps / 2))
    ck = res.load_checkpoint(ckdir, mid)
    st_r, stats_r = solve(meta, st0, cfg, resume_from=ck)
    assert int(st_r.flow_to_t) == int(base_st.flow_to_t)
    assert stats_r.sweeps == base_stats.sweeps
    np.testing.assert_array_equal(np.asarray(st_r.d), np.asarray(base_st.d))
    resume_s = _median_wall(lambda: solve(meta, st0, cfg, resume_from=ck))

    overhead = 100.0 * (ck_s - plain_s) / plain_s
    return dict(
        instance=ilabel, route=rlabel, cadence=cadence,
        sweeps=base_stats.sweeps, checkpoints=len(steps),
        flow=int(base_st.flow_to_t),
        plain_s=round(plain_s, 4), checkpointed_s=round(ck_s, 4),
        overhead_pct=round(overhead, 2),
        resume_from_sweep=mid,
        cold_s=round(plain_s, 4), resume_s=round(resume_s, 4),
        resume_savings_pct=round(100.0 * (1 - resume_s / plain_s), 2),
        resume_bit_exact=True,
    )


def collect(quick: bool = False) -> dict:
    import jax

    rows = []
    with tempfile.TemporaryDirectory(prefix="bench_resilience_") as wd:
        for ilabel, prob, part in _instances(quick):
            for rlabel, cfg in _routes():
                for cadence in (1, 5):
                    if rlabel != "host" and cadence != 5:
                        continue      # device boundaries sit at sync5
                    rows.append(_bench(ilabel, prob, part, rlabel, cfg,
                                       cadence, Path(wd)))
    if not quick:
        for row in rows:
            if row["cadence"] >= 5:   # the acceptance bar (full sizes only)
                assert row["overhead_pct"] < 10.0, row
    return dict(
        bench="resilience",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        repeats=REPEATS,
        results=rows,
    )


def smoke() -> None:
    """CI guard: both routes checkpoint, resume bit-exactly, and match the
    Edmonds-Karp oracle on a tiny instance."""
    from repro.core import build, grid_partition, init_labels
    from repro.core import resilience as res
    from repro.core.sweep import solve
    from repro.data.grids import synthetic_grid
    from repro.kernels.ref import maxflow_oracle

    prob = synthetic_grid(10, 10, connectivity=8, strength=150, seed=0)
    part = grid_partition((10, 10), (2, 2))
    want, _ = maxflow_oracle(prob)
    meta, state, _ = build(prob, np.asarray(part))
    st0 = init_labels(meta, state)
    with tempfile.TemporaryDirectory() as wd:
        for rlabel, cfg in _routes():
            every = 1 if rlabel == "host" else 5
            ckdir = Path(wd) / rlabel
            base_st, base_stats = solve(meta, st0, cfg)
            solve(meta, st0, cfg, checkpoint=res.CheckpointPolicy(
                directory=ckdir, every=every))
            latest = res.latest_checkpoint(ckdir)
            assert latest is not None
            st_r, stats_r = solve(meta, st0, cfg, resume_from=ckdir)
            assert int(st_r.flow_to_t) == int(base_st.flow_to_t) == want
            assert stats_r.sweeps == base_stats.sweeps
            np.testing.assert_array_equal(np.asarray(st_r.d),
                                          np.asarray(base_st.d))
            print(f"smoke ok: {rlabel} flow={want} "
                  f"sweeps={base_stats.sweeps} "
                  f"latest_checkpoint={latest.sweeps}")
    print("smoke passed: both routes checkpoint to disk and resume "
          "bit-exactly to the oracle flow")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        emit(f"resilience/{row['route']}/c{row['cadence']}/"
             f"{row['instance']}",
             row["checkpointed_s"] * 1e6,
             f"overhead_pct={row['overhead_pct']};"
             f"resume_savings_pct={row['resume_savings_pct']};"
             f"checkpoints={row['checkpoints']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-instance checkpoint/resume oracle check "
                         "(CI), no JSON output")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_resilience.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
