"""Batched multi-instance solving benchmark: throughput and launch sharing.

PR 3 made a *single* solve one kernel launch per sweep and one host sync
per solve; this benchmark measures the instance axis on top — a fleet of
problems packed into shape buckets (``graph.pack_instances``) and solved
by ONE batched device program per bucket (``grid=(B, K)`` fused kernel,
per-instance convergence flags).  Per batch and configuration it records:

  * ``seq_s`` / ``batch_s``            — wall time of the sequential loop
                                         (device-resident single solves)
                                         vs the batched solve, post-warmup;
  * ``inst_per_s_{seq,batch}``         — the throughput headline;
  * ``seq_launches`` / ``batch_launches`` — compute-program dispatches,
                                         summed over the loop vs global to
                                         the batch;
  * ``launch_reduction``               — seq/batch: >= B on the fused
                                         pallas path for a uniform batch
                                         (every instance rides the same
                                         grid=(B,K) launch stream);
  * ``launches_per_instance``          — batch_launches / B;
  * ``retraces_second_solve``          — batched device-program traces
                                         incurred by a second batch in the
                                         same bucket: must be 0 (the
                                         compile cache is keyed on bucket
                                         shape, not instance content).

Per-instance results are asserted bit-exact against the single-instance
driver (flow, sweeps, engine iters) — every column is a pure performance
knob.  Results go to ``BENCH_batch.json``; on this CPU-only container the
Pallas kernel runs in interpret mode, so absolute times measure
correctness-path overhead, not TPU speed (the JSON records platform +
interpret mode).

    PYTHONPATH=src python benchmarks/bench_batch.py [--quick]
        [--smoke] [--out BENCH_batch.json]

``--smoke`` runs a tiny mixed-shape batch through every configuration,
asserts every flow against the Edmonds-Karp oracle, the >= B x launch
reduction on the uniform fused-pallas batch, and the zero-recompile
property — the CI guard for the batched plumbing.

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv  # noqa: E402

FUSED_CHUNK_ITERS = 8
PRD_BIG_CHUNK = 1 << 20     # larger than any discharge: 1 launch per sweep


def _configs():
    import dataclasses

    from repro.core import SweepConfig

    fused = SweepConfig(method="ard", engine_backend="pallas",
                        engine_chunk_iters=FUSED_CHUNK_ITERS)
    yield "ard/pallas-fused", fused
    yield "ard/xla", SweepConfig(method="ard")
    yield "prd/pallas-1launch", dataclasses.replace(
        fused, method="prd", engine_chunk_iters=PRD_BIG_CHUNK)


def _batches(quick: bool):
    """(label, problems, parts).  'uniform' = B copies of one instance
    (identical trip structure -> the exact >= B x launch-reduction bar);
    'mixed' = different sizes/partitions spanning multiple shape buckets
    (the per-row bucket split is recorded as ``num_buckets``)."""
    from repro.core import grid_partition
    from repro.data.grids import random_sparse, synthetic_grid

    g = 10 if quick else 16
    uni_b = 4 if quick else 8
    uniform = [synthetic_grid(g, g, connectivity=8, strength=150, seed=0)
               for _ in range(uni_b)]
    upart = [grid_partition((g, g), (2, 2))] * uni_b
    yield f"uniform{uni_b}_grid{g}", uniform, upart

    sizes = [10, 12, 10, 14] if quick else [16, 12, 16, 20]
    mixed = [synthetic_grid(s, s, connectivity=8, strength=150, seed=i)
             for i, s in enumerate(sizes)]
    mpart = [grid_partition((s, s), (2, 2)) for s in sizes]
    mixed.append(random_sparse(14, 28, seed=9))
    mpart.append(None)
    yield "mixed5_multibucket", mixed, mpart


def _bench_batch(label, cfg, probs, parts):
    import dataclasses

    from repro.core import BatchedSolver, solve_mincut
    from repro.core import batch as batch_mod

    B = len(probs)
    # sequential baseline: the strongest single-instance configuration
    # (device-resident: 1 host sync per solve), check off on both sides
    seq_cfg = dataclasses.replace(cfg, device_resident=True)
    seq = lambda: [solve_mincut(p, part=pt, num_regions=4, config=seq_cfg,
                                check=False)
                   for p, pt in zip(probs, parts)]
    seq()                                   # warm-up: trace + compile
    t0 = time.perf_counter()
    singles = seq()
    seq_s = time.perf_counter() - t0

    solver = BatchedSolver(cfg, num_regions=4, check=False)
    solver.solve(probs, parts)              # warm-up: trace + compile
    before = batch_mod.trace_count()
    t0 = time.perf_counter()
    batched = solver.solve(probs, parts)
    batch_s = time.perf_counter() - t0
    retraces = batch_mod.trace_count() - before

    for i, (s, b) in enumerate(zip(singles, batched)):
        assert b.flow_value == s.flow_value, (label, i)
        assert b.stats.sweeps == s.stats.sweeps, (label, i)
        assert b.stats.engine_iters == s.stats.engine_iters, (label, i)
    seq_launches = sum(s.stats.engine_launches for s in singles)
    batch_launches = sum(bs.engine_launches
                         for bs in solver.last_batch_stats)
    return dict(
        batch=label,
        config=f"{cfg.method}/{cfg.engine_backend}",
        backend=cfg.engine_backend,
        method=cfg.method,
        chunk_iters=cfg.engine_chunk_iters,
        num_instances=B,
        num_buckets=len(solver.last_batch_stats),
        seq_s=round(seq_s, 3),
        batch_s=round(batch_s, 3),
        inst_per_s_seq=round(B / seq_s, 2),
        inst_per_s_batch=round(B / batch_s, 2),
        seq_launches=seq_launches,
        batch_launches=batch_launches,
        launch_reduction=round(seq_launches / max(1, batch_launches), 2),
        launches_per_instance=round(batch_launches / B, 2),
        host_syncs_batch=sum(bs.host_syncs
                             for bs in solver.last_batch_stats),
        retraces_second_solve=retraces,
        flows=[r.flow_value for r in batched],
    )


def collect(quick: bool = False) -> dict:
    import jax

    rows = []
    for blabel, probs, parts in _batches(quick):
        for clabel, cfg in _configs():
            row = _bench_batch(blabel, cfg, probs, parts)
            row["config"] = clabel
            rows.append(row)
            assert row["retraces_second_solve"] == 0, (clabel, blabel)
            if cfg.engine_backend == "pallas" \
                    and blabel.startswith("uniform"):
                # identical instances ride one launch stream: the batch
                # costs what ONE instance costs in dispatches
                assert row["launch_reduction"] >= row["num_instances"], row
    return dict(
        bench="batch",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        pallas_interpret=jax.default_backend() != "tpu",
        fused_chunk_iters=FUSED_CHUNK_ITERS,
        prd_big_chunk=PRD_BIG_CHUNK,
        results=rows,
    )


def smoke() -> None:
    """CI guard: tiny batches, every configuration, oracle flows, the
    >= B x launch-reduction bar and the zero-recompile property."""
    from repro.kernels.ref import maxflow_oracle

    for blabel, probs, parts in _batches(quick=True):
        oracle = [maxflow_oracle(p)[0] for p in probs]
        for clabel, cfg in _configs():
            row = _bench_batch(blabel, cfg, probs, parts)
            assert row["flows"] == oracle, (clabel, blabel)
            assert row["retraces_second_solve"] == 0, (clabel, blabel)
            if cfg.engine_backend == "pallas" \
                    and blabel.startswith("uniform"):
                assert row["launch_reduction"] >= row["num_instances"], row
            print(f"smoke ok: {blabel} x {clabel} flows={row['flows']} "
                  f"launches {row['seq_launches']}->"
                  f"{row['batch_launches']} "
                  f"(x{row['launch_reduction']})")
    print("smoke passed: oracle flows, bit-exact vs single driver, "
          ">=Bx launch reduction on uniform fused-pallas batches, "
          "zero recompilation on bucket re-solve")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        emit(f"batch/{row['config']}/{row['batch']}",
             row["batch_s"] * 1e6,
             f"inst_per_s={row['inst_per_s_batch']};"
             f"launch_reduction={row['launch_reduction']};"
             f"launches_per_instance={row['launches_per_instance']};"
             f"retraces={row['retraces_second_solve']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-batch oracle + launch-reduction check (CI), "
                         "no JSON output")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_batch.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
