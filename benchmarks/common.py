"""Shared benchmark utilities: timing + CSV emission.

Every benchmark module exposes ``run(emit, quick)`` and prints rows through
``emit(name, us_per_call, derived)`` — the ``name,us_per_call,derived``
CSV contract of benchmarks/run.py.
"""

from __future__ import annotations

import time


def emit_csv(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_call(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) in microseconds (post-warmup)."""
    import jax

    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6, r
