"""Shared benchmark utilities: timing + CSV emission + peak-RSS probes.

Every benchmark module exposes ``run(emit, quick)`` and prints rows through
``emit(name, us_per_call, derived)`` — the ``name,us_per_call,derived``
CSV contract of benchmarks/run.py.
"""

from __future__ import annotations

import resource
import sys
import time


def emit_csv(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def peak_rss_bytes() -> int:
    """High-water host RSS of this process, in bytes.

    ``ru_maxrss`` is a process-LIFETIME maximum — it never goes back
    down, so comparing two arms within one process attributes the first
    arm's peak to the second.  Memory benchmarks must run each arm in
    its own subprocess (see ``benchmarks/bench_streaming.py``) and
    report this at exit.  Linux reports KiB; macOS reports bytes.
    """
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(rss) if sys.platform == "darwin" else int(rss) * 1024


def time_call(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time of fn(*args) in microseconds (post-warmup)."""
    import jax

    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6, r
