"""Engine-backend benchmark: XLA dense rows vs the fused Pallas kernel.

Times one jitted parallel ARD sweep and the full solve on the synthetic
grids of Sec. 7.1, once per engine backend, and writes ``BENCH_engine.json``
so the perf trajectory of the hot path is recorded per PR.  On this
CPU-only container the Pallas kernel runs in interpret mode, so its
absolute numbers measure correctness-path overhead, not TPU speed — the
JSON records platform and interpret mode so TPU runs are comparable.

    PYTHONPATH=src python benchmarks/bench_engine_backend.py [--quick]
        [--out BENCH_engine.json]

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv, time_call  # noqa: E402

BACKENDS = ("xla", "pallas")


def _bench_instance(size, regions, backend, quick):
    import jax
    import jax.numpy as jnp

    from repro.core import SweepConfig, grid_partition, solve_mincut
    from repro.core.graph import build, init_labels
    from repro.core.sweep import parallel_sweep
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(size, size, connectivity=8, strength=150, seed=0)
    part = grid_partition((size, size), regions)
    cfg = SweepConfig(method="ard", engine_backend=backend)

    # one-sweep latency (jitted program, post-warmup median)
    meta, state, _ = build(p, part)
    state = init_labels(meta, state)
    sweep_us, _ = time_call(
        lambda: parallel_sweep(meta, state, cfg, jnp.asarray(0, jnp.int32)),
        repeats=2 if quick else 3)

    # full-solve wall time + solution stats (warm-up run first so the
    # number measures execution, not trace/compile time)
    solve_mincut(p, part=part, config=cfg)
    t0 = time.perf_counter()
    res = solve_mincut(p, part=part, config=cfg)
    solve_s = time.perf_counter() - t0
    return dict(
        instance=f"grid{size}x{size}_r{regions[0]}x{regions[1]}",
        backend=backend,
        sweep_us=round(sweep_us, 1),
        solve_s=round(solve_s, 3),
        sweeps=res.stats.sweeps,
        engine_iters=res.stats.engine_iters,
        flow=res.flow_value,
    )


def collect(quick: bool = False) -> dict:
    import jax

    sizes = [(12, (2, 2))] if quick else [(16, (2, 2)), (24, (2, 2))]
    rows = []
    for size, regions in sizes:
        per_backend = {}
        for backend in BACKENDS:
            row = _bench_instance(size, regions, backend, quick)
            per_backend[backend] = row
            rows.append(row)
        a, b = per_backend["xla"], per_backend["pallas"]
        assert a["flow"] == b["flow"], "backend parity violated in bench"
        a["speedup_vs_pallas"] = round(b["sweep_us"] / a["sweep_us"], 2)
    return dict(
        bench="engine_backend",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        pallas_interpret=jax.default_backend() != "tpu",
        results=rows,
    )


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        emit(f"engine/{row['backend']}/{row['instance']}", row["sweep_us"],
             f"solve_s={row['solve_s']};sweeps={row['sweeps']};"
             f"flow={row['flow']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_engine.json"))
    args = ap.parse_args()
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
