"""Engine-backend benchmark: XLA rows vs Pallas kernel, unfused vs fused.

Times one jitted parallel ARD sweep and the full solve on the synthetic
grids of Sec. 7.1, for every (backend, engine mode) pair:

  * backend   — "xla" dense rows vs the "pallas" kernel (interpret off-TPU);
  * mode      — unfused two-phase engine (2 compute launches + XLA scatter
                per iteration) vs the region-resident fused chunked engine
                (one launch per ``chunk_iters`` complete iterations, state
                resident, in-kernel early exit).

Writes ``BENCH_engine.json`` so the perf trajectory of the hot path is
recorded per PR, including ``kernel_launches`` (compute-program dispatches
per solve, from ``SweepStats.engine_launches``) and the per-backend
``launch_reduction`` of fused vs unfused — the HBM-round-trip win the fused
mode exists for.  On this CPU-only container the Pallas kernel runs in
interpret mode, so absolute times measure correctness-path overhead, not
TPU speed — the JSON records platform and interpret mode so TPU runs are
comparable.

    PYTHONPATH=src python benchmarks/bench_engine_backend.py [--quick]
        [--smoke] [--out BENCH_engine.json]

``--smoke`` runs one tiny instance through all four configurations and
asserts the flow matches the Edmonds-Karp oracle — the CI guard that the
perf plumbing cannot silently break the solver.

Also exposes the ``run(emit, quick)`` contract of benchmarks/run.py.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.common import emit_csv, time_call  # noqa: E402

BACKENDS = ("xla", "pallas")
FUSED_CHUNK_ITERS = 8


def _configs():
    from repro.core import SweepConfig

    for backend in BACKENDS:
        for chunk in (None, FUSED_CHUNK_ITERS):
            yield SweepConfig(method="ard", engine_backend=backend,
                              engine_chunk_iters=chunk)


def _bench_instance(size, regions, cfg, quick):
    import jax.numpy as jnp

    from repro.core import grid_partition, solve_mincut
    from repro.core.graph import build, init_labels
    from repro.core.sweep import parallel_sweep
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(size, size, connectivity=8, strength=150, seed=0)
    part = grid_partition((size, size), regions)

    # one-sweep latency (jitted program, post-warmup median)
    meta, state, _ = build(p, part)
    state = init_labels(meta, state)
    sweep_us, _ = time_call(
        lambda: parallel_sweep(meta, state, cfg, jnp.asarray(0, jnp.int32)),
        repeats=2 if quick else 3)

    # full-solve wall time + solution stats (warm-up run first so the
    # number measures execution, not trace/compile time)
    solve_mincut(p, part=part, config=cfg)
    t0 = time.perf_counter()
    res = solve_mincut(p, part=part, config=cfg)
    solve_s = time.perf_counter() - t0
    return dict(
        instance=f"grid{size}x{size}_r{regions[0]}x{regions[1]}",
        backend=cfg.engine_backend,
        fused=cfg.engine_chunk_iters is not None,
        chunk_iters=cfg.engine_chunk_iters,
        sweep_us=round(sweep_us, 1),
        solve_s=round(solve_s, 3),
        sweeps=res.stats.sweeps,
        engine_iters=res.stats.engine_iters,
        kernel_launches=res.stats.engine_launches,
        flow=res.flow_value,
    )


def collect(quick: bool = False) -> dict:
    import jax

    sizes = ([(12, (2, 2))] if quick
             else [(16, (2, 2)), (24, (2, 2)), (32, (2, 2))])
    rows = []
    for size, regions in sizes:
        per_cfg = {}
        for cfg in _configs():
            row = _bench_instance(size, regions, cfg, quick)
            per_cfg[(cfg.engine_backend, row["fused"])] = row
            rows.append(row)
        flows = {r["flow"] for r in per_cfg.values()}
        assert len(flows) == 1, "backend/mode parity violated in bench"
        for backend in BACKENDS:
            unf, fus = per_cfg[(backend, False)], per_cfg[(backend, True)]
            assert unf["engine_iters"] == fus["engine_iters"]
            fus["launch_reduction"] = round(
                unf["kernel_launches"] / max(1, fus["kernel_launches"]), 2)
            fus["speedup_vs_unfused"] = round(
                unf["sweep_us"] / fus["sweep_us"], 2)
    return dict(
        bench="engine_backend",
        platform=jax.default_backend(),
        jax_version=jax.__version__,
        pallas_interpret=jax.default_backend() != "tpu",
        fused_chunk_iters=FUSED_CHUNK_ITERS,
        results=rows,
    )


def smoke() -> None:
    """CI guard: tiny instance, every engine configuration, oracle flow."""
    from repro.core import SweepConfig, grid_partition, solve_mincut
    from repro.data.grids import synthetic_grid
    from repro.kernels.ref import maxflow_oracle

    p = synthetic_grid(8, 8, connectivity=8, strength=150, seed=0)
    part = grid_partition((8, 8), (2, 2))
    want, _ = maxflow_oracle(p)
    for cfg in _configs():
        res = solve_mincut(p, part=part, config=cfg)
        assert res.flow_value == want, (
            f"{cfg.engine_backend} chunk={cfg.engine_chunk_iters}: "
            f"flow {res.flow_value} != oracle {want}")
        print(f"smoke ok: backend={cfg.engine_backend} "
              f"chunk={cfg.engine_chunk_iters} flow={res.flow_value} "
              f"launches={res.stats.engine_launches}")
    print(f"smoke passed: oracle flow {want} on all engine configurations")


def run(emit=emit_csv, quick: bool = False) -> None:
    data = collect(quick=quick)
    for row in data["results"]:
        mode = "fused" if row["fused"] else "unfused"
        emit(f"engine/{row['backend']}/{mode}/{row['instance']}",
             row["sweep_us"],
             f"solve_s={row['solve_s']};sweeps={row['sweeps']};"
             f"launches={row['kernel_launches']};flow={row['flow']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-instance oracle check (CI), no JSON output")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parents[1]
                                         / "BENCH_engine.json"))
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    data = collect(quick=args.quick)
    Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {args.out}")
    for row in data["results"]:
        print(row)


if __name__ == "__main__":
    main()
