"""Out-of-core streaming vs all-resident: peak host RSS + bit-exactness.

The claim under test is the subsystem's reason to exist: an instance
that arrives as a DIMACS file can be solved while holding only
``max_resident_regions`` region slabs (plus the |B|-sized boundary
layer) in memory, producing the bit-identical flow of the all-resident
pipeline.  Three subprocesses:

  setup     — ``data.generators.pipeline_levels`` -> ``write_dimacs``.
              Unmeasured: the file on disk is the instance.
  resident  — ``read_dimacs`` (the whole edge list in memory) ->
              ``build`` (the full ``[K, V, E]`` state) -> solve.
  streaming — ``read_dimacs_sharded`` (single pass, O(n) vectors,
              per-region shards spilled to disk) -> ``to_stream`` ->
              ``solve_stream`` with ``max_resident_regions=2``.

Each measured arm runs in its OWN subprocess because ``ru_maxrss`` is a
process-lifetime high-water mark (see ``common.peak_rss_bytes``) — two
arms in one process would attribute the first arm's peak to the second.
The pipeline instance emits its edges in sorted order, so the file-order
sharded ingest and the sort-order resident build assign identical arc
slots: the two arms agree sweep for sweep, not just on the flow value.

Usage:

    PYTHONPATH=src python benchmarks/bench_streaming.py --smoke
    PYTHONPATH=src python benchmarks/bench_streaming.py \
        --out BENCH_streaming.json          # n = 1,048,576 evidence run

``--smoke`` (CI) runs a small instance and asserts the same contract:
bit-exact flow/sweeps and streaming peak RSS < ``--ratio`` (default
0.5) of the resident peak.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))


def _part(rows, levels, regions):
    import numpy as np

    assert levels % regions == 0
    return np.arange(rows * levels) // (rows * (levels // regions))


def _cfg():
    from repro.core.sweep import SweepConfig

    return SweepConfig(method="ard", parallel=False, use_global_gap=False)


def run_arm(arm, path, args) -> None:
    """Child entry: one arm, one JSON result line on stdout."""
    from common import peak_rss_bytes

    t0 = time.perf_counter()
    if arm == "setup":
        from repro.data.dimacs import write_dimacs
        from repro.data.generators import pipeline_levels

        p = pipeline_levels(rows=args.rows, levels=args.levels)
        write_dimacs(p, path)
        out = {"num_vertices": p.num_vertices, "num_arcs": len(p.edges),
               "file_mb": round(os.path.getsize(path) / 2**20, 1)}
    elif arm == "resident":
        from repro.core import solve_mincut
        from repro.data.dimacs import read_dimacs

        p = read_dimacs(path)
        res = solve_mincut(p, _part(args.rows, args.levels, args.regions),
                           config=_cfg(), check=False)
        assert res.stats.converged
        out = {"flow": int(res.flow_value), "sweeps": int(res.stats.sweeps),
               "engine_iters": int(res.stats.engine_iters),
               "num_boundary": int(res.stats.num_boundary or 0),
               "staged_in_bytes": 0}
    else:
        from repro.stream.executor import solve_stream
        from repro.data.dimacs import read_dimacs_sharded

        sd = read_dimacs_sharded(path,
                                 _part(args.rows, args.levels, args.regions))
        ss = sd.to_stream(_cfg(),
                          max_resident_regions=args.max_resident_regions)
        ss, stats = solve_stream(ss)
        assert stats.converged
        out = {"flow": int(ss.bnd.flow_to_t), "sweeps": int(stats.sweeps),
               "engine_iters": int(stats.engine_iters),
               "num_boundary": int(stats.num_boundary or 0),
               "staged_in_bytes": int(stats.staged_in_bytes)}
        ss.store.close()
        sd.close()
    out.update(arm=arm, wall_s=round(time.perf_counter() - t0, 2),
               peak_rss_bytes=peak_rss_bytes())
    print(json.dumps(out), flush=True)


def _spawn(arm, path, args):
    cmd = [sys.executable, __file__, "--arm", arm, "--instance", str(path),
           "--rows", str(args.rows), "--levels", str(args.levels),
           "--regions", str(args.regions),
           "--max-resident-regions", str(args.max_resident_regions)]
    proc = subprocess.run(cmd, env={**os.environ, "JAX_PLATFORMS": "cpu"},
                          capture_output=True, text=True)
    assert proc.returncode == 0, \
        f"{arm} arm failed:\n{proc.stdout}\n{proc.stderr}"
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main() -> None:
    import tempfile

    from common import emit_csv

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small instance, assert the contract, no JSON")
    ap.add_argument("--rows", type=int, default=8192)
    ap.add_argument("--levels", type=int, default=128)
    ap.add_argument("--regions", type=int, default=16,
                    help="level-major blocks (levels %% regions == 0)")
    ap.add_argument("--max-resident-regions", type=int, default=2)
    ap.add_argument("--ratio", type=float, default=0.5,
                    help="required streaming/resident peak-RSS ceiling")
    ap.add_argument("--out", default=None, metavar="JSON")
    ap.add_argument("--arm", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--instance", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.smoke:
        # big enough that the edge list / region slabs dominate the
        # interpreter's ~200 MB baseline RSS, or the ratio says nothing
        args.rows, args.levels, args.regions = 2048, 128, 16

    if args.arm:
        run_arm(args.arm, args.instance, args)
        return

    n = args.rows * args.levels
    with tempfile.TemporaryDirectory(prefix="bench_streaming_") as d:
        path = Path(d) / "instance.max"
        print(f"[bench_streaming] pipeline_levels rows={args.rows} "
              f"levels={args.levels} (n={n}), {args.regions} regions, "
              f"max_resident_regions={args.max_resident_regions}",
              flush=True)
        setup = _spawn("setup", path, args)
        print(f"[bench_streaming] instance: {setup['num_arcs']} arcs, "
              f"{setup['file_mb']} MB DIMACS", flush=True)
        res = _spawn("resident", path, args)
        stm = _spawn("streaming", path, args)

    assert stm["flow"] == res["flow"], \
        f"streaming flow {stm['flow']} != resident {res['flow']}"
    assert stm["sweeps"] == res["sweeps"], (stm["sweeps"], res["sweeps"])
    assert stm["engine_iters"] == res["engine_iters"]
    ratio = stm["peak_rss_bytes"] / res["peak_rss_bytes"]
    for r in (res, stm):
        emit_csv(f"streaming/n{n}/{r['arm']}", r["wall_s"] * 1e6,
                 f"rss_mb={r['peak_rss_bytes'] / 2**20:.0f} "
                 f"sweeps={r['sweeps']} flow={r['flow']}")
    print(f"[bench_streaming] peak RSS streaming/resident = {ratio:.3f} "
          f"(required < {args.ratio}); flow bit-exact ({res['flow']})",
          flush=True)
    assert ratio < args.ratio, \
        f"streaming peak RSS ratio {ratio:.3f} >= {args.ratio}"

    if args.out:
        doc = {"instance": {"kind": "pipeline_levels", "rows": args.rows,
                            "levels": args.levels, "num_vertices": n,
                            "num_arcs": setup["num_arcs"],
                            "dimacs_mb": setup["file_mb"],
                            "regions": args.regions},
               "config": {"method": "ard", "parallel": False,
                          "use_global_gap": False,
                          "max_resident_regions": args.max_resident_regions},
               "resident": res, "streaming": stm,
               "rss_ratio": round(ratio, 4)}
        Path(args.out).write_text(json.dumps(doc, indent=1) + "\n")
        print(f"[bench_streaming] wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
