"""Format the dry-run JSON artifacts into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline_table \
        --dir experiments/dryrun [--markdown]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_records(d: str | Path):
    recs = []
    for p in sorted(Path(d).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_seconds(s: float) -> str:
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s * 1e6:.0f}us"
    if s < 1:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def rows(recs):
    out = []
    for r in recs:
        if r.get("status") != "ok":
            out.append({
                "cell": f"{r['arch']}/{r.get('shape')}/{r['mesh']}",
                "status": r.get("status"),
                "note": (r.get("reason") or r.get("error", ""))[:80],
            })
            continue
        rl = r["roofline"]
        mem = r.get("memory", {}).get("approx_peak_bytes_per_device", 0)
        dom = rl["bottleneck"]
        dom_s = rl[f"{dom}_s"] if f"{dom}_s" in rl else 0
        frac = 0.0
        if dom_s:
            frac = rl["compute_s"] / dom_s
        out.append({
            "cell": f"{r['arch']}/{r.get('shape')}/{r['mesh']}",
            "status": "ok",
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "bottleneck": dom,
            "mem_gb": mem / 1e9,
            "useful": rl.get("useful_ratio", 0.0),
            "roofline_frac": frac,
        })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rs = rows(load_records(args.dir))
    if args.markdown:
        print("| cell | compute | memory | collective | bound | mem/dev "
              "| useful |")
        print("|---|---|---|---|---|---|---|")
        for r in rs:
            if r["status"] != "ok":
                print(f"| {r['cell']} | {r['status']}: {r['note']} "
                      "| | | | | |")
                continue
            print(f"| {r['cell']} | {fmt_seconds(r['compute_s'])} "
                  f"| {fmt_seconds(r['memory_s'])} "
                  f"| {fmt_seconds(r['collective_s'])} "
                  f"| {r['bottleneck']} | {r['mem_gb']:.1f}GB "
                  f"| {r['useful']:.2f} |")
    else:
        print("cell,compute_s,memory_s,collective_s,bottleneck,mem_gb,useful")
        for r in rs:
            if r["status"] != "ok":
                print(f"{r['cell']},{r['status']},{r['note']},,,,")
                continue
            print(f"{r['cell']},{r['compute_s']:.4f},{r['memory_s']:.4f},"
                  f"{r['collective_s']:.4f},{r['bottleneck']},"
                  f"{r['mem_gb']:.2f},{r['useful']:.3f}")


if __name__ == "__main__":
    main()
