#!/usr/bin/env python
"""Docs link check: every relative path referenced from README.md and
docs/*.md must exist in the tree.

Checked reference forms:
  * markdown links  [text](path)  — external URLs and #anchors are skipped;
  * fenced/inline code mentions of repo paths are NOT parsed (too noisy) —
    keep load-bearing file references as markdown links.

Exit code 1 and a listing on any dangling reference.  Run from anywhere:

    python tools/check_doc_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files() -> list[Path]:
    docs = [ROOT / "README.md"]
    docs += sorted((ROOT / "docs").glob("*.md"))
    docs += [p for p in (ROOT / "EXPERIMENTS.md",) if p.exists()]
    return [p for p in docs if p.exists()]


def check(doc: Path) -> list[str]:
    bad = []
    for target in LINK_RE.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#")[0]
        if not target:
            continue
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            bad.append(f"{doc.relative_to(ROOT)}: dangling link -> {target}")
    return bad


def main() -> int:
    docs = doc_files()
    if not docs:
        print("no docs found", file=sys.stderr)
        return 1
    problems = [p for doc in docs for p in check(doc)]
    for p in problems:
        print(p, file=sys.stderr)
    print(f"checked {len(docs)} docs: "
          f"{'FAIL' if problems else 'all links resolve'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
