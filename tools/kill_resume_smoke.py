"""Kill-and-resume smoke: a REAL process death, not a simulated one.

The in-process fault matrix (tests/test_resilience.py) injects exceptions;
this script closes the remaining gap in the deployment story by SIGKILLing
a checkpointing solve mid-sweep — no cleanup handlers, no atexit, exactly
what a preempted worker looks like — and then resuming from whatever the
dead process managed to publish:

1. the parent solves the instance uninterrupted (the baseline);
2. a child process runs the same solve with sweep-boundary checkpoints
   and ``os.kill(getpid(), SIGKILL)`` at sweep K (installed through the
   executor fault hook, which fires AFTER the boundary's checkpoint);
3. the parent asserts the child died on SIGKILL, that the latest published
   checkpoint is a mid-solve boundary, resumes from it, and asserts the
   result is BIT-EXACT against the baseline (flow, labels, residuals,
   sweep count, engine iterations, curves).

The atomic write-to-temp-then-rename snapshot protocol is what makes step
3 safe: a snapshot the child was writing when it died is a ``.tmp`` dir
the resume never sees.

``--streaming`` runs the same protocol through the out-of-core route:
the child builds the instance straight into a DURABLE spill pool
(``<ckdir>_pool``), checkpoints the |B|-sized boundary layer + pool
version vector at every sweep boundary, and dies mid-solve; the resume
re-attaches the surviving pool at the checkpointed versions — including
any orphan newer versions the dead process published after its last
checkpoint — and must match the uninterrupted streamed solve bit-exactly.

Usage (CI: the ``resilience`` and ``streaming`` jobs):

    PYTHONPATH=src python tools/kill_resume_smoke.py
    PYTHONPATH=src python tools/kill_resume_smoke.py --streaming
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

KILL_AT = 3


def _built():
    import numpy as np

    from repro.core import build, grid_partition
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(10, 10, connectivity=8, strength=150, seed=0)
    part = np.asarray(grid_partition((10, 10), (2, 2)))
    meta, state, _ = build(p, part)
    return meta, state


def child(ckdir: str) -> None:
    """Checkpoint every boundary; die hard at sweep KILL_AT."""
    from repro.core import executor, init_labels, resilience
    from repro.core.sweep import SweepConfig, solve

    def die(route, state, sweeps_done):
        if sweeps_done >= KILL_AT:
            os.kill(os.getpid(), signal.SIGKILL)   # no goodbye

    executor.set_fault_hook(die)
    meta, state = _built()
    solve(meta, init_labels(meta, state), SweepConfig(method="ard"),
          checkpoint=resilience.CheckpointPolicy(directory=ckdir, every=1))
    raise SystemExit("unreachable: the solve outlived its kill sweep")


def _stream_cfg():
    from repro.core.sweep import SweepConfig

    return SweepConfig(method="ard", parallel=False, use_global_gap=False)


def _stream_problem():
    import numpy as np

    from repro.core import grid_partition
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(10, 10, connectivity=8, strength=150, seed=0)
    return p, np.asarray(grid_partition((10, 10), (2, 2)))


def child_streaming(ckdir: str) -> None:
    """Streamed solve into a durable pool; die hard at sweep KILL_AT."""
    from repro.core import executor, resilience
    from repro.stream import build_stream, solve_stream

    def die(route, state, sweeps_done):
        if sweeps_done >= KILL_AT:
            os.kill(os.getpid(), signal.SIGKILL)   # no goodbye

    executor.set_fault_hook(die)
    p, part = _stream_problem()
    ss = build_stream(p, part, _stream_cfg(), spill_dir=ckdir + "_pool",
                      prefetch=False)
    solve_stream(ss, checkpoint=resilience.CheckpointPolicy(
        directory=ckdir, every=1))
    raise SystemExit("unreachable: the solve outlived its kill sweep")


def parent_streaming(ckdir: str) -> None:
    import numpy as np

    from repro.core import resilience
    from repro.stream import build_stream, solve_stream

    p, part = _stream_problem()
    ss = build_stream(p, part, _stream_cfg(), prefetch=False)
    ss, base_stats = solve_stream(ss)
    base_bnd = (ss.bnd.d_B.copy(), ss.bnd.e_B.copy(), ss.bnd.flow_to_t)
    ss.store.close()
    assert base_stats.sweeps > KILL_AT, \
        f"instance converges in {base_stats.sweeps} sweeps; nothing to kill"

    proc = subprocess.run(
        [sys.executable, __file__, "--streaming", "--child", ckdir],
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, (
        f"child exited {proc.returncode}, wanted SIGKILL "
        f"({-signal.SIGKILL})\n--- child stderr ---\n{proc.stderr}")

    latest = resilience.latest_checkpoint(ckdir)
    assert latest is not None, "the killed child published no checkpoint"
    assert latest.route == "stream", latest.route
    assert latest.sweeps == KILL_AT, \
        f"latest checkpoint at sweep {latest.sweeps}, wanted {KILL_AT}"
    print(f"[kill-resume --streaming] child SIGKILLed; latest checkpoint "
          f"at sweep {latest.sweeps}/{base_stats.sweeps}")

    # resume against the pool the dead process left behind
    ss2 = build_stream(p, part, _stream_cfg(), spill_dir=ckdir + "_pool",
                       prefetch=False)
    ss2, stats = solve_stream(ss2, resume_from=ckdir)
    np.testing.assert_array_equal(ss2.bnd.d_B, base_bnd[0])
    np.testing.assert_array_equal(ss2.bnd.e_B, base_bnd[1])
    assert ss2.bnd.flow_to_t == base_bnd[2]
    for k in ("sweeps", "engine_iters", "flow_curve", "converged"):
        assert getattr(stats, k) == getattr(base_stats, k), k
    assert stats.staged_in_bytes > 0
    ss2.store.close()
    print(f"[kill-resume --streaming] resumed {latest.sweeps} -> "
          f"{stats.sweeps} sweeps: flow={base_bnd[2]} — bit-exact vs "
          f"uninterrupted. OK")


def parent(ckdir: str) -> None:
    import numpy as np

    from repro.core import init_labels, resilience
    from repro.core.sweep import SweepConfig, solve

    meta, state = _built()
    cfg = SweepConfig(method="ard")
    base_st, base_stats = solve(meta, init_labels(meta, state), cfg)
    assert base_stats.sweeps > KILL_AT, \
        f"instance converges in {base_stats.sweeps} sweeps; nothing to kill"

    proc = subprocess.run(
        [sys.executable, __file__, "--child", ckdir],
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, (
        f"child exited {proc.returncode}, wanted SIGKILL "
        f"({-signal.SIGKILL})\n--- child stderr ---\n{proc.stderr}")

    latest = resilience.latest_checkpoint(ckdir)
    assert latest is not None, "the killed child published no checkpoint"
    assert latest.sweeps == KILL_AT, \
        f"latest checkpoint at sweep {latest.sweeps}, wanted {KILL_AT}"
    print(f"[kill-resume] child SIGKILLed; latest checkpoint at sweep "
          f"{latest.sweeps}/{base_stats.sweeps}")

    st, stats = solve(meta, init_labels(meta, state), cfg,
                      resume_from=ckdir)
    np.testing.assert_array_equal(np.asarray(st.d), np.asarray(base_st.d))
    np.testing.assert_array_equal(np.asarray(st.cf), np.asarray(base_st.cf))
    assert int(st.flow_to_t) == int(base_st.flow_to_t)
    for k in ("sweeps", "engine_iters", "engine_launches", "flow_curve",
              "active_curve", "converged"):
        assert getattr(stats, k) == getattr(base_stats, k), k
    print(f"[kill-resume] resumed {latest.sweeps} -> {stats.sweeps} "
          f"sweeps: flow={int(st.flow_to_t)} — bit-exact vs uninterrupted. "
          f"OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default=None, metavar="CKDIR",
                    help=argparse.SUPPRESS)
    ap.add_argument("--streaming", action="store_true",
                    help="run the protocol through the out-of-core "
                         "streaming route (durable spill pool + O(|B|) "
                         "checkpoints)")
    args = ap.parse_args()
    if args.child:
        (child_streaming if args.streaming else child)(args.child)
    else:
        with tempfile.TemporaryDirectory(prefix="kill_resume_") as d:
            (parent_streaming if args.streaming else parent)(
                str(Path(d) / "ck"))


if __name__ == "__main__":
    main()
