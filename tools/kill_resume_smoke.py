"""Kill-and-resume smoke: a REAL process death, not a simulated one.

The in-process fault matrix (tests/test_resilience.py) injects exceptions;
this script closes the remaining gap in the deployment story by SIGKILLing
a checkpointing solve mid-sweep — no cleanup handlers, no atexit, exactly
what a preempted worker looks like — and then resuming from whatever the
dead process managed to publish:

1. the parent solves the instance uninterrupted (the baseline);
2. a child process runs the same solve with sweep-boundary checkpoints
   and ``os.kill(getpid(), SIGKILL)`` at sweep K (installed through the
   executor fault hook, which fires AFTER the boundary's checkpoint);
3. the parent asserts the child died on SIGKILL, that the latest published
   checkpoint is a mid-solve boundary, resumes from it, and asserts the
   result is BIT-EXACT against the baseline (flow, labels, residuals,
   sweep count, engine iterations, curves).

The atomic write-to-temp-then-rename snapshot protocol is what makes step
3 safe: a snapshot the child was writing when it died is a ``.tmp`` dir
the resume never sees.

Usage (CI: the ``resilience`` job):

    PYTHONPATH=src python tools/kill_resume_smoke.py
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

KILL_AT = 3


def _built():
    import numpy as np

    from repro.core import build, grid_partition
    from repro.data.grids import synthetic_grid

    p = synthetic_grid(10, 10, connectivity=8, strength=150, seed=0)
    part = np.asarray(grid_partition((10, 10), (2, 2)))
    meta, state, _ = build(p, part)
    return meta, state


def child(ckdir: str) -> None:
    """Checkpoint every boundary; die hard at sweep KILL_AT."""
    from repro.core import executor, init_labels, resilience
    from repro.core.sweep import SweepConfig, solve

    def die(route, state, sweeps_done):
        if sweeps_done >= KILL_AT:
            os.kill(os.getpid(), signal.SIGKILL)   # no goodbye

    executor.set_fault_hook(die)
    meta, state = _built()
    solve(meta, init_labels(meta, state), SweepConfig(method="ard"),
          checkpoint=resilience.CheckpointPolicy(directory=ckdir, every=1))
    raise SystemExit("unreachable: the solve outlived its kill sweep")


def parent(ckdir: str) -> None:
    import numpy as np

    from repro.core import init_labels, resilience
    from repro.core.sweep import SweepConfig, solve

    meta, state = _built()
    cfg = SweepConfig(method="ard")
    base_st, base_stats = solve(meta, init_labels(meta, state), cfg)
    assert base_stats.sweeps > KILL_AT, \
        f"instance converges in {base_stats.sweeps} sweeps; nothing to kill"

    proc = subprocess.run(
        [sys.executable, __file__, "--child", ckdir],
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == -signal.SIGKILL, (
        f"child exited {proc.returncode}, wanted SIGKILL "
        f"({-signal.SIGKILL})\n--- child stderr ---\n{proc.stderr}")

    latest = resilience.latest_checkpoint(ckdir)
    assert latest is not None, "the killed child published no checkpoint"
    assert latest.sweeps == KILL_AT, \
        f"latest checkpoint at sweep {latest.sweeps}, wanted {KILL_AT}"
    print(f"[kill-resume] child SIGKILLed; latest checkpoint at sweep "
          f"{latest.sweeps}/{base_stats.sweeps}")

    st, stats = solve(meta, init_labels(meta, state), cfg,
                      resume_from=ckdir)
    np.testing.assert_array_equal(np.asarray(st.d), np.asarray(base_st.d))
    np.testing.assert_array_equal(np.asarray(st.cf), np.asarray(base_st.cf))
    assert int(st.flow_to_t) == int(base_st.flow_to_t)
    for k in ("sweeps", "engine_iters", "engine_launches", "flow_curve",
              "active_curve", "converged"):
        assert getattr(stats, k) == getattr(base_stats, k), k
    print(f"[kill-resume] resumed {latest.sweeps} -> {stats.sweeps} "
          f"sweeps: flow={int(st.flow_to_t)} — bit-exact vs uninterrupted. "
          f"OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default=None, metavar="CKDIR",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        child(args.child)
    else:
        with tempfile.TemporaryDirectory(prefix="kill_resume_") as d:
            parent(str(Path(d) / "ck"))


if __name__ == "__main__":
    main()
