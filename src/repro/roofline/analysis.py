"""Three-term roofline analysis from AOT-compiled artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bandwidth_per_chip
    collective term = collective_bytes_per_device / ICI_link_bandwidth

The compiled module is the per-device SPMD program, so cost_analysis()
already reports per-device FLOPs/bytes; equivalently the spec's
"global / (chips x peak)" formulation.  collective_bytes is not in
cost_analysis — we parse the optimized HLO and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (the conservative single-link figure; see EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (conservative: 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# instruction definition:  %name = bf16[8,4096]{1,0} op-name(...)
_DEF_RE = re.compile(
    r"%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def _type_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, dims) for d, dims in
               _SHAPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> dict:
    """Wire bytes per device, per collective kind, from optimized HLO.

    Operand refs in optimized HLO don't carry types, so a first pass builds
    a symbol table %name -> result bytes; the second pass applies the usual
    ring-algorithm wire-byte estimates:

        all-gather:          out - in          (per device)
        reduce-scatter:      in - out
        all-reduce:          2 * in * (g-1)/g  ~= 2 * in
        all-to-all:          in * (g-1)/g      ~= in
        collective-permute:  in

    Collectives inside while bodies appear once in the text — the dry-run
    lowers scans fully unrolled so the static sum is the true per-step sum.
    """
    sizes: dict[str, int] = {}
    insts = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _DEF_RE.search(s)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        sizes[name] = _type_bytes(type_str)
        base_op = op.rstrip("0123456789.")
        if base_op in _COLLECTIVES:
            insts.append((s, name, type_str, base_op))

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for s, name, type_str, op in insts:
        kind = op if op in _COLLECTIVES else op.rstrip("0123456789.")
        paren = s.find("(", s.find(kind))
        if paren < 0:
            continue
        depth, end = 0, paren
        for i in range(paren, len(s)):
            if s[i] == "(":
                depth += 1
            elif s[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        in_bytes = sum(sizes.get(o, 0)
                       for o in _OPERAND_RE.findall(s[paren:end]))
        out_bytes = _type_bytes(type_str)
        if kind == "all-gather":
            b = max(out_bytes - in_bytes, 0)
        elif kind == "reduce-scatter":
            b = max(in_bytes - out_bytes, 0)
        elif kind == "all-reduce":
            b = 2 * in_bytes
        elif kind == "all-to-all":
            b = in_bytes
        else:                        # collective-permute
            b = in_bytes
        out[kind] += b
        counts[kind] += 1
    return {"per_kind": out, "counts": counts, "total": sum(out.values())}


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    coll_detail: dict = field(default_factory=dict)

    def as_dict(self):
        return dict(flops=self.flops, bytes_accessed=self.bytes_accessed,
                    coll_bytes=self.coll_bytes, compute_s=self.compute_s,
                    memory_s=self.memory_s, collective_s=self.collective_s,
                    bottleneck=self.bottleneck, model_flops=self.model_flops,
                    useful_ratio=self.useful_ratio,
                    coll_detail=self.coll_detail)


def analyze(compiled, *, n_chips: int, model_flops_global: float = 0.0,
            hlo_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    compute_s = flops / PEAK_FLOPS
    memory_s = nbytes / HBM_BW
    collective_s = coll["total"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = 0.0
    if model_flops_global and flops:
        useful = model_flops_global / (flops * n_chips)
    return Roofline(flops=flops, bytes_accessed=nbytes,
                    coll_bytes=coll["total"], compute_s=compute_s,
                    memory_s=memory_s, collective_s=collective_s,
                    bottleneck=bottleneck, model_flops=model_flops_global,
                    useful_ratio=useful, coll_detail=coll)


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                          # backend-dependent
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        live = out.get("argument_size_in_bytes", 0) + \
            out.get("temp_size_in_bytes", 0) + \
            out.get("output_size_in_bytes", 0) - \
            out.get("alias_size_in_bytes", 0)
        out["approx_peak_bytes_per_device"] = live
    return out
