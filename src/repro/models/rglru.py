"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit is a *diagonal* linear recurrence

    a_t = exp(-c * softplus(Lambda) * r_t),   r_t = sigmoid(W_r x_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

which maps to ``jax.lax.associative_scan`` over (a, b) pairs — O(log S)
depth, fully parallel across the feature dimension: the TPU-native form.
Decode carries the [B, D_r] hidden state (O(1) per step — the reason the
long_500k shape runs for this arch).

The surrounding Griffin block: two up-projections (recurrent branch +
GeLU gate), a short temporal conv (width 4) on the recurrent branch, the
RG-LRU, gated merge, down-projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_C = 8.0


def init_rglru_block(key, d_model, dtype):
    dr = d_model
    ks = jax.random.split(key, 7)
    s = 1.0 / np.sqrt(d_model)
    return {
        "w_x": jax.random.normal(ks[0], (d_model, dr), dtype) * s,
        "w_gate": jax.random.normal(ks[1], (d_model, dr), dtype) * s,
        "conv": jax.random.normal(ks[2], (4, dr), dtype) * 0.5,
        "w_r": jax.random.normal(ks[3], (dr, dr), dtype) * s,
        "w_i": jax.random.normal(ks[4], (dr, dr), dtype) * s,
        "lam": jnp.full((dr,), 2.0, jnp.float32),      # softplus(2) ~ 2.1
        "w_out": jax.random.normal(ks[6], (dr, d_model), dtype) * s,
    }


def rglru_init_state(batch, d_model, dtype):
    return (jnp.zeros((batch, d_model), jnp.float32),        # lru hidden
            jnp.zeros((batch, 3, d_model), jnp.float32))     # conv tail


def _lru_scan(a, b, h0):
    """h_t = a_t h_{t-1} + b_t with initial h0; a,b [B,S,D]."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    # fold h0 into the first step
    b = b.at[:, 0].add(a[:, 0] * h0)
    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    return Bc


def rglru_block_apply(params, x, state):
    """x [B,S,D] -> [B,S,D]; state = (lru hidden, conv tail)."""
    B, S, D = x.shape
    h0, conv_tail = state
    u = jnp.einsum("bsd,de->bse", x, params["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, params["w_gate"]))
    # temporal conv width 4 with carried tail (decode-friendly)
    uc = jnp.concatenate([conv_tail.astype(u.dtype), u], axis=1)
    w = params["conv"]
    u = sum(uc[:, 3 - i: 3 - i + S] * w[i] for i in range(4))
    new_tail = uc[:, -3:].astype(jnp.float32)

    r = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", u, params["w_r"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", u, params["w_i"])
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0)) * \
        (i * u.astype(jnp.float32))
    h = _lru_scan(a, b, h0)
    new_h0 = h[:, -1]
    out = (h.astype(x.dtype) * gate)
    return jnp.einsum("bse,ed->bsd", out, params["w_out"]), (new_h0, new_tail)
