"""Mixture-of-Experts FFN with sort-based token dispatch (dropping).

Expert parallelism: experts live on the leading axis of every expert weight
and are sharded over the "model" mesh axis (launch/shardings.py).  Dispatch
is the sort-based capacity scheme (as in MaxText / Switch): tokens are
sorted by expert id, ranked within their expert group, dropped beyond the
capacity C = ceil(T * top_k / E * capacity_factor), processed as a dense
[E, C, D] batch (one einsum — MXU friendly, flops proportional to *active*
parameters), and combined back with their router gates.  Shared experts
(DeepSeekMoE) are a dense SwiGLU over num_shared * d_expert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import layers


def init_moe(key, d_model, cfg: MoEConfig, d_ff_default, dtype):
    d_e = cfg.d_expert or d_ff_default
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_e)
    E = cfg.num_experts
    p = {
        "router": jax.random.normal(k1, (d_model, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k2, (E, d_model, d_e), dtype) * s_in,
        "w_up": jax.random.normal(k3, (E, d_model, d_e), dtype) * s_in,
        "w_down": jax.random.normal(k4, (E, d_e, d_model), dtype) * s_out,
    }
    if cfg.num_shared:
        p["shared"] = layers.init_mlp(k5, d_model, cfg.num_shared * d_e,
                                      dtype)
    return p


def _constrain(x, *spec):
    """Best-effort sharding constraint (no-op without an ambient mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    except Exception:
        return x


def moe_ffn(params, x, cfg: MoEConfig, token_axes=None, expert_axis=None):
    """x [B,S,D] -> [B,S,D]; returns (out, aux_loss).

    ``token_axes`` / ``expert_axis``: mesh axes for the flattened token dim
    and the expert dim.  GSPMD cannot infer shardings through the
    sort/gather dispatch chain, so without explicit constraints the
    token-major [T*k, D] tensors replicate per device (O(10GB) each at
    production shapes) — pinning them is the difference between the
    274GB/dev baseline and the fitting version (EXPERIMENTS.md §Perf,
    deepseek-moe hillclimb).
    """
    B, S, D = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.top_k
    xf = x.reshape(T, D)
    if token_axes:
        xf = _constrain(xf, token_axes, None)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                        # [T,k]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch) ----
    me = probs.mean(axis=0)                                     # [E]
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    flat_e = eidx.reshape(-1)                                   # [N], N = T*k
    N = T * k
    flat_t = jnp.arange(N, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    st = flat_t[order]
    sg = gate.reshape(-1)[order]
    rank = jnp.arange(N, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left").astype(jnp.int32)
    C = int(np.ceil(T * k / E * cfg.capacity_factor))
    C = max(8, -(-C // 8) * 8)                                  # pad to 8
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)                # drop slot
    gathered = xf[st]
    if token_axes:
        gathered = _constrain(gathered, token_axes, None)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[dest].set(gathered)
    buf = buf[:-1].reshape(E, C, D)
    if expert_axis:
        buf = _constrain(buf, expert_axis, None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if expert_axis:
        eout = _constrain(eout, expert_axis, None, None)
    eout = eout.reshape(E * C, D)

    vals = eout[jnp.clip(dest, 0, E * C - 1)] * sg[:, None].astype(x.dtype)
    if token_axes:
        vals = _constrain(vals, token_axes, None)
    out = jnp.zeros((T, D), x.dtype).at[st].add(
        jnp.where(keep[:, None], vals, 0))
    if token_axes:
        out = _constrain(out, token_axes, None)

    if "shared" in params:
        out = out + layers.mlp(params["shared"], x).reshape(T, D)
    return out.reshape(B, S, D), aux
