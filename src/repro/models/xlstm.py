"""xLSTM blocks (arXiv:2405.04517): chunkwise-parallel mLSTM + recurrent sLSTM.

TPU adaptation notes (DESIGN.md §Arch-applicability):

* mLSTM — matrix-memory linear recurrence  C_t = f_t C_{t-1} + i_t v_t k_t^T,
  h_t = C_t q_t / max(|n_t . q_t|, 1).  Implemented in the standard chunkwise
  form: O(c^2) masked intra-chunk attention + an [dh, dh] state scanned
  across chunks — the MXU-friendly shape (all matmuls, no per-step scan).
  Gates are per-head scalars; the paper's exponential input gate is
  stabilised here as sigmoid gating in log space (bounded chunk arithmetic),
  preserving the matrix-memory structure.
* sLSTM — genuinely recurrent (hidden-to-gate connections): lax.scan over
  time with per-head block-diagonal recurrent weights.  Sequential by
  construction; it is the reason xlstm-350m keeps a modest d_model.

Both blocks support O(1)-state decode (the long_500k shape): the mLSTM state
is [B, H, dh, dh] + normaliser, the sLSTM state [B, H, dh] tuples — no KV
cache growth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


PROJ_FACTOR = 2      # xLSTM mLSTM pre-up-projection factor


def init_mlstm(key, d_model, n_heads, dtype):
    inner = PROJ_FACTOR * d_model
    dh = inner // n_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d_model)
    si = 1.0 / np.sqrt(inner)
    return {
        "wu": jax.random.normal(ks[7], (d_model, inner), dtype) * s,
        "wq": jax.random.normal(ks[0], (inner, n_heads, dh), dtype) * si,
        "wk": jax.random.normal(ks[1], (inner, n_heads, dh), dtype) * si,
        "wv": jax.random.normal(ks[2], (inner, n_heads, dh), dtype) * si,
        "wi": jax.random.normal(ks[3], (inner, n_heads), dtype) * si,
        "wf": jax.random.normal(ks[4], (inner, n_heads), dtype) * si,
        "wg": jax.random.normal(ks[5], (d_model, inner), dtype) * s,
        "wo": jax.random.normal(ks[6], (inner, d_model), dtype) * si,
    }


def mlstm_init_state(batch, n_heads, dh, dtype):
    return (jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            jnp.zeros((batch, n_heads, dh), jnp.float32))


def _mlstm_scan_chunks(q, k, v, logf, logi, state, chunk,
                       unroll: int | bool = 1):
    """q,k,v [B,H,S,dh]; logf/logi [B,H,S]; state (C [B,H,dh,dh], n [B,H,dh])."""
    B, H, S, dh = q.shape
    nc = S // chunk
    # -> [nc, B, H, chunk, ...] with the chunk axis scanned on dim 0
    qc = q.reshape(B, H, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(B, H, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    fc = logf.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)
    ic = logi.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)

    def body(carry, inp):
        C, n = carry                                   # [B,H,dh,dh], [B,H,dh]
        qt, kt, vt, lf, li = inp                       # [B,H,c,dh], [B,H,c]
        qf = qt.astype(jnp.float32)
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        cum = jnp.cumsum(lf, axis=-1)                  # inclusive
        # intra-chunk decay: D[i,j] = exp(cum_i - cum_j + li_j), j <= i
        gap = cum[..., :, None] - cum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((qt.shape[-2], qt.shape[-2]), bool))
        D = jnp.where(tri, jnp.exp(gap), 0.0)
        scores = jnp.einsum("bhid,bhjd->bhij", qf, kf) * D
        intra = jnp.einsum("bhij,bhjd->bhid", scores, vf)
        n_intra = jnp.einsum("bhij,bhjd->bhid", D, kf)
        # inter-chunk contribution of the carried (C, n) state
        qdec = qf * jnp.exp(cum)[..., None]
        inter = jnp.einsum("bhid,bhde->bhie", qdec, C)
        n_vec = n_intra + jnp.exp(cum)[..., None] * n[..., None, :]
        num = intra + inter
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhid,bhid->bhi", qf, n_vec)), 1.0)
        h = num / denom[..., None]
        # state update to the end of the chunk
        total = cum[..., -1]
        kdec = kf * jnp.exp(total[..., None] - cum + li)[..., None]
        C_new = C * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bhjd,bhje->bhde", kdec, vf)
        n_new = n * jnp.exp(total)[..., None] + kdec.sum(axis=-2)
        return (C_new, n_new), h

    (C, n), hs = jax.lax.scan(body, state, (qc, kc, vc, fc, ic),
                               unroll=unroll)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
    return h, (C, n)


def mlstm_apply(params, x, state, *, chunk: int,
                unroll: int | bool = 1):
    """x [B,S,D] -> [B,S,D]; state carried across calls (decode)."""
    B, S, D = x.shape
    H = params["wq"].shape[1]
    dh = params["wq"].shape[2]
    u = jnp.einsum("bsd,de->bse", x, params["wu"])     # pre-up-projection
    q = jnp.einsum("bse,ehk->bhsk", u, params["wq"])
    k = jnp.einsum("bse,ehk->bhsk", u, params["wk"]) / np.sqrt(dh)
    v = jnp.einsum("bse,ehk->bhsk", u, params["wv"])
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bhs", u, params["wf"]).astype(jnp.float32))
    logi = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bhs", u, params["wi"]).astype(jnp.float32))
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) *
                               (a.ndim - 3))
        q, k, v, logf, logi = zp(q), zp(k), zp(v), zp(logf), zp(logi)
    h, state = _mlstm_scan_chunks(q, k, v, logf, logi, state, c,
                                  unroll=unroll)
    h = h[:, :, :S]
    inner = H * dh
    h = h.transpose(0, 2, 1, 3).reshape(B, S, inner).astype(x.dtype)
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["wg"]))
    return jnp.einsum("bse,ed->bsd", h * gate, params["wo"]), state


def init_slstm(key, d_model, n_heads, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(d_model)
    return {
        "w_in": jax.random.normal(ks[0], (d_model, n_heads, 4 * dh),
                                  dtype) * s,
        "r": jax.random.normal(ks[1], (n_heads, dh, 4 * dh), dtype) / \
            np.sqrt(dh),
        "wo": jax.random.normal(ks[2], (d_model, d_model), dtype) * s,
    }


def slstm_init_state(batch, n_heads, dh, dtype):
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return (z, z, z)          # (c, n, h)


def slstm_apply(params, x, state):
    """Truly recurrent sLSTM: lax.scan over time."""
    B, S, D = x.shape
    H, dh4 = params["r"].shape[0], params["r"].shape[2]
    dh = dh4 // 4
    pre_in = jnp.einsum("bsd,dhk->sbhk", x, params["w_in"])

    def step(carry, pre_t):
        c, n, h = carry
        pre = pre_t.astype(jnp.float32) + jnp.einsum(
            "bhd,hdk->bhk", h, params["r"].astype(jnp.float32))
        zi, zf, zz, zo = jnp.split(pre, 4, axis=-1)
        i = jnp.exp(jnp.minimum(zi, 0.0))            # stabilised exp gate
        f = jax.nn.sigmoid(zf)
        z = jnp.tanh(zz)
        o = jax.nn.sigmoid(zo)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, h), h

    state, hs = jax.lax.scan(step, state, pre_in)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", h, params["wo"]), state
