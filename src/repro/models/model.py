"""Model factory: assembles every assigned architecture family from the
shared layer library.

Layers are grouped into *periods* — the smallest repeating block pattern of
the architecture (dense: 1 attention layer; xLSTM: [mLSTM, sLSTM];
RecurrentGemma: [RG-LRU, RG-LRU, local-attn], each with its own MLP) — and
the period is scanned with stacked parameters (+ optional remat), so the
HLO stays O(period) deep regardless of depth: essential for the 62-layer
x 512-device dry-runs.

Heterogeneous per-layer state (full-length KV for global-attention layers,
ring-buffer KV for sliding-window layers, matrix/vector recurrent states)
is threaded through the scan; partially-filled final periods are masked with
static per-period activity flags (their outputs are zeroed).

Modes:
  train    — full-sequence logits (no cache) + MoE aux loss;
  prefill  — fills the cache, returns last-position logits;
  decode   — one token against a pre-filled cache (the serve_step that the
             decode_* / long_* shapes lower).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers, moe as moe_lib, rglru, xlstm

_I32 = jnp.int32


# --------------------------------------------------------------------------
# period plan
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Plan:
    period: tuple                 # block kinds in one period
    n_periods: int
    active: dict                  # kind -> np.bool_[n_periods]
    is_global: np.ndarray         # [n_periods] attention flavour per period


def build_plan(cfg: ArchConfig) -> Plan:
    L = cfg.num_layers
    if cfg.block_kind == "xlstm":
        period = ("mlstm", "slstm")
        n = -(-L // 2)
        active = {"mlstm": np.arange(n) * 2 < L,
                  "slstm": np.arange(n) * 2 + 1 < L}
        return Plan(period, n, active, np.zeros(n, bool))
    if cfg.block_kind == "rglru":
        period = ("rglru", "rglru2", "attn")
        n = -(-L // 3)
        active = {"rglru": np.arange(n) * 3 < L,
                  "rglru2": np.arange(n) * 3 + 1 < L,
                  "attn": np.arange(n) * 3 + 2 < L}
        return Plan(period, n, active, np.zeros(n, bool))  # attn all local
    period = ("attn",)
    if cfg.pattern_local:
        p = cfg.pattern_local + cfg.pattern_global
        is_global = (np.arange(L) % p) >= cfg.pattern_local
    else:
        is_global = np.ones(L, bool)
    return Plan(period, L, {"attn": np.ones(L, bool)}, is_global)


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def _stack_init(fn, key, n, *args, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: fn(k, *args, **kw))(keys)


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    plan = build_plan(cfg)
    keys = jax.random.split(key, 12)
    D, H, Kv, Dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    p: dict[str, Any] = {}
    p["embed"] = jax.random.normal(keys[0], (cfg.vocab_size, D), dtype) \
        * (1.0 / np.sqrt(D))
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(keys[1], (D, cfg.vocab_size), dtype) \
            * (1.0 / np.sqrt(D))
    p["final_norm"] = layers.init_rms_norm(D, dtype)

    n = plan.n_periods
    blocks: dict[str, Any] = {}
    if "attn" in plan.period:
        blocks["attn"] = _stack_init(
            layers.init_attention, keys[2], n, D, H, Kv, Dh,
            qkv_bias=cfg.qkv_bias, dtype=dtype)
        blocks["ln_attn"] = jnp.zeros((n, D), dtype)
        if cfg.moe is not None:
            blocks["moe"] = _stack_init(moe_lib.init_moe, keys[4], n, D,
                                        cfg.moe, cfg.d_ff, dtype)
            blocks["ln_moe"] = jnp.zeros((n, D), dtype)
        elif cfg.d_ff:
            blocks["mlp_attn"] = _stack_init(layers.init_mlp, keys[3], n, D,
                                             cfg.d_ff, dtype)
            blocks["ln_mlp_attn"] = jnp.zeros((n, D), dtype)
    if "mlstm" in plan.period:
        blocks["mlstm"] = _stack_init(xlstm.init_mlstm, keys[5], n, D, H,
                                      dtype)
        blocks["ln_mlstm"] = jnp.zeros((n, D), dtype)
    if "slstm" in plan.period:
        blocks["slstm"] = _stack_init(xlstm.init_slstm, keys[6], n, D, H,
                                      dtype)
        blocks["ln_slstm"] = jnp.zeros((n, D), dtype)
    for kind, kidx in (("rglru", 7), ("rglru2", 8)):
        if kind in plan.period:
            blocks[kind] = _stack_init(rglru.init_rglru_block, keys[kidx],
                                       n, D, dtype)
            blocks[f"ln_{kind}"] = jnp.zeros((n, D), dtype)
            blocks[f"mlp_{kind}"] = _stack_init(layers.init_mlp, keys[9], n,
                                                D, cfg.d_ff, dtype)
            blocks[f"ln_mlp_{kind}"] = jnp.zeros((n, D), dtype)
    p["blocks"] = blocks

    if cfg.frontend == "audio_frames":
        p["frontend"] = {"proj": jax.random.normal(
            keys[10], (cfg.frontend_dim, D), dtype)
            / np.sqrt(cfg.frontend_dim)}
    elif cfg.frontend == "vision_patches":
        k1, k2 = jax.random.split(keys[10])
        p["frontend"] = {
            "proj1": jax.random.normal(k1, (cfg.frontend_dim, D), dtype)
            / np.sqrt(cfg.frontend_dim),
            "proj2": jax.random.normal(k2, (D, D), dtype) / np.sqrt(D),
        }
    return p


def param_count(cfg: ArchConfig, *, active_only: bool = False) -> int:
    """Analytic parameter count (used for MODEL_FLOPS = 6 N D)."""
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        d_e = m.d_expert or cfg.d_ff
        per_expert = 3 * cfg.d_model * d_e
        plan = build_plan(cfg)
        inactive = plan.n_periods * per_expert * (m.num_experts - m.top_k)
        total -= inactive
    return total


# --------------------------------------------------------------------------
# cache
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    plan = build_plan(cfg)
    D, H, Kv, Dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    n = plan.n_periods
    cache: dict[str, Any] = {"pos": jnp.zeros((), _I32)}
    if "attn" in plan.period:
        n_global = int(plan.is_global.sum())
        n_local = n - n_global
        W = min(cfg.local_window or max_seq, max_seq)
        if n_global:
            cache["gk"] = jnp.zeros((n_global, batch, max_seq, Kv, Dh), dtype)
            cache["gv"] = jnp.zeros((n_global, batch, max_seq, Kv, Dh), dtype)
            cache["gpos"] = jnp.full((batch, max_seq), -1, _I32)
        if n_local:
            cache["lk"] = jnp.zeros((n_local, batch, W, Kv, Dh), dtype)
            cache["lv"] = jnp.zeros((n_local, batch, W, Kv, Dh), dtype)
            cache["lpos"] = jnp.full((batch, W), -1, _I32)
    if "mlstm" in plan.period:
        dh_m = xlstm.PROJ_FACTOR * D // H
        cache["mlstm"] = jax.vmap(
            lambda _: xlstm.mlstm_init_state(batch, H, dh_m, dtype))(
            jnp.arange(n))
    if "slstm" in plan.period:
        cache["slstm"] = jax.vmap(
            lambda _: xlstm.slstm_init_state(batch, H, D // H, dtype))(
            jnp.arange(n))
    for kind in ("rglru", "rglru2"):
        if kind in plan.period:
            cache[kind] = jax.vmap(
                lambda _: rglru.rglru_init_state(batch, D, dtype))(
                jnp.arange(n))
    return cache


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch_inputs, dtype):
    if cfg.frontend == "audio_frames":
        frames = batch_inputs["frames"]                       # [B,S,Fd]
        return jnp.einsum("bsf,fd->bsd", frames.astype(dtype),
                          params["frontend"]["proj"])
    x = layers.embed_lookup(params["embed"], batch_inputs["tokens"])
    if cfg.frontend == "vision_patches" and "patches" in batch_inputs:
        pt = batch_inputs["patches"].astype(dtype)            # [B,P,Fd]
        pe = jnp.einsum("bpf,fd->bpd", pt, params["frontend"]["proj1"])
        pe = jnp.einsum("bpd,de->bpe", jax.nn.gelu(pe),
                        params["frontend"]["proj2"])
        x = jnp.concatenate([pe, x], axis=1)
    return x


def forward(cfg: ArchConfig, params, batch_inputs, *, mode: str = "train",
            cache: dict | None = None, dtype=jnp.bfloat16,
            return_hidden: bool = False, act_sharding=None,
            scan_unroll: int | bool = 1, attn_q_chunk: int | None = None,
            attn_chunk_unroll: int | bool = 1):
    """train: (logits [B,S,V], aux);  prefill/decode: (logits [B,V], cache).

    ``return_hidden`` (train only): skip the LM head and return the final
    hidden states — the training loss computes the head in sequence chunks
    so the full [B, S, V] logits tensor is never materialised (essential
    for 262k vocabularies).

    ``act_sharding`` — optional NamedSharding for the residual stream
    (Megatron-style sequence parallelism: P(data, "model", None)); applied
    to the scan carry so remat activation memory is sharded over the full
    mesh.

    ``scan_unroll`` — forwarded to the layer scan; the dry-run lowers with
    True (full unroll) so XLA cost analysis counts every layer (while-loop
    bodies are otherwise counted once).
    """
    assert mode in ("train", "prefill", "decode")
    plan = build_plan(cfg)
    D, H = cfg.d_model, cfg.num_heads
    x = _embed_inputs(cfg, params, batch_inputs, dtype)
    B, S, _ = x.shape
    causal = not cfg.encoder_only
    serving = mode != "train"
    W = cfg.local_window or 0
    n = plan.n_periods

    if serving:
        assert cache is not None
        pos0 = cache["pos"]
    else:
        pos0 = jnp.zeros((), _I32)
    positions = pos0 + jnp.broadcast_to(jnp.arange(S, dtype=_I32), (B, S))

    has_g = serving and cache is not None and "gk" in cache
    has_l = serving and cache is not None and "lk" in cache
    rec_kinds = [k for k in ("mlstm", "slstm", "rglru", "rglru2")
                 if k in plan.period]

    # shared (all-layers) cache position arrays, updated once
    gpos_new = lpos_new = None
    if has_g:
        gpos_new = jax.lax.dynamic_update_slice(cache["gpos"], positions,
                                                (0, pos0))
    if has_l:
        Wc = cache["lk"].shape[2]
        if S >= Wc:
            tailp = positions[:, -Wc:]
            lpos_new = cache["lpos"].at[
                jnp.arange(B)[:, None], tailp % Wc].set(tailp)
        else:
            lpos_new = cache["lpos"].at[
                jnp.arange(B)[:, None], positions % Wc].set(positions)

    def attn_sublayer(x, prm, ln, is_global, g_ord, l_ord, kvstacks):
        h = layers.rms_norm(x, ln, cfg.norm_eps)
        window = jnp.where(is_global, 0, W).astype(_I32) if W else \
            jnp.zeros((), _I32)
        if not serving:
            k, v = layers.project_kv(prm, h, positions, cfg.rope_theta)
            out = layers.attention(
                prm, h, positions=positions, kv_positions=positions,
                k_cache=k, v_cache=v, causal=causal, window=window,
                rope_theta=cfg.rope_theta,
                use_flash=cfg.use_flash_attention and W == 0,
                q_chunk=attn_q_chunk, chunk_unroll=attn_chunk_unroll)
            return x + out, kvstacks
        k, v = layers.project_kv(prm, h, positions, cfg.rope_theta)
        gk, gv, lk, lv = kvstacks
        prefilling = S > 1        # static: prefill chunks vs one-token decode

        def write_global(stacks, kc_new=None):
            gk, gv, lk, lv = stacks
            kc = jax.lax.dynamic_update_slice(
                gk[g_ord], k.astype(gk.dtype), (0, pos0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                gv[g_ord], v.astype(gv.dtype), (0, pos0, 0, 0))
            return kc, vc, (gk.at[g_ord].set(kc), gv.at[g_ord].set(vc),
                            lk, lv)

        def write_local(stacks):
            gk, gv, lk, lv = stacks
            Wc = lk.shape[2]
            if S >= Wc:
                kt, vt = k[:, -Wc:], v[:, -Wc:]
                pt = positions[:, -Wc:]
            else:
                kt, vt, pt = k, v, positions
            rows = jnp.arange(B)[:, None]
            kc = lk[l_ord].at[rows, pt % Wc].set(kt.astype(lk.dtype))
            vc = lv[l_ord].at[rows, pt % Wc].set(vt.astype(lv.dtype))
            return kc, vc, (gk, gv, lk.at[l_ord].set(kc),
                            lv.at[l_ord].set(vc))

        if prefilling:
            # attend within the current chunk (prefill starts at pos 0);
            # the cache is written for subsequent decode steps.
            window = jnp.where(is_global, 0, W).astype(_I32) if W else \
                jnp.zeros((), _I32)
            out = layers.attention(
                prm, h, positions=positions, kv_positions=positions,
                k_cache=k, v_cache=v, causal=causal, window=window,
                rope_theta=cfg.rope_theta,
                q_chunk=attn_q_chunk, chunk_unroll=attn_chunk_unroll)

            def wg(stacks):
                return write_global(stacks)[2]

            def wl(stacks):
                return write_local(stacks)[2]

            if has_g and has_l:
                stacks = jax.lax.cond(is_global, wg, wl, (gk, gv, lk, lv))
            elif has_g:
                stacks = wg((gk, gv, lk, lv))
            else:
                stacks = wl((gk, gv, lk, lv))
            return x + out, stacks

        # one-token decode: attend against the cache stack for this layer
        def dec_global(stacks):
            kc, vc, stacks = write_global(stacks)
            out = layers.attention(
                prm, h, positions=positions, kv_positions=gpos_new,
                k_cache=kc, v_cache=vc, causal=causal,
                window=jnp.zeros((), _I32), rope_theta=cfg.rope_theta)
            return out, stacks

        def dec_local(stacks):
            kc, vc, stacks = write_local(stacks)
            out = layers.attention(
                prm, h, positions=positions, kv_positions=lpos_new,
                k_cache=kc, v_cache=vc, causal=causal,
                window=jnp.asarray(W or kc.shape[1], _I32),
                rope_theta=cfg.rope_theta)
            return out, stacks

        if has_g and has_l:
            out, stacks = jax.lax.cond(is_global, dec_global, dec_local,
                                       (gk, gv, lk, lv))
        elif has_g:
            out, stacks = dec_global((gk, gv, lk, lv))
        else:
            out, stacks = dec_local((gk, gv, lk, lv))
        return x + out, stacks

    # token/expert mesh axes for the MoE dispatch sharding constraints.
    # Tokens stay on the *data* axes only (Megatron-style: gather the
    # sequence shards before the expert FFN) — constraining tokens over
    # (data, model) was measured to force involuntary SPMD remats
    # (EXPERIMENTS.md §Perf iteration log).
    moe_token_axes = None
    moe_expert_axis = None
    if act_sharding is not None and cfg.moe is not None:
        sp = act_sharding.spec
        part = list(sp)[0] if len(sp) else None
        if part is not None:
            moe_token_axes = tuple(part) if isinstance(part, tuple) \
                else (part,)
        moe_expert_axis = "model"

    def mlp_sublayer(x, blk, tag):
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe is not None and tag == "attn":
            h = layers.rms_norm(x, blk["ln_moe"], cfg.norm_eps)
            out, aux = moe_lib.moe_ffn(blk["moe"], h, cfg.moe,
                                       token_axes=moe_token_axes,
                                       expert_axis=moe_expert_axis)
            return x + out, aux
        key = f"mlp_{tag}"
        if key in blk:
            h = layers.rms_norm(x, blk[f"ln_mlp_{tag}"], cfg.norm_eps)
            return x + layers.mlp(blk[key], h), aux
        return x, aux

    def fresh_state(kind):
        if kind == "mlstm":
            return xlstm.mlstm_init_state(B, H, xlstm.PROJ_FACTOR * D // H,
                                          dtype)
        if kind == "slstm":
            return xlstm.slstm_init_state(B, H, D // H, dtype)
        return rglru.rglru_init_state(B, D, dtype)

    def period_body(carry, xs_t):
        x, stacks, aux_tot = carry
        if act_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, act_sharding)
        blk = xs_t["params"]
        rec_out = {}
        for kind in plan.period:
            act = xs_t["active"][kind]
            if kind == "attn":
                x2, stacks2 = attn_sublayer(
                    x, blk["attn"], blk["ln_attn"], xs_t["is_global"],
                    xs_t["g_ord"], xs_t["l_ord"], stacks)
                x2, aux = mlp_sublayer(x2, blk, "attn")
                x = jnp.where(act, x2, x)
                stacks = jax.tree.map(
                    lambda a, b: jnp.where(act, b, a), stacks, stacks2)
                aux_tot = aux_tot + jnp.where(act, aux, 0.0)
            else:
                st_in = xs_t["rec"][kind] if serving else fresh_state(kind)
                h = layers.rms_norm(x, blk[f"ln_{kind}"], cfg.norm_eps)
                if kind == "mlstm":
                    out, st = xlstm.mlstm_apply(blk["mlstm"], h, st_in,
                                                chunk=cfg.mlstm_chunk,
                                                unroll=attn_chunk_unroll)
                elif kind == "slstm":
                    out, st = xlstm.slstm_apply(blk["slstm"], h, st_in)
                else:
                    out, st = rglru.rglru_block_apply(blk[kind], h, st_in)
                x2 = x + out
                x2, aux = mlp_sublayer(x2, blk, kind)
                x = jnp.where(act, x2, x)
                aux_tot = aux_tot + jnp.where(act, aux, 0.0)
                rec_out[kind] = jax.tree.map(
                    lambda a, b: jnp.where(act, a, b), st, st_in)
        return (x, stacks, aux_tot), rec_out

    # ---- per-period xs ----
    isg = plan.is_global
    g_ord = np.maximum(np.cumsum(isg) - 1, 0)
    l_ord = np.maximum(np.cumsum(~isg) - 1, 0)
    xs = {
        "params": params["blocks"],
        "is_global": jnp.asarray(isg),
        "g_ord": jnp.asarray(g_ord, _I32),
        "l_ord": jnp.asarray(l_ord, _I32),
        "active": {k: jnp.asarray(v) for k, v in plan.active.items()},
    }
    if serving and rec_kinds:
        xs["rec"] = {k: cache[k] for k in rec_kinds}
    else:
        xs["rec"] = {}

    if has_g:
        stacks0 = (cache["gk"], cache["gv"],
                   cache.get("lk", jnp.zeros((0,))),
                   cache.get("lv", jnp.zeros((0,))))
    elif has_l:
        stacks0 = (jnp.zeros((0,)), jnp.zeros((0,)), cache["lk"],
                   cache["lv"])
    else:
        z = jnp.zeros((0,))
        stacks0 = (z, z, z, z)

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    if act_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, act_sharding)
    (x, stacks, aux), rec_ys = jax.lax.scan(
        body, (x, stacks0, jnp.zeros((), jnp.float32)), xs,
        unroll=scan_unroll)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden and not serving:
        return x, aux
    head = params.get("head")
    if serving:
        x = x[:, -1:]
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head)

    if not serving:
        return logits, aux

    new_cache = dict(cache)
    gk, gv, lk, lv = stacks
    if has_g:
        new_cache["gk"], new_cache["gv"] = gk, gv
        new_cache["gpos"] = gpos_new
    if has_l:
        new_cache["lk"], new_cache["lv"] = lk, lv
        new_cache["lpos"] = lpos_new
    for k in rec_kinds:
        new_cache[k] = rec_ys[k]
    new_cache["pos"] = pos0 + S
    return logits[:, 0], new_cache
