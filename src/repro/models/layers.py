"""Shared neural layers: norms, RoPE, GQA attention (global / sliding-window
ring cache), SwiGLU MLP, embeddings.

All layers are pure functions over parameter dicts (no framework deps).
Dtype policy: parameters and activations in the caller's dtype (bf16 for the
production configs), reductions and softmax in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms / embeddings
# --------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * (1.0 + w)


def init_rms_norm(d, dtype):
    return jnp.zeros((d,), dtype)


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x [..., S, H, Dh]; positions [..., S] (absolute)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freq      # [..,S,half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(key, d_model, n_heads, n_kv, head_dim, *, qkv_bias, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d_model)
    p = {
        "wq": jax.random.normal(k1, (d_model, n_heads, head_dim), dtype) * s,
        "wk": jax.random.normal(k2, (d_model, n_kv, head_dim), dtype) * s,
        "wv": jax.random.normal(k3, (d_model, n_kv, head_dim), dtype) * s,
        "wo": jax.random.normal(k4, (n_heads, head_dim, d_model), dtype) * s,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((n_kv, head_dim), dtype)
        p["bv"] = jnp.zeros((n_kv, head_dim), dtype)
    return p


def _sdpa(q, k, v, mask):
    """q [B,S,H,Dh], k/v [B,T,Kv,Dh], mask [B,1,S,T] bool — pure jnp path."""
    B, S, H, Dh = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    qg = q.reshape(B, S, Kv, rep, Dh)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(Dh)
    logits = jnp.where(mask[:, :, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrst,btkd->bskrd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, Dh).astype(q.dtype)


def _sdpa_q_chunked(q, k, v, mask, q_chunk: int, unroll: int | bool = 1):
    """Query-chunked SDPA: scores exist only as [.., q_chunk, T] tiles.

    Long-sequence prefill cannot materialise [S, T] score tensors (32k x 32k
    is terabytes); k/v fit comfortably, so each scan step computes a full
    softmax over T for one query tile.  This is the pure-jnp analogue of the
    Pallas flash kernel that keeps XLA cost analysis transparent.
    """
    B, S, H, Dh = q.shape
    pad = (-S) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // q_chunk
    qs = q.reshape(B, nc, q_chunk, H, Dh).swapaxes(0, 1)
    ms = mask.reshape(B, 1, nc, q_chunk, -1).swapaxes(0, 2)

    def body(_, inp):
        qc, mc = inp                      # [B,qc,H,Dh], [1,B,qc? ...]
        return None, _sdpa(qc, k, v, mc.swapaxes(0, 1))

    _, outs = jax.lax.scan(body, None, (qs, ms), unroll=unroll)
    out = outs.swapaxes(0, 1).reshape(B, S + pad, H, Dh)
    return out[:, :S]


def attention(params, x, *, positions, kv_positions, k_cache, v_cache,
              causal: bool, window, rope_theta: float,
              use_flash: bool = False, q_chunk: int | None = None,
              chunk_unroll: int | bool = 1):
    """Generic GQA attention against a (possibly cached) KV set.

    x [B,S,D]; k_cache/v_cache [B,T,Kv,Dh] already containing this step's
    keys (the caller writes them); kv_positions [B,T] absolute positions of
    cache slots (-1 = empty).  ``window`` may be a traced i32 scalar:
    window > 0 masks keys older than position - window + 1 (sliding-window
    attention / ring cache); window == 0 means global.
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = rope(q, positions, rope_theta)
    T = k_cache.shape[1]
    valid = kv_positions[:, None, None, :] >= 0                 # [B,1,1,T]
    mask = jnp.broadcast_to(valid, (B, 1, S, T))
    if causal:
        mask = mask & (kv_positions[:, None, None, :]
                       <= positions[:, None, :, None])
    window = jnp.asarray(window, jnp.int32)
    eff = jnp.where(window > 0, window, T + S + 2)    # 0 => effectively inf
    mask = mask & (kv_positions[:, None, None, :]
                   > positions[:, None, :, None] - eff)
    if use_flash and causal and S == T:
        # contiguous full-causal case lowers to the Pallas kernel
        # (caller guarantees window == 0 statically on this path)
        o = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k_cache.transpose(0, 2, 1, 3),
            v_cache.transpose(0, 2, 1, 3), causal=True)
        o = o.transpose(0, 2, 1, 3)
    elif q_chunk is not None and S > q_chunk:
        o = _sdpa_q_chunked(q, k_cache, v_cache, mask, q_chunk,
                            unroll=chunk_unroll)
    else:
        o = _sdpa(q, k_cache, v_cache, mask)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


def project_kv(params, x, positions, rope_theta):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    k = rope(k, positions, rope_theta)
    return k, v


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }


def mlp(params, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, params["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
