"""Distance labelings: region-relabel (Alg. 3) and gap heuristics (Alg. 4).

Two label semantics coexist in the paper and here:

* PRD labels lower-bound the *hop* distance ``d*`` to the sink
  (ceiling ``d_inf_prd = n``);
* ARD labels lower-bound the *region* distance ``d*B`` — the number of
  inter-region boundary crossings on a residual path to the sink
  (ceiling ``d_inf_ard = |B|``, paper Sec. 4.1).

Both region-relabel variants are one vectorized Bellman-Ford fixpoint over
the region's residual arcs: ARD propagates labels at zero cost through
intra-region arcs (Alg. 3 without the `d_current += 1` line), PRD at unit
cost.  Gap heuristics operate on label histograms — boundary-only bins for
ARD (sufficient per Sec. 5.3), all-vertex bins for PRD.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import dtypes as _dt
from repro.core.graph import FlowState, GraphMeta, INF_LABEL, intra_mask

_I32 = jnp.int32

# traces of the jitted global-relabel program (the warm-start label
# refresh) — part of the session front-end's combined cache observable
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT

# static histogram cap for gap heuristics (labels above the cap are simply
# not gap-checked; the heuristic stays sound)
GAP_HIST_CAP = 4096


def gather_ghost_labels(state: FlowState) -> jax.Array:
    """i32[K,V,E] — label of every arc's destination vertex (global gather).

    In the distributed runtime this is the per-sweep boundary label exchange;
    under pjit it lowers to an all-gather of the (small) label array.
    """
    return state.d[state.nbr_region, state.nbr_local]


def _region_relabel_one(cf, sink_cf, ghost_d, *, nbr_local, intra, emask,
                        vmask, d_inf, hop_cost: int):
    """Alg. 3 on one region network (vmapped over regions by the caller)."""
    V, E = cf.shape
    ldt = ghost_d.dtype
    inf = jnp.asarray(_dt.inf_label_for(ldt.name), ldt)
    d_inf = jnp.asarray(d_inf).astype(ldt)
    cross = emask & ~intra
    seed_ok = cross & (cf > 0) & (ghost_d < d_inf)
    base = jnp.where(seed_ok, ghost_d + 1, inf).min(axis=1)
    sink_lab = ldt.type(0) if hop_cost == 0 else ldt.type(1)
    base = jnp.where(sink_cf > 0, jnp.minimum(base, sink_lab), base)
    base = jnp.where(vmask, base, inf)

    def body(carry):
        lab, _ = carry
        nlab = jnp.where(intra & emask & (cf > 0), lab[nbr_local], inf)
        relaxed = jnp.minimum(base, nlab.min(axis=1) + hop_cost)
        relaxed = jnp.minimum(lab, jnp.where(vmask, relaxed, inf))
        return relaxed, (relaxed != lab).any()

    lab, _ = jax.lax.while_loop(lambda c: c[1], body, (base, jnp.asarray(True)))
    return jnp.minimum(lab, d_inf)


def region_relabel(meta: GraphMeta, state: FlowState, *, ard: bool) -> FlowState:
    """Recompute labels of every region from the boundary labels (Alg. 3).

    Returns labels ``max(d, relabel(d))`` — the max of two valid labelings is
    valid (paper Sec. 6.1), and monotony (d' >= d) is required by the sweep
    complexity proofs.
    """
    ghost_d = gather_ghost_labels(state)
    intra = intra_mask(state)
    d_inf = meta.d_inf_ard if ard else meta.d_inf_prd
    fn = jax.vmap(
        lambda cf, s, g, nl, it, em, vm: _region_relabel_one(
            cf, s, g, nbr_local=nl, intra=it, emask=em, vmask=vm,
            d_inf=d_inf, hop_cost=0 if ard else 1))
    new_d = fn(state.cf, state.sink_cf, ghost_d, state.nbr_local, intra,
               state.emask, state.vmask)
    return state.replace(d=jnp.maximum(state.d, new_d))


@partial(jax.jit, static_argnums=(0, 2))
def global_relabel(meta: GraphMeta, state: FlowState, ard: bool) -> FlowState:
    """Exact distance labeling of the whole residual network, from scratch.

    Iterates the region-relabel operator from the all-zero labeling to its
    least fixpoint — the exact region distance d*B (ARD) / hop distance d*
    (PRD) of every vertex in the *current* residual network, with
    unreachable vertices at ``d_inf``.  One outer iteration propagates
    labels one region hop, so the trip count is the region-graph diameter
    (devices: a handful of cheap relabel programs, no discharge engine
    runs).

    This is the warm-start label refresh: after ``graph.apply_update``
    adds residual capacity, previously-kept labels can overestimate true
    distances arbitrarily far upstream (unsound — trapped excess would
    never re-activate); exact recomputation is sound *unconditionally*
    (exact distances are valid labels by definition) and tight, so a warm
    re-solve starts from the best labeling the network admits.
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1

    def body(carry):
        st, _ = carry
        new = region_relabel(meta, st, ard=ard)
        return new, (new.d != st.d).any()

    st = state.replace(d=jnp.zeros_like(state.d))
    st, _ = jax.lax.while_loop(lambda c: c[1], body,
                               (st, jnp.asarray(True)))
    return st


def gap_new_labels(d, vmask, is_boundary, d_inf, *, cap: int, ard: bool):
    """Shared body of the global gap heuristic (Sec. 5.1).

    ``d_inf`` may be a python int (single-instance path) or a traced
    scalar (the batched driver's per-instance ceiling); ``cap`` is the
    static histogram capacity.  Any cap >= min(d_inf + 1, GAP_HIST_CAP)
    yields the same gap label: member labels are < d_inf so larger
    histograms only add empty bins beyond the scan range — which is what
    lets ``core.batch`` pin cap at ``GAP_HIST_CAP`` under vmap while
    staying bit-equal to this heuristic.
    """
    member = vmask & (d < d_inf)
    if ard:
        member = member & is_boundary
    vals = jnp.where(member, d, 0).reshape(-1)
    w = member.reshape(-1).astype(_I32)
    hist = jnp.zeros((cap,), _I32).at[jnp.clip(vals, 0, cap - 1)].add(w)
    idx = jnp.arange(cap)
    max_lab = jnp.max(jnp.where(member, d, 0))
    is_gap = (hist == 0) & (idx >= 1) & (idx <= jnp.minimum(max_lab, cap - 1))
    g = jnp.min(jnp.where(is_gap, idx, INF_LABEL))
    return jnp.where(vmask & (d > g) & (d < d_inf), d_inf, d).astype(d.dtype)


def global_gap(meta: GraphMeta, state: FlowState, *, ard: bool) -> FlowState:
    """Global gap heuristic (Sec. 5.1).

    If no vertex carries label g (0 < g < d_inf) then every vertex with a
    label above g cannot reach the sink and is raised to d_inf.  For ARD the
    histogram over *boundary* labels suffices (Sec. 5.3); PRD uses all
    vertices.
    """
    d_inf = meta.d_inf_ard if ard else meta.d_inf_prd
    cap = min(d_inf + 1, GAP_HIST_CAP)
    new_d = gap_new_labels(state.d, state.vmask, state.is_boundary, d_inf,
                           cap=cap, ard=ard)
    return state.replace(d=new_d)


def region_gap_prd(meta: GraphMeta, state: FlowState, region: jax.Array) -> FlowState:
    """Region gap heuristic for PRD (Alg. 4), applied to one region.

    If no vertex of R has label g, vertices of R with g < d(v) < d_next are
    raised to d_next + 1 where d_next is the smallest boundary label > g.
    """
    d_inf = meta.d_inf_prd
    cap = min(d_inf + 1, GAP_HIST_CAP)
    K, V = state.d.shape
    in_r = (jnp.arange(K)[:, None] == region) & state.vmask
    member = in_r & (state.d < d_inf)
    vals = jnp.where(member, state.d, 0).reshape(-1)
    w = member.reshape(-1).astype(_I32)
    hist = jnp.zeros((cap,), _I32).at[jnp.clip(vals, 0, cap - 1)].add(w)
    idx = jnp.arange(cap)
    max_lab = jnp.max(jnp.where(member, state.d, 0))
    is_gap = (hist == 0) & (idx >= 1) & (idx <= jnp.minimum(max_lab, cap - 1))
    g = jnp.min(jnp.where(is_gap, idx, INF_LABEL))
    # smallest boundary label above the gap (paper: d_next; d_inf if none)
    ghost_d = gather_ghost_labels(state)
    cross = state.emask & ~intra_mask(state)
    r_cross = cross & in_r[:, :, None]
    # heuristic bookkeeping runs int32 (outside the kernels); the result is
    # cast back to the state's label dtype, which d_inf fits by the range
    # check whenever labels are stored narrow
    bnd = jnp.where(r_cross & (ghost_d > g), ghost_d.astype(_I32), INF_LABEL)
    d_next = jnp.minimum(jnp.min(bnd), d_inf)
    raise_mask = member & (state.d > g) & (state.d < d_next)
    new_d = jnp.where(raise_mask,
                      jnp.minimum(d_next + 1, d_inf), state.d)
    return state.replace(d=new_d.astype(state.d.dtype))
