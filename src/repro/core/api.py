"""Public entry points for the distributed mincut/maxflow solver."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core import partition as _partition
from repro.core import sweep as _sweep
from repro.core.graph import (FlowState, GraphMeta, Layout, Problem, build,
                              init_labels)


@dataclass
class MincutResult:
    flow_value: int                 # maximum preflow value == mincut cost
    source_side: np.ndarray         # bool[n] vertex in the source set C
    stats: _sweep.SweepStats
    meta: GraphMeta
    state: FlowState
    layout: Layout


def solve_mincut(
    problem: Problem,
    part: np.ndarray | None = None,
    num_regions: int = 4,
    config: _sweep.SweepConfig | None = None,
) -> MincutResult:
    """Solve MINCUT/MAXFLOW with region discharge sweeps.

    ``part`` — region id per vertex; defaults to node-number slicing into
    ``num_regions`` regions (the paper's fallback partitioner).
    """
    if part is None:
        part = _partition.block_partition(problem.num_vertices, num_regions)
    meta, state, layout = build(problem, part)
    state0 = state
    state = init_labels(meta, state)
    cfg = config or _sweep.SweepConfig()
    state, stats = _sweep.solve(meta, state, cfg)
    sink_side = _sweep.extract_cut(meta, state)
    # sanity: the cut cost in the initial network equals the preflow value
    cost = int(_sweep.cut_value(meta, state0, sink_side))
    flow = int(state.flow_to_t)
    assert cost == flow, (
        f"internal error: cut cost {cost} != max preflow {flow}")
    source_flat = ~layout.to_flat(np.asarray(sink_side))
    return MincutResult(flow_value=flow, source_side=source_flat,
                        stats=stats, meta=meta, state=state, layout=layout)
