"""Public entry points for the distributed mincut/maxflow solver.

Two front-ends share all solver machinery:

* ``solve_mincut`` — one problem, one solve (host-loop or device-resident
  drivers, see ``sweep.solve``);
* ``solve_mincut_batch`` / ``BatchedSolver`` — a fleet of problems packed
  into shape buckets (``graph.pack_instances``) and solved together, one
  batched device program per bucket with the compiled solve cached per
  ``(bucket_shape, SweepConfig)``.  Per-instance results are bit-identical
  to ``solve_mincut`` on the same problem.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core import batch as _batch
from repro.core import partition as _partition
from repro.core import sweep as _sweep
from repro.core.graph import (FlowState, GraphMeta, Layout, PackedBatch,
                              Problem, build, init_labels, pack_instances)


@dataclass
class MincutResult:
    flow_value: int                 # maximum preflow value == mincut cost
    source_side: np.ndarray         # bool[n] vertex in the source set C
    stats: _sweep.SweepStats
    meta: GraphMeta
    state: FlowState
    layout: Layout


def _finish(meta: GraphMeta, state0: FlowState, state: FlowState,
            layout: Layout, stats: _sweep.SweepStats,
            check: bool) -> MincutResult:
    """Extract the cut and package a result (shared by both front-ends).

    ``check`` verifies that the cut cost in the initial network equals the
    preflow value — an extra device fetch plus an O(n*E) host reduction,
    so serving paths may disable it; correctness tests keep it on.
    """
    sink_side = _sweep.extract_cut(meta, state)
    flow = int(state.flow_to_t)
    if check:
        cost = int(_sweep.cut_value(meta, state0, sink_side))
        assert cost == flow, (
            f"internal error: cut cost {cost} != max preflow {flow}")
    source_flat = ~layout.to_flat(np.asarray(sink_side))
    return MincutResult(flow_value=flow, source_side=source_flat,
                        stats=stats, meta=meta, state=state, layout=layout)


def solve_mincut(
    problem: Problem,
    part: np.ndarray | None = None,
    num_regions: int = 4,
    config: _sweep.SweepConfig | None = None,
    check: bool = True,
) -> MincutResult:
    """Solve MINCUT/MAXFLOW with region discharge sweeps.

    ``part`` — region id per vertex; defaults to node-number slicing into
    ``num_regions`` regions (the paper's fallback partitioner).
    ``check=False`` skips the host-side cut-cost == flow assertion (one
    device fetch + an O(n*E) host reduction per solve) on serving paths.
    """
    if part is None:
        part = _partition.block_partition(problem.num_vertices, num_regions)
    meta, state, layout = build(problem, part)
    state0 = state
    state = init_labels(meta, state)
    cfg = config or _sweep.SweepConfig()
    state, stats = _sweep.solve(meta, state, cfg)
    return _finish(meta, state0, state, layout, stats, check)


def _unpack_batch(packed: PackedBatch, bstate, bstats,
                  check: bool) -> list[tuple[int, MincutResult]]:
    """Slice a solved bucket back into per-instance ``MincutResult``s.

    Instance i's mutable state is the ``[:K_i, :V_i, :E_i]`` corner of its
    batch slot (packing pads at the high end, so real slots are preserved
    verbatim) recombined with its ORIGINAL unpadded topology — the result
    is a bona fide ``FlowState`` that ``extract_cut``/``cut_value``/
    ``Layout.to_flat`` consume unchanged.
    """
    out = []
    for b, idx in enumerate(packed.indices):
        meta = packed.metas[b]
        st0 = packed.states0[b]
        layout = packed.layouts[b]
        K, V, E = meta.num_regions, meta.region_size, meta.max_degree
        st = st0.replace(
            cf=bstate.cf[b, :K, :V, :E],
            sink_cf=bstate.sink_cf[b, :K, :V],
            excess=bstate.excess[b, :K, :V],
            d=bstate.d[b, :K, :V],
            flow_to_t=bstate.flow_to_t[b])
        sweeps = int(bstats.sweeps[b])
        page_bytes, msg_bytes = _sweep._page_and_msg_bytes(meta, st0)
        stats = _sweep.SweepStats(
            sweeps=sweeps,
            engine_iters=int(bstats.engine_iters[b]),
            engine_launches=bstats.engine_launches,   # global: the batch
            host_syncs=bstats.host_syncs,             # shares one stream
            boundary_bytes=sweeps * msg_bytes,
            page_bytes=sweeps * meta.num_regions * page_bytes,
            regions_discharged=sweeps * meta.num_regions)
        out.append((idx, _finish(meta, st0, st, layout, stats, check)))
    return out


@dataclass
class BatchCacheInfo:
    hits: int = 0        # solves served by an already-compiled bucket
    misses: int = 0      # bucket shapes that traced/compiled a new solve


class BatchedSolver:
    """Shape-bucketed, compile-cached multi-instance solver front-end.

    Packs problems into power-of-two shape buckets
    (``graph.pack_instances``), runs one batched device program per bucket
    (``batch.solve_batch``), and reuses the compiled solve for every batch
    that lands in a previously seen ``(bucket_shape, SweepConfig)`` —
    ``cache_info()`` reports hits/misses, where a miss is an actual trace
    of the batched device program (``batch.trace_count``).

    The instance-throughput front-end for serving: amortizes compiles
    across requests and kernel launches/host syncs across the instances of
    each batch.
    """

    def __init__(self, config: _sweep.SweepConfig | None = None, *,
                 num_regions: int = 4, check: bool = True):
        self.config = config or _sweep.SweepConfig()
        # fail fast on configurations the batched driver does not take
        if not self.config.parallel or self.config.use_boundary_relabel:
            raise ValueError(
                "BatchedSolver runs parallel sweeps without the "
                "boundary-relabel heuristic; use solve_mincut for those")
        self.num_regions = num_regions
        self.check = check
        self.cache = BatchCacheInfo()
        self.last_batch_stats: list[_batch.BatchStats] = []

    def solve(self, problems, parts=None) -> list[MincutResult]:
        packs = pack_instances(problems, parts,
                               num_regions=self.num_regions)
        results: list[MincutResult | None] = [None] * len(problems)
        self.last_batch_stats = []
        for packed in packs:
            before = _batch.trace_count()
            bstate, bstats = _batch.solve_batch(packed, self.config)
            if _batch.trace_count() > before:
                self.cache.misses += 1
            else:
                self.cache.hits += 1
            self.last_batch_stats.append(bstats)
            for idx, res in _unpack_batch(packed, bstate, bstats,
                                          self.check):
                results[idx] = res
        return results

    def cache_info(self) -> BatchCacheInfo:
        return self.cache


def solve_mincut_batch(
    problems,
    parts=None,
    num_regions: int = 4,
    config: _sweep.SweepConfig | None = None,
    check: bool = True,
) -> list[MincutResult]:
    """Solve a fleet of independent problems through the batched driver.

    One-shot convenience over ``BatchedSolver`` (which amortizes the
    compile cache across calls): problems are packed into shape buckets
    and each bucket is solved by one batched device program — on the fused
    pallas path one ``grid=(B, K)`` kernel launch per engine chunk-trip
    for the whole bucket.  Results are returned in input order and are
    bit-identical per instance to ``solve_mincut``.
    """
    solver = BatchedSolver(config, num_regions=num_regions, check=check)
    return solver.solve(problems, parts)
