"""Legacy one-shot entry points — thin shims over the ``Solver`` session.

The public front-end is ``core.solver``: ``Solver(options)`` →
``prepare(problem)`` → ``handle.solve()`` / ``handle.update(...)`` /
``Solver.solve_many([...])``, one unified ``MincutResult``/``SweepStats``
shape across the host-loop, device-resident, sharded and batched routes,
plus warm-start incremental re-solves.

This module keeps the pre-session surface alive, bit-identically:

* ``solve_mincut`` — one problem, one cold solve
  (``Solver.prepare().solve()``);
* ``solve_mincut_batch`` / ``BatchedSolver`` — a fleet of problems through
  the shape-bucketed batched driver (``Solver.solve_many``).

Downstream callers and all pre-session tests run unmodified; new code
should talk to ``Solver`` directly.
"""

from __future__ import annotations

import numpy as np

from repro.core import executor as _executor
from repro.core import sweep as _sweep
from repro.core.graph import Problem
from repro.core.solver import (MincutResult, ProblemHandle, Solver,
                               SolverCacheInfo, SolverOptions)

# legacy name for the cache-accounting record returned by
# ``BatchedSolver.cache_info`` (now the session-wide ``SolverCacheInfo``)
BatchCacheInfo = SolverCacheInfo

__all__ = [
    "BatchCacheInfo", "BatchedSolver", "MincutResult", "ProblemHandle",
    "Solver", "SolverCacheInfo", "SolverOptions", "solve_mincut",
    "solve_mincut_batch",
]


def solve_mincut(
    problem: Problem,
    part: np.ndarray | None = None,
    num_regions: int = 4,
    config: _sweep.SweepConfig | None = None,
    check: bool = True,
) -> MincutResult:
    """Solve MINCUT/MAXFLOW with region discharge sweeps (one-shot).

    ``part`` — region id per vertex; defaults to node-number slicing into
    ``num_regions`` regions (the paper's fallback partitioner).
    ``check=False`` skips the host-side cut-cost == flow assertion (one
    device fetch + an O(n*E) host reduction per solve) on serving paths.

    Equivalent to ``Solver(...).prepare(problem, part).solve()`` — for
    sequences of related problems, keep the ``Solver`` session instead:
    it amortizes build/compile across calls and re-solves warm after
    ``handle.update``.
    """
    solver = Solver(SolverOptions.from_sweep_config(
        config, num_regions=num_regions, check=check))
    return solver.prepare(problem, part).solve()


class BatchedSolver:
    """Shape-bucketed, compile-cached multi-instance solver front-end.

    Legacy wrapper over ``Solver.solve_many``: packs problems into
    power-of-two shape buckets, one batched device program per bucket,
    compiled solves cached per ``(bucket_shape, SweepConfig)`` —
    ``cache_info()`` reports hits/misses.  Per-instance results are
    bit-identical to ``solve_mincut`` on the same problem.
    """

    def __init__(self, config: _sweep.SweepConfig | None = None, *,
                 num_regions: int = 4, check: bool = True):
        self.config = config or _sweep.SweepConfig()
        self._solver = Solver(SolverOptions.from_sweep_config(
            self.config, num_regions=num_regions, check=check))
        # fail fast on configurations the batched executor does not take
        # (UnsupportedFeatureError is a ValueError, as this raise always was)
        _executor.BatchedExecutor.validate(self.config)
        self.num_regions = num_regions
        self.check = check

    def solve(self, problems, parts=None) -> list[MincutResult]:
        return self._solver.solve_many(problems, parts)

    @property
    def last_batch_stats(self):
        return self._solver.last_batch_stats

    def cache_info(self) -> BatchCacheInfo:
        return self._solver.cache_info()


def solve_mincut_batch(
    problems,
    parts=None,
    num_regions: int = 4,
    config: _sweep.SweepConfig | None = None,
    check: bool = True,
) -> list[MincutResult]:
    """Solve a fleet of independent problems through the batched driver.

    One-shot convenience over ``Solver.solve_many`` (a kept ``Solver``
    session amortizes the compile cache across calls): problems are packed
    into shape buckets and each bucket is solved by one batched device
    program — on the fused pallas path one ``grid=(B, K)`` kernel launch
    per engine chunk-trip for the whole bucket.  Results are returned in
    input order and are bit-identical per instance to ``solve_mincut``.
    """
    solver = BatchedSolver(config, num_regions=num_regions, check=check)
    return solver.solve(problems, parts)
