"""Generic sequential (Alg. 1) and parallel (Alg. 2) region-discharge sweeps.

A *sweep* is one pass in which every region is discharged once — the paper's
complexity currency (≈ disk I/O in streaming mode, ≈ network messages in
parallel mode, ≈ ICI collective traffic here).

Parallel sweeps discharge all regions concurrently on frozen boundary labels
and then *fuse* boundary flow with the conflict rule of Alg. 2:

    alpha(u, v) = [ d'(u) <= d'(v) + 1 ]
    flow u->v is accepted iff alpha(v, u)   (the reverse arc stays valid)

Rejected flow is refunded to the sender's excess and residual.  Sequential
sweeps discharge regions one at a time, applying boundary flow immediately
(no conflicts by construction).

The driver also hosts the optional heuristics of Secs. 5-6 (global gap,
boundary-relabel, partial discharges) and the per-sweep accounting used by
the paper's tables (sweeps, boundary bytes, engine iterations, page I/O).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import heuristics
from repro.core.ard import ard_discharge_one
from repro.core.engine import ENGINE_BACKENDS
from repro.core.graph import FlowState, GraphMeta, intra_mask
from repro.core.labels import (gather_ghost_labels, global_gap,
                               region_relabel)
from repro.core.prd import prd_discharge_one

_I32 = jnp.int32


@dataclass(frozen=True)
class SweepConfig:
    """Solver configuration.

    method              — "ard" (paper's contribution) or "prd" (baseline).
    parallel            — Alg. 2 (all regions concurrently + fusion) vs Alg. 1.
    partial_discharge   — Sec. 6.2: sweep s only augments to labels < s.
    use_global_gap      — Sec. 5.1 global gap heuristic each sweep.
    use_boundary_relabel— Sec. 6.1 boundary-relabel heuristic each sweep.
    max_sweeps          — hard cap (defaults to the theoretical bound).
    engine_max_iters    — safety cap for the inner engine (None = unbounded).
    engine_backend      — compute-phase backend of the discharge engine:
                          "xla" (dense rows) or "pallas" (fused kernel,
                          interpret mode off-TPU); bit-identical results.
    engine_chunk_iters  — fused chunked engine: k complete iterations per
                          compute-program launch with region state resident
                          (one pallas_call per chunk on the "pallas"
                          backend, one traced body per iteration on "xla");
                          None keeps the unfused two-phase engine.  All
                          combinations are bit-identical.
    """

    method: str = "ard"
    parallel: bool = True
    partial_discharge: bool = False
    use_global_gap: bool = True
    use_boundary_relabel: bool = False
    max_sweeps: int | None = None
    engine_max_iters: int | None = None
    engine_backend: str = "xla"
    engine_chunk_iters: int | None = None

    def __post_init__(self):
        assert self.method in ("ard", "prd")
        assert self.engine_backend in ENGINE_BACKENDS
        assert self.engine_chunk_iters is None or self.engine_chunk_iters >= 1


@dataclass
class SweepStats:
    sweeps: int = 0
    engine_iters: int = 0
    engine_launches: int = 0     # compute-program dispatches (2/iter unfused;
    #                              fused: 1/chunk pallas, 1/iter xla)
    boundary_bytes: int = 0      # flow+label messages over the cut (paper: I/O)
    page_bytes: int = 0          # streaming-mode region load/store bytes
    regions_discharged: int = 0
    flow_curve: list = dataclasses.field(default_factory=list)
    active_curve: list = dataclasses.field(default_factory=list)


def _d_inf(meta: GraphMeta, cfg: SweepConfig) -> int:
    return meta.d_inf_ard if cfg.method == "ard" else meta.d_inf_prd


def _discharge_all(meta: GraphMeta, state: FlowState, cfg: SweepConfig,
                   ghost_d: jax.Array, stage_cap) :
    """vmap the configured discharge over all regions."""
    intra = intra_mask(state)
    if cfg.method == "ard":
        fn = lambda cf, s, e, g, nl, rs, it, em, vm: ard_discharge_one(
            cf, s, e, g, nbr_local=nl, rev_slot=rs, intra=it, emask=em,
            vmask=vm, d_inf=meta.d_inf_ard, stage_cap=stage_cap,
            max_iters=cfg.engine_max_iters, backend=cfg.engine_backend,
            chunk_iters=cfg.engine_chunk_iters)
        return jax.vmap(fn)(state.cf, state.sink_cf, state.excess, ghost_d,
                            state.nbr_local, state.rev_slot, intra,
                            state.emask, state.vmask)
    fn = lambda cf, s, e, d, g, nl, rs, it, em, vm: prd_discharge_one(
        cf, s, e, d, g, nbr_local=nl, rev_slot=rs, intra=it, emask=em,
        vmask=vm, d_inf=meta.d_inf_prd, max_iters=cfg.engine_max_iters,
        backend=cfg.engine_backend, chunk_iters=cfg.engine_chunk_iters)
    return jax.vmap(fn)(state.cf, state.sink_cf, state.excess, state.d,
                        ghost_d, state.nbr_local, state.rev_slot, intra,
                        state.emask, state.vmask)


def _apply_cross_flow(state: FlowState, out_push: jax.Array,
                      accept: jax.Array) -> FlowState:
    """Apply fused boundary flow through the flat cross-arc table.

    ``accept[x]`` — Alg. 2 line 5 decision for cross arc x.  Accepted flow
    raises the receiver's reverse residual + excess; rejected flow is
    refunded to the sender (residual and excess), matching the paper's
    "do not allow the flow to cross the boundary in one of the directions".
    """
    K, V, E = state.cf.shape
    src, dst = state.cross_src, state.cross_dst
    delta = out_push[src[:, 0], src[:, 1], src[:, 2]]
    acc = jnp.where(accept, delta, 0)
    rej = delta - acc
    cf = state.cf
    flat = cf.reshape(-1)
    dst_idx = (dst[:, 0] * V + dst[:, 1]) * E + dst[:, 2]
    src_idx = (src[:, 0] * V + src[:, 1]) * E + src[:, 2]
    flat = flat.at[dst_idx].add(acc, mode="drop")
    flat = flat.at[src_idx].add(rej, mode="drop")
    cf = flat.reshape(K, V, E)
    excess = state.excess
    eflat = excess.reshape(-1)
    eflat = eflat.at[dst[:, 0] * V + dst[:, 1]].add(acc, mode="drop")
    eflat = eflat.at[src[:, 0] * V + src[:, 1]].add(rej, mode="drop")
    excess = eflat.reshape(K, V)
    return state.replace(cf=cf, excess=excess)


@partial(jax.jit, static_argnums=(0, 2))
def parallel_sweep(meta: GraphMeta, state: FlowState, cfg: SweepConfig,
                   sweep_idx: jax.Array):
    """One sweep of Alg. 2: concurrent discharges + label/flow fusion."""
    ghost_d = gather_ghost_labels(state)
    stage_cap = jnp.where(
        jnp.asarray(cfg.partial_discharge),
        jnp.maximum(sweep_idx - 1, -1).astype(_I32),
        _I32(meta.d_inf_ard))
    res = _discharge_all(meta, state, cfg, ghost_d, stage_cap)
    new = state.replace(cf=res.cf, sink_cf=res.sink_cf, excess=res.excess,
                        d=jnp.maximum(state.d, res.d),
                        flow_to_t=state.flow_to_t + res.sink_pushed.sum())
    # ---- fusion (Alg. 2 lines 4-6) ----
    src, dst = new.cross_src, new.cross_dst
    du = new.d[src[:, 0], src[:, 1]]
    dv = new.d[dst[:, 0], dst[:, 1]]
    accept = dv <= du + 1          # alpha(v, u): reverse arc stays valid
    new = _apply_cross_flow(new, res.out_push, accept)
    if cfg.use_boundary_relabel and cfg.method == "ard":
        new = heuristics.boundary_relabel(meta, new)
    if cfg.use_global_gap:
        new = global_gap(meta, new, ard=cfg.method == "ard")
    return new, res.engine_iters.sum(), res.engine_launches.sum()


@partial(jax.jit, static_argnums=(0, 2))
def sequential_sweep(meta: GraphMeta, state: FlowState, cfg: SweepConfig,
                     sweep_idx: jax.Array):
    """One sweep of Alg. 1: discharge regions one by one, apply immediately.

    Regions with no active vertex are skipped (paper Sec. 5.3) — the
    discharge engine exits in O(1) for them and the page-I/O accounting in
    ``solve`` only counts discharged regions.
    """
    K, V, E = state.cf.shape
    d_inf = _d_inf(meta, cfg)
    stage_cap_all = jnp.where(
        jnp.asarray(cfg.partial_discharge),
        jnp.maximum(sweep_idx - 1, -1).astype(_I32),
        _I32(meta.d_inf_ard))
    # sweep-invariant: depends only on static topology, so hoist it out of
    # the per-region loop (ghost labels change per discharge and stay inside)
    intra = intra_mask(state)

    def body(k, carry):
        state, iters, launches, discharged = carry
        ghost_d = gather_ghost_labels(state)
        sl = lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False)
        active = ((sl(state.excess) > 0) & (sl(state.d) < d_inf)
                  & sl(state.vmask)).any()

        def run(state):
            if cfg.method == "ard":
                res = ard_discharge_one(
                    sl(state.cf), sl(state.sink_cf), sl(state.excess),
                    sl(ghost_d), nbr_local=sl(state.nbr_local),
                    rev_slot=sl(state.rev_slot), intra=sl(intra),
                    emask=sl(state.emask), vmask=sl(state.vmask),
                    d_inf=meta.d_inf_ard, stage_cap=stage_cap_all,
                    max_iters=cfg.engine_max_iters,
                    backend=cfg.engine_backend,
                    chunk_iters=cfg.engine_chunk_iters)
            else:
                res = prd_discharge_one(
                    sl(state.cf), sl(state.sink_cf), sl(state.excess),
                    sl(state.d), sl(ghost_d), nbr_local=sl(state.nbr_local),
                    rev_slot=sl(state.rev_slot), intra=sl(intra),
                    emask=sl(state.emask), vmask=sl(state.vmask),
                    d_inf=meta.d_inf_prd, max_iters=cfg.engine_max_iters,
                    backend=cfg.engine_backend,
                    chunk_iters=cfg.engine_chunk_iters)
            upd = lambda a, v: jax.lax.dynamic_update_index_in_dim(a, v, k, 0)
            st = state.replace(
                cf=upd(state.cf, res.cf),
                sink_cf=upd(state.sink_cf, res.sink_cf),
                excess=upd(state.excess, res.excess),
                d=upd(state.d, jnp.maximum(sl(state.d), res.d)),
                flow_to_t=state.flow_to_t + res.sink_pushed)
            # apply this region's boundary pushes immediately (no conflicts)
            out_push = jnp.zeros_like(state.cf).at[k].set(res.out_push)
            src = st.cross_src
            mine = src[:, 0] == k
            st = _apply_cross_flow(st, out_push, accept=mine)
            if cfg.use_global_gap:
                st = global_gap(meta, st, ard=cfg.method == "ard")
            return st, res.engine_iters, res.engine_launches

        def skip(state):
            return state, jnp.zeros((), _I32), jnp.zeros((), _I32)

        state, it, ln = jax.lax.cond(active, run, skip, state)
        return (state, iters + it, launches + ln,
                discharged + active.astype(_I32))

    state, iters, launches, discharged = jax.lax.fori_loop(
        0, K, body,
        (state, jnp.zeros((), _I32), jnp.zeros((), _I32),
         jnp.zeros((), _I32)))
    if cfg.use_boundary_relabel and cfg.method == "ard":
        state = heuristics.boundary_relabel(meta, state)
    return state, iters, launches, discharged


def num_active(meta: GraphMeta, state: FlowState, cfg: SweepConfig) -> jax.Array:
    return state.active(_d_inf(meta, cfg)).sum()


def sweep_bound(meta: GraphMeta, cfg: SweepConfig) -> int:
    """Theoretical sweep bound: 2|B|^2 + 1 for ARD, 2 n^2 for PRD."""
    if cfg.method == "ard":
        return 2 * meta.num_boundary * meta.num_boundary + 1
    return 2 * meta.num_vertices * meta.num_vertices


def solve(meta: GraphMeta, state: FlowState, cfg: SweepConfig | None = None):
    """Run sweeps until no active vertex remains (maximum preflow reached).

    Returns (state, SweepStats).  The host-level loop is intentional: each
    sweep is one jitted device program and the paper's statistics (sweeps,
    I/O bytes) are accumulated between programs, exactly like the streaming
    solver accounts disk I/O between region loads.
    """
    cfg = cfg or SweepConfig()
    stats = SweepStats()
    bound = sweep_bound(meta, cfg)
    max_sweeps = cfg.max_sweeps if cfg.max_sweeps is not None else bound
    # bytes of one region page (cf + labels + excess + topology) — paper's
    # streaming unit; boundary message = 4B flow + 4B label per cross arc.
    page_bytes = (state.cf.itemsize * state.cf[0].size * 4
                  + 4 * state.excess[0].size * 4)
    msg_bytes = 8 * meta.num_cross_arcs

    sweep_idx = 0
    n_act = int(num_active(meta, state, cfg))
    while sweep_idx < max_sweeps:
        stats.active_curve.append(n_act)
        if n_act == 0:
            break
        if cfg.parallel:
            state, iters, launches = parallel_sweep(
                meta, state, cfg, jnp.asarray(sweep_idx, _I32))
            disc = _I32(meta.num_regions)
        else:
            state, iters, launches, disc = sequential_sweep(
                meta, state, cfg, jnp.asarray(sweep_idx, _I32))
        # all per-sweep device stats in one device->host transfer (a single
        # sync point per sweep instead of one int(...) per statistic)
        n_act, flow, it, ln, dc = (int(x) for x in jax.device_get(
            (num_active(meta, state, cfg), state.flow_to_t, iters, launches,
             disc)))
        stats.sweeps += 1
        stats.engine_iters += it
        stats.engine_launches += ln
        stats.regions_discharged += dc
        stats.page_bytes += dc * page_bytes
        stats.boundary_bytes += msg_bytes
        stats.flow_curve.append(flow)
        sweep_idx += 1
    return state, stats


def extract_cut(meta: GraphMeta, state: FlowState) -> jax.Array:
    """Minimum cut (bool[K,V]: True = sink side T = {v : v -> t in G_f}).

    Global residual-reachability fixpoint — the paper's final labeling
    sweeps, collapsed into one exact computation.
    """
    @jax.jit
    def run(state: FlowState):
        def body(carry):
            reach, _ = carry
            nbr_reach = reach[state.nbr_region, state.nbr_local]
            ok = (state.cf > 0) & state.emask & nbr_reach
            new = (state.sink_cf > 0) | ok.any(axis=2)
            new = (new | reach) & state.vmask
            return new, (new != reach).any()

        init = (state.sink_cf > 0) & state.vmask
        reach, _ = jax.lax.while_loop(lambda c: c[1], body,
                                      (init, jnp.asarray(True)))
        return reach

    return run(state)


def cut_value(meta: GraphMeta, state0: FlowState, sink_side: jax.Array) -> jax.Array:
    """Cost of the cut (C, C̄) with C̄ = sink_side, in the *initial* network.

    cost = sum_{v in C̄} e(v) + sum_{v in C} sink_cap(v)
         + sum of cap(u,v) over arcs u in C, v in C̄.
    """
    src_side = ~sink_side & state0.vmask
    e_term = jnp.sum(jnp.where(sink_side & state0.vmask, state0.excess, 0))
    t_term = jnp.sum(jnp.where(src_side, state0.sink_cf, 0))
    nbr_sink = sink_side[state0.nbr_region, state0.nbr_local]
    arc_cut = (src_side[:, :, None] & nbr_sink & state0.emask)
    c_term = jnp.sum(jnp.where(arc_cut, state0.cf, 0))
    return e_term + t_term + c_term
