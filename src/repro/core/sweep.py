"""Generic sequential (Alg. 1) and parallel (Alg. 2) region-discharge sweeps.

A *sweep* is one pass in which every region is discharged once — the paper's
complexity currency (≈ disk I/O in streaming mode, ≈ network messages in
parallel mode, ≈ ICI collective traffic here).

Parallel sweeps discharge all regions concurrently on frozen boundary labels
and then *fuse* boundary flow with the conflict rule of Alg. 2:

    alpha(u, v) = [ d'(u) <= d'(v) + 1 ]
    flow u->v is accepted iff alpha(v, u)   (the reverse arc stays valid)

Rejected flow is refunded to the sender's excess and residual.  Sequential
sweeps discharge regions one at a time, applying boundary flow immediately
(no conflicts by construction).

The driver also hosts the optional heuristics of Secs. 5-6 (global gap,
boundary-relabel, partial discharges) and the per-sweep accounting used by
the paper's tables (sweeps, boundary bytes, engine iterations, page I/O).

Two solve drivers share the same sweep programs and are bit-identical:
the host loop runs one jitted program + one host sync per sweep, while the
device-resident driver (``SweepConfig.device_resident``) runs the whole
loop — discharge, fusion, heuristics, convergence check and statistics —
inside one ``lax.while_loop``, syncing to the host once per
``host_sync_every`` sweeps (default: once per solve).  Parallel sweeps
discharge through the *batched* operators (grid-over-regions kernel: one
launch covers all K regions) instead of vmapping the per-region path.

Both drivers are thin composition over the generic region-executor loop
(``core.executor``): ``solve`` instantiates ``executor.LocalExecutor``
over this module's sweep bodies and hands it to ``executor.run_host`` /
``executor.run_device`` — the same loop that runs the batched
(``core.batch``) and sharded (``core.distributed``) executors, so the
convergence/statistics logic exists exactly once.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor as _executor
from repro.core import heuristics
from repro.core import resilience as _res
from repro.core.ard import ard_discharge_batched, ard_discharge_one
from repro.core.engine import ENGINE_BACKENDS
from repro.core.graph import FlowState, GraphMeta, intra_mask
from repro.core.labels import (gather_ghost_labels, global_gap,
                               region_relabel)
from repro.core.prd import prd_discharge_batched, prd_discharge_one

_I32 = jnp.int32

# bumped once per trace of a jitted sweep program (one-sweep bodies and the
# device-resident multi-sweep driver) — the observable behind the session
# front-end's ``Solver.cache_info``: a re-solve on a known shape must not
# bump it.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def _bump_trace() -> None:
    """Called from inside traced code (the generic executor device chunk):
    runs once per trace, never on cached invocations."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1


@dataclass(frozen=True)
class SweepConfig:
    """Solver configuration.

    method              — "ard" (paper's contribution) or "prd" (baseline).
    parallel            — Alg. 2 (all regions concurrently + fusion) vs Alg. 1.
    partial_discharge   — Sec. 6.2: sweep s only augments to labels < s.
    use_global_gap      — Sec. 5.1 global gap heuristic each sweep.
    use_boundary_relabel— Sec. 6.1 boundary-relabel heuristic each sweep.
    max_sweeps          — hard cap (defaults to the theoretical bound).
    engine_max_iters    — safety cap for the inner engine (None = unbounded).
    engine_backend      — compute-phase backend of the discharge engine:
                          "xla" (dense rows) or "pallas" (fused kernel,
                          interpret mode off-TPU); bit-identical results.
    engine_chunk_iters  — fused chunked engine: k complete iterations per
                          compute-program launch with region state resident
                          (one pallas_call per chunk on the "pallas"
                          backend, one traced body per iteration on "xla");
                          None keeps the unfused two-phase engine.  All
                          combinations are bit-identical.
    device_resident     — run the whole solve loop (discharge, fusion, gap
                          heuristic, convergence check, statistics) inside
                          one ``lax.while_loop`` on device instead of one
                          jitted program + one host sync per sweep;
                          bit-identical results, per-sweep curves kept in
                          fixed ``stats_ring_size`` device rings.
    host_sync_every     — device-resident escape hatch: return to the host
                          (one ``device_get``) every m sweeps; None (the
                          default) syncs only at convergence / the sweep
                          cap, i.e. a single sync per solve.
    stats_ring_size     — capacity of the device-resident flow/active curve
                          rings; only the last ``stats_ring_size`` sweeps
                          of the curves survive when a solve runs longer
                          (counters stay exact).
    """

    method: str = "ard"
    parallel: bool = True
    partial_discharge: bool = False
    use_global_gap: bool = True
    use_boundary_relabel: bool = False
    max_sweeps: int | None = None
    engine_max_iters: int | None = None
    engine_backend: str = "xla"
    engine_chunk_iters: int | None = None
    device_resident: bool = False
    host_sync_every: int | None = None
    stats_ring_size: int = 1024

    def __post_init__(self):
        assert self.method in ("ard", "prd")
        assert self.engine_backend in ENGINE_BACKENDS
        assert self.engine_chunk_iters is None or self.engine_chunk_iters >= 1
        assert self.host_sync_every is None or self.host_sync_every >= 1
        assert self.stats_ring_size >= 1


@dataclass
class SweepStats:
    """Per-solve accounting in the paper's I/O currency.

    ``scope`` says what the launch/sync counters cover: ``"instance"`` —
    every field is about this one solve; ``"batch"`` — the result came out
    of a batched multi-instance solve, so ``engine_launches``/``host_syncs``
    are GLOBAL to the whole batch that shared the launch/sync stream (the
    per-instance split would be fiction), while ``sweeps``/``engine_iters``
    and the byte counters remain exact per-instance values.  Fields typed
    ``int | None`` are ``None`` on routes that cannot observe them (the
    sharded driver does not count engine dispatches).
    """

    sweeps: int = 0
    engine_iters: int | None = 0
    engine_launches: int | None = 0   # compute-program dispatches (2/iter
    #                              unfused; fused: 1/chunk-trip pallas —
    #                              batched over all regions of a parallel
    #                              sweep — 1/iter xla)
    host_syncs: int = 0          # device->host transfers of the solve loop
    #                              (host loop: 1 + 1/sweep; device-resident:
    #                              1 per host_sync_every sweeps, 1 total by
    #                              default)
    boundary_bytes: int = 0      # flow+label messages over the cut (paper: I/O)
    page_bytes: int | None = 0   # streaming-mode region load/store bytes
    #                              (in-memory routes: the MODEL cost — what
    #                              the sweep WOULD stage; the streaming
    #                              executor reports measured staged bytes in
    #                              staged_in/out_bytes alongside it)
    num_boundary: int | None = None   # |B|: boundary vertices (cross-table
    #                              endpoints at build time) — the paper's
    #                              sweep-bound parameter (2|B|^2 + 1)
    staged_in_bytes: int = 0     # streaming executor: bytes actually read
    #                              from the spill pool (cache hits are free)
    staged_out_bytes: int = 0    # streaming executor: bytes written back
    regions_discharged: int | None = 0
    flow_curve: list = dataclasses.field(default_factory=list)
    active_curve: list = dataclasses.field(default_factory=list)
    scope: str = "instance"      # "instance" | "batch" (see class docstring)
    converged: bool = True       # False: stopped at max_sweeps with active
    #                              vertices left (see MincutResult.diagnosis)
    degraded: list = dataclasses.field(default_factory=list)
    #                              engine degradations taken mid-solve
    #                              (resilience ladder rungs, static VMEM
    #                              fallbacks) — never silent


_STAT_KEYS = ("sweeps", "engine_iters", "engine_launches", "host_syncs",
              "boundary_bytes", "page_bytes", "num_boundary",
              "staged_in_bytes", "staged_out_bytes", "regions_discharged",
              "flow_curve", "active_curve", "converged", "degraded")


def stats_to_dict(stats: SweepStats) -> dict:
    """JSON-serializable accounting snapshot (checkpoint manifests)."""
    return {k: getattr(stats, k) for k in _STAT_KEYS}


def stats_from_dict(d: dict) -> SweepStats:
    """Inverse of :func:`stats_to_dict` (tolerates missing keys)."""
    return SweepStats(**{k: d[k] for k in _STAT_KEYS if k in d})


def _d_inf(meta: GraphMeta, cfg: SweepConfig) -> int:
    return meta.d_inf_ard if cfg.method == "ard" else meta.d_inf_prd


def _discharge_all(meta: GraphMeta, state: FlowState, cfg: SweepConfig,
                   ghost_d: jax.Array, stage_cap):
    """Discharge all regions of a parallel sweep through the batched entry
    points (``ard_discharge_batched``/``prd_discharge_batched``) — one
    grid-over-regions kernel launch per engine chunk on the fused pallas
    path instead of vmapping K per-region launch sequences.  Per-region
    results are bit-identical to the vmapped scalar path;
    ``DischargeResult.engine_launches`` is the sweep's global dispatch
    count.
    """
    intra = intra_mask(state)
    kw = dict(nbr_local=state.nbr_local, rev_slot=state.rev_slot,
              intra=intra, emask=state.emask, vmask=state.vmask,
              max_iters=cfg.engine_max_iters, backend=cfg.engine_backend,
              chunk_iters=cfg.engine_chunk_iters)
    if cfg.method == "ard":
        return ard_discharge_batched(
            state.cf, state.sink_cf, state.excess, ghost_d,
            d_inf=meta.d_inf_ard, stage_cap=stage_cap, **kw)
    return prd_discharge_batched(
        state.cf, state.sink_cf, state.excess, state.d, ghost_d,
        d_inf=meta.d_inf_prd, **kw)


def _apply_cross_flow(state: FlowState, out_push: jax.Array,
                      accept: jax.Array) -> FlowState:
    """Apply fused boundary flow through the flat cross-arc table.

    ``accept[x]`` — Alg. 2 line 5 decision for cross arc x.  Accepted flow
    raises the receiver's reverse residual + excess; rejected flow is
    refunded to the sender (residual and excess), matching the paper's
    "do not allow the flow to cross the boundary in one of the directions".
    The flat scatter indices are the build-time precomputed
    ``cross_*_arc``/``cross_*_vtx`` fields of ``FlowState`` — static
    topology, so no jitted sweep rebuilds them from ``cross_src``/
    ``cross_dst``.
    """
    K, V, E = state.cf.shape
    delta = out_push.reshape(-1)[state.cross_src_arc]
    acc = jnp.where(accept, delta, 0)
    rej = delta - acc
    flat = state.cf.reshape(-1)
    flat = flat.at[state.cross_dst_arc].add(acc, mode="drop")
    flat = flat.at[state.cross_src_arc].add(rej, mode="drop")
    cf = flat.reshape(K, V, E)
    eflat = state.excess.reshape(-1)
    eflat = eflat.at[state.cross_dst_vtx].add(acc, mode="drop")
    eflat = eflat.at[state.cross_src_vtx].add(rej, mode="drop")
    excess = eflat.reshape(K, V)
    return state.replace(cf=cf, excess=excess)


@partial(jax.jit, static_argnums=(0, 2))
def parallel_sweep(meta: GraphMeta, state: FlowState, cfg: SweepConfig,
                   sweep_idx: jax.Array):
    """One sweep of Alg. 2: concurrent discharges + label/flow fusion."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1
    ghost_d = gather_ghost_labels(state)
    stage_cap = jnp.where(
        jnp.asarray(cfg.partial_discharge),
        jnp.maximum(sweep_idx - 1, -1).astype(_I32),
        _I32(meta.d_inf_ard))
    res = _discharge_all(meta, state, cfg, ghost_d, stage_cap)
    new = state.replace(cf=res.cf, sink_cf=res.sink_cf, excess=res.excess,
                        d=jnp.maximum(state.d, res.d),
                        flow_to_t=state.flow_to_t + res.sink_pushed.sum())
    # ---- fusion (Alg. 2 lines 4-6) ----
    src, dst = new.cross_src, new.cross_dst
    du = new.d[src[:, 0], src[:, 1]]
    dv = new.d[dst[:, 0], dst[:, 1]]
    accept = dv <= du + 1          # alpha(v, u): reverse arc stays valid
    new = _apply_cross_flow(new, res.out_push, accept)
    if cfg.use_boundary_relabel and cfg.method == "ard":
        new = heuristics.boundary_relabel(meta, new)
    if cfg.use_global_gap:
        new = global_gap(meta, new, ard=cfg.method == "ard")
    return new, res.engine_iters.sum(), res.engine_launches.sum()


@partial(jax.jit, static_argnums=(0, 2))
def sequential_sweep(meta: GraphMeta, state: FlowState, cfg: SweepConfig,
                     sweep_idx: jax.Array):
    """One sweep of Alg. 1: discharge regions one by one, apply immediately.

    Regions with no active vertex are skipped (paper Sec. 5.3) — the
    discharge engine exits in O(1) for them and the page-I/O accounting in
    ``solve`` only counts discharged regions.
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1
    K, V, E = state.cf.shape
    d_inf = _d_inf(meta, cfg)
    stage_cap_all = jnp.where(
        jnp.asarray(cfg.partial_discharge),
        jnp.maximum(sweep_idx - 1, -1).astype(_I32),
        _I32(meta.d_inf_ard))
    # sweep-invariant: depends only on static topology, so hoist it out of
    # the per-region loop (ghost labels change per discharge and stay inside)
    intra = intra_mask(state)

    def body(k, carry):
        state, iters, launches, discharged = carry
        sl = lambda a: jax.lax.dynamic_index_in_dim(a, k, 0, keepdims=False)
        # ghost labels only for the arcs of region k (a [V,E] gather) — the
        # other K-1 regions' ghosts are never read by this discharge, so
        # gathering the full [K,V,E] table per region iteration is K x
        # wasted label traffic
        ghost_k = state.d[sl(state.nbr_region), sl(state.nbr_local)]
        active = ((sl(state.excess) > 0) & (sl(state.d) < d_inf)
                  & sl(state.vmask)).any()

        def run(state):
            if cfg.method == "ard":
                res = ard_discharge_one(
                    sl(state.cf), sl(state.sink_cf), sl(state.excess),
                    ghost_k, nbr_local=sl(state.nbr_local),
                    rev_slot=sl(state.rev_slot), intra=sl(intra),
                    emask=sl(state.emask), vmask=sl(state.vmask),
                    d_inf=meta.d_inf_ard, stage_cap=stage_cap_all,
                    max_iters=cfg.engine_max_iters,
                    backend=cfg.engine_backend,
                    chunk_iters=cfg.engine_chunk_iters)
            else:
                res = prd_discharge_one(
                    sl(state.cf), sl(state.sink_cf), sl(state.excess),
                    sl(state.d), ghost_k, nbr_local=sl(state.nbr_local),
                    rev_slot=sl(state.rev_slot), intra=sl(intra),
                    emask=sl(state.emask), vmask=sl(state.vmask),
                    d_inf=meta.d_inf_prd, max_iters=cfg.engine_max_iters,
                    backend=cfg.engine_backend,
                    chunk_iters=cfg.engine_chunk_iters)
            upd = lambda a, v: jax.lax.dynamic_update_index_in_dim(a, v, k, 0)
            st = state.replace(
                cf=upd(state.cf, res.cf),
                sink_cf=upd(state.sink_cf, res.sink_cf),
                excess=upd(state.excess, res.excess),
                d=upd(state.d, jnp.maximum(sl(state.d), res.d)),
                flow_to_t=state.flow_to_t + res.sink_pushed)
            # apply this region's boundary pushes immediately (no conflicts)
            out_push = jnp.zeros_like(state.cf).at[k].set(res.out_push)
            src = st.cross_src
            mine = src[:, 0] == k
            st = _apply_cross_flow(st, out_push, accept=mine)
            if cfg.use_global_gap:
                st = global_gap(meta, st, ard=cfg.method == "ard")
            return st, res.engine_iters, res.engine_launches

        def skip(state):
            return state, jnp.zeros((), _I32), jnp.zeros((), _I32)

        state, it, ln = jax.lax.cond(active, run, skip, state)
        return (state, iters + it, launches + ln,
                discharged + active.astype(_I32))

    state, iters, launches, discharged = jax.lax.fori_loop(
        0, K, body,
        (state, jnp.zeros((), _I32), jnp.zeros((), _I32),
         jnp.zeros((), _I32)))
    if cfg.use_boundary_relabel and cfg.method == "ard":
        state = heuristics.boundary_relabel(meta, state)
    return state, iters, launches, discharged


def num_active(meta: GraphMeta, state: FlowState, cfg: SweepConfig) -> jax.Array:
    return state.active(_d_inf(meta, cfg)).sum()


def sweep_bound(meta: GraphMeta, cfg: SweepConfig) -> int:
    """Theoretical sweep bound: 2|B|^2 + 1 for ARD, 2 n^2 for PRD."""
    if cfg.method == "ard":
        return 2 * meta.num_boundary * meta.num_boundary + 1
    return 2 * meta.num_vertices * meta.num_vertices


def _page_and_msg_bytes(meta):
    # bytes of one region page (cf + labels + excess + topology) — paper's
    # streaming unit; boundary message = flow + label per cross arc.  Costed
    # per value family at the build-selected storage dtypes: the [V,E] page
    # is one flow array (cf), two int32 topology arrays (nbr/rev) and one
    # mask (emask); the [V] vectors are two flow (sink_cf/excess), one label
    # (d) and one mask (vmask).  All-int32 this is the historical
    # ``16*V*E + 16*V`` and 8 bytes/cross-arc exactly.  Computable from the
    # meta alone so the streaming executor can account pages without ever
    # materializing a FlowState.
    fb = np.dtype(meta.flow_dtype).itemsize
    lb = np.dtype(meta.label_dtype).itemsize
    mb = 1 if (fb < 4 or lb < 4) else 4
    V, E = meta.region_size, meta.max_degree
    page_bytes = (fb + 2 * 4 + mb) * V * E + (2 * fb + lb + mb) * V
    return page_bytes, (fb + lb) * meta.num_cross_arcs


def _device_stats(host, syncs, max_sweeps, R, page_bytes, msg_bytes,
                  seed_syncs=0):
    """SweepStats from a fetched device-resident carry.

    The carry holds ABSOLUTE counters (a checkpoint-resumed ``carry0``
    seeds them with the interrupted solve's values), so the reconstruction
    is complete without seed accumulation; only ``host_syncs`` counts per
    incarnation and needs the checkpoint's total added.
    """
    idx, it, ln, dc, fr, ar, n_act = host
    stats = SweepStats()
    done = int(idx)
    stats.host_syncs = seed_syncs + syncs
    stats.sweeps = done
    stats.engine_iters = int(it)
    stats.engine_launches = int(ln)
    stats.regions_discharged = int(dc)
    stats.page_bytes = int(dc) * page_bytes
    stats.boundary_bytes = done * msg_bytes
    first = max(0, done - R)
    stats.flow_curve = [int(fr[j % R]) for j in range(first, done)]
    stats.active_curve = [int(ar[j % R]) for j in range(first, done)]
    stats.converged = int(n_act) == 0
    if int(n_act) == 0 and done < max_sweeps:
        stats.active_curve.append(int(n_act))   # the terminal 0 the host
        #                                         loop records on its exit
    return stats


def _solve_device_resident(meta: GraphMeta, state: FlowState,
                           cfg: SweepConfig, ex, *, fp: str = "",
                           checkpoint=None, ckpt=None, on_sweep=None):
    """Device-resident solve: one kernel-program chain per host sync.

    The whole sweep loop — discharge, fusion, gap heuristic, convergence
    check and statistics accumulation — runs inside the generic
    ``executor.run_device`` loop; the host is re-entered once per
    ``cfg.host_sync_every`` sweeps (default: only at convergence or the
    sweep cap, i.e. exactly one ``device_get`` per solve).  Bit-exact with
    the host loop on state and counters; the flow/active curves live in
    fixed-size device rings, so only the last ``stats_ring_size`` sweeps
    of the curves survive very long solves.

    Checkpoints (``checkpoint``: a ``resilience.CheckpointPolicy``) are
    captured at the host-sync boundaries — the only host re-entry this
    driver has, so ``cfg.host_sync_every`` bounds the checkpoint cadence
    from below.  ``ckpt`` (a verified ``resilience.SolveCheckpoint``)
    resumes: counters and curve rings are rebuilt into the loop carry, so
    the continued solve is bit-exact with an uninterrupted one.
    """
    bound = sweep_bound(meta, cfg)
    max_sweeps = cfg.max_sweeps if cfg.max_sweeps is not None else bound
    R = cfg.stats_ring_size
    page_bytes, msg_bytes = _page_and_msg_bytes(meta)

    carry0 = None
    seed_syncs = 0
    degraded: list = []
    if ckpt is not None:
        state = _res.restore_state(state, ckpt.payload)
        seed = stats_from_dict(ckpt.stats)
        seed_syncs = seed.host_syncs
        degraded = list(seed.degraded)
        done0 = seed.sweeps
        # rebuild the curve rings: ring slot j % R holds sweep j's value
        # for the last min(done0, R) sweeps (older slots are never read);
        # the active curve is trimmed to the flow curve's length to drop
        # the terminal 0 a converged checkpoint may carry
        flow_curve = seed.flow_curve
        active_curve = seed.active_curve[:len(flow_curve)]
        first = max(0, done0 - R)
        fr = np.zeros((R,), np.int32)
        ar = np.zeros((R,), np.int32)
        for j in range(first, done0):
            fr[j % R] = flow_curve[j - first]
            ar[j % R] = active_curve[j - first]
        carry0 = (jnp.asarray(done0, _I32),
                  jnp.asarray(seed.engine_iters, _I32),
                  jnp.asarray(seed.engine_launches, _I32),
                  jnp.asarray(seed.regions_discharged, _I32),
                  jnp.asarray(fr), jnp.asarray(ar),
                  jnp.asarray(int(ckpt.payload["n_act"]), _I32))

    ckpt_sync = None
    if checkpoint is not None:
        last_saved = [ckpt.sweeps if ckpt is not None else 0]

        def ckpt_sync(st, host, syncs):
            done, running = ex.progress(host, max_sweeps)
            if running and done - last_saved[0] < checkpoint.every:
                return
            stats = _device_stats(host, syncs, max_sweeps, R, page_bytes,
                                  msg_bytes, seed_syncs=seed_syncs)
            stats.degraded = list(degraded)
            payload = _res.state_payload(st)
            payload["n_act"] = np.asarray(host[-1], np.int32)
            _res.save_checkpoint(checkpoint.directory, _res.SolveCheckpoint(
                fingerprint=fp, route="device", sweeps=done,
                payload=payload, stats=stats_to_dict(stats),
                flow_offset=checkpoint.flow_offset))
            last_saved[0] = done

    on_sync = ckpt_sync
    if on_sweep is not None:
        # the device route's sweep-boundary hook fires at the
        # host_sync_every boundaries — the only host re-entries it has;
        # the checkpoint capture runs FIRST so a hook that aborts the
        # solve (the serving tier's deadline enforcement) leaves the
        # boundary durably checkpointed
        def on_sync(st, host, syncs):
            if ckpt_sync is not None:
                ckpt_sync(st, host, syncs)
            on_sweep(st, int(host[0]))

    state, host, syncs = _executor.run_device(
        ex, state, max_sweeps, cfg.host_sync_every, carry0=carry0,
        on_sync=on_sync)
    stats = _device_stats(host, syncs, max_sweeps, R, page_bytes, msg_bytes,
                          seed_syncs=seed_syncs)
    stats.degraded = list(degraded)
    return state, stats


def solve(meta: GraphMeta, state: FlowState, cfg: SweepConfig | None = None,
          *, warm: bool = False, on_sweep=None, checkpoint=None,
          resume_from=None, salt: str = ""):
    """Run sweeps until no active vertex remains (maximum preflow reached).

    ``warm`` — continue from the given state *as is*: its preflow (``cf``/
    ``excess``/``sink_cf``/``flow_to_t``) and labels are taken as the
    starting point, so a re-solve after a warm-start update
    (``graph.apply_update``) picks up from the previous optimum instead of
    from zero.  The caller owns label validity (the session front-end's
    ``warm_labels`` policy).  With ``warm=False`` (the cold entry) labels
    are (re-)initialized to the paper's ``Init`` — idempotent with
    ``graph.init_labels``, so pre-initialized callers are unaffected.

    ``on_sweep(state, sweeps_done)`` — optional sweep-boundary hook (tests
    use it to check the preflow/labeling invariants mid-solve; the serving
    tier enforces request deadlines with it).  On the host loop it fires
    at every sweep boundary; on the device-resident driver at the
    ``host_sync_every`` boundaries (the only host re-entries it has —
    requesting it with ``host_sync_every=None`` is an error, since the
    hook could never fire before the solve completes).

    ``checkpoint`` — a ``resilience.CheckpointPolicy``: capture a
    resumable ``SolveCheckpoint`` atomically on disk at sweep boundaries
    (host loop: every ``checkpoint.every`` sweeps + the final boundary;
    device-resident: at the ``host_sync_every`` boundaries under the same
    cadence).  ``resume_from`` — a ``SolveCheckpoint`` or a checkpoint
    directory (latest wins): continue the interrupted solve BIT-EXACTLY —
    flow, labels, sweeps and engine counters match the uninterrupted run
    (``host_syncs`` honestly counts both incarnations' syncs).  A
    checkpoint from different math (method/heuristics/layout) is rejected
    with ``CheckpointMismatchError``; engine-backend and driver knobs are
    deliberately NOT part of the identity (every route/rung is
    bit-identical), so cross-driver resume is allowed.  ``salt`` — extra
    fingerprint input (the session front-end's layout digest); a given
    ``checkpoint.salt`` wins.

    Returns (state, SweepStats).  Two drivers, bit-identical results, both
    thin composition over the generic executor loop (``core.executor``):

    * host loop (default) — ``executor.run_host``: each sweep is one
      jitted device program with one host sync after it; the paper's
      statistics (sweeps, I/O bytes) are accumulated between programs,
      exactly like the streaming solver accounts disk I/O between region
      loads;
    * ``cfg.device_resident`` — ``executor.run_device``: the loop itself
      moves into a ``lax.while_loop``; the host is re-entered once per
      ``cfg.host_sync_every`` sweeps (default: once per solve).
    """
    cfg = cfg or SweepConfig()
    _executor.LocalExecutor.validate(cfg)
    ex = _executor.LocalExecutor(meta, cfg)
    if checkpoint is not None:
        salt = checkpoint.salt
    fp = _res.solve_fingerprint(meta, cfg, salt)
    ckpt = _res.resolve_resume(resume_from, fp)
    if ckpt is None and not warm:
        state = state.replace(d=jnp.zeros_like(state.d))
    if cfg.device_resident:
        if on_sweep is not None and cfg.host_sync_every is None:
            raise ValueError(
                "on_sweep needs a host boundary to fire from; the "
                "device-resident driver only has them at host_sync_every "
                "boundaries (set cfg.host_sync_every), not inside the "
                "lax.while_loop")
        state, stats = _solve_device_resident(
            meta, state, cfg, ex, fp=fp, checkpoint=checkpoint, ckpt=ckpt,
            on_sweep=on_sweep)
    else:
        state, stats = _solve_host(
            meta, state, cfg, ex, on_sweep=on_sweep, fp=fp,
            checkpoint=checkpoint, ckpt=ckpt)
    note = _res.vmem_fallback_note(cfg, state.cf.shape[1], state.cf.shape[2],
                                   dtypes=meta.kernel_dtypes)
    if note is not None and note not in stats.degraded:
        stats.degraded.append(note)
    stats.num_boundary = meta.num_boundary
    return state, stats


def _solve_host(meta: GraphMeta, state: FlowState, cfg: SweepConfig, ex, *,
                on_sweep=None, fp: str = "", checkpoint=None, ckpt=None):
    """Host-loop solve with checkpoint capture at every sweep boundary."""
    bound = sweep_bound(meta, cfg)
    max_sweeps = cfg.max_sweeps if cfg.max_sweeps is not None else bound
    page_bytes, msg_bytes = _page_and_msg_bytes(meta)

    seed = None
    start = 0
    if ckpt is not None:
        state = _res.restore_state(state, ckpt.payload)
        seed = stats_from_dict(ckpt.stats)
        # drop the terminal 0 a converged checkpoint may carry in its
        # active curve — the resumed loop's entry check re-records it
        seed.active_curve = seed.active_curve[:len(seed.flow_curve)]
        start = ckpt.sweeps

    def build(trace, active_pre, syncs, sweeps):
        """Accumulated stats = checkpoint seed + this incarnation's trace."""
        stats = SweepStats() if seed is None else stats_from_dict(
            stats_to_dict(seed))
        stats.host_syncs += syncs
        stats.sweeps = sweeps
        stats.active_curve = stats.active_curve + active_pre
        stats.flow_curve = list(stats.flow_curve)
        stats.degraded = list(stats.degraded)
        for n_act, flow, it, ln, dc in trace:
            stats.engine_iters += it
            stats.engine_launches += ln
            stats.regions_discharged += dc
            stats.page_bytes += dc * page_bytes
            stats.boundary_bytes += msg_bytes
            stats.flow_curve.append(flow)
        return stats

    on_obs = None
    last_saved = [start]
    if checkpoint is not None:
        def on_obs(st, idx, trace, active_pre):
            if idx - last_saved[0] < checkpoint.every:
                return
            _save_host_ckpt(st, idx, trace, active_pre)

        def _save_host_ckpt(st, idx, trace, active_pre):
            # syncs so far this incarnation: 1 entry check + 1 per sweep
            stats = build(trace, active_pre, 1 + len(trace), idx)
            stats.converged = bool(trace and trace[-1][0] == 0)
            payload = _res.state_payload(st)
            payload["n_act"] = np.asarray(
                trace[-1][0] if trace else 0, np.int32)
            _res.save_checkpoint(checkpoint.directory, _res.SolveCheckpoint(
                fingerprint=fp, route="host", sweeps=idx, payload=payload,
                stats=stats_to_dict(stats),
                flow_offset=checkpoint.flow_offset))
            last_saved[0] = idx

    state, trace, active_pre, syncs, sweeps = _executor.run_host(
        ex, state, max_sweeps, on_sweep=on_sweep, start=start, on_obs=on_obs)
    stats = build(trace, active_pre, syncs, sweeps)
    if trace:
        stats.converged = trace[-1][0] == 0
    elif active_pre:
        stats.converged = active_pre[-1] == 0
    elif seed is not None:
        stats.converged = bool(seed.converged)
    if checkpoint is not None and sweeps > last_saved[0]:
        _save_host_ckpt(state, sweeps, trace, active_pre)
    return state, stats


def extract_cut(meta: GraphMeta, state: FlowState) -> jax.Array:
    """Minimum cut (bool[K,V]: True = sink side T = {v : v -> t in G_f}).

    Global residual-reachability fixpoint — the paper's final labeling
    sweeps, collapsed into one exact computation.
    """
    @jax.jit
    def run(state: FlowState):
        def body(carry):
            reach, _ = carry
            nbr_reach = reach[state.nbr_region, state.nbr_local]
            ok = (state.cf > 0) & state.emask & nbr_reach
            new = (state.sink_cf > 0) | ok.any(axis=2)
            new = (new | reach) & state.vmask
            return new, (new != reach).any()

        init = (state.sink_cf > 0) & state.vmask
        reach, _ = jax.lax.while_loop(lambda c: c[1], body,
                                      (init, jnp.asarray(True)))
        return reach

    return run(state)


def cut_value(meta: GraphMeta, state0: FlowState, sink_side: jax.Array) -> jax.Array:
    """Cost of the cut (C, C̄) with C̄ = sink_side, in the *initial* network.

    cost = sum_{v in C̄} e(v) + sum_{v in C} sink_cap(v)
         + sum of cap(u,v) over arcs u in C, v in C̄.
    """
    src_side = ~sink_side & state0.vmask
    e_term = jnp.sum(jnp.where(sink_side & state0.vmask, state0.excess, 0),
                     dtype=_I32)
    t_term = jnp.sum(jnp.where(src_side, state0.sink_cf, 0), dtype=_I32)
    nbr_sink = sink_side[state0.nbr_region, state0.nbr_local]
    arc_cut = (src_side[:, :, None] & nbr_sink & state0.emask)
    c_term = jnp.sum(jnp.where(arc_cut, state0.cf, 0), dtype=_I32)
    return e_term + t_term + c_term
