"""Synchronous vectorized push-relabel engine (region-local).

This is the TPU-native replacement for the paper's region-internal solvers
(BK search trees for ARD, HPR buckets for PRD).  All per-vertex work is a
dense row operation over the padded ELL adjacency, so one engine iteration is
a handful of vector ops — the shape the VPU/MXU wants.  The scheme alternates
two *pure* phases, which keeps the labeling valid under full synchrony:

  push phase    — every active vertex pushes through its admissible arcs
                  (labels frozen); pairwise push conflicts are impossible
                  because d(u) = d(v)+1 and d(v) = d(u)+1 cannot both hold;
  relabel phase — every vertex that is still active *and* has no admissible
                  arc on the post-push residual graph relabels to
                  1 + min(neighbour labels).  Relabels see the arcs created
                  by this iteration's pushes, so validity is preserved.

The per-row multi-arc push uses an exclusive-cumsum split of the vertex's
excess over its admissible arcs (sink column first), i.e. a vertex performs
*all* its saturating pushes plus at most one non-saturating push per
iteration, like a whole Discharge step of [Goldberg-Tarjan 88] at once.

Used by prd.py (global labels, paper Sec. 3) and by each ARD stage
(BFS-initialised local labels toward the stage target set, Sec. 4.2).

Backends
--------
The per-iteration *compute phase* (admissibility, excess split, relabel
minimum — everything except the scatter application of the deltas) is a pure
function from the current state to ``(delta [V, 1+E], new_lab [V])``, and is
selectable:

  "xla"    — dense-row jnp ops (``_phase_xla``), the original engine code;
  "pallas" — the fused VMEM-tiled kernel ``repro.kernels.push_relabel``
             (interpret mode off-TPU), sharing the exact int32 math of the
             XLA phase, so the two backends are bit-identical.

Each iteration calls the phase twice: once on the pre-push state (the delta
output drives the push) and once on the post-push state (the new_lab output
is the relabel — relabels must see the arcs created by this iteration's
pushes).  Scatter application of the deltas (reverse arcs, receiver excess)
stays in XLA in both backends, as the kernel docstring prescribes.

Fused chunked mode (``chunk_iters``)
------------------------------------
With ``chunk_iters=k`` the engine switches to the *region-resident fused*
driver: the outer ``lax.while_loop`` body advances up to ``k`` complete
iterations per trip instead of one.  For ``backend="pallas"`` one trip is a
single ``fused_engine_run`` kernel launch with the whole region state in
VMEM (push split, intra-region scatter and post-push relabel all in-kernel,
early exit when no vertex is active); when the region exceeds the VMEM
budget (``kernels.push_relabel.fused_region_fits_vmem``) the engine falls
back to the blocked two-phase path.  For ``backend="xla"`` one trip is the
symmetric single traced body (the shared
``kernels.push_relabel.make_fused_iteration`` inside an inner bounded
loop) — one compute+apply+relabel program per iteration instead of two
phase calls.  All four paths (fused/unfused × xla/pallas) are bit-exact;
``EngineState.launches`` counts compute-program dispatches per engine run
(2 per iteration unfused; fused: 1 per chunk on pallas — a real kernel
launch — and 1 per iteration on xla, which fuses the two phase calls but
keeps per-iteration program structure) for the benchmark's
launch-reduction accounting.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dtypes as _dt
from repro.kernels import push_relabel as _pr_kernel

_I32 = jnp.int32


def _mask_dtype(cf, lab):
    """Kernel mask staging dtype: int8 whenever either value family is
    stored narrow (the KernelDtypes policy), int32 otherwise."""
    return jnp.int8 if (cf.dtype.itemsize < 4 or lab.dtype.itemsize < 4) \
        else jnp.int32


def _kernel_dtypes(cf, lab) -> _dt.KernelDtypes:
    """Reconstruct the KernelDtypes policy in force from live arrays (for
    the dtype-aware VMEM budget check)."""
    mask = "int8" if (cf.dtype.itemsize < 4 or lab.dtype.itemsize < 4) \
        else "int32"
    return _dt.KernelDtypes(label=lab.dtype.name, flow=cf.dtype.name,
                            mask=mask)

ENGINE_BACKENDS = ("xla", "pallas")


class EngineState(NamedTuple):
    cf: jax.Array          # i32[V,E]
    sink_cf: jax.Array     # i32[V]
    excess: jax.Array      # i32[V]
    lab: jax.Array         # i32[V]
    out_push: jax.Array    # i32[V,E]  flow pushed over cross arcs (not yet applied remotely)
    sink_pushed: jax.Array  # i32[]    flow absorbed by the sink this run
    iters: jax.Array       # i32[]
    relabel_sum: jax.Array  # i32[]    total label increase (for complexity accounting)
    launches: jax.Array    # i32[]    compute-program dispatches: 2/iter unfused,
    #                                 1/chunk fused-pallas, 1/iter fused-xla


def _phase_xla(lab, cf, sink_cf, excess, *, nbr_local, intra, pushable,
               cross_lab, d_inf):
    """One push/relabel compute phase in dense XLA row ops.

    Same contract as the Pallas kernel (``kernels.push_relabel``): inputs are
    pre-gated (``pushable`` already folds cross/emask; inactive vertices have
    zero excess; a closed sink is zero ``sink_cf``), output is the push delta
    split (sink in column 0) plus the relabel target of every active vertex
    with no admissible arc.  Mirrors ``kernels.ref.push_relabel_iteration_ref``.
    """
    inf = jnp.asarray(_dt.inf_label_for(lab.dtype.name), lab.dtype)
    d_inf = jnp.asarray(d_inf).astype(lab.dtype)
    act = (excess > 0) & (lab < d_inf)
    nlab = jnp.where(intra, lab[nbr_local], cross_lab)
    nlab = jnp.where(pushable, nlab, inf)
    adm = (cf > 0) & (lab[:, None] == nlab + 1) & act[:, None]
    sink_adm = (sink_cf > 0) & (lab == 1) & act
    sink_cap = jnp.where(sink_adm, sink_cf, 0)
    arc_cap = jnp.where(adm, cf, 0)
    caps = jnp.concatenate([sink_cap[:, None], arc_cap], axis=1)   # [V,1+E]
    avail = jnp.where(act, excess, 0)
    cum_excl = jnp.cumsum(caps, axis=1, dtype=caps.dtype) - caps
    delta = jnp.clip(avail[:, None] - cum_excl, 0, caps)           # [V,1+E]
    no_adm = act & ~adm.any(axis=1) & ~sink_adm
    cand = jnp.where(cf > 0, nlab + 1, inf).min(axis=1)
    cand = jnp.where(sink_cf > 0, jnp.minimum(cand, 1), cand)
    new_lab = jnp.where(no_adm,
                        jnp.maximum(jnp.minimum(cand, d_inf), lab), lab)
    return delta, new_lab


def make_phase(backend: str, *, nbr_local, intra, emask, vmask,
               cross_pushable, cross_lab, d_inf, sink_open: bool = True,
               block_v: int | None = None, interpret: bool | None = None):
    """Build the compute-phase closure for ``backend``.

    The returned ``phase(lab, cf, sink_cf, excess, mode="both") -> (delta,
    new_lab)`` applies the engine's gating (cross/emask arc gate, vmask
    excess gate, sink_open) and dispatches to the XLA rows or the Pallas
    kernel.  Both backends receive identical gated inputs and implement
    identical int32 math, so their outputs are bit-equal.  ``mode`` ("push" /
    "relabel") statically prunes the output the caller discards — XLA DCEs
    that itself, but a pallas_call is opaque to DCE, so the kernel takes the
    hint explicitly.
    """
    if backend not in ENGINE_BACKENDS:
        raise ValueError(f"unknown engine backend {backend!r}; "
                         f"expected one of {ENGINE_BACKENDS}")
    d_inf = jnp.asarray(d_inf, _I32)

    if backend == "pallas":
        # interpret mode everywhere but real TPUs (CPU containers, tests)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if block_v is None:
            block_v = _pr_kernel.DEFAULT_BLOCK_V

        def phase(lab, cf, sink_cf, excess, mode="both"):
            return _pr_kernel.engine_phase(
                lab, cf, sink_cf, excess, nbr_local=nbr_local, intra=intra,
                emask=emask, vmask=vmask, cross_pushable=cross_pushable,
                cross_lab=cross_lab, d_inf=d_inf, sink_open=sink_open,
                block_v=block_v, interpret=interpret, mode=mode,
                mask_dtype=_mask_dtype(cf, lab))
        return phase

    pushable = (cross_pushable | intra) & emask

    def phase(lab, cf, sink_cf, excess, mode="both"):
        excess = jnp.where(vmask, excess, 0)
        sink = sink_cf if sink_open else jnp.zeros_like(sink_cf)
        return _phase_xla(lab, cf, sink, excess, nbr_local=nbr_local,
                          intra=intra, pushable=pushable,
                          cross_lab=cross_lab, d_inf=d_inf)
    return phase


def _push_relabel_fused(cf, sink_cf, excess, lab, *, nbr_local, rev_slot,
                        intra, emask, vmask, cross_pushable, cross_lab, d_inf,
                        sink_open, max_iters, backend, chunk_iters,
                        interpret) -> EngineState:
    """Chunked fused driver on a single region: one launch advances up to
    ``chunk_iters`` complete iterations, early-exiting as soon as no vertex
    is active.  Thin K = 1 wrapper over ``_push_relabel_fused_batched`` so
    the chunk-clamping / early-exit / launch-accounting logic exists once;
    the accounting is identical at K = 1 (pallas: 1 per trip; xla: 1 per
    advanced iteration).
    """
    one = lambda a: a[None]
    es = _push_relabel_fused_batched(
        one(cf), one(sink_cf), one(excess), one(lab),
        nbr_local=one(nbr_local), rev_slot=one(rev_slot), intra=one(intra),
        emask=one(emask), vmask=one(vmask),
        cross_pushable=one(cross_pushable), cross_lab=one(cross_lab),
        d_inf=d_inf, sink_open=sink_open, max_iters=max_iters,
        backend=backend, chunk_iters=chunk_iters, interpret=interpret)
    return EngineState(es.cf[0], es.sink_cf[0], es.excess[0], es.lab[0],
                       es.out_push[0], es.sink_pushed[0], es.iters[0],
                       es.relabel_sum[0], es.launches)


def push_relabel(
    cf: jax.Array,
    sink_cf: jax.Array,
    excess: jax.Array,
    lab: jax.Array,
    *,
    nbr_local: jax.Array,
    rev_slot: jax.Array,
    intra: jax.Array,
    emask: jax.Array,
    vmask: jax.Array,
    cross_pushable: jax.Array,   # bool[V,E] cross arcs usable in this run
    cross_lab: jax.Array,        # i32[V,E]  frozen label of cross destinations
    d_inf,                       # label ceiling (python int or i32 scalar)
    sink_open: bool = True,
    max_iters: int | None = None,
    backend: str = "xla",
    block_v: int | None = None,
    interpret: bool | None = None,
    chunk_iters: int | None = None,
    vmem_budget_bytes: int | None = None,
) -> EngineState:
    """Run push/relabel until no active vertex remains.

    Returns the final engine state; ``out_push`` holds the flow sent over
    cross-region arcs, to be fused/applied by the sweep driver.  ``backend``
    selects the compute-phase implementation ("xla" dense rows or the fused
    "pallas" kernel); ``chunk_iters=k`` selects the fused chunked driver
    (one launch per k iterations, region state resident); all combinations
    produce bit-identical states.  A Pallas region that exceeds the VMEM
    budget falls back to the blocked two-phase path.
    """
    V, E = cf.shape
    d_inf = jnp.asarray(d_inf, _I32)
    if chunk_iters is not None and backend == "pallas" \
            and not _pr_kernel.fused_region_fits_vmem(
                V, E, vmem_budget_bytes, dtypes=_kernel_dtypes(cf, lab)):
        chunk_iters = None       # region too big to sit in VMEM: blocked path
    if chunk_iters is not None:
        return _push_relabel_fused(
            cf, sink_cf, excess, lab, nbr_local=nbr_local, rev_slot=rev_slot,
            intra=intra, emask=emask, vmask=vmask,
            cross_pushable=cross_pushable, cross_lab=cross_lab, d_inf=d_inf,
            sink_open=sink_open, max_iters=max_iters, backend=backend,
            chunk_iters=chunk_iters, interpret=interpret)
    flat_n = V * E
    zero_e = jnp.zeros((V, E), cf.dtype)
    phase = make_phase(backend, nbr_local=nbr_local, intra=intra, emask=emask,
                       vmask=vmask, cross_pushable=cross_pushable,
                       cross_lab=cross_lab, d_inf=d_inf, sink_open=sink_open,
                       block_v=block_v, interpret=interpret)

    def active_mask(s: EngineState):
        return (s.excess > 0) & (s.lab < d_inf) & vmask

    def body(s: EngineState) -> EngineState:
        # ---- push phase (compute on the pre-push state) ----
        delta, _ = phase(s.lab, s.cf, s.sink_cf, s.excess, mode="push")
        d_sink = delta[:, 0]
        d_arc = delta[:, 1:]
        # row sums stay in the storage dtype (bounded by the vertex's
        # excess, which the narrow range check already covers); an implicit
        # int32 promotion here would silently widen the while-loop carry
        pushed = d_sink + jnp.sum(d_arc, axis=1, dtype=d_arc.dtype)

        # ---- scatter application (always XLA: global, cross-tile) ----
        excess = s.excess - pushed
        sink_cf = s.sink_cf - d_sink
        cf = s.cf - d_arc
        # intra reverse arcs + receiver excess
        d_intra = jnp.where(intra, d_arc, 0)
        flat_idx = (nbr_local * E + rev_slot).reshape(flat_n)
        cf = (cf.reshape(flat_n).at[flat_idx]
              .add(d_intra.reshape(flat_n), mode="drop").reshape(V, E))
        recv = jnp.zeros((V,), cf.dtype).at[nbr_local.reshape(flat_n)].add(
            d_intra.reshape(flat_n), mode="drop")
        excess = excess + recv
        # cross arcs: flow leaves the region (applied later by the driver)
        d_cross = d_arc - d_intra
        out_push = s.out_push + d_cross

        s2 = EngineState(cf, sink_cf, excess, s.lab, out_push,
                         s.sink_pushed + jnp.sum(d_sink, dtype=_I32),
                         s.iters + 1, s.relabel_sum, s.launches + 2)
        # ---- relabel phase (on the post-push residual graph) ----
        _, new_lab = phase(s2.lab, s2.cf, s2.sink_cf, s2.excess,
                           mode="relabel")
        relabel_sum = s2.relabel_sum + jnp.sum(
            jnp.where(vmask, new_lab - s2.lab, 0), dtype=_I32)
        return s2._replace(lab=new_lab, relabel_sum=relabel_sum)

    def cond(s: EngineState):
        ok = active_mask(s).any()
        if max_iters is not None:
            ok = ok & (s.iters < max_iters)
        return ok

    init = EngineState(cf, sink_cf, excess, lab, zero_e,
                       jnp.zeros((), _I32), jnp.zeros((), _I32),
                       jnp.zeros((), _I32), jnp.zeros((), _I32))
    return jax.lax.while_loop(cond, body, init)


def _push_relabel_fused_batched(cf, sink_cf, excess, lab, *, nbr_local,
                                rev_slot, intra, emask, vmask, cross_pushable,
                                cross_lab, d_inf, sink_open, max_iters,
                                backend, chunk_iters, interpret,
                                grid2d: tuple[int, int] | None = None
                                ) -> EngineState:
    """Fused chunked driver over ALL regions at once (grid-over-regions).

    One outer trip advances every still-running region by up to
    ``chunk_iters`` iterations: on ``backend="pallas"`` the trip is a single
    ``fused_engine_run_batched`` launch (``grid=(K,)``, per-region in-kernel
    early exit); on ``backend="xla"`` it is one traced batched body with
    per-region run masking.  Each region's iteration sequence is exactly the
    scalar driver's (a region advances iff it has an active vertex and
    budget left), so per-region states and iteration counts are
    bit-identical to ``jax.vmap`` of the scalar path.  ``launches`` is the
    *global* dispatch count: 1 per trip on pallas (the kernel covers every
    region), one traced body per advanced region-iteration on xla —
    mirroring the scalar fused accounting summed over regions.

    ``d_inf`` may be a scalar or a per-region i32[K] vector (a solve
    batch's regions carry their instance's ceiling).  ``grid2d=(B, Kr)``
    with ``K == B*Kr`` reshapes the pallas launch to the ``grid=(B, Kr)``
    kernel form — same launch count, but the grid names the instance axis.
    """
    K, V, E = cf.shape
    chunk = int(chunk_iters)
    assert chunk >= 1
    d_inf = jnp.broadcast_to(jnp.asarray(d_inf, _I32), (K,))
    pushable = (cross_pushable | intra) & emask
    zero_e = jnp.zeros((K, V, E), cf.dtype)
    zero_k = jnp.zeros((K,), _I32)

    def region_active(excess, lab):
        return ((excess > 0) & (lab < d_inf[:, None]) & vmask).any(axis=1)

    if backend == "pallas":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        md = _mask_dtype(cf, lab)
        intra_i = intra.astype(md)
        pushable_i = pushable.astype(md)
        vmask_i = vmask.astype(md)
        lead = (K,) if grid2d is None else tuple(grid2d)
        assert math.prod(lead) == K, (lead, K)
        rs = lambda a: a.reshape(lead + a.shape[1:])

        def launch(lab, cf, sink_cf, excess, limit):
            out = _pr_kernel.fused_engine_run_batched(
                rs(lab), rs(cf), rs(sink_cf), rs(excess), rs(nbr_local),
                rs(rev_slot), rs(intra_i), rs(pushable_i), rs(cross_lab),
                rs(vmask_i), rs(d_inf), rs(limit),
                sink_open=sink_open, interpret=interpret)
            return tuple(o.reshape((K,) + o.shape[len(lead):]) for o in out)
    else:
        # the same pure fused iteration, vmapped over the region axis; a
        # per-region run mask freezes regions that are idle or out of
        # budget, exactly like vmap-of-while_loop batching does
        def one_region(cf, sink_cf, excess, lab, nbr, rev, it_m, pu_m, cl,
                       vm, di):
            step = _pr_kernel.make_fused_iteration(
                nbr=nbr, rev_slot=rev, intra=it_m, pushable=pu_m,
                cross_lab=cl, vmask=vm, d_inf=di, sink_open=sink_open)
            return step(cf, sink_cf, excess, lab)

        batched_iteration = jax.vmap(one_region)

        def launch(lab, cf, sink_cf, excess, limit):
            def icond(c):
                cf, sink_cf, excess, lab, op, sp, rs, it = c
                return ((it < limit) & region_active(excess, lab)).any()

            def ibody(c):
                cf, sink_cf, excess, lab, op, sp, rs, it = c
                run = (it < limit) & region_active(excess, lab)      # [K]
                ncf, nsink, nexc, nlab, d_cross, d_sink, rinc = \
                    batched_iteration(cf, sink_cf, excess, lab, nbr_local,
                                      rev_slot, intra, pushable, cross_lab,
                                      vmask, d_inf)
                w3, w2 = run[:, None, None], run[:, None]
                cf = jnp.where(w3, ncf, cf)
                sink_cf = jnp.where(w2, nsink, sink_cf)
                excess = jnp.where(w2, nexc, excess)
                lab = jnp.where(w2, nlab, lab)
                op = op + jnp.where(w3, d_cross, 0)
                sp = sp + jnp.where(run, d_sink, 0)
                rs = rs + jnp.where(run, rinc, 0)
                return (cf, sink_cf, excess, lab, op, sp, rs,
                        it + run.astype(_I32))

            init = (cf, sink_cf, excess, lab, zero_e, zero_k, zero_k, zero_k)
            return jax.lax.while_loop(icond, ibody, init)

    def cond(s: EngineState):
        run = region_active(s.excess, s.lab)
        if max_iters is not None:
            run = run & (s.iters < max_iters)
        return run.any()

    def body(s: EngineState) -> EngineState:
        limit = jnp.full((K,), chunk, _I32)
        if max_iters is not None:
            limit = jnp.minimum(limit, jnp.asarray(max_iters, _I32) - s.iters)
        cf, sink_cf, excess, lab, dpush, dsink, drls, dit = launch(
            s.lab, s.cf, s.sink_cf, s.excess, limit)
        # one real kernel launch covers every region on pallas; the fused
        # XLA body is one compute program per advanced region-iteration
        # (the scalar fused-xla accounting, summed over regions)
        dln = jnp.ones((), _I32) if backend == "pallas" else dit.sum()
        return EngineState(cf, sink_cf, excess, lab, s.out_push + dpush,
                           s.sink_pushed + dsink, s.iters + dit,
                           s.relabel_sum + drls, s.launches + dln)

    init = EngineState(cf, sink_cf, excess, lab, zero_e, zero_k, zero_k,
                       zero_k, jnp.zeros((), _I32))
    return jax.lax.while_loop(cond, body, init)


def push_relabel_batched(
    cf: jax.Array,               # i32[K,V,E]
    sink_cf: jax.Array,          # i32[K,V]
    excess: jax.Array,           # i32[K,V]
    lab: jax.Array,              # i32[K,V]
    *,
    nbr_local: jax.Array,
    rev_slot: jax.Array,
    intra: jax.Array,
    emask: jax.Array,
    vmask: jax.Array,
    cross_pushable: jax.Array,
    cross_lab: jax.Array,
    d_inf,
    sink_open: bool = True,
    max_iters: int | None = None,
    backend: str = "xla",
    block_v: int | None = None,
    interpret: bool | None = None,
    chunk_iters: int | None = None,
    vmem_budget_bytes: int | None = None,
    grid2d: tuple[int, int] | None = None,
) -> EngineState:
    """Run push/relabel on all K regions of a sweep through one entry point.

    The batched counterpart of ``push_relabel``: per-region results (state,
    ``out_push``, iteration counts) are bit-identical to vmapping the
    scalar engine, but the fused paths dispatch over regions collectively —
    one ``grid=(K,)`` kernel launch per chunk on ``backend="pallas"``
    instead of K independent launch sequences.  ``EngineState`` fields are
    the [K]-batched forms except ``launches``, which is the global dispatch
    count of this engine run.  Unfused configurations (``chunk_iters=None``)
    and Pallas regions over the VMEM budget fall back to ``jax.vmap`` of
    the scalar engine (per-region launch counts summed).

    ``d_inf`` may be a scalar or per-region i32[K] (each region of a solve
    batch keeps its own instance's ceiling).  ``grid2d=(B, Kr)`` renders
    the fused pallas launch as a ``grid=(B, Kr)`` program over the flat
    region axis ``K == B*Kr`` (the solve-batch form); results and launch
    counts are unchanged.
    """
    K, V, E = cf.shape
    d_inf = jnp.asarray(d_inf, _I32)
    if chunk_iters is not None and backend == "pallas" \
            and not _pr_kernel.fused_region_fits_vmem(
                V, E, vmem_budget_bytes, dtypes=_kernel_dtypes(cf, lab)):
        chunk_iters = None
    if chunk_iters is None:
        d_inf_k = jnp.broadcast_to(d_inf, (K,))
        fn = lambda cf, s, e, l, nl, rs, it, em, vm, cp, cl, di: push_relabel(
            cf, s, e, l, nbr_local=nl, rev_slot=rs, intra=it, emask=em,
            vmask=vm, cross_pushable=cp, cross_lab=cl, d_inf=di,
            sink_open=sink_open, max_iters=max_iters, backend=backend,
            block_v=block_v, interpret=interpret)
        es = jax.vmap(fn)(cf, sink_cf, excess, lab, nbr_local, rev_slot,
                          intra, emask, vmask, cross_pushable, cross_lab,
                          d_inf_k)
        return es._replace(launches=es.launches.sum())
    return _push_relabel_fused_batched(
        cf, sink_cf, excess, lab, nbr_local=nbr_local, rev_slot=rev_slot,
        intra=intra, emask=emask, vmask=vmask, cross_pushable=cross_pushable,
        cross_lab=cross_lab, d_inf=d_inf, sink_open=sink_open,
        max_iters=max_iters, backend=backend, chunk_iters=chunk_iters,
        interpret=interpret, grid2d=grid2d)


def bfs_to_targets(
    cf: jax.Array,
    sink_cf: jax.Array,
    *,
    nbr_local: jax.Array,
    intra: jax.Array,
    emask: jax.Array,
    vmask: jax.Array,
    target_cross: jax.Array,   # bool[V,E] cross arcs that enter the target set
    linf,
    sink_open: bool = True,
    label_dtype=None,
) -> jax.Array:
    """Exact hop distance to the target set through residual arcs.

    Vectorized Bellman-Ford (unit weights); converges in <= diameter rounds.
    Used to initialise each ARD stage's local labels — the engine then starts
    from the true distance, which is what makes the staged discharge behave
    like the paper's shortest-path-first augmentation.
    """
    V, E = cf.shape
    ldt = _I32 if label_dtype is None else jnp.dtype(label_dtype)
    linf = jnp.asarray(linf).astype(ldt)
    base = jnp.where(
        (target_cross & emask & (cf > 0)).any(axis=1), linf.dtype.type(1),
        linf)
    if sink_open:
        base = jnp.where(sink_cf > 0, jnp.minimum(base, 1), base)
    base = jnp.where(vmask, base, linf)

    def body(carry):
        lab, _ = carry
        nlab = jnp.where(intra & emask & (cf > 0), lab[nbr_local], linf)
        relaxed = jnp.minimum(lab, jnp.minimum(base, nlab.min(axis=1) + 1))
        relaxed = jnp.where(vmask, relaxed, linf)
        return relaxed, (relaxed != lab).any()

    def cond(carry):
        return carry[1]

    lab0 = base
    lab, _ = jax.lax.while_loop(cond, body, (lab0, jnp.asarray(True)))
    return jnp.minimum(lab, linf)
