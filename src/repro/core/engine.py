"""Synchronous vectorized push-relabel engine (region-local).

This is the TPU-native replacement for the paper's region-internal solvers
(BK search trees for ARD, HPR buckets for PRD).  All per-vertex work is a
dense row operation over the padded ELL adjacency, so one engine iteration is
a handful of vector ops — the shape the VPU/MXU wants.  The scheme alternates
two *pure* phases, which keeps the labeling valid under full synchrony:

  push phase    — every active vertex pushes through its admissible arcs
                  (labels frozen); pairwise push conflicts are impossible
                  because d(u) = d(v)+1 and d(v) = d(u)+1 cannot both hold;
  relabel phase — every vertex that is still active *and* has no admissible
                  arc on the post-push residual graph relabels to
                  1 + min(neighbour labels).  Relabels see the arcs created
                  by this iteration's pushes, so validity is preserved.

The per-row multi-arc push uses an exclusive-cumsum split of the vertex's
excess over its admissible arcs (sink column first), i.e. a vertex performs
*all* its saturating pushes plus at most one non-saturating push per
iteration, like a whole Discharge step of [Goldberg-Tarjan 88] at once.

Used by prd.py (global labels, paper Sec. 3) and by each ARD stage
(BFS-initialised local labels toward the stage target set, Sec. 4.2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.graph import INF_LABEL

_I32 = jnp.int32


class EngineState(NamedTuple):
    cf: jax.Array          # i32[V,E]
    sink_cf: jax.Array     # i32[V]
    excess: jax.Array      # i32[V]
    lab: jax.Array         # i32[V]
    out_push: jax.Array    # i32[V,E]  flow pushed over cross arcs (not yet applied remotely)
    sink_pushed: jax.Array  # i32[]    flow absorbed by the sink this run
    iters: jax.Array       # i32[]
    relabel_sum: jax.Array  # i32[]    total label increase (for complexity accounting)


def _neighbor_labels(lab, nbr_local, intra, cross_lab, pushable, emask):
    """Per-arc destination label; blocked arcs get INF_LABEL."""
    nlab = jnp.where(intra, lab[nbr_local], cross_lab)
    return jnp.where(pushable & emask, nlab, INF_LABEL)


def push_relabel(
    cf: jax.Array,
    sink_cf: jax.Array,
    excess: jax.Array,
    lab: jax.Array,
    *,
    nbr_local: jax.Array,
    rev_slot: jax.Array,
    intra: jax.Array,
    emask: jax.Array,
    vmask: jax.Array,
    cross_pushable: jax.Array,   # bool[V,E] cross arcs usable in this run
    cross_lab: jax.Array,        # i32[V,E]  frozen label of cross destinations
    d_inf,                       # label ceiling (python int or i32 scalar)
    sink_open: bool = True,
    max_iters: int | None = None,
) -> EngineState:
    """Run push/relabel until no active vertex remains.

    Returns the final engine state; ``out_push`` holds the flow sent over
    cross-region arcs, to be fused/applied by the sweep driver.
    """
    V, E = cf.shape
    d_inf = jnp.asarray(d_inf, _I32)
    flat_n = V * E
    zero_e = jnp.zeros((V, E), _I32)

    def active_mask(s: EngineState):
        return (s.excess > 0) & (s.lab < d_inf) & vmask

    def admissible(s: EngineState):
        nlab = _neighbor_labels(s.lab, nbr_local, intra, cross_lab,
                                cross_pushable | intra, emask)
        adm = (s.cf > 0) & (s.lab[:, None] == nlab + 1)
        sink_adm = (s.sink_cf > 0) & (s.lab == 1) if sink_open else jnp.zeros((V,), bool)
        return adm, sink_adm

    def body(s: EngineState) -> EngineState:
        act = active_mask(s)
        # ---- push phase ----
        adm, sink_adm = admissible(s)
        adm = adm & act[:, None]
        sink_adm = sink_adm & act
        sink_cap = jnp.where(sink_adm, s.sink_cf, 0)
        arc_cap = jnp.where(adm, s.cf, 0)
        caps = jnp.concatenate([sink_cap[:, None], arc_cap], axis=1)   # [V,1+E]
        avail = jnp.where(act, s.excess, 0)
        cum_excl = jnp.cumsum(caps, axis=1) - caps
        delta = jnp.clip(avail[:, None] - cum_excl, 0, caps)           # [V,1+E]
        d_sink = delta[:, 0]
        d_arc = delta[:, 1:]
        pushed = d_sink + d_arc.sum(axis=1)

        excess = s.excess - pushed
        sink_cf = s.sink_cf - d_sink
        cf = s.cf - d_arc
        # intra reverse arcs + receiver excess
        d_intra = jnp.where(intra, d_arc, 0)
        flat_idx = (nbr_local * E + rev_slot).reshape(flat_n)
        cf = (cf.reshape(flat_n).at[flat_idx]
              .add(d_intra.reshape(flat_n), mode="drop").reshape(V, E))
        recv = jnp.zeros((V,), _I32).at[nbr_local.reshape(flat_n)].add(
            d_intra.reshape(flat_n), mode="drop")
        excess = excess + recv
        # cross arcs: flow leaves the region (applied later by the driver)
        d_cross = d_arc - d_intra
        out_push = s.out_push + d_cross

        s2 = EngineState(cf, sink_cf, excess, s.lab, out_push,
                         s.sink_pushed + d_sink.sum(), s.iters + 1,
                         s.relabel_sum)
        # ---- relabel phase (on post-push residual graph) ----
        act2 = active_mask(s2)
        adm2, sink_adm2 = admissible(s2)
        has_adm = adm2.any(axis=1) | sink_adm2
        need = act2 & ~has_adm
        nlab = _neighbor_labels(s2.lab, nbr_local, intra, cross_lab,
                                cross_pushable | intra, emask)
        cand = jnp.where(s2.cf > 0, nlab + 1, INF_LABEL)
        cand_min = cand.min(axis=1)
        if sink_open:
            cand_min = jnp.where(s2.sink_cf > 0, jnp.minimum(cand_min, 1), cand_min)
        new_lab = jnp.minimum(cand_min, d_inf)
        new_lab = jnp.where(need, jnp.maximum(new_lab, s2.lab), s2.lab)
        relabel_sum = s2.relabel_sum + jnp.sum(
            jnp.where(vmask, new_lab - s2.lab, 0))
        return s2._replace(lab=new_lab, relabel_sum=relabel_sum)

    def cond(s: EngineState):
        ok = active_mask(s).any()
        if max_iters is not None:
            ok = ok & (s.iters < max_iters)
        return ok

    init = EngineState(cf, sink_cf, excess, lab, zero_e,
                       jnp.zeros((), _I32), jnp.zeros((), _I32),
                       jnp.zeros((), _I32))
    return jax.lax.while_loop(cond, body, init)


def bfs_to_targets(
    cf: jax.Array,
    sink_cf: jax.Array,
    *,
    nbr_local: jax.Array,
    intra: jax.Array,
    emask: jax.Array,
    vmask: jax.Array,
    target_cross: jax.Array,   # bool[V,E] cross arcs that enter the target set
    linf,
    sink_open: bool = True,
) -> jax.Array:
    """Exact hop distance to the target set through residual arcs.

    Vectorized Bellman-Ford (unit weights); converges in <= diameter rounds.
    Used to initialise each ARD stage's local labels — the engine then starts
    from the true distance, which is what makes the staged discharge behave
    like the paper's shortest-path-first augmentation.
    """
    V, E = cf.shape
    linf = jnp.asarray(linf, _I32)
    base = jnp.where(
        (target_cross & emask & (cf > 0)).any(axis=1), _I32(1), linf)
    if sink_open:
        base = jnp.where(sink_cf > 0, jnp.minimum(base, 1), base)
    base = jnp.where(vmask, base, linf)

    def body(carry):
        lab, _ = carry
        nlab = jnp.where(intra & emask & (cf > 0), lab[nbr_local], linf)
        relaxed = jnp.minimum(lab, jnp.minimum(base, nlab.min(axis=1) + 1))
        relaxed = jnp.where(vmask, relaxed, linf)
        return relaxed, (relaxed != lab).any()

    def cond(carry):
        return carry[1]

    lab0 = base
    lab, _ = jax.lax.while_loop(cond, body, (lab0, jnp.asarray(True)))
    return jnp.minimum(lab, linf)
