"""Core distributed mincut/maxflow library (the paper's contribution).

Public surface:
  Problem, build, solve_mincut, SweepConfig — single-host solver
  solve_mincut_batch, BatchedSolver,
  pack_instances                            — shape-bucketed batched solver
  solve_sharded, make_sharded_sweep        — shard_map distributed solver
  region_reduction                          — Alg. 5 preprocessing
"""

from repro.core.api import (BatchedSolver, MincutResult, solve_mincut,
                            solve_mincut_batch)
from repro.core.graph import (BatchMeta, BatchState, FlowState, GraphMeta,
                              Layout, PackedBatch, Problem, bucket_shape_for,
                              build, init_labels, pack_instances)
from repro.core.partition import bfs_partition, block_partition, grid_partition
from repro.core.reduction import region_reduction
from repro.core.sweep import SweepConfig, SweepStats, cut_value, extract_cut, solve

__all__ = [
    "BatchMeta", "BatchState", "BatchedSolver", "FlowState", "GraphMeta",
    "Layout", "MincutResult", "PackedBatch", "Problem", "SweepConfig",
    "SweepStats", "bfs_partition", "block_partition", "bucket_shape_for",
    "build", "cut_value", "extract_cut", "grid_partition", "init_labels",
    "pack_instances",
    "region_reduction", "solve", "solve_mincut", "solve_mincut_batch",
]
