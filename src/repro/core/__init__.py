"""Core distributed mincut/maxflow library (the paper's contribution).

Public surface:
  Problem, build, solve_mincut, SweepConfig — single-host solver
  solve_sharded, make_sharded_sweep        — shard_map distributed solver
  region_reduction                          — Alg. 5 preprocessing
"""

from repro.core.api import MincutResult, solve_mincut
from repro.core.graph import (FlowState, GraphMeta, Layout, Problem, build,
                              init_labels)
from repro.core.partition import bfs_partition, block_partition, grid_partition
from repro.core.reduction import region_reduction
from repro.core.sweep import SweepConfig, SweepStats, cut_value, extract_cut, solve

__all__ = [
    "FlowState", "GraphMeta", "Layout", "MincutResult", "Problem",
    "SweepConfig", "SweepStats", "bfs_partition", "block_partition", "build",
    "cut_value", "extract_cut", "grid_partition", "init_labels",
    "region_reduction", "solve", "solve_mincut",
]
