"""Core distributed mincut/maxflow library (the paper's contribution).

Public surface:
  Solver, SolverOptions, ProblemHandle      — solver sessions: prepared
                                              handles, warm-start re-solves
                                              (handle.update + solve), and
                                              the unified front-end over the
                                              host-loop / device-resident /
                                              sharded / batched routes
  Problem, build, solve_mincut, SweepConfig — legacy one-shot solver
  solve_mincut_batch, BatchedSolver,
  pack_instances                            — shape-bucketed batched solver
  solve_sharded, make_sharded_sweep        — shard_map distributed solver
  RegionExecutor, Capabilities,
  UnsupportedFeatureError                   — the region-executor interface
                                              every route drives (executor
                                              instances: LocalExecutor,
                                              BatchedExecutor,
                                              ShardedExecutor,
                                              StreamingExecutor — the
                                              out-of-core route, see
                                              repro.stream)
  region_reduction                          — Alg. 5 preprocessing
  SolveSupervisor, CheckpointPolicy,
  FaultPlan, SolveCheckpoint                — resilience layer: sweep-
                                              boundary checkpoint/resume,
                                              supervised retry with fault
                                              injection, degradation ladder
  validate_problem, CertificateError,
  NonConvergence                            — structured input validation
                                              and solve diagnostics
"""

from repro.core.api import (BatchCacheInfo, BatchedSolver, MincutResult,
                            solve_mincut, solve_mincut_batch)
from repro.core.executor import (BatchedExecutor, Capabilities,
                                 LocalExecutor, RegionExecutor,
                                 ShardedExecutor, StreamingExecutor,
                                 UnsupportedFeatureError)
from repro.core.graph import (BatchMeta, BatchState, FlowState, GraphMeta,
                              GraphUpdate, Layout, PackedBatch, Problem,
                              ProblemValidationError, apply_update,
                              bucket_shape_for, build, init_labels,
                              pack_built, pack_instances, validate_problem)
from repro.core.invariants import (CertificateError, NonConvergence,
                                   Violation, invariant_report)
from repro.core.resilience import (CheckpointMismatchError, CheckpointPolicy,
                                   FaultPlan, InjectedFault, PreemptionError,
                                   RetryPolicy, SolveCheckpoint,
                                   SolveSupervisor, SupervisorReport,
                                   VmemOverflowError, fault_injection,
                                   latest_checkpoint, load_checkpoint,
                                   save_checkpoint)
from repro.core.autotune import TunedConfig, tune, tuned_sweep_config
from repro.core.dtypes import DTYPE_POLICIES, KernelDtypes
from repro.core.partition import bfs_partition, block_partition, grid_partition
from repro.core.reduction import region_reduction
from repro.core.solver import (ProblemHandle, Solver, SolverCacheInfo,
                               SolverOptions)
from repro.core.sweep import SweepConfig, SweepStats, cut_value, extract_cut, solve

__all__ = [
    "BatchCacheInfo", "BatchMeta", "BatchState", "BatchedExecutor",
    "BatchedSolver", "Capabilities", "CertificateError",
    "CheckpointMismatchError", "CheckpointPolicy", "DTYPE_POLICIES",
    "FaultPlan", "FlowState", "GraphMeta", "GraphUpdate", "InjectedFault",
    "KernelDtypes", "Layout",
    "LocalExecutor", "MincutResult", "NonConvergence",
    "PackedBatch", "PreemptionError", "Problem", "ProblemHandle",
    "ProblemValidationError", "RegionExecutor", "RetryPolicy",
    "ShardedExecutor", "SolveCheckpoint", "SolveSupervisor", "Solver",
    "StreamingExecutor",
    "SolverCacheInfo", "SolverOptions", "SupervisorReport", "SweepConfig",
    "SweepStats", "TunedConfig", "UnsupportedFeatureError", "Violation",
    "VmemOverflowError", "apply_update",
    "bfs_partition", "block_partition", "bucket_shape_for",
    "build", "cut_value", "extract_cut", "fault_injection",
    "grid_partition", "init_labels", "invariant_report",
    "latest_checkpoint", "load_checkpoint",
    "pack_built", "pack_instances",
    "region_reduction", "save_checkpoint", "solve", "solve_mincut",
    "solve_mincut_batch", "tune", "tuned_sweep_config", "validate_problem",
]
