"""Batched multi-instance solving: the instance axis as a first dimension.

The paper solves one network at a time, but its target workloads (vision
maxflow fleets, serving traffic) arrive as many similar-shaped problems.
This module lifts the device-resident sweep driver of ``sweep.py`` over a
leading instance axis B:

* one batched parallel sweep discharges **every region of every instance**
  through the grid-over-regions discharge operators — on the fused pallas
  path a single ``grid=(B, K)`` kernel launch per engine chunk-trip
  (``kernels.push_relabel.fused_engine_run_batched``);
* the whole multi-sweep loop runs in one ``lax.while_loop`` with
  **per-instance convergence flags**: an instance that has converged (or
  exhausted its sweep budget) is frozen by per-instance selects and its
  excess is zeroed on the way into the discharge, so its regions take the
  engine's O(1) early exit — a converged instance costs what an idle
  region costs today;
* per-instance label ceilings (``BatchState.d_inf_*``, ``linf``) are
  device arrays, so every instance runs exactly the iteration sequence of
  its standalone solve regardless of bucket padding: flow, labels, sweep
  counts and engine iteration counts are **bit-identical per instance** to
  ``sweep.solve`` on the unpacked problem (asserted in
  tests/test_batch.py).

Compilation is keyed by ``(BatchMeta, SweepConfig)`` — the hashable
fields of the frozen ``executor.BatchedExecutor`` that is the jit static
of the generic device chunk — so any batch landing in a previously seen
shape bucket reuses the executable with zero retracing (``trace_count()``
exposes the retrace counter for benchmarks/tests).

Batched solving is intentionally scoped to the serving configuration:
parallel sweeps (Alg. 2) with the optional global-gap / partial-discharge
heuristics; sequential sweeps and the boundary-relabel heuristic keep the
single-instance driver.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor as _executor
from repro.core import resilience as _res
from repro.core.ard import ard_discharge_batched
from repro.core.graph import BatchMeta, BatchState, PackedBatch
from repro.core.labels import GAP_HIST_CAP, gap_new_labels
from repro.core.prd import prd_discharge_batched
from repro.core.sweep import SweepConfig, sweep_bound

_I32 = jnp.int32

# bumped once per trace of the batched device program — the observable the
# compile-cache accounting (BatchedSolver.cache_info, bench_batch --smoke)
# asserts against: a second batch in a known bucket must not bump it.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def _bump_trace() -> None:
    """Called from inside traced code (the generic executor device chunk):
    runs once per trace, never on cached invocations."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1


@dataclass
class BatchStats:
    """Per-batch solve accounting (host side, after the final sync).

    ``sweeps``/``engine_iters`` are per-instance i32[B] (bit-equal to the
    standalone drivers); ``engine_launches`` and ``host_syncs`` are global
    to the batch — the whole point of batching is that the batch shares
    one launch/sync stream, so a per-instance split would be fiction.
    Per-instance ``SweepStats`` derived from this record are marked
    ``scope="batch"`` so the global counters cannot be misread as
    per-instance (see ``sweep.SweepStats``).
    """

    sweeps: np.ndarray
    engine_iters: np.ndarray
    engine_launches: int = 0
    host_syncs: int = 0
    converged: np.ndarray | None = None   # bool[B]: instance reached zero
    #                                       active vertices within budget
    degraded: list = dataclasses.field(default_factory=list)


def _ghost_labels(state: BatchState) -> jax.Array:
    """i32[B,K,V,E] — per-instance gather of every arc destination's label."""
    return jax.vmap(lambda d, r, l: d[r, l])(
        state.d, state.nbr_region, state.nbr_local)


def _intra(state: BatchState) -> jax.Array:
    K = state.nbr_region.shape[1]
    own = jnp.arange(K, dtype=state.nbr_region.dtype)[None, :, None, None]
    return (state.nbr_region == own) & state.emask


def num_active_batch(state: BatchState, d_inf: jax.Array) -> jax.Array:
    """i32[B] — active-vertex count of every instance."""
    act = (state.excess > 0) & (state.d < d_inf[:, None, None]) & state.vmask
    return act.sum(axis=(1, 2)).astype(_I32)


def _global_gap_batch(state: BatchState, d_inf: jax.Array,
                      ard: bool) -> BatchState:
    """Per-instance ``labels.global_gap`` with dynamic ceilings.

    The histogram capacity must be static under vmap, so it is pinned at
    ``GAP_HIST_CAP``; ``labels.gap_new_labels`` documents why that is
    bit-equal to the single-instance heuristic's ``min(d_inf + 1, cap)``.
    """
    fn = partial(gap_new_labels, cap=GAP_HIST_CAP, ard=ard)
    new_d = jax.vmap(fn)(state.d, state.vmask, state.is_boundary, d_inf)
    return state.replace(d=new_d)


def _apply_cross_flow_batch(state: BatchState, out_push: jax.Array,
                            accept: jax.Array) -> BatchState:
    """Per-instance form of ``sweep._apply_cross_flow``.

    Gathers each cross arc's pushed flow through the bucket-dim flat
    indices, zeroing padded table entries (their index-0 slots alias real
    arcs), and scatters accepted/refunded flow instance-locally.
    """
    B = state.cf.shape[0]
    delta = jnp.take_along_axis(out_push.reshape(B, -1),
                                state.cross_src_arc, axis=1)
    delta = jnp.where(state.cross_valid, delta, 0)
    acc = jnp.where(accept, delta, 0)
    rej = delta - acc

    def one(flat, dst, src, acc, rej):
        flat = flat.at[dst].add(acc, mode="drop")
        return flat.at[src].add(rej, mode="drop")

    cf = jax.vmap(one)(state.cf.reshape(B, -1), state.cross_dst_arc,
                       state.cross_src_arc, acc, rej).reshape(state.cf.shape)
    excess = jax.vmap(one)(
        state.excess.reshape(B, -1), state.cross_dst_vtx,
        state.cross_src_vtx, acc, rej).reshape(state.excess.shape)
    return state.replace(cf=cf, excess=excess)


def _parallel_sweep_batch(bmeta: BatchMeta, cfg: SweepConfig,
                          state: BatchState, sweep_idx: jax.Array,
                          run: jax.Array | None = None):
    """One parallel sweep (Alg. 2) over every instance of the batch.

    Identical math to ``sweep.parallel_sweep`` applied per instance: the
    discharge goes through the flat [B*K] grid-over-regions operators with
    per-region ceilings (``grid2d`` renders the fused pallas launch as the
    ``grid=(B, K)`` program), fusion uses the bucket-dim cross tables, and
    the gap heuristic runs per instance.  ``run`` (bool[B]) marks the
    instances whose result the driver will keep — frozen instances get
    their ARD stage schedule emptied (cap -2 admits not even the sink
    stage) so they never add stage-loop trips to the shared launch stream.
    Returns ``(state, engine_iters [B], engine_launches scalar)`` —
    launches are global to the batch.
    """
    B, K = bmeta.num_instances, bmeta.num_regions
    V, E = bmeta.region_size, bmeta.max_degree
    ard = cfg.method == "ard"
    d_inf = state.d_inf_ard if ard else state.d_inf_prd       # [B]
    ghost = _ghost_labels(state)
    intra = _intra(state)
    f3 = lambda a: a.reshape(B * K, V, E)
    f2 = lambda a: a.reshape(B * K, V)
    rep = lambda a: jnp.repeat(a, K)                          # [B] -> [B*K]
    kw = dict(nbr_local=f3(state.nbr_local), rev_slot=f3(state.rev_slot),
              intra=f3(intra), emask=f3(state.emask), vmask=f2(state.vmask),
              max_iters=cfg.engine_max_iters, backend=cfg.engine_backend,
              chunk_iters=cfg.engine_chunk_iters, grid2d=(B, K))
    if ard:
        if cfg.partial_discharge:
            stage_cap = jnp.broadcast_to(
                jnp.maximum(sweep_idx - 1, -1).astype(_I32), (B,))
        else:
            stage_cap = d_inf
        if run is not None:
            stage_cap = jnp.where(run, stage_cap, -2)
        res = ard_discharge_batched(
            f3(state.cf), f2(state.sink_cf), f2(state.excess), f3(ghost),
            d_inf=rep(d_inf), stage_cap=rep(stage_cap), linf=rep(state.linf),
            **kw)
    else:
        res = prd_discharge_batched(
            f3(state.cf), f2(state.sink_cf), f2(state.excess), f2(state.d),
            f3(ghost), d_inf=rep(d_inf), **kw)
    u3 = lambda a: a.reshape(B, K, V, E)
    u2 = lambda a: a.reshape(B, K, V)
    new = state.replace(
        cf=u3(res.cf), sink_cf=u2(res.sink_cf), excess=u2(res.excess),
        d=jnp.maximum(state.d, u2(res.d)),
        flow_to_t=state.flow_to_t + res.sink_pushed.reshape(B, K).sum(1))
    # ---- fusion (Alg. 2 lines 4-6), per instance ----
    dflat = new.d.reshape(B, K * V)
    du = jnp.take_along_axis(dflat, new.cross_src_vtx, axis=1)
    dv = jnp.take_along_axis(dflat, new.cross_dst_vtx, axis=1)
    accept = (dv <= du + 1) & new.cross_valid
    new = _apply_cross_flow_batch(new, u3(res.out_push), accept)
    if cfg.use_global_gap:
        new = _global_gap_batch(new, d_inf, ard)
    iters = res.engine_iters.reshape(B, K).sum(1)
    return new, iters, res.engine_launches


def solve_batch(packed: PackedBatch, cfg: SweepConfig | None = None, *,
                checkpoint=None, resume_from=None, salt: str = ""):
    """Solve every instance of a packed bucket; returns (BatchState, stats).

    The batched mirror of ``sweep.solve`` in its device-resident form —
    ``executor.BatchedExecutor`` through the same generic
    ``executor.run_device`` loop as the local driver, with per-instance
    sweep budgets and convergence flags in the carry: one
    ``lax.while_loop`` trip is one complete parallel sweep of every
    still-running instance; frozen instances (converged or out of budget)
    are excluded by per-instance selects, with excess zeroed on the way
    into the discharge so their regions cost the engine's O(1) early exit
    inside the shared launch.  The host is re-entered once per
    ``cfg.host_sync_every`` sweeps (default: once per solve).
    Per-instance flow, labels, sweep counts and engine iteration counts
    are bit-identical to solving each instance alone.

    ``checkpoint``/``resume_from`` — sweep-boundary checkpointing exactly
    as in ``sweep.solve``, captured at the ``host_sync_every`` boundaries;
    the whole bucket is one checkpoint (per-instance sweeps/iters arrays
    ride in the payload), fingerprinted over the bucket shape AND every
    member instance's ``GraphMeta``, so a resume must re-pack the same
    instances in the same order.
    """
    cfg = cfg or SweepConfig()
    _executor.BatchedExecutor.validate(cfg)
    bmeta, state = packed.meta, packed.state
    B = bmeta.num_instances

    limit = np.zeros(B, np.int64)
    for b, meta in enumerate(packed.metas):
        bound = sweep_bound(meta, cfg)
        limit[b] = bound if cfg.max_sweeps is None \
            else min(cfg.max_sweeps, bound)
    limit = np.minimum(limit, np.iinfo(np.int32).max).astype(np.int32)

    ex = _executor.BatchedExecutor(bmeta, cfg)

    fp = _res.solve_fingerprint(
        bmeta, cfg, salt + "|" + ";".join(repr(m) for m in packed.metas))
    ckpt = _res.resolve_resume(resume_from, fp)
    carry0 = None
    seed_syncs = 0
    if ckpt is not None:
        state = _res.restore_state(state, ckpt.payload)
        seed_syncs = int(ckpt.stats.get("host_syncs", 0))
        carry0 = (jnp.asarray(ckpt.payload["sweeps"], _I32),
                  jnp.asarray(ckpt.payload["engine_iters"], _I32),
                  jnp.asarray(int(ckpt.stats["engine_launches"]), _I32),
                  jnp.asarray(ckpt.payload["n_act"], _I32))

    on_sync = None
    if checkpoint is not None:
        last_saved = [ckpt.sweeps if ckpt is not None else 0]

        def on_sync(st, host, syncs):
            done, running = ex.progress(host, limit)
            if running and done - last_saved[0] < checkpoint.every:
                return
            sweeps, iters, launches, n_act = host
            payload = _res.state_payload(st)
            payload["sweeps"] = np.asarray(sweeps, np.int32)
            payload["engine_iters"] = np.asarray(iters, np.int32)
            payload["n_act"] = np.asarray(n_act, np.int32)
            _res.save_checkpoint(checkpoint.directory, _res.SolveCheckpoint(
                fingerprint=fp, route="batch", sweeps=done, payload=payload,
                stats={"engine_launches": int(launches),
                       "host_syncs": seed_syncs + syncs},
                flow_offset=checkpoint.flow_offset))
            last_saved[0] = done

    state, host, syncs = _executor.run_device(
        ex, state, limit, cfg.host_sync_every, carry0=carry0,
        on_sync=on_sync)
    sweeps, iters, launches, n_act = host
    note = _res.vmem_fallback_note(cfg, bmeta.region_size, bmeta.max_degree,
                                   dtypes=bmeta.kernel_dtypes)
    return state, BatchStats(
        sweeps=np.asarray(sweeps, np.int64),
        engine_iters=np.asarray(iters, np.int64),
        engine_launches=int(launches), host_syncs=seed_syncs + syncs,
        converged=np.asarray(n_act) == 0,
        degraded=[] if note is None else [note])
