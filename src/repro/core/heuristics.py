"""Boundary-relabel heuristic (Sec. 6.1).

Improves the ARD distance estimate by running a shortest-path computation on
the *boundary group graph* G̅ only — no region interior is touched, so the
cost is O(|(B,B)|) per sweep, cheap enough to run every sweep:

* boundary vertices of a region with equal label form one group;
* a 0-length arc goes from each group to the group with the next higher
  label in the same region (within a region, everything must pessimistically
  be assumed connected *except* that d(u) > d(v) proves u -> v only);
* every residual boundary arc (u, v) adds a 1-length arc between the
  endpoint groups;
* the distance from each group to the label-0 groups is a valid labeling
  and a lower bound on d^B, so d := max(d, dist) is valid (both proofs in
  Sec. 6.1).

The group-graph Dijkstra is replaced by a vectorized Bellman-Ford whose
relaxation alternates (a) per-(region,label) group minimisation, (b) a
*suffix-min over label values* inside each region (the 0-length chain
arcs compose), and (c) +1 relaxation over residual boundary arcs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import FlowState, GraphMeta, INF_LABEL

_I32 = jnp.int32

# static cap on distinct label values tracked per region (labels above the
# cap are left untouched — the heuristic stays a sound lower bound)
LABEL_CAP = 2048


def boundary_relabel(meta: GraphMeta, state: FlowState) -> FlowState:
    K, V = state.d.shape
    L = min(meta.d_inf_ard + 1, LABEL_CAP)
    member = state.is_boundary & state.vmask & (state.d < meta.d_inf_ard)
    lab = jnp.clip(state.d, 0, L - 1)

    src, dst = state.cross_src, state.cross_dst
    src_vid = src[:, 0] * V + src[:, 1]
    dst_vid = dst[:, 0] * V + dst[:, 1]
    arc_cf = state.cf[src[:, 0], src[:, 1], src[:, 2]]
    arc_ok = (arc_cf > 0) & state.cross_valid

    delta0 = jnp.where(member & (state.d == 0), 0, INF_LABEL).reshape(-1)
    memf = member.reshape(-1)
    labf = lab.reshape(-1)
    region_of = (jnp.arange(K * V) // V).astype(_I32)

    def body(carry):
        delta, _ = carry
        # (a,b) group-min + suffix-min over label values per region
        gm = jnp.full((K, L), INF_LABEL, _I32).at[
            region_of, labf].min(jnp.where(memf, delta, INF_LABEL))
        suf = jax.lax.associative_scan(jnp.minimum, gm[:, ::-1], axis=1)[:, ::-1]
        d1 = jnp.minimum(delta, jnp.where(memf, suf[region_of, labf], INF_LABEL))
        # (c) residual boundary arcs: delta(u) <= delta(v) + 1
        cand = jnp.where(arc_ok, d1[dst_vid] + 1, INF_LABEL)
        d2 = d1.at[src_vid].min(cand)
        d2 = jnp.minimum(d2, delta0)
        return d2, (d2 != delta).any()

    delta, _ = jax.lax.while_loop(lambda c: c[1], body,
                                  (delta0, jnp.asarray(True)))
    delta = jnp.minimum(delta.reshape(K, V), meta.d_inf_ard)
    new_d = jnp.where(member, jnp.maximum(state.d, delta), state.d)
    return state.replace(d=new_d.astype(_I32))
