"""Solver sessions: prepared problem handles, warm-start incremental
re-solves, and one unified front-end over every solve route.

The paper's target workloads are *sequences* of closely related maxflow
problems — vision instances whose capacities change a little between
frames while the region structure stays fixed (Sec. 7; the dynamic-cuts
line of work in PAPERS.md).  A serving system therefore wants three things
the one-shot entry points cannot give it:

* **prepared handles** — ``Solver.prepare(problem)`` runs the host-side
  ``build``/``Layout`` blocking ONCE and keeps the ``GraphMeta`` plus the
  device-resident ``FlowState``; every subsequent solve and update reuses
  them;
* **warm-start re-solves** — ``handle.update(...)`` applies a capacity
  delta directly on device by reparameterizing the residual network in the
  Kohli-Torr dynamic-cuts style (``graph.apply_update``): residuals are
  clamped into the new capacities, clamped overflow returns to vertex
  excess, uncoverable deficits are cancelled against the t-link with the
  flow-value offset tracked per handle.  ``handle.solve()`` then continues
  from the warm preflow through the *same* sweep drivers instead of
  re-solving from zero;
* **one front-end** — ``handle.solve()`` dispatches to the host-loop or
  device-resident driver (``SolverOptions.device_resident``), to the
  sharded SPMD driver (``mesh=``), and ``Solver.solve_many([...])`` to the
  shape-bucketed batched driver — all returning the same
  ``MincutResult``/``SweepStats`` shape, all sharing one compile cache
  (``Solver.cache_info``).

Label semantics across an update (``SolverOptions.warm_labels``): labels
must stay valid *lower bounds* on residual distance-to-sink.  Capacity
*decreases* only remove residual arcs, so kept labels stay valid; any
residual-capacity *increase* (including the deficit-cancellation t-links)
can create new residual arcs that invalidate labels arbitrarily far
upstream — trapped excess parked at ``d_inf`` would never re-activate.
The default ``"auto"`` policy therefore refreshes labels with
``labels.global_relabel`` — the exact distance labeling of the updated
residual network, sound unconditionally and *tight*, computed by a
handful of cheap relabel programs (no discharge engine runs) — but only
when an update actually added residual capacity (``apply_update``'s
``grew`` flag); pure decreases keep their still-valid labels for free.
``"keep"`` always skips the refresh (caller asserts decrease-only
updates), ``"reset"`` starts from the cold ``Init`` labels.
"""

from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import autotune as _autotune
from repro.core import batch as _batch
from repro.core import distributed as _distributed
from repro.core import dtypes as _dt
from repro.core import executor as _executor
from repro.core import graph as _graph
from repro.core import invariants as _inv
from repro.core import labels as _labels
from repro.core import partition as _partition
from repro.core import resilience as _res
from repro.core import sweep as _sweep
from repro.core.graph import (FlowState, GraphMeta, GraphUpdate, Layout,
                              Problem, _round_pow2)


@dataclass
class MincutResult:
    flow_value: int                 # maximum preflow value == mincut cost
    source_side: np.ndarray         # bool[n] vertex in the source set C
    stats: _sweep.SweepStats
    meta: GraphMeta
    state: FlowState
    layout: Layout
    converged: bool = True          # False: max_sweeps ran out with active
    #                                 vertices left — flow_value is a valid
    #                                 preflow's, possibly below the maximum,
    #                                 and the cut certificate was NOT checked
    diagnosis: _inv.NonConvergence | None = None
    #                                 structured report when not converged
    #                                 (which invariants hold, what stopped)


@dataclass(frozen=True)
class SolverOptions:
    """One place for every solver knob (a frozen, hashable dataclass).

    Absorbs the previously scattered configuration surface: the
    ``SweepConfig`` fields (see ``sweep.SweepConfig`` for their meaning),
    the front-end kwargs ``num_regions``/``check``, and the sharded-route
    ``exchange`` mode.  Session-only knobs:

    warm_labels — label policy of a warm re-solve after ``update``:
        ``"auto"`` (default) refresh labels with the exact global relabel
        (``labels.global_relabel`` — sound for any update, tight, a few
        cheap device programs) iff the update added residual capacity
        anywhere, else keep them (capacity removal only raises true
        distances, so kept labels stay valid); ``"keep"``/``True`` always
        keep (caller asserts decrease-only updates); ``"reset"``/
        ``False`` re-initialize to the cold ``Init`` labels.
    dtype_policy — kernel storage-dtype policy (``dtypes.DTYPE_POLICIES``):
        ``"int32"`` (default) keeps the wide baseline; ``"auto"`` narrows
        labels/residuals to int16 (masks to int8) whenever this problem's
        range bounds allow, falling back to int32 per family; ``"narrow"``
        forces narrowing and makes a failed bound a typed
        ``ProblemValidationError`` at ``prepare`` time.  Narrowed handles
        re-check the flow bound on every ``update`` (capacity growth can
        outgrow int16; topology — hence the label bound — cannot change).
    autotune — resolve ``engine_chunk_iters`` (and fused-vs-blocked
        dispatch) per ``(bucket dims, backend, dtypes)`` key through the
        VMEM-budget autotuner (``core.autotune``) instead of the static
        default.  An explicitly pinned ``engine_chunk_iters`` wins over
        the tuner; tuned decisions persist in a JSON cache so repeat keys
        cost zero search and zero retrace.
    streaming — route solves through the out-of-core streaming executor
        (``repro.stream``): regions are staged one at a time from a disk
        spill pool, at most ``max_resident_regions`` region states are in
        memory at once, and only the |B|-sized boundary layer persists
        between visits.  Requires the sequential sweep without the global
        gap heuristic (``parallel=False``, ``use_global_gap=False``) —
        anything else raises ``UnsupportedFeatureError`` naming the flag.
        ``spill_dir`` pins the pool to a durable directory (kill-resume
        needs the pool to outlive the process); ``None`` uses a temp dir
        deleted when the solve finishes.  ``prefetch`` overlaps the next
        region's disk read with the current region's discharge.
    """

    # --- sweep/engine knobs (mirror sweep.SweepConfig) ---
    method: str = "ard"
    parallel: bool = True
    partial_discharge: bool = False
    use_global_gap: bool = True
    use_boundary_relabel: bool = False
    max_sweeps: int | None = None
    engine_max_iters: int | None = None
    engine_backend: str = "xla"
    engine_chunk_iters: int | None = None
    device_resident: bool = False
    host_sync_every: int | None = None
    stats_ring_size: int = 1024
    # --- session knobs ---
    num_regions: int = 4
    check: bool = True
    warm_labels: bool | str = "auto"
    dtype_policy: str = "int32"
    autotune: bool = False
    # --- sharded-route knobs ---
    exchange: str = "full"
    # --- streaming-route knobs ---
    streaming: bool = False
    max_resident_regions: int = 2
    spill_dir: str | None = None
    prefetch: bool = True

    def __post_init__(self):
        assert self.warm_labels in (True, False, "auto", "keep", "reset")
        assert self.exchange in ("full", "boundary")
        assert self.max_resident_regions >= 1
        if self.dtype_policy not in _dt.DTYPE_POLICIES:
            raise ValueError(
                f"unknown dtype_policy {self.dtype_policy!r}; expected one "
                f"of {_dt.DTYPE_POLICIES}")
        self.sweep_config()     # delegate knob validation to SweepConfig

    def sweep_config(self) -> _sweep.SweepConfig:
        """The ``SweepConfig`` view consumed by the sweep drivers."""
        fields = {f.name for f in dataclasses.fields(_sweep.SweepConfig)}
        return _sweep.SweepConfig(**{
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self) if f.name in fields})

    @classmethod
    def from_sweep_config(cls, cfg: _sweep.SweepConfig | None = None,
                          **session_kw) -> "SolverOptions":
        """Lift a legacy ``SweepConfig`` (plus front-end kwargs) into
        session options — the bridge the backward-compat shims use."""
        kw = dataclasses.asdict(cfg) if cfg is not None else {}
        kw.update(session_kw)
        return cls(**kw)

    def _labels_mode(self) -> str:
        return {True: "keep", False: "reset"}.get(
            self.warm_labels, self.warm_labels)


@dataclass
class SolverCacheInfo:
    """Compile-cache accounting of one ``Solver`` session.

    ``hits``/``misses`` count solve/update program invocations served by an
    already-compiled executable vs ones that traced something new;
    ``traces`` is the raw trace counter those are derived from (a
    same-shape re-solve must leave it unchanged).
    """

    hits: int = 0
    misses: int = 0
    traces: int = 0


def _finish(meta: GraphMeta, state0: FlowState, state: FlowState,
            layout: Layout, stats: _sweep.SweepStats, check: bool,
            offset: int = 0, *, converged: bool = True, ard: bool = True,
            max_sweeps: int | None = None) -> MincutResult:
    """Extract the cut and package a result (shared by every route).

    ``offset`` — accumulated flow-value offset of the handle's
    deficit-cancelling reparameterizations: the solved ``flow_to_t`` of
    the reparameterized network exceeds the true maxflow by exactly this
    constant (see ``graph.apply_update``), and the cut partition is
    unchanged, so subtracting it here restores the true value.
    ``check`` verifies that the cut cost in the (current, un-reparameter-
    ized) initial network equals that value — an extra device fetch plus
    an O(n*E) host reduction, so serving paths may disable it.

    A solve that stopped at ``max_sweeps`` (``converged=False``) returns a
    structured result — ``MincutResult.converged=False`` plus a
    ``NonConvergence`` diagnosis naming the active-vertex count and any
    broken invariants — and SKIPS the certificate (a non-maximum preflow's
    cut cost legitimately differs from its flow).  A converged solve whose
    certificate fails raises the typed ``CertificateError`` (an
    ``AssertionError``, as the historical bare assert was) carrying the
    same diagnosis on ``.diagnosis``.
    """
    sink_side = _sweep.extract_cut(meta, state)
    flow = int(state.flow_to_t) - offset
    diagnosis = None
    if not converged:
        diagnosis = _inv.diagnose(
            meta, state, ard=ard, reason="max_sweeps", sweeps=stats.sweeps,
            max_sweeps=max_sweeps, flow_value=flow)
    elif check:
        cost = int(_sweep.cut_value(meta, state0, sink_side))
        if cost != flow:
            raise _inv.CertificateError(
                f"internal error: cut cost {cost} != max preflow {flow}",
                _inv.diagnose(meta, state, ard=ard, reason="certificate",
                              sweeps=stats.sweeps, max_sweeps=max_sweeps,
                              flow_value=flow, cut_cost=cost))
    source_flat = ~layout.to_flat(np.asarray(sink_side))
    return MincutResult(flow_value=flow, source_side=source_flat,
                        stats=stats, meta=meta, state=state, layout=layout,
                        converged=converged, diagnosis=diagnosis)


def _pad_i32(a: np.ndarray, size: int) -> jnp.ndarray:
    out = np.zeros(size, np.int32)
    out[: len(a)] = a
    return jnp.asarray(out)


def _widen_state(st: FlowState) -> FlowState:
    """Cast a (possibly narrowed) state up to the sharded driver's int32.

    Label sentinels translate by a monotone offset — the narrow infinity
    class ``[2**14, ...)`` maps onto the wide class ``[2**30, ...)``
    preserving relative order — so the widened state is exactly what a
    wide build of the same problem would hold, and the sharded solve is
    bit-identical to the wide route.
    """
    if st.cf.dtype == jnp.int32 and st.d.dtype == jnp.int32:
        return st
    d = st.d.astype(jnp.int32)
    if st.d.dtype != jnp.int32:
        d = jnp.where(d >= _dt.NARROW_INF_LABEL,
                      d - _dt.NARROW_INF_LABEL + _dt.INF_LABEL_WIDE, d)
    return st.replace(cf=st.cf.astype(jnp.int32),
                      sink_cf=st.sink_cf.astype(jnp.int32),
                      excess=st.excess.astype(jnp.int32), d=d)


def _narrow_state(st: FlowState, meta: GraphMeta) -> FlowState:
    """Cast a sharded-route int32 result back to the handle's storage
    dtypes (inverse of ``_widen_state``; no-op for wide handles).

    Finite labels are all below the narrow limit by the prepare-time
    bound; anything in the wide infinity class maps back by the same
    offset, and any other over-limit value (all ``>= d_inf``, hence
    semantically infinite) clamps to the narrow sentinel.
    """
    kd = meta.kernel_dtypes
    if kd.flow == "int32" and kd.label == "int32":
        return st
    fdt = jnp.dtype(kd.flow_np)
    d = st.d
    if kd.label != "int32":
        d = jnp.where(
            d >= _dt.INF_LABEL_WIDE,
            d - _dt.INF_LABEL_WIDE + _dt.NARROW_INF_LABEL,
            jnp.minimum(d, _dt.NARROW_INF_LABEL)).astype(
                jnp.dtype(kd.label_np))
    return st.replace(cf=st.cf.astype(fdt), sink_cf=st.sink_cf.astype(fdt),
                      excess=st.excess.astype(fdt), d=d)


class ProblemHandle:
    """A prepared problem inside a ``Solver`` session.

    Holds the one-time ``build`` artifacts (``meta``/``layout``), the
    device-resident current state, and the initial network of the
    *current* problem (``state0``, maintained incrementally across
    updates) used by the cut-cost check.  After a solve the handle is
    *warm*: ``update`` reparameterizes the solved preflow in place and the
    next ``solve`` continues from it.
    """

    def __init__(self, solver: "Solver", problem: Problem,
                 part: np.ndarray, meta: GraphMeta, state: FlowState,
                 layout: Layout):
        self.solver = solver
        self.problem = problem
        self.part = part
        self.meta = meta
        self.layout = layout
        self.state = state            # current device state (residuals, d)
        self.state0 = state           # initial network of current problem
        self.warm = False             # a solved preflow is resident
        self._dirty = False           # updates applied since the last solve
        self._grew = jnp.zeros((), bool)   # any residual capacity increase
        #                                    since the last solve (device)
        self._flow_offset = jnp.zeros((), jnp.int32)

    # -- update ------------------------------------------------------------

    def update(self, *, cap_fwd=None, cap_bwd=None, excess=None,
               sink_cap=None, arcs=None) -> "ProblemHandle":
        """Apply a capacity/terminal delta to the prepared problem.

        ``cap_fwd``/``cap_bwd`` — new ABSOLUTE edge capacities: full
        ``[m]`` arrays, or, with ``arcs`` (edge indices into
        ``problem.edges``), values for just those edges.  ``excess``/
        ``sink_cap`` — new absolute terminal arrays ``[n]``.  Topology is
        fixed per handle (that is the point of preparing); new edges need
        a fresh ``prepare``.

        The delta lands on device through one jitted scatter program
        (``graph.apply_update``) with the changed-entry count padded to a
        power of two, so steady-state perturbations of similar size reuse
        one compiled update.  Statistics semantics: ``SweepStats`` always
        describes one solve call, so counters "reset" naturally on the
        next ``solve``; ``flow_to_t`` (and the flow-offset bookkeeping)
        carry across updates.  Returns ``self`` for chaining.
        """
        p = self.problem
        m, n = len(p.edges), p.num_vertices
        if arcs is not None:
            idx = np.atleast_1d(np.asarray(arcs, np.int64))
            assert idx.ndim == 1
            if len(idx):
                assert idx.min() >= 0 and idx.max() < m, "arc index range"
            new_fwd, new_bwd = p.cap_fwd.copy(), p.cap_bwd.copy()
            if cap_fwd is not None:
                new_fwd[idx] = np.asarray(cap_fwd, np.int32)
            if cap_bwd is not None:
                new_bwd[idx] = np.asarray(cap_bwd, np.int32)
        else:
            # np.array (not asarray): the arrays become the handle's new
            # baseline, so aliasing the caller's buffer would make a later
            # mutate-and-update diff against itself and drop the edit
            new_fwd = p.cap_fwd if cap_fwd is None \
                else np.array(cap_fwd, np.int32)
            new_bwd = p.cap_bwd if cap_bwd is None \
                else np.array(cap_bwd, np.int32)
        new_exc = p.excess if excess is None else np.array(excess, np.int32)
        new_snk = p.sink_cap if sink_cap is None \
            else np.array(sink_cap, np.int32)
        assert new_fwd.shape == (m,) and new_bwd.shape == (m,)
        assert new_exc.shape == (n,) and new_snk.shape == (n,)
        newp = dataclasses.replace(p, cap_fwd=new_fwd, cap_bwd=new_bwd,
                                   excess=new_exc, sink_cap=new_snk)
        if self.solver.options.check:
            # reject negative / overflow-risk capacities before they land
            # on device (opt-out: SolverOptions.check=False serving paths)
            _graph.validate_problem(newp, context="update")
        else:
            assert (new_fwd >= 0).all() and (new_bwd >= 0).all()
            assert (new_exc >= 0).all() and (new_snk >= 0).all()
        # narrowed storage is sized by the flow-mass bound at prepare time;
        # an update that grows total capacity past it would wrap int16
        # residuals silently — always rejected, even with check=False
        _graph.validate_update_dtypes(self.meta, newp)

        d_fwd = new_fwd.astype(np.int64) - p.cap_fwd
        d_bwd = new_bwd.astype(np.int64) - p.cap_bwd
        changed = np.nonzero((d_fwd != 0) | (d_bwd != 0))[0]
        d_snk = new_snk.astype(np.int64) - p.sink_cap
        d_exc = new_exc.astype(np.int64) - p.excess
        tchanged = np.nonzero((d_snk != 0) | (d_exc != 0))[0]
        lay = self.layout
        V = self.meta.region_size
        tflat = lay.part[tchanged] * V + lay.local_id[tchanged]

        j = _round_pow2(max(1, len(changed)))
        tp = _round_pow2(max(1, len(tchanged)))
        upd = GraphUpdate(
            arc_u=_pad_i32(lay.edge_arc_u[changed], j),
            arc_v=_pad_i32(lay.edge_arc_v[changed], j),
            vtx_u=_pad_i32(lay.edge_vtx_u[changed], j),
            vtx_v=_pad_i32(lay.edge_vtx_v[changed], j),
            d_cap_fwd=_pad_i32(d_fwd[changed], j),
            d_cap_bwd=_pad_i32(d_bwd[changed], j),
            t_vtx=_pad_i32(tflat, tp),
            d_sink=_pad_i32(d_snk[tchanged], tp),
            d_excess=_pad_i32(d_exc[tchanged], tp))

        before = self.solver._trace_total()
        self.state, self.state0, grew, doff = _graph.apply_update(
            self.state, self.state0, upd)
        self.solver._note(before)
        self._dirty = True
        self._grew = self._grew | grew
        self._flow_offset = self._flow_offset + doff
        self.problem = newp
        return self

    def reset(self) -> "ProblemHandle":
        """Forget the solved preflow: the next solve runs cold (from the
        current problem's initial network)."""
        self.state = self.state0
        self.warm = False
        self._dirty = False
        self._grew = jnp.zeros((), bool)
        self._flow_offset = jnp.zeros((), jnp.int32)
        return self

    # -- solve -------------------------------------------------------------

    def _entry_state(self) -> FlowState:
        """The state a solve starts from, with the label policy applied.

        ``"auto"`` refreshes labels (exact global relabel) only when an
        update actually ADDED residual capacity somewhere
        (``apply_update``'s ``grew`` flag, one scalar fetch): pure
        decreases only remove residual arcs, so the kept labels remain
        valid lower bounds and the relabel fixpoint would be wasted work.
        """
        if not self.warm:
            return _graph.init_labels(self.meta, self.state)
        mode = self.solver.options._labels_mode()
        st = self.state
        if mode == "reset":
            return st.replace(d=jnp.zeros_like(st.d))
        if mode == "auto" and self._dirty and bool(self._grew):
            return _labels.global_relabel(
                self.meta, st, self.solver.options.method == "ard")
        return st                     # "keep", or labels provably valid

    def _layout_salt(self) -> str:
        """Fingerprint salt binding checkpoints to THIS partition — two
        same-shaped problems with different region assignments must not
        cross-resume."""
        return hashlib.sha256(
            np.ascontiguousarray(self.part).tobytes()).hexdigest()[:16]

    def solve(self, *, mesh=None, axes=("regions",), checkpoint=None,
              resume_from=None, on_sweep=None) -> MincutResult:
        """Solve (or warm re-solve) the prepared problem.

        Routes on the session options: host-loop or device-resident sweep
        driver by default, the sharded SPMD driver when a ``mesh`` is
        given.  Cold solves start from the paper's ``Init``; warm solves
        continue from the resident preflow with labels per
        ``SolverOptions.warm_labels``.

        ``checkpoint`` — a ``resilience.CheckpointPolicy`` or a directory
        path: capture resumable sweep-boundary checkpoints (the handle
        stamps its layout digest and warm-start flow offset into them).
        ``resume_from`` — a ``SolveCheckpoint`` or checkpoint directory:
        continue an interrupted solve bit-exactly; the checkpoint's flow
        offset is adopted (authoritative for a cross-process resume).

        Kernel lowering/VMEM failures degrade the engine configuration
        one ladder rung at a time (pallas-fused -> xla-fused ->
        xla-unfused, ``resilience.degrade_config``) and re-run — every
        rung is bit-exact, and each degradation is recorded in
        ``stats.degraded``, never silent.

        ``on_sweep(state, sweeps_done)`` — optional sweep-boundary hook
        (fires at every boundary on the host loop, at the
        ``host_sync_every`` boundaries on the device-resident and sharded
        drivers) — the serving tier's deadline-enforcement point.
        """
        opts = self.solver.options
        cfg = opts.sweep_config()
        if opts.autotune:
            cfg = _autotune.tuned_sweep_config(cfg, self.meta)
        salt = self._layout_salt()
        if isinstance(checkpoint, (str, Path)):
            checkpoint = _res.CheckpointPolicy(directory=checkpoint)
        ckpt_obj = resume_from
        if isinstance(ckpt_obj, (str, Path)):
            ckpt_obj = _res.load_checkpoint(ckpt_obj)
        if ckpt_obj is not None:
            # the checkpoint's bookkeeping is authoritative across processes
            self._flow_offset = jnp.asarray(ckpt_obj.flow_offset, jnp.int32)
        if checkpoint is not None:
            checkpoint = dataclasses.replace(
                checkpoint, salt=salt, flow_offset=int(self._flow_offset))
        before = self.solver._trace_total()  # before _entry_state: the
        #                 warm-labels relabel program's trace must count
        st_in = self._entry_state()
        d_inf = (self.meta.d_inf_ard if opts.method == "ard"
                 else self.meta.d_inf_prd)

        def run(c):
            if opts.streaming:
                if mesh is not None:
                    raise ValueError(
                        "streaming and mesh are mutually exclusive routes: "
                        "the streaming executor stages regions through host "
                        "memory one at a time, the sharded driver keeps all "
                        "of them device-resident")
                from repro import stream as _stream
                ss = _stream.open_stream(
                    self.meta, st_in, c, spill_dir=opts.spill_dir,
                    max_resident_regions=opts.max_resident_regions,
                    prefetch=opts.prefetch, cold_labels=False)
                try:
                    ss, stats = _stream.solve_stream(
                        ss, on_sweep=on_sweep, checkpoint=checkpoint,
                        resume_from=ckpt_obj, salt=salt)
                    st = _stream.assemble_state(ss, st_in)
                finally:
                    ss.store.close()
                return st, stats
            if mesh is not None:
                # the sharded driver's state specs are pinned to int32
                # (distributed.py builds abstract int32 avals for the SPMD
                # programs), so a narrowed handle widens at entry and
                # narrows back at exit.  The sentinel classes map 1:1
                # (monotone offset), so results are bit-exact either way.
                st_sh = _widen_state(st_in)
                st, sweeps, syncs = _distributed.solve_sharded(
                    self.meta, st_sh, mesh, c, axes=tuple(axes),
                    exchange=opts.exchange, return_stats=True,
                    checkpoint=checkpoint, resume_from=ckpt_obj, salt=salt,
                    on_sweep=on_sweep)
                st = _narrow_state(st, self.meta)
                _pb, msg_bytes = _sweep._page_and_msg_bytes(self.meta)
                stats = _sweep.SweepStats(
                    sweeps=sweeps, engine_iters=None, engine_launches=None,
                    host_syncs=syncs, boundary_bytes=sweeps * msg_bytes,
                    page_bytes=None, num_boundary=self.meta.num_boundary,
                    regions_discharged=None,
                    converged=int(st.active(d_inf).sum()) == 0)
                return st, stats
            return _sweep.solve(self.meta, st_in, c, warm=True,
                                checkpoint=checkpoint, resume_from=ckpt_obj,
                                salt=salt, on_sweep=on_sweep)

        notes: list[str] = []
        st, stats = _res.run_with_degradation(run, cfg, notes)
        stats.degraded = notes + stats.degraded
        self.solver._note(before)
        self.state = st
        self.warm = True
        self._dirty = False
        self._grew = jnp.zeros((), bool)
        return _finish(self.meta, self.state0, st, self.layout, stats,
                       opts.check, offset=int(self._flow_offset),
                       converged=stats.converged, ard=opts.method == "ard",
                       max_sweeps=cfg.max_sweeps)


class Solver:
    """A solver session: one ``SolverOptions``, one compile cache, every
    route.

    ``prepare`` a problem once, then ``solve``/``update``/``solve`` its
    handle as capacities evolve; hand a fleet of handles (or raw problems)
    to ``solve_many`` for the shape-bucketed batched driver; pass
    ``mesh=`` to a handle's solve for the sharded SPMD driver.  All routes
    return the same ``MincutResult`` shape and share the session's
    compiled programs — ``cache_info()`` reports hits/misses, where a miss
    is an invocation that actually traced a device program (sweep, batch,
    sharded-sweep or update tracers combined).
    """

    def __init__(self, options: SolverOptions | None = None, **overrides):
        if options is None:
            options = SolverOptions(**overrides)
        elif overrides:
            options = dataclasses.replace(options, **overrides)
        self.options = options
        self.cache = SolverCacheInfo()
        self.last_batch_stats: list[_batch.BatchStats] = []

    # -- compile-cache accounting -----------------------------------------

    @staticmethod
    def _trace_total() -> int:
        import sys
        sm = sys.modules.get("repro.stream.executor")
        return (_sweep.trace_count() + _batch.trace_count()
                + _graph.update_trace_count() + _labels.trace_count()
                + _distributed.trace_count()
                + (sm.trace_count() if sm is not None else 0))

    def _note(self, before: int) -> None:
        now = self._trace_total()
        if now > before:
            self.cache.misses += 1
        else:
            self.cache.hits += 1
        self.cache.traces = now

    def cache_info(self) -> SolverCacheInfo:
        self.cache.traces = self._trace_total()
        return dataclasses.replace(self.cache)   # a snapshot, not an alias

    # -- the front-end -----------------------------------------------------

    def prepare(self, problem: Problem,
                part: np.ndarray | None = None) -> ProblemHandle:
        """Region-block a problem once; returns its session handle.

        ``part`` — region id per vertex; defaults to node-number slicing
        into ``options.num_regions`` regions (the paper's fallback
        partitioner, as before).
        """
        if self.options.check or self.options.dtype_policy == "narrow":
            # fail fast on malformed input (negative capacities, int32
            # overflow risk vs INF_CAP) before any device work; serving
            # paths opt out with SolverOptions.check=False — except the
            # forced-narrow bound check, which must never be silent
            _graph.validate_problem(problem, context="problem",
                                    dtype_policy=self.options.dtype_policy)
        if part is None:
            part = _partition.block_partition(problem.num_vertices,
                                              self.options.num_regions)
        part = np.asarray(part)
        meta, state, layout = _graph.build(
            problem, part, dtype_policy=self.options.dtype_policy)
        return ProblemHandle(self, problem, part, meta, state, layout)

    def solve(self, problem: Problem, part: np.ndarray | None = None, *,
              mesh=None) -> MincutResult:
        """One-shot convenience: ``prepare(problem, part).solve()``."""
        return self.prepare(problem, part).solve(mesh=mesh)

    def solve_many(self, items, parts=None, *, checkpoint=None,
                   resume_from=None) -> list[MincutResult]:
        """Solve a fleet through the shape-bucketed batched driver.

        ``items`` — ``ProblemHandle``s of this session and/or raw
        ``Problem``s (prepared on the fly, ``parts[i]`` honored).  Handles
        enter with their current state — so previously-solved, updated
        handles ride the batched driver *warm* — and leave warm, exactly
        as if solved individually.  Per-instance results are bit-identical
        to ``handle.solve()`` on the same state; ``engine_launches``/
        ``host_syncs`` in the returned stats are global to each batch
        (``SweepStats.scope == "batch"``).

        ``checkpoint``/``resume_from`` — sweep-boundary checkpointing as
        in ``handle.solve``, restricted to fleets that pack into ONE shape
        bucket (one checkpoint stream per solve; re-pack the same items in
        the same order to resume).
        """
        cfg = self.options.sweep_config()
        if self.options.streaming:
            raise ValueError(
                "solve_many and streaming are mutually exclusive: the "
                "batched driver packs every instance device-resident; "
                "solve streaming handles one at a time instead")
        _executor.BatchedExecutor.validate(cfg)
        if isinstance(checkpoint, (str, Path)):
            checkpoint = _res.CheckpointPolicy(directory=checkpoint)
        handles: list[ProblemHandle] = []
        for i, it in enumerate(items):
            if isinstance(it, ProblemHandle):
                if it.solver is not self:
                    raise ValueError("handle belongs to another Solver "
                                     "session")
                handles.append(it)
            else:
                part = parts[i] if parts is not None else None
                handles.append(self.prepare(it, part))

        # trace window opens before the entry states: a warm handle's
        # label-refresh program must be attributed to this invocation
        before = self._trace_total()
        builds = [(i, h.meta, h._entry_state(), h.layout, h.state0)
                  for i, h in enumerate(handles)]
        packs = _graph.pack_built(builds)
        if (checkpoint is not None or resume_from is not None) \
                and len(packs) != 1:
            raise ValueError(
                f"checkpointed solve_many needs a single shape bucket "
                f"(one checkpoint stream per solve); these items pack "
                f"into {len(packs)} buckets")
        salt = hashlib.sha256(b"".join(
            np.ascontiguousarray(h.part).tobytes()
            for h in handles)).hexdigest()[:16]
        results: list[MincutResult | None] = [None] * len(handles)
        self.last_batch_stats = []
        for packed in packs:
            cfg_b = cfg
            if self.options.autotune:
                cfg_b = _autotune.tuned_sweep_config(cfg, packed.meta)
            bstate, bstats = _batch.solve_batch(
                packed, cfg_b, checkpoint=checkpoint, resume_from=resume_from,
                salt=salt)
            self._note(before)
            before = self._trace_total()
            self.last_batch_stats.append(bstats)
            for b, idx in enumerate(packed.indices):
                h = handles[idx]
                meta = h.meta
                K, V, E = (meta.num_regions, meta.region_size,
                           meta.max_degree)
                st = h.state0.replace(
                    cf=bstate.cf[b, :K, :V, :E],
                    sink_cf=bstate.sink_cf[b, :K, :V],
                    excess=bstate.excess[b, :K, :V],
                    d=bstate.d[b, :K, :V],
                    flow_to_t=bstate.flow_to_t[b])
                sweeps = int(bstats.sweeps[b])
                page_bytes, msg_bytes = _sweep._page_and_msg_bytes(meta)
                converged = bool(bstats.converged[b]) \
                    if bstats.converged is not None else True
                stats = _sweep.SweepStats(
                    sweeps=sweeps,
                    engine_iters=int(bstats.engine_iters[b]),
                    engine_launches=bstats.engine_launches,
                    host_syncs=bstats.host_syncs,
                    boundary_bytes=sweeps * msg_bytes,
                    page_bytes=sweeps * meta.num_regions * page_bytes,
                    num_boundary=meta.num_boundary,
                    regions_discharged=sweeps * meta.num_regions,
                    scope="batch", converged=converged)
                h.state = st
                h.warm = True
                h._dirty = False
                h._grew = jnp.zeros((), bool)
                results[idx] = _finish(
                    meta, h.state0, st, h.layout, stats, self.options.check,
                    offset=int(h._flow_offset), converged=converged,
                    ard=self.options.method == "ard",
                    max_sweeps=cfg.max_sweeps)
        return results
