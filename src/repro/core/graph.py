"""Region-partitioned flow-network representation.

The paper (Shekhovtsov & Hlavac 2011) partitions the vertex set of a sparse
network into K regions; every discharge operation touches exactly one region's
subnetwork plus its boundary.  On TPU we mirror that structure directly:

* vertices are stored region-blocked, padded to a common region size ``V``;
* adjacency is a padded ELL layout ``[K, V, E]`` (``E`` = max degree) so that
  every per-vertex operation is a dense, vectorizable row operation;
* the source is eliminated by the paper's ``Init`` (saturate all (s,v) edges
  -> per-vertex ``excess``), the sink is kept as an implicit 0-labelled
  vertex reachable through a per-vertex terminal capacity ``sink_cf``;
* every *directed* residual arc (u,v) lives in u's row.  A cross-region arc
  (u,v), part(u)=r != q=part(v), therefore lives in region r while its
  reverse (v,u) lives in region q — exactly the paper's region network
  ``G^R`` in which incoming boundary arcs ``(B^R, R)`` have zero capacity
  *inside* R (they simply are not R's rows).

All capacities are int32 (the paper uses natural numbers); flow arithmetic is
exact.  ``INF_CAP`` marks "unbounded" arcs used by region reduction.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dtypes as _dt
from repro.core.dtypes import KernelDtypes

# Large-but-safe sentinel values (int32 arithmetic must never overflow:
# INF_LABEL + 1 and INF_CAP + INF_CAP must stay < 2**31).  Narrowed
# storage (``dtype_policy="auto"|"narrow"``) swaps in the int16 sentinel
# ``dtypes.NARROW_INF_LABEL`` wherever labels are narrow.
INF_LABEL = np.int32(2**30)
INF_CAP = np.int32(2**30)


@dataclass(frozen=True)
class GraphMeta:
    """Static (host-side) metadata for a region-partitioned network."""

    num_regions: int          # K
    region_size: int          # V  (padded per-region vertex count)
    max_degree: int           # E  (padded per-vertex arc slots)
    num_vertices: int         # n  (true, unpadded)
    num_boundary: int         # |B|  (vertices incident to inter-region arcs)
    num_cross_arcs: int       # X  (directed inter-region arcs, padded table)
    num_ghost_groups: int     # distinct (region, adjacent-ghost) pairs
    d_inf_ard: int            # |B|      (ARD label ceiling, paper Sec. 4.1)
    d_inf_prd: int            # n        (PRD label ceiling, paper Sec. 2)
    # storage dtypes selected at build time (dtype_policy); recorded here
    # so every compile-cache key that hashes the meta stays sound when the
    # same shapes are built under a different narrowing policy
    label_dtype: str = "int32"
    flow_dtype: str = "int32"
    mask_dtype: str = "int32"

    def __post_init__(self):
        assert self.num_regions >= 1
        assert self.region_size >= 1

    @property
    def kernel_dtypes(self) -> KernelDtypes:
        return KernelDtypes(label=self.label_dtype, flow=self.flow_dtype,
                            mask=self.mask_dtype)


@jax.tree_util.register_dataclass
@dataclass
class FlowState:
    """Device-resident mutable state of the solver (a JAX pytree).

    Shapes: K = num_regions, V = region_size, E = max_degree,
    X = num_cross_arcs (flattened inter-region arc table).
    """

    # --- static topology (never mutated) ---
    nbr_region: jax.Array    # i32[K,V,E] neighbour's region id (== own for intra)
    nbr_local: jax.Array     # i32[K,V,E] neighbour's local vertex id
    rev_slot: jax.Array      # i32[K,V,E] slot of the reverse arc in nbr's row
    emask: jax.Array         # bool[K,V,E] valid arc slot
    vmask: jax.Array         # bool[K,V] valid vertex
    is_boundary: jax.Array   # bool[K,V] vertex in the boundary set B
    # flat cross-arc table: for cross arc x: (region,local,slot) of source row
    cross_src: jax.Array     # i32[X,3]
    cross_dst: jax.Array     # i32[X,3]  (row holding the reverse arc)
    cross_group: jax.Array   # i32[X]    id of the (src_region, dst_vertex)
    #                                    pair — "ghost w as seen from R"
    cross_valid: jax.Array   # bool[X]   padded-entry mask
    # flat scatter indices of the cross table, precomputed at build time so
    # no jitted sweep rebuilds them: arc index (r*V + l)*E + s into the
    # flattened [K,V,E] arrays, vertex index r*V + l into flattened [K,V]
    cross_src_arc: jax.Array  # i32[X]
    cross_dst_arc: jax.Array  # i32[X]
    cross_src_vtx: jax.Array  # i32[X]
    cross_dst_vtx: jax.Array  # i32[X]
    # --- mutable flow state ---
    cf: jax.Array            # i32[K,V,E] residual capacity of each arc
    sink_cf: jax.Array       # i32[K,V]  residual capacity of the t-link
    excess: jax.Array        # i32[K,V]  current excess e_f(v)
    d: jax.Array             # i32[K,V]  distance labels
    flow_to_t: jax.Array     # i32[]     |f| — total flow absorbed by the sink

    def active(self, d_inf: int) -> jax.Array:
        """Active vertices w.r.t. (f, d): positive excess and d < d_inf."""
        return (self.excess > 0) & (self.d < d_inf) & self.vmask

    def replace(self, **kw) -> "FlowState":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Problem:
    """Host-side problem description before region blocking."""

    num_vertices: int
    edges: np.ndarray        # i64[m, 2]  undirected pairs (u, v), u != v
    cap_fwd: np.ndarray      # i32[m]     capacity u->v
    cap_bwd: np.ndarray      # i32[m]     capacity v->u
    excess: np.ndarray       # i32[n]     source-side terminal mass (paper Init)
    sink_cap: np.ndarray     # i32[n]     t-link capacity


def _check_problem(p: Problem) -> None:
    n, m = p.num_vertices, len(p.edges)
    assert p.edges.shape == (m, 2)
    assert p.cap_fwd.shape == (m,) and p.cap_bwd.shape == (m,)
    assert p.excess.shape == (n,) and p.sink_cap.shape == (n,)
    assert (p.cap_fwd >= 0).all() and (p.cap_bwd >= 0).all()
    assert (p.excess >= 0).all() and (p.sink_cap >= 0).all()
    if m:
        assert p.edges.min() >= 0 and p.edges.max() < n
        assert (p.edges[:, 0] != p.edges[:, 1]).all(), "self loops not allowed"


class ProblemValidationError(ValueError):
    """A ``Problem`` carries capacities the int32 solver cannot run safely.

    Raised by :func:`validate_problem` — the typed front door for
    negative/overflow-risk inputs; the bare ``_check_problem`` asserts
    stay as the internal (post-validation) sanity net inside ``build``.
    """


def validate_problem(p: Problem, *, context: str = "problem",
                     dtype_policy: str = "int32") -> None:
    """Reject negative and overflow-risk capacities before they reach the
    int32 flow arithmetic.

    The solver's sentinels (``INF_CAP = INF_LABEL = 2**30``) rely on int32
    sums never overflowing (see the module header): per undirected edge
    the two directed capacities share one residual budget
    (``cf(u,v) + cf(v,u)`` is invariant under pushes), per vertex
    ``excess + sink_cf`` rides the same bound, the total source mass
    bounds every accumulated excess and ``flow_to_t``, and the cut-cost
    certificate sums capacities across the cut.  Checks (all sums in
    int64):

    * shapes consistent, edge endpoints in range, no self loops;
    * every capacity/terminal >= 0;
    * per edge: ``cap_fwd + cap_bwd < INF_CAP``;
    * per vertex: ``excess + sink_cap < INF_CAP``;
    * ``sum(excess) < INF_CAP`` (bounds excess accumulation, flow_to_t);
    * ``sum(excess) + sum(sink_cap) + sum(caps) < 2**31`` (bounds the
      cut-cost certificate reduction).

    Under ``dtype_policy="narrow"`` (forced int16 storage) the bounds
    tighten: the total capacity mass must fit the narrowed residual dtype
    and the label ceiling the narrowed label dtype — a violation is a
    typed error naming the dtype and bound instead of silent wraparound.
    ``"auto"`` needs no extra checks here (it falls back to int32).

    Raises :class:`ProblemValidationError` (a ``ValueError``) naming the
    first offending quantity.  ``context`` labels the error source
    ("prepare", "update", a DIMACS path, ...).
    """
    n, m = p.num_vertices, len(p.edges)

    def fail(msg: str):
        raise ProblemValidationError(f"invalid {context}: {msg}")

    if p.edges.shape != (m, 2):
        fail(f"edges shape {p.edges.shape} != ({m}, 2)")
    if p.cap_fwd.shape != (m,) or p.cap_bwd.shape != (m,):
        fail(f"edge-capacity shapes {p.cap_fwd.shape}/{p.cap_bwd.shape} "
             f"!= ({m},)")
    if p.excess.shape != (n,) or p.sink_cap.shape != (n,):
        fail(f"terminal shapes {p.excess.shape}/{p.sink_cap.shape} != ({n},)")
    if m:
        if p.edges.min() < 0 or p.edges.max() >= n:
            fail("edge endpoint outside [0, num_vertices)")
        if (p.edges[:, 0] == p.edges[:, 1]).any():
            fail("self loop")
    for name, a in (("cap_fwd", p.cap_fwd), ("cap_bwd", p.cap_bwd),
                    ("excess", p.excess), ("sink_cap", p.sink_cap)):
        a = np.asarray(a)
        if a.size and int(a.min()) < 0:
            fail(f"negative {name} (min {int(a.min())}) at index "
                 f"{int(np.argmin(a))}")
    inf = int(INF_CAP)
    pair = p.cap_fwd.astype(np.int64) + p.cap_bwd.astype(np.int64)
    if m and int(pair.max()) >= inf:
        i = int(np.argmax(pair))
        fail(f"edge {i}: cap_fwd + cap_bwd = {int(pair[i])} >= INF_CAP "
             f"(2^30) — the shared residual budget of one edge overflows")
    term = p.excess.astype(np.int64) + p.sink_cap.astype(np.int64)
    if n and int(term.max()) >= inf:
        i = int(np.argmax(term))
        fail(f"vertex {i}: excess + sink_cap = {int(term[i])} >= INF_CAP "
             f"(2^30)")
    total_excess = int(p.excess.astype(np.int64).sum())
    if total_excess >= inf:
        fail(f"sum(excess) = {total_excess} >= INF_CAP (2^30) — "
             f"accumulated excess / flow_to_t can overflow int32")
    total = (total_excess + int(p.sink_cap.astype(np.int64).sum())
             + int(pair.sum()))
    if total >= 2**31:
        fail(f"total capacity mass {total} >= 2^31 — the int32 cut-cost "
             f"certificate reduction can overflow")
    # forced-narrow policy: the int16 families must actually fit.  The
    # label bound is the conservative problem-level one (n + 2 dominates
    # max(n, V + 2) for every partition, since V <= n).
    for family, dt, value, limit in _dt.narrow_violations(
            dtype_policy, mass=total, bound=n + 2):
        what = ("total capacity mass" if family == "flow"
                else "label ceiling")
        fail(f"{what} {value} exceeds the {dt} {family} bound {limit} "
             f"under dtype_policy='narrow' — narrowed {family} storage "
             f"would wrap; use dtype_policy='auto' (int32 fallback) or "
             f"'int32'")


def validate_update_dtypes(meta, p: Problem, *,
                           context: str = "update") -> None:
    """A capacity update on a handle built with narrowed storage must still
    fit the narrow ranges.

    The handle's dtypes are frozen at ``build`` time (they key the compile
    cache), so an update that pushes the total capacity mass past the int16
    bound cannot silently widen — and silently wrapping would corrupt flow.
    Typed error instead; the label bound depends only on the fixed topology
    and cannot change under an update.
    """
    kd = meta.kernel_dtypes
    if kd.flow != "int16":
        return
    mass = _dt.flow_mass(p)
    if not _dt.flows_fit_narrow(mass):
        raise ProblemValidationError(
            f"invalid {context}: total capacity mass {mass} exceeds the "
            f"int16 flow bound {_dt.NARROW_FLOW_LIMIT} of this prepared "
            f"handle's narrowed storage — re-prepare the problem (a fresh "
            f"build under dtype_policy='auto' falls back to int32)")


@dataclass(frozen=True)
class Layout:
    """Host-side mapping between flat vertex ids and (region, local) slots.

    ``edge_arc_u``/``edge_arc_v`` give, for every undirected input edge i,
    the flat ``[K*V*E]`` index of its two directed arc slots (u's row and
    v's row); ``edge_vtx_u``/``edge_vtx_v`` the flat ``[K*V]`` index of its
    endpoints.  They are what lets a prepared handle scatter a capacity
    delta straight onto the device-resident ``FlowState`` without
    re-running ``build`` (``apply_update``).
    """

    part: np.ndarray        # i64[n] region of each vertex
    local_id: np.ndarray    # i64[n] slot within the region
    edge_arc_u: np.ndarray | None = None   # i64[m] flat arc slot of u->v
    edge_arc_v: np.ndarray | None = None   # i64[m] flat arc slot of v->u
    edge_vtx_u: np.ndarray | None = None   # i64[m] flat vertex slot of u
    edge_vtx_v: np.ndarray | None = None   # i64[m] flat vertex slot of v

    def to_flat(self, arr_kv: np.ndarray) -> np.ndarray:
        """Gather a [K,V] per-slot array back to flat vertex order."""
        return np.asarray(arr_kv)[self.part, self.local_id]


def _stable_cumcount(keys: np.ndarray) -> np.ndarray:
    """Occurrence index of each element among equal values, in array order.

    Vectorized equivalent of ``count[k]; count[k] += 1`` loops: a stable
    argsort groups equal keys while preserving their original order, so the
    within-group offset is position minus group start.  ``build`` and the
    shard-wise streaming build (``repro.stream.build``) both derive arc
    slots from it, which is what makes their layouts bit-identical.
    """
    n = len(keys)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    starts = np.r_[0, np.flatnonzero(sk[1:] != sk[:-1]) + 1]
    counts = np.diff(np.r_[starts, n])
    out = np.empty(n, dtype=np.int64)
    out[order] = np.arange(n, dtype=np.int64) - np.repeat(starts, counts)
    return out


def build(problem: Problem, part: np.ndarray, *,
          dtype_policy: str = "int32") -> tuple[GraphMeta, FlowState, "Layout"]:
    """Block a flat problem into the region-partitioned device layout.

    ``part[v]`` gives the region id of vertex v (0..K-1).  Pure numpy; runs
    once on the host (the paper's ``splitter`` tool, Sec. 5.3).

    ``dtype_policy`` selects the storage dtypes of the mutable state
    (``repro.core.dtypes``): ``"auto"``/``"narrow"`` store residuals and
    excess as int16 when the total capacity mass fits and labels as int16
    when the label ceiling fits, recording the choice in ``GraphMeta`` so
    compile-cache keys stay sound; ``"auto"`` falls back to int32 per
    family, ``"narrow"`` raises ``ProblemValidationError`` instead.
    """
    _check_problem(problem)
    n = problem.num_vertices
    part = np.asarray(part, dtype=np.int64)
    assert part.shape == (n,)
    K = int(part.max()) + 1 if n else 1

    # local ids within each region (cumcount in vertex order, per region)
    local_id = _stable_cumcount(part)
    region_count = np.bincount(part, minlength=K)
    V = max(1, int(region_count.max()) if n else 0)

    # per-vertex directed arc lists (both directions of every undirected edge)
    u_arr = problem.edges[:, 0]
    v_arr = problem.edges[:, 1]
    deg = np.zeros(n, dtype=np.int64)
    np.add.at(deg, u_arr, 1)
    np.add.at(deg, v_arr, 1)
    E = max(1, int(deg.max()) if n else 1)

    nbr_region = np.full((K, V, E), 0, dtype=np.int32)
    nbr_local = np.full((K, V, E), 0, dtype=np.int32)
    rev_slot = np.zeros((K, V, E), dtype=np.int32)
    emask = np.zeros((K, V, E), dtype=bool)
    cf = np.zeros((K, V, E), dtype=np.int32)

    # first pass: assign slots — cumcount over the interleaved (u, v)
    # endpoint sequence, exactly the per-vertex counter a scalar loop
    # over edges would keep
    m = len(problem.edges)
    occ = np.empty(2 * m, dtype=np.int64)
    occ[0::2] = u_arr
    occ[1::2] = v_arr
    cc = _stable_cumcount(occ)
    slot_u, slot_v = cc[0::2], cc[1::2]
    # second pass: fill rows (vectorised where possible)
    ru, lu = part[u_arr], local_id[u_arr]
    rv, lv = part[v_arr], local_id[v_arr]
    nbr_region[ru, lu, slot_u] = rv.astype(np.int32)
    nbr_local[ru, lu, slot_u] = lv.astype(np.int32)
    rev_slot[ru, lu, slot_u] = slot_v.astype(np.int32)
    emask[ru, lu, slot_u] = True
    cf[ru, lu, slot_u] = problem.cap_fwd
    nbr_region[rv, lv, slot_v] = ru.astype(np.int32)
    nbr_local[rv, lv, slot_v] = lu.astype(np.int32)
    rev_slot[rv, lv, slot_v] = slot_u.astype(np.int32)
    emask[rv, lv, slot_v] = True
    cf[rv, lv, slot_v] = problem.cap_bwd

    vmask = np.zeros((K, V), dtype=bool)
    vmask[part, local_id] = True

    sink_cf = np.zeros((K, V), dtype=np.int32)
    sink_cf[part, local_id] = problem.sink_cap
    excess = np.zeros((K, V), dtype=np.int32)
    excess[part, local_id] = problem.excess

    # boundary set B: endpoints of inter-region edges
    cross_edge = ru != rv
    is_boundary = np.zeros((K, V), dtype=bool)
    if cross_edge.any():
        cu = u_arr[cross_edge]; cv = v_arr[cross_edge]
        is_boundary[part[cu], local_id[cu]] = True
        is_boundary[part[cv], local_id[cv]] = True
    num_boundary = int(is_boundary.sum())

    # flat directed cross-arc table.  Invariant: arcs come in mutual-reverse
    # pairs at indices (2i, 2i+1), so pair(x) = x ^ 1.
    src_list, dst_list = [], []
    idx = np.nonzero(cross_edge)[0]
    for i in idx:
        u, v = u_arr[i], v_arr[i]
        a = (part[u], local_id[u], slot_u[i])
        b = (part[v], local_id[v], slot_v[i])
        src_list += [a, b]
        dst_list += [b, a]
    X = max(1, len(src_list))
    cross_src = np.zeros((X, 3), dtype=np.int32)
    cross_dst = np.zeros((X, 3), dtype=np.int32)
    cross_group = np.zeros(X, dtype=np.int32)
    cross_valid = np.zeros(X, dtype=bool)
    num_groups = 1
    if src_list:
        cross_src[: len(src_list)] = np.asarray(src_list, dtype=np.int32)
        cross_dst[: len(dst_list)] = np.asarray(dst_list, dtype=np.int32)
        cross_valid[: len(src_list)] = True
        # group id of the (viewing region, ghost vertex) pair: arcs from R to
        # the same boundary vertex w share a group — region reduction and the
        # boundary heuristics aggregate per ghost, not per arc.
        keys = {}
        for x in range(len(src_list)):
            k = (src_list[x][0], dst_list[x][0], dst_list[x][1])
            cross_group[x] = keys.setdefault(k, len(keys))
        num_groups = max(1, len(keys))

    kd = _dt.select_dtypes(dtype_policy, mass=_dt.flow_mass(problem),
                           bound=_dt.label_bound(n, V))
    bad = _dt.narrow_violations(dtype_policy, mass=_dt.flow_mass(problem),
                                bound=_dt.label_bound(n, V))
    if bad:
        family, dt, value, limit = bad[0]
        raise ProblemValidationError(
            f"invalid build: {family} range {value} exceeds the {dt} "
            f"bound {limit} under dtype_policy='narrow'")

    meta = GraphMeta(
        num_regions=K,
        region_size=V,
        max_degree=E,
        num_vertices=n,
        num_boundary=num_boundary,
        num_cross_arcs=X,
        num_ghost_groups=num_groups,
        d_inf_ard=max(1, num_boundary),
        d_inf_prd=max(1, n),
        label_dtype=kd.label,
        flow_dtype=kd.flow,
        mask_dtype=kd.mask,
    )
    state = FlowState(
        nbr_region=jnp.asarray(nbr_region),
        nbr_local=jnp.asarray(nbr_local),
        rev_slot=jnp.asarray(rev_slot),
        emask=jnp.asarray(emask),
        vmask=jnp.asarray(vmask),
        is_boundary=jnp.asarray(is_boundary),
        cross_src=jnp.asarray(cross_src),
        cross_dst=jnp.asarray(cross_dst),
        cross_group=jnp.asarray(cross_group),
        cross_valid=jnp.asarray(cross_valid),
        cross_src_arc=jnp.asarray(
            (cross_src[:, 0].astype(np.int64) * V + cross_src[:, 1]) * E
            + cross_src[:, 2], dtype=jnp.int32),
        cross_dst_arc=jnp.asarray(
            (cross_dst[:, 0].astype(np.int64) * V + cross_dst[:, 1]) * E
            + cross_dst[:, 2], dtype=jnp.int32),
        cross_src_vtx=jnp.asarray(
            cross_src[:, 0].astype(np.int64) * V + cross_src[:, 1],
            dtype=jnp.int32),
        cross_dst_vtx=jnp.asarray(
            cross_dst[:, 0].astype(np.int64) * V + cross_dst[:, 1],
            dtype=jnp.int32),
        cf=jnp.asarray(cf.astype(kd.flow_np)),
        sink_cf=jnp.asarray(sink_cf.astype(kd.flow_np)),
        excess=jnp.asarray(excess.astype(kd.flow_np)),
        d=jnp.zeros((K, V), dtype=kd.label_np),
        flow_to_t=jnp.zeros((), dtype=jnp.int32),
    )
    layout = Layout(
        part=part, local_id=local_id,
        edge_arc_u=(ru * V + lu) * E + slot_u,
        edge_arc_v=(rv * V + lv) * E + slot_v,
        edge_vtx_u=ru * V + lu,
        edge_vtx_v=rv * V + lv)
    return meta, state, layout


def init_labels(meta: GraphMeta, state: FlowState) -> FlowState:
    """Paper's ``Init``: d := 0 everywhere (source already eliminated)."""
    return state.replace(d=jnp.zeros_like(state.d))


# --------------------------------------------------------------------------
# Per-region state slabs: the streaming executor's unit of disk I/O.  One
# region's view is [V,E]/[V] arrays — never the full [K,V,E] state — split
# into the immutable topology (spilled once per solve) and the mutable flow
# family (staged in/out every region visit).
# --------------------------------------------------------------------------

REGION_TOPO_FIELDS = ("nbr_region", "nbr_local", "rev_slot", "emask",
                      "vmask", "is_boundary")
REGION_FLOW_FIELDS = ("cf", "sink_cf", "excess", "d")


def extract_region(state: FlowState, r: int, fields=None) -> dict:
    """One region's slabs as host numpy arrays: ``{field: array[V,E]|[V]}``.

    ``fields`` defaults to topology + flow; pass ``REGION_FLOW_FIELDS`` /
    ``REGION_TOPO_FIELDS`` to stage one family.  Fetches only the indexed
    slices — a prepared handle spilling its regions to disk never copies
    the whole state to host at once.
    """
    if fields is None:
        fields = REGION_TOPO_FIELDS + REGION_FLOW_FIELDS
    return {f: np.asarray(getattr(state, f)[r]) for f in fields}


def insert_region(state: FlowState, r: int, shard: dict) -> FlowState:
    """Write one region's mutable slabs back into a full ``FlowState``.

    The inverse of :func:`extract_region` over the flow family (topology is
    immutable and never re-inserted); used to reassemble a resident state
    from streamed shards for cut extraction / certificate checks.
    """
    upd = {}
    for f in REGION_FLOW_FIELDS:
        if f in shard:
            cur = getattr(state, f)
            upd[f] = cur.at[r].set(jnp.asarray(shard[f], dtype=cur.dtype))
    return state.replace(**upd)


# --------------------------------------------------------------------------
# Warm-start updates: reparameterize the residual network under a capacity
# delta (Kohli-Torr dynamic-cuts style), keeping the preflow device-resident.
# --------------------------------------------------------------------------

# traces of the jitted update program — a session's ``cache_info`` counts
# these together with the sweep/batch program traces
_UPDATE_TRACES = 0


def update_trace_count() -> int:
    return _UPDATE_TRACES


@jax.tree_util.register_dataclass
@dataclass
class GraphUpdate:
    """Device-side capacity/terminal delta of a prepared problem (a pytree).

    ``j`` edge entries and ``p`` vertex entries, each padded (to a power of
    two by the session front-end) with index-0 / zero-delta slots that are
    inert under the scatter arithmetic of ``apply_update`` — so repeated
    same-sized updates reuse one compiled program.  Indices are flat:
    ``arc_*`` into the flattened ``[K*V*E]`` residual table (the build-time
    ``Layout.edge_arc_*`` slots of the updated edges), ``vtx_*``/``t_vtx``
    into the flattened ``[K*V]`` vertex arrays.
    """

    arc_u: jax.Array       # i32[j] flat slot of the edge's u->v arc
    arc_v: jax.Array       # i32[j] flat slot of the edge's v->u arc
    vtx_u: jax.Array       # i32[j] flat vertex slot of u
    vtx_v: jax.Array       # i32[j] flat vertex slot of v
    d_cap_fwd: jax.Array   # i32[j] capacity delta of u->v
    d_cap_bwd: jax.Array   # i32[j] capacity delta of v->u
    t_vtx: jax.Array       # i32[p] flat vertex slot of a terminal update
    d_sink: jax.Array      # i32[p] t-link capacity delta
    d_excess: jax.Array    # i32[p] source-mass delta


@jax.jit
def apply_update(state: FlowState, state0: FlowState, upd: GraphUpdate):
    """Apply a capacity/terminal delta to a solved (or fresh) ``FlowState``.

    The residual network is reparameterized in the Kohli-Torr dynamic-cuts
    style so the current preflow stays valid on the updated problem:

    * each updated edge's residual pair moves by the capacity delta; where
      the new capacity falls below the flow the residual is clamped to 0
      and the clamped overflow is *returned to the sender's excess*, with
      the matching inflow deficit charged to the receiver;
    * t-link decreases below the flow already drained return the overflow
      to the vertex excess and roll ``flow_to_t`` back;
    * a deficit a vertex cannot cover from its (post-return) excess is
      cancelled by adding the shortfall to BOTH its conceptual source arc
      (absorbed into excess, netting zero) and its t-link ``sink_cf`` —
      adding the same amount to (s,v) and (v,t) raises every s-t cut by
      exactly that constant, so the mincut partition is unchanged and the
      solved flow value is simply ``flow_to_t - offset``.

    Returns ``(state', state0', grew, offset_delta)`` where ``state0'`` is
    the *unreparameterized* initial network of the updated problem (what
    cut-cost checks price cuts against), ``grew`` flags whether any
    residual capacity increased (new residual arcs can invalidate kept
    labels — see ``SolverOptions.warm_labels``), and ``offset_delta`` is
    the flow-value offset introduced by deficit cancellation.
    """
    global _UPDATE_TRACES
    _UPDATE_TRACES += 1
    K, V, E = state.cf.shape

    # --- edge capacity deltas, clamped into the new capacity ---
    # deltas arrive int32; the state may be stored narrow — cast at the
    # door (the session front-end re-validates that the updated problem
    # still fits the narrowed ranges, so the casts cannot wrap)
    cf = state.cf.reshape(-1)
    fdt = cf.dtype
    d_fwd = upd.d_cap_fwd.astype(fdt)
    d_bwd = upd.d_cap_bwd.astype(fdt)
    d_sink_t = upd.d_sink.astype(fdt)
    d_excess_t = upd.d_excess.astype(fdt)
    ra0, rb0 = cf[upd.arc_u], cf[upd.arc_v]
    ra = ra0 + d_fwd
    rb = rb0 + d_bwd
    # at most one side of a pair can go negative (ra + rb = c_f' + c_b' >= 0)
    ov_a = jnp.maximum(-ra, 0)          # flow over the new u->v capacity
    ra, rb = ra + ov_a, rb - ov_a
    ov_b = jnp.maximum(-rb, 0)          # flow over the new v->u capacity
    rb, ra = rb + ov_b, ra - ov_b
    cf = cf.at[upd.arc_u].add(ra - ra0, mode="drop")
    cf = cf.at[upd.arc_v].add(rb - rb0, mode="drop")

    # clamped overflow goes back to the sender; the receiver is charged
    nv = K * V
    returns = jnp.zeros((nv,), fdt).at[upd.vtx_u].add(ov_a, mode="drop")
    returns = returns.at[upd.vtx_v].add(ov_b, mode="drop")
    deficits = jnp.zeros((nv,), fdt).at[upd.vtx_v].add(ov_a, mode="drop")
    deficits = deficits.at[upd.vtx_u].add(ov_b, mode="drop")

    # --- terminal deltas ---
    sink = state.sink_cf.reshape(-1)
    s0 = sink[upd.t_vtx]
    s1 = s0 + d_sink_t
    t_ret = jnp.maximum(-s1, 0)         # flow returned from the sink
    s1 = s1 + t_ret
    sink = sink.at[upd.t_vtx].add(s1 - s0, mode="drop")
    flow_to_t = state.flow_to_t - jnp.sum(t_ret, dtype=jnp.int32)
    returns = returns.at[upd.t_vtx].add(
        t_ret + jnp.maximum(d_excess_t, 0), mode="drop")
    deficits = deficits.at[upd.t_vtx].add(
        jnp.maximum(-d_excess_t, 0), mode="drop")

    # --- resolve deficits against excess; cancel the shortfall ---
    excess = state.excess.reshape(-1) + returns
    short = jnp.maximum(deficits - excess, 0)
    excess = jnp.maximum(excess - deficits, 0)
    sink = sink + short
    offset = jnp.sum(short, dtype=jnp.int32)

    grew = ((ra > ra0).any() | (rb > rb0).any() | (s1 > s0).any()
            | (short > 0).any())

    new_state = state.replace(
        cf=cf.reshape(K, V, E), sink_cf=sink.reshape(K, V),
        excess=excess.reshape(K, V), flow_to_t=flow_to_t)

    # initial network of the updated problem (zero flow): plain deltas
    cf0 = state0.cf.reshape(-1).at[upd.arc_u].add(d_fwd, mode="drop")
    cf0 = cf0.at[upd.arc_v].add(d_bwd, mode="drop")
    sink0 = state0.sink_cf.reshape(-1).at[upd.t_vtx].add(d_sink_t,
                                                         mode="drop")
    exc0 = state0.excess.reshape(-1).at[upd.t_vtx].add(d_excess_t,
                                                       mode="drop")
    new_state0 = state0.replace(
        cf=cf0.reshape(K, V, E), sink_cf=sink0.reshape(K, V),
        excess=exc0.reshape(K, V))
    return new_state, new_state0, grew, offset


# --------------------------------------------------------------------------
# Multi-instance packing: stack independent problems into shape buckets.
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BatchMeta:
    """Static bucket-shape metadata of a packed instance batch.

    Deliberately holds ONLY the padded bucket dimensions — everything that
    varies between same-shaped batches (instance count, label ceilings,
    sweep bounds) lives in ``BatchState`` device arrays or host-side in
    ``PackedBatch``, so a compiled batched solve is keyed purely by
    ``(bucket_shape, SweepConfig)`` and is reused verbatim for any batch
    that lands in the same bucket.
    """

    num_instances: int        # B  (padded bucket batch size)
    num_regions: int          # K  (padded)
    region_size: int          # V  (padded)
    max_degree: int           # E  (padded)
    num_cross_arcs: int       # X  (padded)
    # storage dtypes of the bucket (all members share them — packing
    # groups by dtype as well as shape); part of the compile-cache key
    label_dtype: str = "int32"
    flow_dtype: str = "int32"
    mask_dtype: str = "int32"

    @property
    def bucket_shape(self) -> tuple[int, int, int, int, int]:
        return (self.num_instances, self.num_regions, self.region_size,
                self.max_degree, self.num_cross_arcs)

    @property
    def kernel_dtypes(self) -> KernelDtypes:
        return KernelDtypes(label=self.label_dtype, flow=self.flow_dtype,
                            mask=self.mask_dtype)


@jax.tree_util.register_dataclass
@dataclass
class BatchState:
    """Device-resident state of a packed solve batch (a JAX pytree).

    The ``[B, ...]`` forms of the ``FlowState`` fields the batched sweep
    driver needs, plus the per-instance dynamic metadata (label ceilings)
    that a single solve bakes in statically from ``GraphMeta``.  Keeping
    the ceilings as device arrays is what lets instances of *different
    original sizes* share one bucket-shaped executable while running
    exactly the iteration sequence of their standalone solves.
    """

    # --- static topology (never mutated) ---
    nbr_region: jax.Array     # i32[B,K,V,E]
    nbr_local: jax.Array      # i32[B,K,V,E]
    rev_slot: jax.Array       # i32[B,K,V,E]
    emask: jax.Array          # bool[B,K,V,E]
    vmask: jax.Array          # bool[B,K,V]
    is_boundary: jax.Array    # bool[B,K,V]
    # flat cross-arc scatter/gather indices, recomputed for the bucket dims
    cross_src_arc: jax.Array  # i32[B,X]  (r*V + l)*E + s of the source row
    cross_dst_arc: jax.Array  # i32[B,X]
    cross_src_vtx: jax.Array  # i32[B,X]  r*V + l
    cross_dst_vtx: jax.Array  # i32[B,X]
    cross_valid: jax.Array    # bool[B,X] padded-entry mask
    # --- per-instance dynamic metadata ---
    d_inf_ard: jax.Array      # i32[B]  |B_b|  (ARD ceiling of instance b)
    d_inf_prd: jax.Array      # i32[B]  n_b    (PRD ceiling)
    linf: jax.Array           # i32[B]  V_b+2  (ARD stage/BFS local ceiling,
    #                                   the instance's ORIGINAL region size)
    # --- mutable flow state ---
    cf: jax.Array             # i32[B,K,V,E]
    sink_cf: jax.Array        # i32[B,K,V]
    excess: jax.Array         # i32[B,K,V]
    d: jax.Array              # i32[B,K,V]
    flow_to_t: jax.Array      # i32[B]

    def replace(self, **kw) -> "BatchState":
        return dataclasses.replace(self, **kw)


@dataclass
class PackedBatch:
    """Host-side handle on one shape bucket of a packed batch.

    ``metas``/``layouts``/``states0`` are the per-real-instance build
    artifacts (unpadded), kept for unpacking results, the cut check and
    the byte accounting; ``indices`` maps bucket slots back to positions
    in the caller's problem list.  Slots beyond ``len(indices)`` are inert
    padding instances (all-masked, zero excess) that converge at entry.
    """

    meta: BatchMeta
    state: BatchState
    metas: list
    layouts: list
    states0: list
    indices: list

    @property
    def num_real(self) -> int:
        return len(self.indices)


def _round_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def bucket_shape_for(meta: GraphMeta) -> tuple[int, int, int, int]:
    """(K, V, E, X) bucket of an instance: each dim rounded up to a power
    of two, so mixed problem sizes collapse onto a small set of compiled
    executables."""
    return (_round_pow2(meta.num_regions), _round_pow2(meta.region_size),
            _round_pow2(meta.max_degree), _round_pow2(meta.num_cross_arcs))


def _pad_to(a: np.ndarray, shape: tuple[int, ...]) -> np.ndarray:
    return np.pad(a, [(0, s - d) for d, s in zip(a.shape, shape)])


def pack_instances(problems, parts=None, *, num_regions: int = 4,
                   pad_batch: bool = True,
                   dtype_policy: str = "int32") -> list[PackedBatch]:
    """Stack independent problems into shape-bucketed solve batches.

    Each problem is region-blocked with ``build`` (``parts[i]`` or the
    node-number fallback partitioner) and handed to ``pack_built`` — one
    ``PackedBatch`` per power-of-two shape bucket.  ``dtype_policy`` runs
    the per-problem capacity/label range check of ``build``; instances
    resolving to different storage dtypes land in different buckets.
    """
    from repro.core.partition import block_partition

    builds = []
    for i, p in enumerate(problems):
        part = parts[i] if parts is not None and parts[i] is not None \
            else block_partition(p.num_vertices, num_regions)
        meta, state, layout = build(p, np.asarray(part),
                                    dtype_policy=dtype_policy)
        builds.append((i, meta, state, layout, state))
    return pack_built(builds, pad_batch=pad_batch)


def pack_built(builds, *, pad_batch: bool = True) -> list[PackedBatch]:
    """Stack already-built instances into shape-bucketed solve batches.

    ``builds`` — ``(index, meta, state, layout, state0)`` tuples: ``state``
    is the FlowState the batched solve starts from (fresh from ``build``,
    or a session handle's warm, possibly-updated state — its preflow,
    labels and ``flow_to_t`` are all carried into the batch), ``state0``
    the instance's initial network kept for result unpacking and the
    cut-cost check.  Each instance's (K, V, E, X) is rounded up to the
    power-of-two bucket and instances sharing a bucket are stacked along a
    new leading instance axis.  Padding is inert by construction:
    masked-off vertices/arcs/cross entries and (with ``pad_batch``) the
    batch axis rounded up with all-masked dummy instances, so any batch
    landing in a bucket reuses the bucket's compiled solve.  Returns one
    ``PackedBatch`` per bucket (ascending bucket shape).
    """
    groups: dict = {}
    for item in builds:
        m = item[1]
        key = bucket_shape_for(m) + (m.label_dtype, m.flow_dtype,
                                     m.mask_dtype)
        groups.setdefault(key, []).append(item)

    out = []
    for (K, V, E, X, label_dt, flow_dt, mask_dt), items \
            in sorted(groups.items()):
        B = _round_pow2(len(items)) if pad_batch else len(items)
        fdt, ldt = np.dtype(flow_dt), np.dtype(label_dt)
        shp3 = {"nbr_region": np.int32, "nbr_local": np.int32,
                "rev_slot": np.int32, "emask": bool, "cf": fdt}
        shp2 = {"vmask": bool, "is_boundary": bool, "sink_cf": fdt,
                "excess": fdt, "d": ldt}
        cols = {k: np.zeros((B, K, V, E), dt) for k, dt in shp3.items()}
        cols.update({k: np.zeros((B, K, V), dt) for k, dt in shp2.items()})
        cross = {k: np.zeros((B, X), np.int32) for k in
                 ("cross_src_arc", "cross_dst_arc",
                  "cross_src_vtx", "cross_dst_vtx")}
        cross_valid = np.zeros((B, X), bool)
        d_inf_ard = np.ones(B, np.int32)
        d_inf_prd = np.ones(B, np.int32)
        linf = np.full(B, 3, np.int32)
        flow_to_t = np.zeros(B, np.int32)
        for b, (i, meta, state, layout, _state0) in enumerate(items):
            for k in shp3:
                cols[k][b] = _pad_to(np.asarray(getattr(state, k)), (K, V, E))
            for k in shp2:
                cols[k][b] = _pad_to(np.asarray(getattr(state, k)), (K, V))
            # flat scatter indices must be recomputed for the BUCKET dims —
            # the per-instance build derived them from its original (V, E)
            src = np.asarray(state.cross_src, np.int64)
            dst = np.asarray(state.cross_dst, np.int64)
            valid = np.asarray(state.cross_valid)
            n_x = len(valid)
            arc = lambda t: ((t[:, 0] * V + t[:, 1]) * E + t[:, 2]) \
                .astype(np.int32)
            vtx = lambda t: (t[:, 0] * V + t[:, 1]).astype(np.int32)
            cross["cross_src_arc"][b, :n_x] = arc(src)
            cross["cross_dst_arc"][b, :n_x] = arc(dst)
            cross["cross_src_vtx"][b, :n_x] = vtx(src)
            cross["cross_dst_vtx"][b, :n_x] = vtx(dst)
            cross_valid[b, :n_x] = valid
            d_inf_ard[b] = meta.d_inf_ard
            d_inf_prd[b] = meta.d_inf_prd
            linf[b] = meta.region_size + 2
            flow_to_t[b] = int(state.flow_to_t)
        state = BatchState(
            nbr_region=jnp.asarray(cols["nbr_region"]),
            nbr_local=jnp.asarray(cols["nbr_local"]),
            rev_slot=jnp.asarray(cols["rev_slot"]),
            emask=jnp.asarray(cols["emask"]),
            vmask=jnp.asarray(cols["vmask"]),
            is_boundary=jnp.asarray(cols["is_boundary"]),
            cross_src_arc=jnp.asarray(cross["cross_src_arc"]),
            cross_dst_arc=jnp.asarray(cross["cross_dst_arc"]),
            cross_src_vtx=jnp.asarray(cross["cross_src_vtx"]),
            cross_dst_vtx=jnp.asarray(cross["cross_dst_vtx"]),
            cross_valid=jnp.asarray(cross_valid),
            d_inf_ard=jnp.asarray(d_inf_ard),
            d_inf_prd=jnp.asarray(d_inf_prd),
            linf=jnp.asarray(linf),
            cf=jnp.asarray(cols["cf"]),
            sink_cf=jnp.asarray(cols["sink_cf"]),
            excess=jnp.asarray(cols["excess"]),
            d=jnp.asarray(cols["d"]),
            flow_to_t=jnp.asarray(flow_to_t),
        )
        out.append(PackedBatch(
            meta=BatchMeta(num_instances=B, num_regions=K, region_size=V,
                           max_degree=E, num_cross_arcs=X,
                           label_dtype=label_dt, flow_dtype=flow_dt,
                           mask_dtype=mask_dt),
            state=state,
            metas=[it[1] for it in items],
            layouts=[it[3] for it in items],
            states0=[it[4] for it in items],
            indices=[it[0] for it in items]))
    return out


def intra_mask(state: FlowState) -> jax.Array:
    """bool[K,V,E] — arc stays within its own region."""
    K = state.nbr_region.shape[0]
    own = jnp.arange(K, dtype=state.nbr_region.dtype)[:, None, None]
    return (state.nbr_region == own) & state.emask


def flow_value(state: FlowState) -> jax.Array:
    return state.flow_to_t


def total_excess(state: FlowState) -> jax.Array:
    return jnp.sum(jnp.where(state.vmask, state.excess, 0),
                   dtype=jnp.int32)
