"""Push-Relabel Region Discharge (PRD) — the Delong-Boykov baseline (Sec. 3).

Discharge of a region R applies Push and Relabel to vertices of R until no
active vertex remains, with the labels of the boundary B^R frozen.  Labels
live in the *hop-distance* space (ceiling d_inf = n), unlike ARD's region
distance.  The paper proves a tight O(n^2) sweep bound for this operator
(Theorems 1-2, Appendix A) — the experiments reproduce the asymptotic gap
versus ARD's 2|B|^2 + 1.

The region-internal solver is the same synchronous vectorized push-relabel
engine; for PRD it simply runs *directly on the global labels* (which is the
definition of PRD), pushing to lower-labelled intra vertices, to the sink,
and across boundary arcs to frozen-labelled ghosts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ard import DischargeResult
from repro.core.engine import push_relabel, push_relabel_batched

_I32 = jnp.int32


def prd_discharge_one(cf, sink_cf, excess, d, ghost_d, *, nbr_local, rev_slot,
                      intra, emask, vmask, d_inf: int,
                      max_iters: int | None = None,
                      backend: str = "xla",
                      chunk_iters: int | None = None) -> DischargeResult:
    """PRD on a single region network (vmapped over regions by sweep.py)."""
    V, E = cf.shape
    cross = emask & ~intra
    es = push_relabel(
        cf, sink_cf, excess, d,
        nbr_local=nbr_local, rev_slot=rev_slot, intra=intra, emask=emask,
        vmask=vmask, cross_pushable=cross, cross_lab=ghost_d, d_inf=d_inf,
        sink_open=True, max_iters=max_iters, backend=backend,
        chunk_iters=chunk_iters)
    return DischargeResult(es.cf, es.sink_cf, es.excess, es.lab, es.out_push,
                           es.sink_pushed, es.iters,
                           jnp.ones((), _I32), es.launches)


def prd_discharge_batched(cf, sink_cf, excess, d, ghost_d, *, nbr_local,
                          rev_slot, intra, emask, vmask, d_inf,
                          max_iters: int | None = None,
                          backend: str = "xla",
                          chunk_iters: int | None = None,
                          grid2d: tuple[int, int] | None = None
                          ) -> DischargeResult:
    """PRD on all K regions of a parallel sweep, collectively.

    Batched counterpart of ``jax.vmap(prd_discharge_one)``: PRD is a single
    engine run per region, so this is one ``engine.push_relabel_batched``
    call — on the fused pallas path, one grid-over-regions kernel launch
    per chunk for the whole sweep.  Per-region results are bit-identical to
    the vmapped scalar path; ``engine_launches`` is the global dispatch
    count.  ``d_inf`` may be a scalar or per-region i32[K] (a solve batch's
    regions keep their own instance's label ceiling); ``grid2d`` renders
    the fused pallas launch as the ``grid=(B, Kr)`` solve-batch program.
    """
    K, V, E = cf.shape
    cross = emask & ~intra
    d_inf = jnp.broadcast_to(jnp.asarray(d_inf, _I32), (K,))
    es = push_relabel_batched(
        cf, sink_cf, excess, d,
        nbr_local=nbr_local, rev_slot=rev_slot, intra=intra, emask=emask,
        vmask=vmask, cross_pushable=cross, cross_lab=ghost_d, d_inf=d_inf,
        sink_open=True, max_iters=max_iters, backend=backend,
        chunk_iters=chunk_iters, grid2d=grid2d)
    return DischargeResult(es.cf, es.sink_cf, es.excess, es.lab, es.out_push,
                           es.sink_pushed, es.iters,
                           jnp.ones((K,), _I32), es.launches)
