"""VMEM-budget autotuner for the fused engine configuration.

The fused pallas engine has three hand-set knobs — ``engine_chunk_iters``
(iterations per launch), fused-vs-blocked dispatch, and the blocked path's
``block_v`` tile — whose best values are a pure function of the bucket
dimensions, the backend, and the storage dtypes.  This module makes that
choice once per ``(V, E, backend, dtypes)`` key and persists it to a JSON
cache, so the steady state is zero search *and* zero retrace: a tuned key
always maps to the same ``TunedConfig``, hence the same ``SweepConfig``
statics, hence the same jit cache entry.

Two search modes (per the bench methodology):

* **analytic** (interpret mode / no real accelerator — this container):
  the kernel never actually executes on hardware, so timing candidates
  would measure the interpreter.  Instead the bytes model
  (``kernels.push_relabel.fused_region_vmem_bytes``) decides: fused iff the
  region-resident state fits the VMEM budget, chunk depth at the largest
  candidate (the fused working set is chunk-invariant, and deeper chunks
  amortize launches monotonically — the PR 3 launch-accounting result),
  and the largest ``block_v`` whose two-phase tile fits the budget.
* **measured** (a real TPU backend): the same candidate grid is timed on a
  synthetic region of the key's dimensions and the fastest wall-clock
  candidate wins.  The winner is persisted like the analytic one.

``Solver.prepare``/``solve_many`` consume this through
:func:`tuned_sweep_config` when ``SolverOptions.autotune`` is on; a
user-pinned ``engine_chunk_iters`` always wins over the tuner.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass
from pathlib import Path

from repro.core import dtypes as _dt
from repro.kernels import push_relabel as _pr

# candidate grid: chunk depths and blocked-path vertex tiles
CHUNK_CANDIDATES = (1, 2, 4, 8, 16, 32)
BLOCK_V_CANDIDATES = (64, 128, 256, 512)

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"


@dataclass(frozen=True)
class TunedConfig:
    """The autotuner's decision for one ``(V, E, backend, dtypes)`` key."""

    engine_chunk_iters: int | None   # None: unfused two-phase engine
    block_v: int                     # blocked-path vertex tile
    fused: bool                      # region-resident fused kernel in budget
    vmem_bytes: int                  # modeled fused working set of the key
    mode: str = "analytic"           # "analytic" | "measured"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def cache_path(explicit: str | Path | None = None) -> Path:
    """Resolve the JSON cache location (explicit > $REPRO_AUTOTUNE_CACHE >
    a per-user default)."""
    if explicit is not None:
        return Path(explicit)
    env = os.environ.get(CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def tune_key(V: int, E: int, backend: str, dtypes: _dt.KernelDtypes) -> str:
    return (f"{V}x{E}|{backend}|"
            f"{dtypes.label},{dtypes.flow},{dtypes.mask}")


def _load_cache(path: Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, ValueError):
        return {}


def _store_cache(path: Path, cache: dict) -> None:
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")
        tmp.replace(path)
    except OSError:
        pass                      # cache is an optimization, never fatal


def _blocked_tile_bytes(bv: int, E: int, dtypes: _dt.KernelDtypes) -> int:
    """VMEM bytes of one two-phase kernel tile: a (bv, E) slab per input
    (cf/nbr/intra/pushable/cross_lab) + the (bv, 1+E) delta output + the
    per-row vectors, costed at the family itemsizes."""
    fb, lb, mb = (dtypes.flow_np.itemsize, dtypes.label_np.itemsize,
                  dtypes.mask_np.itemsize)
    return (fb * (bv * E + bv * (E + 1) + 2 * bv)    # cf, delta, sink/excess
            + 4 * (bv * E)                           # nbr (int32 indices)
            + mb * (2 * bv * E)                      # intra, pushable
            + lb * (bv * E + 2 * bv))                # cross_lab, lab in/out


def _analytic(V: int, E: int, backend: str, dtypes: _dt.KernelDtypes,
              budget: int) -> TunedConfig:
    bytes_fused = _pr.fused_region_vmem_bytes(V, E, dtypes)
    fused = bytes_fused <= budget
    block_v = BLOCK_V_CANDIDATES[0]
    for bv in BLOCK_V_CANDIDATES:
        if bv <= max(V, BLOCK_V_CANDIDATES[0]) \
                and _blocked_tile_bytes(min(bv, V), E, dtypes) <= budget:
            block_v = bv
    if backend == "pallas" and not fused:
        # over-budget region: the engine's static fallback takes the
        # blocked path anyway; an unfused config skips the dead gate
        chunk = None
    else:
        chunk = CHUNK_CANDIDATES[-1]
    return TunedConfig(engine_chunk_iters=chunk, block_v=block_v,
                       fused=fused, vmem_bytes=bytes_fused, mode="analytic")


def _measured(V: int, E: int, backend: str, dtypes: _dt.KernelDtypes,
              budget: int) -> TunedConfig:
    """Time the candidate grid on a synthetic region (real backends only)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import engine as _engine

    rng = np.random.RandomState(0)
    fdt, ldt = dtypes.flow_np, dtypes.label_np
    cf = jnp.asarray(rng.randint(0, 4, (V, E)).astype(fdt))
    sink_cf = jnp.asarray(rng.randint(0, 3, (V,)).astype(fdt))
    excess = jnp.asarray(rng.randint(0, 3, (V,)).astype(fdt))
    lab = jnp.zeros((V,), ldt)
    nbr = jnp.asarray(rng.randint(0, V, (V, E)).astype(np.int32))
    rev = jnp.zeros((V, E), jnp.int32)
    ones = jnp.ones((V, E), bool)
    base = _analytic(V, E, backend, dtypes, budget)
    best, best_t = base, float("inf")
    for chunk in (None,) + tuple(
            c for c in CHUNK_CANDIDATES if base.fused or backend != "pallas"):
        def run():
            return _engine.push_relabel(
                cf, sink_cf, excess, lab, nbr_local=nbr, rev_slot=rev,
                intra=ones, emask=ones, vmask=jnp.ones((V,), bool),
                cross_pushable=jnp.zeros((V, E), bool),
                cross_lab=jnp.zeros((V, E), ldt), d_inf=V + 2,
                max_iters=8, backend=backend, chunk_iters=chunk,
                interpret=False)
        run()                                  # compile
        t0 = time.perf_counter()
        run().iters.block_until_ready()
        dt = time.perf_counter() - t0
        if dt < best_t:
            best_t = dt
            best = dataclasses.replace(base, engine_chunk_iters=chunk,
                                       mode="measured")
    return best


def tune(V: int, E: int, *, backend: str = "xla",
         dtypes: _dt.KernelDtypes | None = None,
         vmem_budget_bytes: int | None = None,
         cache: str | Path | None = None,
         measure: bool | None = None) -> TunedConfig:
    """Resolve the tuned engine configuration for one key, cached.

    A cache hit returns the stored decision verbatim (zero search); a miss
    searches (analytic under interpret / CPU, measured on a real TPU) and
    persists the winner.  ``measure=None`` auto-selects measurement exactly
    when the DMA-capable real backend is present.
    """
    kd = _dt.WIDE if dtypes is None else dtypes
    budget = (_pr.FUSED_VMEM_BUDGET_BYTES if vmem_budget_bytes is None
              else vmem_budget_bytes)
    key = tune_key(V, E, backend, kd)
    path = cache_path(cache)
    store = _load_cache(path)
    hit = store.get(key)
    if hit is not None:
        try:
            return TunedConfig(**hit)
        except TypeError:
            pass                               # stale schema: re-tune
    if measure is None:
        measure = _pr.dma_overlap_supported()
    tc = (_measured if measure else _analytic)(V, E, backend, kd, budget)
    store[key] = tc.as_dict()
    _store_cache(path, store)
    return tc


def tuned_sweep_config(cfg, meta, *, vmem_budget_bytes: int | None = None,
                       cache: str | Path | None = None):
    """Apply the tuner to a ``SweepConfig`` for one prepared problem/bucket.

    ``meta`` is a ``GraphMeta`` or ``BatchMeta`` (both carry
    ``region_size``/``max_degree``/``kernel_dtypes``).  A user-pinned
    ``engine_chunk_iters`` is left untouched — explicit knobs beat tuning.
    """
    if cfg.engine_chunk_iters is not None:
        return cfg
    tc = tune(meta.region_size, meta.max_degree,
              backend=cfg.engine_backend, dtypes=meta.kernel_dtypes,
              vmem_budget_bytes=vmem_budget_bytes, cache=cache)
    return dataclasses.replace(cfg, engine_chunk_iters=tc.engine_chunk_iters)
