"""Region executors: ONE generic sweep loop over every solve route.

The paper's algorithm is a single loop — "discharge all regions, exchange
boundary flow/labels, apply heuristics, repeat until no vertex is active"
(Alg. 1/2) — but the repo grew three hand-kept copies of it: the
host-loop/device-resident driver (``core.sweep``), the batched
multi-instance driver (``core.batch``) and the sharded SPMD driver
(``core.distributed``).  This module factors the loop out.

A :class:`RegionExecutor` is one *strategy* for advancing a solve by one
sweep (conceptually: ``discharge_all`` -> ``exchange_boundary`` ->
relabel/gap hooks -> ``converged`` -> ``stats``; the concrete drivers fuse
those stages into one traced program per sweep, so the executor interface
exposes them at sweep granularity):

``init_carry(state)``
    The statistics/convergence carry threaded through the loop.
``one_sweep(state, carry, limit)``
    Discharge every region once, fuse boundary flow, run the heuristic
    hooks, refresh the carry (traceable: runs under ``lax.while_loop``).
``keep_running(state, carry, limit)``
    The loop predicate (traceable).
``progress(host_carry, limit)``
    Host-side view of a fetched carry -> ``(sweeps_done, still_running)``.
``sweep_host(state, idx)``
    One sweep for the host-loop driver, returning ``(state, obs)`` with
    ``obs[0]`` the post-sweep active count (the convergence observable).

Two generic drivers run any executor to completion:

* :func:`run_host` — one traced program + one host sync per sweep (the
  paper's streaming accounting point), with an optional ``on_sweep`` hook
  called at every sweep boundary (the conformance suite's mid-solve
  invariant checker);
* :func:`run_device` — the whole loop inside ``lax.while_loop`` on device
  (:func:`while_sweeps`), one host sync per ``host_sync_every`` sweeps.

Executors are frozen dataclasses, hashable on ``(meta, cfg)`` — they ARE
the jit static argument of the generic device chunk, so the compile-cache
semantics (``trace_count``-based ``Solver.cache_info``) are unchanged: a
re-solve on a known shape reuses the executable without retracing.

Feature support is declared, not buried: every executor carries a
:class:`Capabilities` record, and :meth:`RegionExecutor.validate` turns an
unsupported ``SweepConfig`` into one consistent
:class:`UnsupportedFeatureError` at the interface (a ``ValueError`` and a
``NotImplementedError``) instead of a silent fallback or a deep-driver
raise.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

_I32 = jnp.int32


# --------------------------------------------------------------------------
# test-only fault hook (core.resilience.FaultPlan)
# --------------------------------------------------------------------------
# Every generic driver fires the installed hook at its host boundaries —
# after each sweep in run_host, after each device_get in run_device — so
# the SAME deterministic fault matrix (raise at sweep k, corrupt labels,
# preemption, VMEM overflow) exercises every executor route.  The hook may
# raise (the injected failure) or return a replacement state (corruption).
# Production solves never install one; install via
# ``resilience.fault_injection`` (a context manager that restores it).

_FAULT_HOOK: Callable | None = None


def set_fault_hook(hook: Callable | None) -> Callable | None:
    """Install ``hook(route, state, sweeps_done)``; returns the previous
    hook so callers (the ``fault_injection`` context manager) can restore
    it.  ``route`` is ``"host"`` or ``"device"``."""
    global _FAULT_HOOK
    prev, _FAULT_HOOK = _FAULT_HOOK, hook
    return prev


def _fire_fault_hook(route: str, state, sweeps_done: int):
    if _FAULT_HOOK is None:
        return state
    out = _FAULT_HOOK(route, state, sweeps_done)
    return state if out is None else out


# --------------------------------------------------------------------------
# capability flags + the one consistent error surface
# --------------------------------------------------------------------------

class UnsupportedFeatureError(ValueError, NotImplementedError):
    """A ``SweepConfig`` requests a feature its executor does not implement.

    Subclasses ``ValueError`` (the historical raise of the batched front
    ends, kept for callers that catch it) and ``NotImplementedError`` (what
    the capability actually is: one code path away, not a user error).
    """

    def __init__(self, executor: str, feature: str, hint: str):
        self.executor = executor
        self.feature = feature
        super().__init__(
            f"the {executor} executor does not support {FEATURE_DOC[feature]}"
            f" ({feature}); {hint}")


@dataclass(frozen=True)
class Capabilities:
    """What a :class:`RegionExecutor` can run (True = supported).

    ``parallel``/``sequential``/``boundary_relabel``/``partial_discharge``/
    ``global_gap`` map 1:1 onto ``SweepConfig`` knobs and are validated
    against it; ``batched``/``warm_start``/``device_resident``/``host_loop``
    document the driver surface (see the capability table in
    ARCHITECTURE.md).
    """

    parallel: bool = True            # Alg. 2 sweeps (cfg.parallel=True)
    sequential: bool = True          # Alg. 1 sweeps (cfg.parallel=False)
    boundary_relabel: bool = True    # Sec. 6.1 heuristic
    partial_discharge: bool = True   # Sec. 6.2 staged augmentation
    global_gap: bool = True          # Sec. 5.1 heuristic
    batched: bool = False            # leading instance axis
    warm_start: bool = True          # resume from a resident preflow
    device_resident: bool = True     # lax.while_loop multi-sweep driver
    host_loop: bool = True           # one program + one sync per sweep


FEATURE_DOC = {
    "parallel": "parallel sweeps (Alg. 2)",
    "sequential": "sequential sweeps (Alg. 1)",
    "boundary_relabel": "the boundary-relabel heuristic (Sec. 6.1)",
    "partial_discharge": "partial discharges (Sec. 6.2)",
    "global_gap": "the global gap heuristic (Sec. 5.1)",
    "batched": "a leading instance axis",
    "warm_start": "warm-started solves",
    "device_resident": "the device-resident multi-sweep driver",
    "host_loop": "the host-loop driver",
}

_HINTS = {
    "parallel": "set parallel=False: the streaming executor visits staged "
                "regions one at a time (Alg. 1 order) by construction",
    "sequential": "use the local executor (sweep.solve) for Alg. 1 sweeps",
    "boundary_relabel": "use the local executor (sweep.solve) for the "
                        "boundary-relabel heuristic",
}


def required_features(cfg) -> tuple[str, ...]:
    """The :class:`Capabilities` flags a ``SweepConfig`` actually exercises."""
    out = []
    if cfg.parallel:
        out.append("parallel")
    if not cfg.parallel:
        out.append("sequential")
    if cfg.use_boundary_relabel:
        out.append("boundary_relabel")
    if cfg.partial_discharge:
        out.append("partial_discharge")
    if cfg.use_global_gap:
        out.append("global_gap")
    return tuple(out)


# --------------------------------------------------------------------------
# the executor interface
# --------------------------------------------------------------------------

class RegionExecutor(abc.ABC):
    """One strategy for advancing a region-discharge solve by one sweep."""

    name: str = "abstract"
    capabilities: Capabilities = Capabilities()

    # True: the generic host loop checks convergence BEFORE each sweep (and
    # a converged entry state runs zero sweeps); False: the check happens
    # after the sweep (a converged entry still runs one no-op sweep) —
    # the two historical driver semantics, preserved bit-exactly.
    entry_check: bool = True

    @classmethod
    def validate(cls, cfg) -> None:
        """Fail fast (one consistent message) on unsupported features."""
        for feat in required_features(cfg):
            if not getattr(cls.capabilities, feat):
                raise UnsupportedFeatureError(
                    cls.name, feat,
                    _HINTS.get(feat, "see Capabilities in core/executor.py"))

    # -- traceable pieces (run under jit / lax.while_loop) -----------------

    @abc.abstractmethod
    def init_carry(self, state) -> tuple:
        """Statistics/convergence carry at sweep 0 (eager, pre-loop)."""

    @abc.abstractmethod
    def one_sweep(self, state, carry, limit):
        """Advance one sweep: discharge all regions, exchange boundary
        flow/labels, run relabel/gap hooks, update the carry."""

    @abc.abstractmethod
    def keep_running(self, state, carry, limit):
        """Loop predicate: not converged and the sweep budget remains."""

    # -- host-side pieces ---------------------------------------------------

    @abc.abstractmethod
    def num_active(self, state):
        """Convergence observable (scalar active-vertex count)."""

    @abc.abstractmethod
    def sweep_host(self, state, idx):
        """One sweep for the host-loop driver -> ``(state, obs)``;
        ``obs[0]`` must be the post-sweep active count."""

    @abc.abstractmethod
    def progress(self, host_carry, limit):
        """Fetched carry -> ``(sweeps_done: int, still_running: bool)``."""

    def note_trace(self) -> None:
        """Bump the owning module's trace counter (compile-cache stats)."""


# --------------------------------------------------------------------------
# the ONE generic sweep loop (device + host drivers)
# --------------------------------------------------------------------------

def while_sweeps(ex: RegionExecutor, state, carry, limit):
    """The generic loop itself: run sweeps until ``keep_running`` fails.

    Pure traced code — usable directly under ``jax.jit`` (the local and
    batched device chunks) and under ``shard_map`` (the sharded SPMD
    program), which is how all three drivers share it.
    """

    def cond(c):
        st, cr = c
        return ex.keep_running(st, cr, limit)

    def body(c):
        st, cr = c
        return ex.one_sweep(st, cr, limit)

    return jax.lax.while_loop(cond, body, (state, carry))


@partial(jax.jit, static_argnums=(0,))
def _device_chunk(ex: RegionExecutor, state, carry, limit):
    """One host-sync chunk of the device-resident driver.

    Jitted with the executor as the (hashable) static argument — the
    compile cache is keyed on ``(type(ex), meta, cfg)``, exactly the keying
    of the pre-unification per-driver programs.
    """
    ex.note_trace()
    return while_sweeps(ex, state, carry, limit)


@partial(jax.jit, static_argnums=(0,))
def _slot_swap(ex: "BatchedExecutor", state, carry, slot, inst):
    """Swap one instance into slot ``slot`` of a live batch (see
    ``BatchedExecutor.swap_slot``).  One compiled program per bucket shape,
    reused for every admission into that bucket."""
    ex.note_trace()
    state = jax.tree_util.tree_map(
        lambda dst, src: dst.at[slot].set(src[0]), state, inst)
    sweeps, iters, launches, _ = carry
    zero = jnp.zeros((), _I32)
    sweeps = sweeps.at[slot].set(zero)
    iters = iters.at[slot].set(zero)
    return state, (sweeps, iters, launches, ex.num_active(state))


def run_device(ex: RegionExecutor, state, limit, host_sync_every,
               chunk: Callable | None = None, carry0=None,
               on_sync: Callable | None = None):
    """Device-resident driver: the loop lives in ``lax.while_loop``; the
    host is re-entered once per ``host_sync_every`` sweeps (None: once per
    solve).  Returns ``(state, final_host_carry, host_syncs)``.

    ``limit`` — total sweep budget: a python int, or a per-instance
    ``np.int32[B]`` for the batched executor.  ``chunk`` overrides the
    generic jitted chunk (the sharded route passes its memoized
    mesh-bound SPMD program).  ``carry0`` overrides ``ex.init_carry`` —
    the checkpoint-resume entry: a carry restored from a snapshot
    continues counters/rings (and the sweep index the executors thread
    through ``carry[0]``) exactly where the interrupted solve stopped.
    ``on_sync(state, host_carry, host_syncs)`` — optional hook fired at
    every host-sync boundary (after the ``device_get``), the
    checkpoint-capture point of the device-resident routes.
    """
    if chunk is None:
        chunk = partial(_device_chunk, ex)
    carry = ex.init_carry(state) if carry0 is None else carry0
    syncs = 0
    done = 0 if carry0 is None \
        else ex.progress(jax.device_get(carry), limit)[0]
    while True:
        cap = limit if host_sync_every is None \
            else np.minimum(limit, done + host_sync_every)
        state, carry = chunk(state, carry, jnp.asarray(cap, _I32))
        host = jax.device_get(carry)
        syncs += 1
        done, running = ex.progress(host, limit)
        if on_sync is not None:
            on_sync(state, host, syncs)
        state = _fire_fault_hook("device", state, done)
        if not running:
            break
    return state, host, syncs


def run_host(ex: RegionExecutor, state, limit,
             sweep: Callable | None = None,
             on_sweep: Callable | None = None,
             start: int = 0,
             on_obs: Callable | None = None):
    """Host-loop driver: one traced program + one host sync per sweep.

    ``on_sweep(state, sweeps_done)`` — optional hook called at every sweep
    boundary (after the sweep's device program, before the next), the
    attachment point of the conformance suite's mid-solve invariant
    checker.  ``sweep`` overrides ``ex.sweep_host`` (the sharded route
    passes its memoized mesh-bound program).  ``start`` — first sweep
    index (checkpoint resume: the loop continues at the interrupted
    solve's absolute sweep count).  ``on_obs(state, sweeps_done, trace,
    active_pre)`` — optional hook fired after every sweep's fetch with the
    LIVE observation lists, the checkpoint-capture point of the host
    route (it sees this incarnation's full accounting so far).

    Returns ``(state, trace, active_pre, host_syncs, sweeps)`` where
    ``trace`` is the list of fetched per-sweep observations,
    ``active_pre`` the pre-sweep active counts (the host-loop
    ``active_curve``, only populated for ``entry_check`` executors) and
    ``sweeps`` the absolute sweep index reached (counts from ``start``).
    """
    if sweep is None:
        sweep = ex.sweep_host
    trace: list[tuple] = []
    active_pre: list[int] = []
    syncs = 0
    n_act = None
    if ex.entry_check:
        n_act = int(jax.device_get(ex.num_active(state)))
        syncs += 1
    idx = start
    while idx < limit:
        if ex.entry_check:
            active_pre.append(n_act)
            if n_act == 0:
                break
        state, obs = sweep(state, idx)
        host_obs = tuple(int(x) for x in jax.device_get(obs))
        syncs += 1
        idx += 1
        trace.append(host_obs)
        n_act = host_obs[0]
        # on_obs (the checkpoint capture) before on_sweep: a hook that
        # aborts the solve (deadline enforcement) leaves the boundary
        # durably checkpointed
        if on_obs is not None:
            on_obs(state, idx, trace, active_pre)
        if on_sweep is not None:
            on_sweep(state, idx)
        state = _fire_fault_hook("host", state, idx)
        if not ex.entry_check and n_act == 0:
            break
    return state, trace, active_pre, syncs, idx


# --------------------------------------------------------------------------
# the three executors
# --------------------------------------------------------------------------
# The sweep bodies stay in their home modules (they ARE those modules'
# subject matter); the executors import them lazily to break the
# module-level cycle (sweep/batch/distributed import this module for the
# generic loop and the validation surface).

@dataclass(frozen=True)
class LocalExecutor(RegionExecutor):
    """Single-instance solve on the local device (``core.sweep``).

    Carry layout (the device-resident statistics mirror): ``(sweep_idx,
    engine_iters, engine_launches, regions_discharged, flow_ring [R],
    active_ring [R], n_active)``.
    """

    meta: Any
    cfg: Any

    name = "local"
    capabilities = Capabilities(batched=False)
    entry_check = True

    def _sweep_mod(self):
        from repro.core import sweep
        return sweep

    def note_trace(self) -> None:
        self._sweep_mod()._bump_trace()

    def num_active(self, state):
        sw = self._sweep_mod()
        return sw.num_active(self.meta, state, self.cfg)

    def init_carry(self, state) -> tuple:
        z = jnp.zeros((), _I32)
        ring = jnp.zeros((self.cfg.stats_ring_size,), _I32)
        return (z, z, z, z, ring, ring, self.num_active(state).astype(_I32))

    def one_sweep(self, state, carry, limit):
        sw = self._sweep_mod()
        meta, cfg = self.meta, self.cfg
        idx, it, ln, dc, fr, ar, n_act = carry
        R = cfg.stats_ring_size
        ar = ar.at[idx % R].set(n_act)
        if cfg.parallel:
            state, dit, dln = sw.parallel_sweep(meta, state, cfg, idx)
            ddc = _I32(meta.num_regions)
        else:
            state, dit, dln, ddc = sw.sequential_sweep(meta, state, cfg, idx)
        n_act = self.num_active(state).astype(_I32)
        fr = fr.at[idx % R].set(state.flow_to_t)
        return state, (idx + 1, it + dit, ln + dln, dc + ddc, fr, ar, n_act)

    def keep_running(self, state, carry, limit):
        idx, n_act = carry[0], carry[-1]
        return (idx < limit) & (n_act > 0)

    def progress(self, host_carry, limit):
        idx, n_act = host_carry[0], host_carry[-1]
        return int(idx), int(n_act) != 0 and int(idx) < int(limit)

    def sweep_host(self, state, idx):
        sw = self._sweep_mod()
        meta, cfg = self.meta, self.cfg
        sweep_idx = jnp.asarray(idx, _I32)
        if cfg.parallel:
            state, iters, launches = sw.parallel_sweep(
                meta, state, cfg, sweep_idx)
            disc = _I32(meta.num_regions)
        else:
            state, iters, launches, disc = sw.sequential_sweep(
                meta, state, cfg, sweep_idx)
        obs = (self.num_active(state), state.flow_to_t, iters, launches,
               disc)
        return state, obs


@dataclass(frozen=True)
class BatchedExecutor(RegionExecutor):
    """Multi-instance solve over a leading instance axis (``core.batch``).

    Carry layout: ``(sweeps [B], engine_iters [B], engine_launches,
    n_active [B])`` — per-instance convergence flags live in the loop
    (``run = (sweeps < limit) & (n_act > 0)``), so a converged instance is
    frozen by selects and costs the engine's O(1) early exit inside the
    shared launch.  Device-resident only: the whole point of the batch is
    sharing one launch/sync stream, which a per-sweep host loop would
    forfeit.
    """

    bmeta: Any
    cfg: Any

    name = "batched"
    capabilities = Capabilities(
        sequential=False, boundary_relabel=False, batched=True,
        host_loop=False)
    entry_check = True

    def _batch_mod(self):
        from repro.core import batch
        return batch

    def note_trace(self) -> None:
        self._batch_mod()._bump_trace()

    def _d_inf(self, state):
        return state.d_inf_ard if self.cfg.method == "ard" \
            else state.d_inf_prd

    def num_active(self, state):
        return self._batch_mod().num_active_batch(state, self._d_inf(state))

    def init_carry(self, state) -> tuple:
        zb = jnp.zeros((self.bmeta.num_instances,), _I32)
        return (zb, zb, jnp.zeros((), _I32), self.num_active(state))

    def one_sweep(self, state, carry, limit):
        bt = self._batch_mod()
        sweeps, it, ln, n_act = carry
        run = (sweeps < limit) & (n_act > 0)                    # [B]
        st_in = state.replace(
            excess=jnp.where(run[:, None, None], state.excess, 0))
        new, dit, dln = bt._parallel_sweep_batch(
            self.bmeta, self.cfg, st_in, sweeps, run)
        w3 = run[:, None, None, None]
        w2 = run[:, None, None]
        state = state.replace(
            cf=jnp.where(w3, new.cf, state.cf),
            sink_cf=jnp.where(w2, new.sink_cf, state.sink_cf),
            excess=jnp.where(w2, new.excess, state.excess),
            d=jnp.where(w2, new.d, state.d),
            flow_to_t=jnp.where(run, new.flow_to_t, state.flow_to_t))
        n_act = self.num_active(state)
        return state, (sweeps + run.astype(_I32),
                       it + jnp.where(run, dit, 0), ln + dln, n_act)

    def keep_running(self, state, carry, limit):
        sweeps, n_act = carry[0], carry[-1]
        return ((sweeps < limit) & (n_act > 0)).any()

    def progress(self, host_carry, limit):
        sweeps, n_act = host_carry[0], host_carry[-1]
        done = int(sweeps.max(initial=0))
        running = bool(((n_act > 0) & (sweeps < limit)).any())
        return done, running

    def sweep_host(self, state, idx):
        raise UnsupportedFeatureError(
            self.name, "host_loop",
            "the batched driver is device-resident by construction")

    # -- continuous batching -------------------------------------------------

    def swap_slot(self, state, carry, slot, inst_state):
        """Admit one instance into bucket slot ``slot`` of a live batch.

        ``inst_state`` — a ``BatchState`` with instance axis B == 1 and the
        same (K, V, E, X) bucket dims (``graph.pack_built`` on one build):
        every field (topology, cross tables, per-instance ceilings, flow
        state) is written into slot ``slot``, and the carry's per-instance
        counters for that slot reset to zero, with ``n_active`` recomputed
        so the slot's run flag (``sweeps < limit & n_act > 0``) turns live
        on the next chunk.  The previous occupant is overwritten — the
        caller (the serving tier's continuous-batching loop) only swaps
        into slots whose instance has been harvested or cancelled.  Returns
        ``(state, carry)``; one compiled swap program per bucket shape.
        """
        return _slot_swap(self, state, carry, jnp.asarray(slot, _I32),
                          inst_state)


@dataclass(frozen=True)
class ShardedExecutor(RegionExecutor):
    """SPMD solve with regions sharded over a mesh (``core.distributed``).

    The traceable pieces run *per shard under shard_map*: ``one_sweep``
    wraps the collective sweep body (all-gather/psum boundary exchange),
    and the psum'd global active count keeps the loop predicate uniform
    across shards.  Loop carry: ``(sweep_idx, start_idx, n_active)`` —
    ``start_idx`` pins the legacy semantics that a converged entry state
    still runs one (no-op) sweep, which is also why ``entry_check`` is
    False for the host loop.  The host-visible chunk carry is
    ``(sweep_idx, n_active)``.
    """

    meta: Any
    cfg: Any
    axes: tuple
    exchange: str = "full"

    name = "sharded"
    capabilities = Capabilities(sequential=False, boundary_relabel=False)
    entry_check = False

    def _dist_mod(self):
        from repro.core import distributed
        return distributed

    def note_trace(self) -> None:
        self._dist_mod()._bump_trace()

    def _d_inf(self):
        return self.meta.d_inf_ard if self.cfg.method == "ard" \
            else self.meta.d_inf_prd

    def num_active(self, state):
        # per-shard body: psum'd global count, replicated across shards
        act = ((state.excess > 0) & (state.d < self._d_inf())
               & state.vmask).sum()
        return jax.lax.psum(act, self.axes).astype(_I32)

    def init_carry(self, state) -> tuple:
        # host-visible chunk carry; run_device feeds carry[0] back as the
        # next chunk's start index through the mesh-bound program
        return (jnp.zeros((), _I32), jnp.ones((), _I32))

    def loop_carry(self, state, start_idx) -> tuple:
        return (start_idx, start_idx, self.num_active(state))

    def one_sweep(self, state, carry, limit):
        idx, start, _ = carry
        state, n_act = self._dist_mod()._one_sweep_local(
            self.meta, self.cfg, self.axes, state, idx, self.exchange)
        return state, (idx + 1, start, n_act)

    def keep_running(self, state, carry, limit):
        idx, start, n_act = carry
        # (idx == start) keeps the legacy host-loop semantics on an
        # already-converged input: one (no-op) sweep still runs, so every
        # driver reports identical sweep counts in every case
        return (idx < limit) & ((n_act > 0) | (idx == start))

    def progress(self, host_carry, limit):
        idx, n_act = host_carry[0], host_carry[-1]
        return int(idx), int(n_act) != 0 and int(idx) < int(limit)

    def sweep_host(self, state, idx):
        raise RuntimeError("the sharded host loop runs through the memoized "
                           "mesh-bound sweep program (distributed."
                           "make_sharded_sweep), passed to run_host")


@dataclass(frozen=True)
class StreamingExecutor(RegionExecutor):
    """Out-of-core single-instance solve: regions staged one at a time
    from a disk-backed spill pool (``repro.stream``).

    The state threaded through the generic host loop is a
    ``stream.StreamState`` (spill-pool handle + resident-set manager +
    the |B|-sized boundary arrays), NOT a ``FlowState`` — at any moment
    only ``max_resident_regions`` [V, E] slabs are in memory.  Host-loop
    only: the premise is that the instance does not fit resident, so
    there is nothing for a device-side ``while_loop`` to hold.
    Sequential sweeps only: the paper's streaming mode IS Alg. 1 —
    regions are visited in order and boundary flow/labels apply
    immediately, which is what makes one-region residency sufficient.
    Global gap needs every label in memory at once, so it is declared
    unsupported rather than approximated.
    """

    meta: Any
    cfg: Any

    name = "streaming"
    capabilities = Capabilities(
        parallel=False, boundary_relabel=False, global_gap=False,
        device_resident=False)
    entry_check = True

    def _stream_mod(self):
        from repro.stream import executor as stream_executor
        return stream_executor

    def note_trace(self) -> None:
        self._stream_mod()._bump_trace()

    def num_active(self, state):
        return state.num_active()

    def init_carry(self, state) -> tuple:
        raise UnsupportedFeatureError(
            self.name, "device_resident",
            "the streaming executor runs through the host loop (run_host)")

    def one_sweep(self, state, carry, limit):
        raise UnsupportedFeatureError(
            self.name, "device_resident",
            "the streaming executor runs through the host loop (run_host)")

    def keep_running(self, state, carry, limit):
        raise UnsupportedFeatureError(
            self.name, "device_resident",
            "the streaming executor runs through the host loop (run_host)")

    def progress(self, host_carry, limit):
        raise UnsupportedFeatureError(
            self.name, "device_resident",
            "the streaming executor runs through the host loop (run_host)")

    def sweep_host(self, state, idx):
        return self._stream_mod().stream_sweep(state, idx)


EXECUTORS = (LocalExecutor, BatchedExecutor, ShardedExecutor,
             StreamingExecutor)
