"""Fault-tolerant solves: sweep-boundary checkpoints, a solve supervisor
with deterministic fault injection, and a graceful-degradation ladder.

The paper's deployment model is failure-prone by construction — regions
"loaded into the memory one-by-one or located on separate machines in a
network" — so a solve must survive preemption, device loss and kernel
lowering/VMEM failures instead of losing every sweep.  Three layers:

**Sweep-boundary checkpoints.**  A :class:`SolveCheckpoint` captures the
mutable flow state (``cf``/``sink_cf``/``excess``/``d``/``flow_to_t``),
the accumulated :class:`~repro.core.sweep.SweepStats` accounting
(counters + curve tails), the warm-start flow offset of the owning
session handle, and a config/layout fingerprint.  Every route exposes a
capture point at its natural host boundary — the ``on_obs`` hook of the
host loop, the ``on_sync`` hook of the device-resident/batched/sharded
loops — and writes snapshots atomically (write-to-temp, fsync-free
``os.rename`` publish: a crashed writer never corrupts the latest
checkpoint).  ``sweep.solve(resume_from=)`` / ``handle.solve(
resume_from=)`` / ``Solver.solve_many(resume_from=)`` /
``distributed.solve_sharded(resume_from=)`` continue BIT-EXACTLY: an
interrupted-then-resumed solve matches the uninterrupted one on flow,
labels, sweeps and engine iterations (asserted per boundary in
tests/test_resilience.py).

**Solve supervisor + fault injection.**  :class:`SolveSupervisor` wraps
any route with checkpoint-every-N-sweeps, retry with exponential backoff
and resume-from-latest.  The deterministic :class:`FaultPlan` (raise at
sweep k, corrupt boundary-exchange labels, simulate preemption, force a
VMEM overflow) installs into the test-only hook of ``core.executor`` via
:func:`fault_injection`, so every executor is exercised under the same
fault matrix.

**Degradation ladder.**  Kernel lowering/VMEM failures degrade the engine
configuration one rung at a time — pallas-fused -> xla-fused ->
xla-unfused (:func:`degrade_config`) — re-running the route on the next
rung; every rung is bit-exact by the repo's engine-equivalence invariant,
and every degradation is recorded in ``SweepStats.degraded`` (never
silent).  The engine's build-time static VMEM fallback is surfaced the
same way (:func:`vmem_fallback_note`).

This module also owns the ONE atomic-snapshot implementation
(:func:`snapshot_save`/:func:`snapshot_restore`/:func:`snapshot_latest`),
adopted from the orphan ``train/checkpoint.py`` scaffolding — which now
delegates here.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.core import executor as _executor

# --------------------------------------------------------------------------
# error surface
# --------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """A deterministic test fault raised by a :class:`FaultPlan`."""


class PreemptionError(InjectedFault):
    """Simulated preemption: the solve process is torn down mid-solve."""


class VmemOverflowError(RuntimeError):
    """Kernel region state exceeds the VMEM budget (real or injected) —
    a kernel-class failure the degradation ladder handles."""


class CheckpointMismatchError(ValueError):
    """A checkpoint's fingerprint does not match the solve it would resume
    (different method/heuristics, different problem layout)."""


# --------------------------------------------------------------------------
# degradation ladder: pallas-fused -> xla-fused -> xla-unfused
# --------------------------------------------------------------------------

KERNEL_LADDER = ("pallas-fused", "xla-fused", "xla-unfused")


def config_rung(cfg) -> str:
    """The ladder rung a ``SweepConfig``'s engine knobs sit on."""
    fused = "fused" if cfg.engine_chunk_iters is not None else "unfused"
    return f"{cfg.engine_backend}-{fused}"


def degrade_config(cfg):
    """One rung down — or ``None`` at the bottom (nothing left to shed).

    pallas anything -> same shape on xla (sheds the kernel lowering);
    xla-fused -> xla-unfused (sheds the chunked resident engine).  Every
    rung computes bit-identical results (the repo's engine-equivalence
    invariant), so degradation changes performance, never answers.
    """
    if cfg.engine_backend == "pallas":
        return dataclasses.replace(cfg, engine_backend="xla")
    if cfg.engine_chunk_iters is not None:
        return dataclasses.replace(cfg, engine_chunk_iters=None)
    return None


def is_kernel_failure(exc: BaseException) -> bool:
    """Best-effort classifier: does this exception look like a kernel
    lowering / VMEM / accelerator-resource failure (ladder-eligible)
    rather than a logic error or an injected control fault?"""
    if isinstance(exc, VmemOverflowError):
        return True
    if isinstance(exc, InjectedFault):
        return False
    msg = f"{type(exc).__name__}: {exc}"
    needles = ("RESOURCE_EXHAUSTED", "VMEM", "vmem", "Mosaic", "mosaic",
               "pallas", "Pallas", "lowering", "XlaRuntimeError")
    return any(n in msg for n in needles)


def run_with_degradation(run: Callable, cfg, notes: list[str]):
    """Run ``run(cfg)``, stepping down the ladder on kernel failures.

    Appends one note per degradation to ``notes`` (the caller surfaces
    them in ``SweepStats.degraded``).  Non-kernel failures and a ladder
    that bottoms out re-raise.  Returns ``run``'s result.
    """
    while True:
        try:
            return run(cfg)
        except Exception as exc:          # noqa: BLE001 — classified below
            nxt = degrade_config(cfg)
            if nxt is None or not is_kernel_failure(exc):
                raise
            notes.append(
                f"{config_rung(cfg)} -> {config_rung(nxt)}: "
                f"{type(exc).__name__}: {exc}")
            cfg = nxt


def vmem_fallback_note(cfg, region_size: int, max_degree: int,
                       dtypes=None) -> str | None:
    """Surface the engine's build-time static VMEM fallback.

    The fused pallas engine silently falls back to the blocked two-phase
    path when a region's resident state exceeds the VMEM budget
    (``kernels.push_relabel.fused_region_fits_vmem``); this returns the
    degradation note the drivers record in ``SweepStats.degraded`` so the
    fallback is visible (results are bit-exact either way).
    """
    if cfg.engine_backend != "pallas" or cfg.engine_chunk_iters is None:
        return None
    from repro.kernels import push_relabel as _pr
    if _pr.fused_region_fits_vmem(region_size, max_degree, dtypes=dtypes):
        return None
    return (f"pallas-fused: region state (V={region_size}, E={max_degree}) "
            f"exceeds the VMEM budget; engine uses the blocked two-phase "
            f"path (bit-exact)")


# --------------------------------------------------------------------------
# atomic pytree snapshots (the ONE implementation; train/checkpoint.py
# delegates here)
# --------------------------------------------------------------------------

MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out.append((path, leaf))
    return out


def snapshot_save(directory: str | Path, step: int, state: Any,
                  extra: dict | None = None) -> Path:
    """Atomically snapshot a pytree of arrays under ``<dir>/step_NNNNNNNN``.

    Every leaf is saved into one .npz together with a manifest recording
    tree structure, dtypes and shapes (bf16 stored as a raw uint16 view).
    The publish step is an atomic ``os.rename`` of the fully-written temp
    directory — a crashed writer never corrupts the latest snapshot,
    which is the property every resume path here relies on.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i:05d}"
        # bf16 has no numpy dtype: store raw uint16 view + dtype tag
        dtype = str(arr.dtype) if not hasattr(leaf, "dtype") \
            else str(leaf.dtype)
        if dtype == "bfloat16":
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": path, "key": key, "dtype": dtype,
             "shape": list(arr.shape)})
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def snapshot_latest(directory: str | Path) -> int | None:
    """Highest fully-published snapshot step in ``directory`` (or None)."""
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and not p.name.endswith(".tmp") \
                and (p / MANIFEST).exists():
            steps.append(int(p.name[5:]))
    return max(steps) if steps else None


def snapshot_manifest(directory: str | Path, step: int) -> dict:
    return json.loads(
        (Path(directory) / f"step_{step:08d}" / MANIFEST).read_text())


def _snapshot_arrays(directory: str | Path, step: int) -> tuple[dict, dict]:
    """(path -> numpy array, manifest) of one snapshot, dtype-restored."""
    path = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / MANIFEST).read_text())
    data = np.load(path / "arrays.npz")
    by_path = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if leaf["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        by_path[leaf["path"]] = arr
    return by_path, manifest


def snapshot_restore(directory: str | Path, step: int, like: Any,
                     shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) re-lays the arrays
    onto the *current* mesh — the elastic path.
    """
    by_path, _manifest = _snapshot_arrays(directory, step)
    like_leaves = _flatten_with_paths(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(like_leaves))
    out = []
    for (lpath, lleaf), sh in zip(like_leaves, shard_leaves):
        if lpath not in by_path:
            raise KeyError(f"checkpoint missing leaf {lpath!r}")
        arr = by_path[lpath]
        if tuple(arr.shape) != tuple(lleaf.shape):
            raise ValueError(
                f"shape mismatch for {lpath}: ckpt {arr.shape} "
                f"vs state {lleaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# solve checkpoints
# --------------------------------------------------------------------------

def solve_fingerprint(meta, cfg, salt: str = "") -> str:
    """Identity of the math a checkpoint belongs to.

    Hashes the problem layout (``GraphMeta``/``BatchMeta`` — all padded
    shapes and label ceilings), the *math-affecting* ``SweepConfig``
    fields (method, Alg. 1/2, heuristics) and an optional caller salt
    (the session front-end hashes ``Layout.part`` so two same-shaped
    problems do not cross-resume).  Engine-backend knobs, sweep budgets
    and accounting knobs are deliberately EXCLUDED: every backend rung and
    every route computes bit-identical states, so resuming a pallas-fused
    device-resident solve on the xla host loop — or after a degradation —
    is exact and allowed.
    """
    math_fields = ("method", "parallel", "partial_discharge",
                   "use_global_gap", "use_boundary_relabel")
    key = "|".join([repr(meta)]
                   + [f"{f}={getattr(cfg, f)!r}" for f in math_fields]
                   + [salt])
    return hashlib.sha256(key.encode()).hexdigest()[:16]


@dataclass(frozen=True)
class CheckpointPolicy:
    """Where and how often a route captures :class:`SolveCheckpoint`\\ s.

    ``every`` — sweep cadence: the host loop saves at each sweep boundary
    whose absolute index advanced >= ``every`` past the last save; the
    device-resident routes save at their ``host_sync_every`` boundaries
    under the same rule (a sync boundary is the only host re-entry they
    have).  ``flow_offset`` — the owning session handle's warm-start
    flow-value offset, recorded so a cross-process resume restores the
    handle bookkeeping.  ``salt`` — extra fingerprint input (the session
    front-end's layout digest).
    """

    directory: str | Path
    every: int = 5
    flow_offset: int = 0
    salt: str = ""

    def __post_init__(self):
        assert self.every >= 1


@dataclass
class SolveCheckpoint:
    """One resumable sweep-boundary snapshot of a solve.

    ``payload`` — the mutable device state (``cf``/``sink_cf``/``excess``/
    ``d``/``flow_to_t`` as host numpy arrays) plus the route's loop-carry
    scalars/arrays (``n_act``; per-instance ``sweeps``/``iters`` arrays on
    the batched route; on the streaming route the payload is the O(|B|)
    boundary layer plus the spill pool's per-region version vector — the
    region interiors themselves stay in the pool, already durable).
    ``stats`` — the accumulated ``SweepStats``
    accounting at the boundary (counters, curve tails, syncs, degradation
    notes).  ``sweeps`` — absolute sweep index of the boundary (max over
    instances on the batched route); doubles as the snapshot step, so
    ``snapshot_latest`` finds the furthest boundary.
    """

    fingerprint: str
    route: str               # "host" | "device" | "sharded" | "batch"
    #                          | "stream"
    sweeps: int
    payload: dict
    stats: dict
    flow_offset: int = 0


def state_payload(state) -> dict:
    """Host copies of the mutable flow-state fields (one device fetch)."""
    cf, sink_cf, excess, d, flow = jax.device_get(
        (state.cf, state.sink_cf, state.excess, state.d, state.flow_to_t))
    return {"cf": np.asarray(cf), "sink_cf": np.asarray(sink_cf),
            "excess": np.asarray(excess), "d": np.asarray(d),
            "flow_to_t": np.asarray(flow)}


def restore_state(state, payload: dict):
    """The inverse of :func:`state_payload` on a live state pytree."""
    import jax.numpy as jnp
    return state.replace(
        cf=jnp.asarray(payload["cf"]),
        sink_cf=jnp.asarray(payload["sink_cf"]),
        excess=jnp.asarray(payload["excess"]),
        d=jnp.asarray(payload["d"]),
        flow_to_t=jnp.asarray(payload["flow_to_t"]))


def save_checkpoint(directory: str | Path, ckpt: SolveCheckpoint) -> Path:
    """Atomically publish a checkpoint at step ``ckpt.sweeps``."""
    return snapshot_save(
        directory, ckpt.sweeps, ckpt.payload,
        extra={"kind": "solve_checkpoint", "fingerprint": ckpt.fingerprint,
               "route": ckpt.route, "sweeps": ckpt.sweeps,
               "stats": ckpt.stats, "flow_offset": ckpt.flow_offset})


def load_checkpoint(directory: str | Path,
                    step: int | None = None) -> SolveCheckpoint:
    """Load a checkpoint (the latest when ``step`` is None)."""
    if step is None:
        step = snapshot_latest(directory)
        if step is None:
            raise FileNotFoundError(
                f"no checkpoint under {directory!r}")
    payload, manifest = _snapshot_arrays(directory, step)
    extra = manifest["extra"]
    if extra.get("kind") != "solve_checkpoint":
        raise CheckpointMismatchError(
            f"snapshot {directory}/step_{step:08d} is not a solve "
            f"checkpoint")
    return SolveCheckpoint(
        fingerprint=extra["fingerprint"], route=extra["route"],
        sweeps=int(extra["sweeps"]), payload=payload,
        stats=extra["stats"], flow_offset=int(extra.get("flow_offset", 0)))


def latest_checkpoint(directory: str | Path) -> SolveCheckpoint | None:
    """The furthest published checkpoint, or None when none exist."""
    step = snapshot_latest(directory)
    return None if step is None else load_checkpoint(directory, step)


def checkpoint_converged(ckpt: SolveCheckpoint) -> bool:
    """True when the checkpoint was captured at a CONVERGED final boundary.

    The payload's ``n_act`` loop-carry records the active-vertex count at
    the boundary (per instance on the batched route): all-zero means the
    maximum preflow was already reached and there is nothing left to
    sweep, so a resume can return the restored state directly instead of
    re-entering the sweep loop (the sharded loop's converged-entry
    semantics would otherwise burn one no-op sweep).  A checkpoint without
    the carry (foreign/legacy payloads) conservatively counts as not
    converged.
    """
    n_act = ckpt.payload.get("n_act")
    if n_act is None:
        return False
    return bool((np.asarray(n_act) == 0).all())


def resolve_resume(resume_from, fingerprint: str) -> SolveCheckpoint | None:
    """Normalize a route's ``resume_from`` argument and verify identity.

    Accepts a :class:`SolveCheckpoint`, a checkpoint directory (loads the
    latest), or None.  Raises :class:`CheckpointMismatchError` when the
    checkpoint belongs to different math/layout than the solve it would
    resume.
    """
    if resume_from is None:
        return None
    if isinstance(resume_from, (str, Path)):
        resume_from = load_checkpoint(resume_from)
    if resume_from.fingerprint != fingerprint:
        raise CheckpointMismatchError(
            f"checkpoint fingerprint {resume_from.fingerprint} != solve "
            f"fingerprint {fingerprint}: the checkpoint was taken under "
            f"a different method/heuristic configuration or problem "
            f"layout and cannot resume this solve")
    return resume_from


# --------------------------------------------------------------------------
# deterministic fault injection
# --------------------------------------------------------------------------

@dataclass
class FaultPlan:
    """A deterministic fault fired at a sweep boundary.

    ``kind`` — ``"raise"`` (a generic mid-solve failure), ``"preempt"``
    (simulated preemption: :class:`PreemptionError`), ``"vmem_overflow"``
    (a kernel-class :class:`VmemOverflowError` the degradation ladder
    handles), or ``"corrupt_labels"`` (silently pins every boundary
    vertex's label at the ceiling — the boundary-exchange corruption that
    makes a solve "converge" to a WRONG answer, which the cut==flow
    certificate must catch).  Fires at the first boundary whose absolute
    sweep count reaches ``at_sweep``, at most ``times`` times (-1: every
    boundary from there on).  ``route`` optionally restricts firing to
    ``"host"`` or ``"device"`` boundaries.
    """

    kind: str
    at_sweep: int
    times: int = 1
    route: str | None = None
    fired: int = field(default=0, compare=False)

    def __post_init__(self):
        assert self.kind in ("raise", "preempt", "vmem_overflow",
                             "corrupt_labels"), self.kind

    def __call__(self, route: str, state, sweeps_done: int):
        if self.route is not None and route != self.route:
            return None
        if sweeps_done < self.at_sweep:
            return None
        if self.times >= 0 and self.fired >= self.times:
            return None
        self.fired += 1
        where = f"at sweep {sweeps_done} ({route} boundary)"
        if self.kind == "raise":
            raise InjectedFault(f"injected fault {where}")
        if self.kind == "preempt":
            raise PreemptionError(f"injected preemption {where}")
        if self.kind == "vmem_overflow":
            raise VmemOverflowError(
                f"injected VMEM overflow {where}: fused region state "
                f"exceeds the VMEM budget")
        # corrupt_labels: pin boundary labels at the ceiling — excess
        # trapped there goes inactive, the solve stops early with a
        # too-small flow, and check=True must refuse to certify it
        import jax.numpy as jnp

        from repro.core import dtypes as _dt
        inf = state.d.dtype.type(_dt.inf_label_for(state.d.dtype.name))
        d = jnp.where(state.is_boundary & state.vmask, inf, state.d)
        return state.replace(d=d)


@contextmanager
def fault_injection(plan: FaultPlan | Callable | None):
    """Install a fault plan into the executor hook for the ``with`` body.

    The previous hook is restored on exit, including on the injected
    exception itself — the hook never leaks across tests.
    """
    prev = _executor.set_fault_hook(plan)
    try:
        yield plan
    finally:
        _executor.set_fault_hook(prev)


# --------------------------------------------------------------------------
# the solve supervisor
# --------------------------------------------------------------------------

@dataclass
class RetryPolicy:
    """Exponential backoff schedule of the supervisor's retries.

    ``sleep`` is injectable so tests run the full schedule without wall
    time.  Delay of retry i (1-based): ``min(base * factor**(i-1), max)``.
    """

    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_factor: float = 2.0
    backoff_max: float = 2.0
    sleep: Callable = time.sleep


@dataclass
class SupervisorReport:
    """What one supervised solve went through."""

    attempts: int = 0
    resumes: int = 0
    backoffs: list = field(default_factory=list)
    failures: list = field(default_factory=list)


class SolveSupervisor:
    """Run any solve route to completion across failures.

    Wraps a ``runner(policy, resume_from) -> result`` closure (build one
    with :meth:`for_handle` or :meth:`for_batch`) with checkpoint-every-N
    sweeps, retry-with-exponential-backoff and resume-from-latest: each
    failed attempt sleeps the backoff, reloads the newest checkpoint the
    failed attempt published, and re-enters the route, which continues
    bit-exactly from that boundary.  Kernel-class failures are already
    absorbed one level down by the degradation ladder inside the routes
    (recorded in ``SweepStats.degraded``); what reaches the supervisor is
    the process-level failure matrix — preemptions, device loss, injected
    faults — plus anything the ladder could not shed.
    """

    def __init__(self, runner: Callable, *, checkpoint_dir: str | Path,
                 checkpoint_every: int = 5,
                 retry: RetryPolicy | None = None,
                 policy: CheckpointPolicy | None = None):
        self.runner = runner
        self.policy = policy if policy is not None else CheckpointPolicy(
            directory=checkpoint_dir, every=checkpoint_every)
        self.retry = retry or RetryPolicy()
        self.report = SupervisorReport()

    @classmethod
    def for_handle(cls, handle, *, mesh=None, axes=("regions",), **kw):
        """Supervise ``handle.solve()`` (host/device-resident/sharded)."""
        def runner(policy, resume_from):
            return handle.solve(mesh=mesh, axes=axes, checkpoint=policy,
                                resume_from=resume_from)
        return cls(runner, **kw)

    @classmethod
    def for_batch(cls, solver, items, parts=None, **kw):
        """Supervise ``solver.solve_many(items)`` (the batched route)."""
        def runner(policy, resume_from):
            return solver.solve_many(items, parts, checkpoint=policy,
                                     resume_from=resume_from)
        return cls(runner, **kw)

    def _latest(self) -> SolveCheckpoint | None:
        return latest_checkpoint(self.policy.directory)

    def solve(self, *, resume: bool | str = "auto"):
        """Drive the route to a result; raises only when retries exhaust.

        ``resume`` — ``"auto"``/True: start from the latest checkpoint in
        the policy directory when one exists (the restart-after-kill
        path); False: first attempt starts fresh (later retries still
        resume from what this run checkpointed).
        """
        resume_from = self._latest() if resume in ("auto", True) else None
        attempt = 0
        while True:
            self.report.attempts += 1
            try:
                return self.runner(self.policy, resume_from)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as exc:      # noqa: BLE001 — retried/re-raised
                attempt += 1
                self.report.failures.append(
                    f"{type(exc).__name__}: {exc}")
                if attempt > self.retry.max_retries:
                    raise
                delay = min(
                    self.retry.backoff_base
                    * self.retry.backoff_factor ** (attempt - 1),
                    self.retry.backoff_max)
                self.report.backoffs.append(delay)
                self.retry.sleep(delay)
                resume_from = self._latest()
                if resume_from is not None:
                    self.report.resumes += 1


__all__ = [
    "CheckpointMismatchError", "CheckpointPolicy", "FaultPlan",
    "InjectedFault", "KERNEL_LADDER", "PreemptionError", "RetryPolicy",
    "SolveCheckpoint", "SolveSupervisor", "SupervisorReport",
    "VmemOverflowError", "checkpoint_converged", "config_rung",
    "degrade_config",
    "fault_injection", "is_kernel_failure", "latest_checkpoint",
    "load_checkpoint", "resolve_resume", "restore_state",
    "run_with_degradation", "save_checkpoint", "snapshot_latest",
    "snapshot_manifest", "snapshot_restore", "snapshot_save",
    "solve_fingerprint", "state_payload", "vmem_fallback_note",
]
