"""Distributed P-ARD/P-PRD under shard_map — regions sharded over devices.

This is the paper's parallel mode mapped onto a TPU mesh: each device owns a
contiguous block of regions (rows of every [K, V, E] array); one sweep is a
single SPMD program whose only cross-device traffic is

  * an all-gather of the distance labels d[K, V] (the paper's boundary-label
    messages), and
  * a psum of the flat cross-arc flow deltas [X] plus the acceptance fusion
    (the paper's boundary-flow messages),

i.e. exactly the paper's "communication ∝ boundary" property — the roofline
collective term of the maxflow workload is the boundary exchange and nothing
else.  Region discharges themselves contain no collectives (they are the
paper's independent region computations), so compute/communication overlap
is naturally available to the scheduler.

This module provides the sharded one-sweep program plus spec builders for
the multi-pod dry-run; the solve loop itself is the generic region-executor
loop of ``core.executor`` (``ShardedExecutor`` + ``run_host``/
``run_device``), shared with the local and batched drivers.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.5: public top-level API
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: replication checking off on every JAX.

    The checker kwarg was renamed (check_rep -> check_vma) across JAX
    releases; we need it off because the sweep body mixes replicated
    (cross-arc tables) and sharded (region) operands.
    """
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def _axis_size(a):
    """jax.lax.axis_size with a pre-0.5 fallback (psum of ones)."""
    try:
        return jax.lax.axis_size(a)
    except AttributeError:
        return jax.lax.psum(1, a)

from repro.core import executor as _executor
from repro.core import heuristics
from repro.core import resilience as _res
from repro.core.ard import ard_discharge_batched
from repro.core.graph import FlowState, GraphMeta, INF_LABEL
from repro.core.labels import GAP_HIST_CAP
from repro.core.prd import prd_discharge_batched
from repro.core.sweep import SweepConfig

_I32 = jnp.int32

# bumped once per trace of the sharded one-sweep body — part of the session
# front-end's combined compile-cache observable (Solver.cache_info)
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def _bump_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def region_axis_sharding(mesh: Mesh, axes) -> dict:
    """PartitionSpecs for a FlowState sharded over its region axis."""
    kv = P(axes)                     # [K, V]   arrays
    kve = P(axes)                    # [K, V, E] arrays
    rep = P()
    return dict(
        nbr_region=kve, nbr_local=kve, rev_slot=kve, emask=kve, vmask=kv,
        is_boundary=kv, cross_src=rep, cross_dst=rep, cross_group=rep,
        cross_valid=rep, cross_src_arc=rep, cross_dst_arc=rep,
        cross_src_vtx=rep, cross_dst_vtx=rep,
        cf=kve, sink_cf=kv, excess=kv, d=kv, flow_to_t=rep,
    )


def flowstate_shardings(mesh: Mesh, axes) -> FlowState:
    spec = region_axis_sharding(mesh, axes)
    return FlowState(**{k: NamedSharding(mesh, v) for k, v in spec.items()})


def _one_sweep_local(meta: GraphMeta, cfg: SweepConfig, axes,
                     state: FlowState, sweep_idx,
                     exchange: str = "full"):
    """Per-shard body of one parallel sweep (runs under shard_map).

    ``exchange`` — "full": all-gather the whole label array (baseline);
    "boundary": exchange only the labels the remote side actually needs
    (one psum over the flat cross-arc table) — the beyond-paper optimized
    schedule; see EXPERIMENTS.md §Perf for the measured exchange-mode and
    engine-backend numbers.
    """
    global _TRACE_COUNT
    _TRACE_COUNT += 1
    Kl, V, E = state.cf.shape                     # local regions
    # region offset of this shard (flat index over possibly-multiple axes)
    idx = jnp.zeros((), _I32)
    for a in axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    offset = idx * Kl

    src, dst = state.cross_src, state.cross_dst
    dst_local_r0 = dst[:, 0] - offset
    dst_mine0 = (dst_local_r0 >= 0) & (dst_local_r0 < Kl)
    dl0 = jnp.clip(dst_local_r0, 0, Kl - 1)
    src_local_r0 = src[:, 0] - offset
    src_mine0 = (src_local_r0 >= 0) & (src_local_r0 < Kl)
    sl0 = jnp.clip(src_local_r0, 0, Kl - 1)

    # ---- boundary label exchange ----
    if exchange == "full":
        d_full = jax.lax.all_gather(state.d, axes, axis=0, tiled=True)
        ghost_d = d_full[state.nbr_region, state.nbr_local]
    else:
        # labels of cross-arc destinations only: one [X] psum
        contrib = jnp.where(dst_mine0, state.d[dl0, dst[:, 1]], 0)
        dst_label = jax.lax.psum(contrib, axes)                    # [X]
        ghost_flat = jnp.zeros((Kl * V * E,), _I32).at[
            (sl0 * V + src[:, 1]) * E + src[:, 2]].max(
            jnp.where(src_mine0, dst_label, 0), mode="drop")
        ghost_d = ghost_flat.reshape(Kl, V, E)

    own = offset + jnp.arange(Kl, dtype=_I32)
    intra = (state.nbr_region == own[:, None, None]) & state.emask

    stage_cap = jnp.where(
        jnp.asarray(cfg.partial_discharge),
        jnp.maximum(sweep_idx - 1, -1).astype(_I32),
        _I32(meta.d_inf_ard))

    # batched discharge over this shard's local regions: same per-region
    # results as vmapping the scalar operators, but the fused pallas path
    # is one grid-over-regions kernel launch per chunk per shard
    disc_kw = dict(nbr_local=state.nbr_local, rev_slot=state.rev_slot,
                   intra=intra, emask=state.emask, vmask=state.vmask,
                   max_iters=cfg.engine_max_iters,
                   backend=cfg.engine_backend,
                   chunk_iters=cfg.engine_chunk_iters)
    if cfg.method == "ard":
        res = ard_discharge_batched(
            state.cf, state.sink_cf, state.excess, ghost_d,
            d_inf=meta.d_inf_ard, stage_cap=stage_cap, **disc_kw)
    else:
        res = prd_discharge_batched(
            state.cf, state.sink_cf, state.excess, state.d, ghost_d,
            d_inf=meta.d_inf_prd, **disc_kw)

    new_d_local = jnp.maximum(state.d, res.d)
    cf, sink_cf, excess = res.cf, res.sink_cf, res.excess

    # ---- boundary flow exchange + fusion (Alg. 2 lines 4-6) ----
    src_mine, sl = src_mine0, sl0
    dst_mine, dl = dst_mine0, dl0
    delta_local = jnp.where(src_mine,
                            res.out_push[sl, src[:, 1], src[:, 2]], 0)
    if exchange == "full":
        delta = jax.lax.psum(delta_local, axes)                  # [X]
        d_full2 = jax.lax.all_gather(new_d_local, axes, axis=0, tiled=True)
        du = d_full2[src[:, 0], src[:, 1]]
        dv = d_full2[dst[:, 0], dst[:, 1]]
    else:
        # fuse the three [X] exchanges into one stacked psum
        du_c = jnp.where(src_mine, new_d_local[sl, src[:, 1]], 0)
        dv_c = jnp.where(dst_mine, new_d_local[dl, dst[:, 1]], 0)
        packed = jax.lax.psum(
            jnp.stack([delta_local, du_c, dv_c]), axes)          # [3, X]
        delta, du, dv = packed[0], packed[1], packed[2]
    accept = dv <= du + 1
    acc = jnp.where(accept, delta, 0)
    rej = delta - acc
    flat = cf.reshape(-1)
    flat = flat.at[(dl * V + dst[:, 1]) * E + dst[:, 2]].add(
        jnp.where(dst_mine, acc, 0), mode="drop")
    flat = flat.at[(sl * V + src[:, 1]) * E + src[:, 2]].add(
        jnp.where(src_mine, rej, 0), mode="drop")
    cf = flat.reshape(Kl, V, E)
    ef = excess.reshape(-1)
    ef = ef.at[dl * V + dst[:, 1]].add(jnp.where(dst_mine, acc, 0),
                                       mode="drop")
    ef = ef.at[sl * V + src[:, 1]].add(jnp.where(src_mine, rej, 0),
                                       mode="drop")
    excess = ef.reshape(Kl, V)

    flow_to_t = state.flow_to_t + jax.lax.psum(res.sink_pushed.sum(), axes)

    # ---- global gap heuristic (psum histogram) ----
    # the sharded mirror of labels.gap_new_labels: ARD histograms boundary
    # labels only (Sec. 5.3), PRD all vertices — identical member sets and
    # scan range to the local driver's heuristic, so labels stay bit-equal
    d_local = new_d_local
    if cfg.use_global_gap:
        ard = cfg.method == "ard"
        d_inf = meta.d_inf_ard if ard else meta.d_inf_prd
        cap = min(d_inf + 1, GAP_HIST_CAP)
        member = state.vmask & (d_local < d_inf)
        if ard:
            member = member & state.is_boundary
        vals = jnp.where(member, d_local, 0).reshape(-1)
        hist = jnp.zeros((cap,), _I32).at[jnp.clip(vals, 0, cap - 1)].add(
            member.reshape(-1).astype(_I32))
        hist = jax.lax.psum(hist, axes)
        idxs = jnp.arange(cap)
        max_lab = jax.lax.pmax(jnp.max(jnp.where(member, d_local, 0)), axes)
        is_gap = (hist == 0) & (idxs >= 1) & \
            (idxs <= jnp.minimum(max_lab, cap - 1))
        g = jnp.min(jnp.where(is_gap, idxs, INF_LABEL))
        d_local = jnp.where(state.vmask & (d_local > g) & (d_local < d_inf),
                            d_inf, d_local).astype(_I32)

    n_active = jax.lax.psum(
        ((excess > 0) & (d_local < (meta.d_inf_ard if cfg.method == "ard"
                                    else meta.d_inf_prd))
         & state.vmask).sum(), axes)

    out = state.replace(cf=cf, sink_cf=sink_cf, excess=excess, d=d_local,
                        flow_to_t=flow_to_t)
    return out, n_active


def _memoized(fn):
    """Memoize a sharded-program builder on its (hashable) arguments.

    ``jax.jit`` caches per function object, so rebuilding the shard_map
    body on every ``solve_sharded`` call used to retrace/recompile each
    time; a session issuing warm re-solves through the sharded route must
    reuse the program.  Keyed on (meta, mesh, cfg, axes, exchange) — all
    hashable.
    """
    import functools

    return functools.lru_cache(maxsize=64)(fn)


@_memoized
def make_sharded_sweep(meta: GraphMeta, mesh: Mesh, cfg: SweepConfig,
                       axes=("regions",), exchange: str = "full"):
    """Build the jitted one-sweep SPMD program for a region-sharded mesh.

    ``axes`` — mesh axis name(s) the region dimension is sharded over; for
    the production pod mesh the regions axis spans ("pod", "data", "model")
    flattened, i.e. K = 512 regions on 512 chips.
    """
    spec = region_axis_sharding(mesh, axes)
    in_specs = (FlowState(**spec), P())
    out_specs = (FlowState(**spec), P())
    body = partial(_one_sweep_local, meta, cfg, axes, exchange=exchange)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return jax.jit(fn)


@_memoized
def make_sharded_solve(meta: GraphMeta, mesh: Mesh, cfg: SweepConfig,
                       axes=("regions",), exchange: str = "full"):
    """Build the jitted device-resident multi-sweep SPMD program.

    ``run(state, start_idx, limit) -> (state, sweep_idx, n_active)``
    advances the solve from sweep ``start_idx`` until convergence or
    ``limit`` total sweeps inside one ``lax.while_loop`` under shard_map —
    no host round trip between sweeps.  The loop predicate consumes the
    psum'd global active count, which is replicated across shards, so
    control flow stays uniform.
    """
    spec = region_axis_sharding(mesh, axes)
    in_specs = (FlowState(**spec), P(), P())
    out_specs = (FlowState(**spec), P(), P())
    ex = _executor.ShardedExecutor(meta, cfg, tuple(axes), exchange)

    def chunk(state: FlowState, start_idx, limit):
        # the generic executor loop, per shard: the executor's psum'd
        # active count keeps the predicate uniform across shards
        state, carry = _executor.while_sweeps(
            ex, state, ex.loop_carry(state, start_idx), limit)
        idx, _start, n_act = carry
        return state, idx, n_act

    fn = shard_map(chunk, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return jax.jit(fn)


def maxflow_input_specs(meta: GraphMeta) -> FlowState:
    """ShapeDtypeStructs of a FlowState for AOT lowering (dry-run)."""
    K, V, E = meta.num_regions, meta.region_size, meta.max_degree
    X = meta.num_cross_arcs
    f = jax.ShapeDtypeStruct
    return FlowState(
        nbr_region=f((K, V, E), jnp.int32), nbr_local=f((K, V, E), jnp.int32),
        rev_slot=f((K, V, E), jnp.int32), emask=f((K, V, E), jnp.bool_),
        vmask=f((K, V), jnp.bool_), is_boundary=f((K, V), jnp.bool_),
        cross_src=f((X, 3), jnp.int32), cross_dst=f((X, 3), jnp.int32),
        cross_group=f((X,), jnp.int32), cross_valid=f((X,), jnp.bool_),
        cross_src_arc=f((X,), jnp.int32), cross_dst_arc=f((X,), jnp.int32),
        cross_src_vtx=f((X,), jnp.int32), cross_dst_vtx=f((X,), jnp.int32),
        cf=f((K, V, E), jnp.int32), sink_cf=f((K, V), jnp.int32),
        excess=f((K, V), jnp.int32), d=f((K, V), jnp.int32),
        flow_to_t=f((), jnp.int32))


def solve_sharded(meta: GraphMeta, state: FlowState, mesh: Mesh,
                  cfg: SweepConfig | None = None, axes=("regions",),
                  max_sweeps: int | None = None, exchange: str = "full",
                  device_resident: bool | None = None,
                  host_sync_every: int | None = None,
                  return_stats: bool = False,
                  checkpoint=None, resume_from=None, salt: str = "",
                  on_sweep=None):
    """Sharded sweep loop (device-resident state; regions over the mesh).

    Default driver: one jitted SPMD sweep program + one host sync per
    sweep.  With ``device_resident`` (also picked up from
    ``cfg.device_resident``) the whole loop runs in a ``lax.while_loop``
    under shard_map and the host is re-entered once per
    ``host_sync_every`` sweeps (default: once per solve) — the same
    treatment as ``core.sweep.solve``.  Returns (state, sweeps), or
    (state, sweeps, host_syncs) with ``return_stats`` (the session
    front-end's route).  The compiled SPMD programs are memoized on
    (meta, mesh, cfg, axes, exchange), so repeated solves — a session's
    warm re-solves in particular — reuse them.

    ``checkpoint``/``resume_from``/``salt`` — sweep-boundary
    checkpointing exactly as in ``sweep.solve``: the host driver captures
    at every sweep boundary under the ``checkpoint.every`` cadence, the
    device-resident driver at its ``host_sync_every`` boundaries; the
    payload is the fully-gathered flow state (one ``device_get``), so a
    resume may re-land on a different mesh (elastic) — the re-entry
    ``device_put`` re-shards it.  A checkpoint taken at a CONVERGED final
    boundary short-circuits: the finished result returns without
    re-entering the sweep loop (the sharded loop's converged-entry
    semantics would otherwise burn one no-op sweep).

    ``on_sweep(state, sweeps_done)`` — optional sweep-boundary hook, as in
    ``sweep.solve``: every sweep boundary on the host driver, the
    ``host_sync_every`` boundaries on the device-resident driver.
    """
    cfg = cfg or SweepConfig()
    _executor.ShardedExecutor.validate(cfg)
    axes = tuple(axes) if not isinstance(axes, str) else (axes,)
    if device_resident is None:
        device_resident = cfg.device_resident
    if host_sync_every is None:
        host_sync_every = cfg.host_sync_every
    shardings = flowstate_shardings(mesh, axes)
    if checkpoint is not None:
        salt = checkpoint.salt
    fp = _res.solve_fingerprint(meta, cfg, salt)
    ckpt = _res.resolve_resume(resume_from, fp)
    start = 0
    seed_syncs = 0
    if ckpt is not None:
        state = _res.restore_state(state, ckpt.payload)
        start = ckpt.sweeps
        seed_syncs = int(ckpt.stats.get("host_syncs", 0))
    state = jax.device_put(state, shardings)
    if ckpt is not None and _res.checkpoint_converged(ckpt):
        # a converged final-boundary checkpoint: the solve is already
        # finished — re-entering the loop would run one no-op sweep, since
        # the sharded loop keeps the legacy converged-entry semantics
        # (ShardedExecutor.keep_running's ``idx == start`` term)
        return (state, start, seed_syncs) if return_stats \
            else (state, start)
    bound = (2 * meta.num_boundary ** 2 + 1 if cfg.method == "ard"
             else 2 * meta.num_vertices ** 2)
    limit = max_sweeps if max_sweeps is not None else bound
    ex = _executor.ShardedExecutor(meta, cfg, axes, exchange)

    def save(st, sweeps_done, n_act, syncs):
        payload = _res.state_payload(st)
        payload["n_act"] = np.asarray(n_act, np.int32)
        _res.save_checkpoint(checkpoint.directory, _res.SolveCheckpoint(
            fingerprint=fp, route="sharded", sweeps=sweeps_done,
            payload=payload,
            stats={"sweeps": sweeps_done, "host_syncs": seed_syncs + syncs},
            flow_offset=checkpoint.flow_offset))

    if device_resident:
        run = make_sharded_solve(meta, mesh, cfg, axes, exchange=exchange)

        def chunk(state, carry, cap):
            state, idx, n_act = run(state, jnp.asarray(carry[0], _I32), cap)
            return state, (idx, n_act)

        carry0 = None
        if ckpt is not None:
            carry0 = (jnp.asarray(start, _I32),
                      jnp.asarray(int(ckpt.payload["n_act"]), _I32))

        ckpt_sync = None
        if checkpoint is not None:
            last_saved = [start]

            def ckpt_sync(st, host, syncs):
                done, running = ex.progress(host, limit)
                if running and done - last_saved[0] < checkpoint.every:
                    return
                save(st, done, host[-1], syncs)
                last_saved[0] = done

        on_sync = ckpt_sync
        if on_sweep is not None:
            # checkpoint first: a hook that aborts the solve (deadline
            # enforcement) leaves the boundary durably checkpointed
            def on_sync(st, host, syncs):
                if ckpt_sync is not None:
                    ckpt_sync(st, host, syncs)
                on_sweep(st, int(host[0]))

        state, host, host_syncs = _executor.run_device(
            ex, state, limit, host_sync_every, chunk=chunk, carry0=carry0,
            on_sync=on_sync)
        return (state, int(host[0]), seed_syncs + host_syncs) \
            if return_stats else (state, int(host[0]))

    sweep_fn = make_sharded_sweep(meta, mesh, cfg, axes, exchange=exchange)

    def one(state, idx):
        state, n_active = sweep_fn(state, jnp.asarray(idx, _I32))
        return state, (n_active,)

    on_obs = None
    last_saved = [start]
    if checkpoint is not None:
        def on_obs(st, idx, trace, active_pre):
            if idx - last_saved[0] < checkpoint.every:
                return
            save(st, idx, trace[-1][0], len(trace))
            last_saved[0] = idx

    state, trace, _pre, host_syncs, sweeps = _executor.run_host(
        ex, state, limit, sweep=one, start=start, on_obs=on_obs,
        on_sweep=on_sweep)
    if checkpoint is not None and sweeps > last_saved[0] and trace:
        save(state, sweeps, trace[-1][0], len(trace))
    return (state, sweeps, seed_syncs + host_syncs) if return_stats \
        else (state, sweeps)
