"""Host-side graph partitioners (the paper's ``splitter`` tool, Sec. 5.3).

The paper slices regular grids into s equal parts per dimension and falls
back to node-number slicing for irregular graphs; both are provided, plus a
BFS-grown balanced partitioner for generic sparse graphs.
"""

from __future__ import annotations

import numpy as np


def grid_partition(shape: tuple[int, ...], splits: tuple[int, ...]) -> np.ndarray:
    """Partition an N-D grid of vertices into a grid of regions.

    ``shape``  — grid extents, vertex id = row-major raveling.
    ``splits`` — number of slices per dimension; K = prod(splits).
    """
    assert len(shape) == len(splits)
    idx = np.indices(shape)  # [ndim, *shape]
    region = np.zeros(shape, dtype=np.int64)
    for d, (extent, s) in enumerate(zip(shape, splits)):
        bounds = (idx[d] * s) // extent          # 0..s-1 per dimension
        region = region * s + bounds
    return region.reshape(-1)


def block_partition(num_vertices: int, num_regions: int) -> np.ndarray:
    """Paper's node-number slicing (used for KZ2/LB06 instances)."""
    if num_vertices == 0:
        return np.zeros(0, dtype=np.int64)
    per = -(-num_vertices // num_regions)
    return np.minimum(np.arange(num_vertices) // per, num_regions - 1)


def bfs_partition(num_vertices: int, edges: np.ndarray, num_regions: int,
                  seed: int = 0) -> np.ndarray:
    """Balanced BFS-grown regions for irregular graphs.

    Grows regions breadth-first from spread-out seeds with a per-region size
    cap — a cheap, dependency-free stand-in for METIS that keeps boundaries
    small on mesh-like graphs.
    """
    rng = np.random.RandomState(seed)
    cap = -(-num_vertices // num_regions)
    # adjacency (undirected)
    adj_head = [[] for _ in range(num_vertices)]
    for u, v in edges:
        adj_head[u].append(v)
        adj_head[v].append(u)
    part = np.full(num_vertices, -1, dtype=np.int64)
    sizes = np.zeros(num_regions, dtype=np.int64)
    from collections import deque
    queues = []
    seeds = rng.permutation(num_vertices)[:num_regions]
    for r, s in enumerate(seeds):
        queues.append(deque([int(s)]))
    remaining = num_vertices
    while remaining:
        progressed = False
        for r in range(num_regions):
            if sizes[r] >= cap:
                continue
            q = queues[r]
            while q:
                v = q.popleft()
                if part[v] == -1:
                    part[v] = r
                    sizes[r] += 1
                    remaining -= 1
                    progressed = True
                    for w in adj_head[v]:
                        if part[w] == -1:
                            q.append(w)
                    break
        if not progressed:
            # disconnected leftovers: round-robin to the emptiest regions
            for v in range(num_vertices):
                if part[v] == -1:
                    r = int(np.argmin(sizes))
                    part[v] = r
                    sizes[r] += 1
                    remaining -= 1
            break
    return part
