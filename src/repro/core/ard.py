"""Augmented-path Region Discharge (ARD) — the paper's contribution (Sec. 4).

Discharge of a region R:

  stage 0   — augment excess to the sink t inside the region network G^R;
  stage k>0 — augment excess to T_k = {t} ∪ {w in B^R : d(w) < k}, i.e. to
              boundary (ghost) vertices in order of increasing label;
  finally   — region-relabel (Alg. 3, ARD variant) recomputes the region's
              labels w.r.t. the *region distance* d^B from the frozen
              boundary labels.

Each stage is a maxflow from the excess vertices to the stage target set; we
compute it with the vectorized push-relabel engine seeded by exact BFS
distances to the targets (engine.py) — the TPU-native analogue of the BK
search trees used by the paper's implementation.  Stages iterate over the
*distinct* ghost labels actually present (the efficient implementation of
Sec. 6), and the partial-discharge heuristic (Sec. 6.2) caps the admissible
stage by the sweep number.

The returned pair (f', d') satisfies Statement 9 — optimality (no active
vertex left in R), label monotony, validity, and flow direction — which is
what the 2|B|^2 + 1 sweep bound needs; tests/test_invariants.py checks these
properties directly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import (bfs_to_targets, push_relabel,
                               push_relabel_batched)
from repro.core.graph import INF_LABEL
from repro.core.labels import _region_relabel_one

_I32 = jnp.int32


class DischargeResult(NamedTuple):
    cf: jax.Array          # i32[V,E]
    sink_cf: jax.Array     # i32[V]
    excess: jax.Array      # i32[V]
    d: jax.Array           # i32[V]   new labels d' of the region's vertices
    out_push: jax.Array    # i32[V,E] flow pushed over cross arcs
    sink_pushed: jax.Array  # i32[]
    engine_iters: jax.Array  # i32[]
    stages: jax.Array      # i32[]
    engine_launches: jax.Array  # i32[] compute-program dispatches (see engine)


def _distinct_sorted_ghost_labels(ghost_d, cross, emask, d_inf):
    """Leading distinct ghost labels (< d_inf) in ascending order, then INF.

    Prepends -1 so that index 0 is always the sink-only stage (T_0 = {t}).

    Stage scheduling is int32 regardless of the label storage dtype — the
    schedule is tiny and only compared against, never stored back."""
    flat = jnp.where(cross & emask & (ghost_d < d_inf),
                     ghost_d.astype(_I32), INF_LABEL).reshape(-1)
    s = jnp.sort(flat)
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    distinct = jnp.sort(jnp.where(first, s, INF_LABEL))
    return jnp.concatenate([jnp.full((1,), -1, _I32), distinct])


def ard_discharge_one(cf, sink_cf, excess, ghost_d, *, nbr_local, rev_slot,
                      intra, emask, vmask, d_inf: int, stage_cap,
                      max_iters: int | None = None,
                      backend: str = "xla",
                      chunk_iters: int | None = None) -> DischargeResult:
    """ARD on a single region network (vmapped over regions by sweep.py).

    ``ghost_d``  — frozen labels of cross-arc destinations (paper: d|B^R).
    ``stage_cap`` — largest ghost label admissible as an augmentation target
                    this sweep (partial discharges, Sec. 6.2); pass d_inf for
                    a full discharge.
    ``backend``  — engine compute-phase backend ("xla" or "pallas").
    ``chunk_iters`` — fused chunked engine (k iterations per launch); None
                    keeps the unfused two-phase engine.
    """
    V, E = cf.shape
    cross = emask & ~intra
    linf_local = V + 2
    stage_vals = _distinct_sorted_ghost_labels(ghost_d, cross, emask, d_inf)
    n_vals = stage_vals.shape[0]
    stage_cap = jnp.asarray(stage_cap, _I32)

    def stage_body(carry):
        i, cf, sink_cf, excess, out_push, sink_pushed, iters, launches = carry
        lvl = stage_vals[i]
        target_cross = cross & (ghost_d <= lvl) & (ghost_d < d_inf)
        lab0 = bfs_to_targets(
            cf, sink_cf, nbr_local=nbr_local, intra=intra, emask=emask,
            vmask=vmask, target_cross=target_cross, linf=linf_local,
            label_dtype=ghost_d.dtype)
        es = push_relabel(
            cf, sink_cf, excess, lab0,
            nbr_local=nbr_local, rev_slot=rev_slot, intra=intra, emask=emask,
            vmask=vmask, cross_pushable=target_cross,
            cross_lab=jnp.zeros_like(ghost_d), d_inf=linf_local,
            sink_open=True, max_iters=max_iters, backend=backend,
            chunk_iters=chunk_iters)
        return (i + 1, es.cf, es.sink_cf, es.excess,
                out_push + es.out_push, sink_pushed + es.sink_pushed,
                iters + es.iters, launches + es.launches)

    def stage_cond(carry):
        i = carry[0]
        more = i < n_vals
        lvl = stage_vals[jnp.minimum(i, n_vals - 1)]
        return more & (lvl < INF_LABEL) & (lvl <= stage_cap)

    init = (jnp.zeros((), _I32), cf, sink_cf, excess,
            jnp.zeros((V, E), cf.dtype), jnp.zeros((), _I32),
            jnp.zeros((), _I32), jnp.zeros((), _I32))
    (i, cf, sink_cf, excess, out_push, sink_pushed, iters,
     launches) = jax.lax.while_loop(stage_cond, stage_body, init)

    # final region-relabel (Alg. 3, ARD variant) on the post-discharge network
    d_new = _region_relabel_one(
        cf, sink_cf, ghost_d, nbr_local=nbr_local, intra=intra, emask=emask,
        vmask=vmask, d_inf=d_inf, hop_cost=0)
    return DischargeResult(cf, sink_cf, excess, d_new, out_push,
                           sink_pushed, iters, i, launches)


def ard_discharge_batched(cf, sink_cf, excess, ghost_d, *, nbr_local,
                          rev_slot, intra, emask, vmask, d_inf,
                          stage_cap, max_iters: int | None = None,
                          backend: str = "xla",
                          chunk_iters: int | None = None,
                          linf=None,
                          grid2d: tuple[int, int] | None = None
                          ) -> DischargeResult:
    """ARD on all K regions of a parallel sweep, collectively.

    The batched counterpart of ``jax.vmap(ard_discharge_one)``: the stage
    loop advances every region in lockstep (a region whose stage schedule
    is exhausted is frozen by a per-region select, exactly like vmapped
    while_loop batching), and each stage's engine run goes through
    ``engine.push_relabel_batched`` — one grid-over-regions kernel launch
    per chunk on the fused pallas path instead of K per-region launch
    sequences.  Per-region results (state, labels, out_push, engine
    iterations, stage counts) are bit-identical to the vmapped scalar path;
    ``engine_launches`` becomes the global dispatch count of the sweep.

    ``d_inf``/``stage_cap`` may be scalars or per-region i32[K] vectors and
    ``linf`` overrides the per-region engine/BFS ceiling (default: the
    padded row count ``V + 2``) — a solve batch's regions carry their own
    instance's ceilings, which keeps every region's iteration sequence
    identical to the instance's standalone solve regardless of bucket
    padding.  ``grid2d`` renders the fused pallas launch as ``grid=(B,Kr)``.
    """
    K, V, E = cf.shape
    cross = emask & ~intra
    d_inf = jnp.broadcast_to(jnp.asarray(d_inf, _I32), (K,))
    linf = jnp.broadcast_to(
        jnp.asarray(V + 2 if linf is None else linf, _I32), (K,))
    stage_cap = jnp.broadcast_to(jnp.asarray(stage_cap, _I32), (K,))
    stage_vals = jax.vmap(_distinct_sorted_ghost_labels)(
        ghost_d, cross, emask, d_inf)                        # [K, n_vals]
    n_vals = stage_vals.shape[1]

    bfs_batched = jax.vmap(
        lambda cf, s, nl, it, em, vm, tc, li: bfs_to_targets(
            cf, s, nbr_local=nl, intra=it, emask=em, vmask=vm,
            target_cross=tc, linf=li))

    def stage_more(i):
        lvl = jnp.take_along_axis(
            stage_vals, jnp.minimum(i, n_vals - 1)[:, None], axis=1)[:, 0]
        more = (i < n_vals) & (lvl < INF_LABEL) & (lvl <= stage_cap)
        return lvl, more

    def stage_body(carry):
        i, cf, sink_cf, excess, out_push, sink_pushed, iters, launches = carry
        lvl, more = stage_more(i)                            # [K], [K]
        target_cross = cross & (ghost_d <= lvl[:, None, None]) \
            & (ghost_d < d_inf[:, None, None])
        lab0 = bfs_batched(cf, sink_cf, nbr_local, intra, emask, vmask,
                           target_cross, linf)
        lab0 = lab0.astype(ghost_d.dtype)
        es = push_relabel_batched(
            cf, sink_cf, excess, lab0,
            nbr_local=nbr_local, rev_slot=rev_slot, intra=intra, emask=emask,
            vmask=vmask, cross_pushable=target_cross,
            cross_lab=jnp.zeros_like(ghost_d), d_inf=linf,
            sink_open=True, max_iters=max_iters, backend=backend,
            chunk_iters=chunk_iters, grid2d=grid2d)
        w3, w2 = more[:, None, None], more[:, None]
        return (i + more.astype(_I32),
                jnp.where(w3, es.cf, cf),
                jnp.where(w2, es.sink_cf, sink_cf),
                jnp.where(w2, es.excess, excess),
                out_push + jnp.where(w3, es.out_push, 0),
                sink_pushed + jnp.where(more, es.sink_pushed, 0),
                iters + jnp.where(more, es.iters, 0),
                launches + es.launches)

    def stage_cond(carry):
        _, more = stage_more(carry[0])
        return more.any()

    zk = jnp.zeros((K,), _I32)
    init = (zk, cf, sink_cf, excess, jnp.zeros((K, V, E), cf.dtype), zk, zk,
            jnp.zeros((), _I32))
    (i, cf, sink_cf, excess, out_push, sink_pushed, iters,
     launches) = jax.lax.while_loop(stage_cond, stage_body, init)

    d_new = jax.vmap(
        lambda cf, s, g, nl, it, em, vm, di: _region_relabel_one(
            cf, s, g, nbr_local=nl, intra=it, emask=em, vmask=vm,
            d_inf=di, hop_cost=0))(
        cf, sink_cf, ghost_d, nbr_local, intra, emask, vmask, d_inf)
    return DischargeResult(cf, sink_cf, excess, d_new, out_push,
                           sink_pushed, iters, i, launches)
