"""Preflow/labeling invariant checkers and structured solve diagnostics.

The properties the paper's correctness and sweep-bound proofs rest on
(Statements 1/9, eqs. (9)/(10)), promoted from the test fixture module
(``tests/invariants.py``, now a thin assert wrapper over this one) so the
*solver itself* can report them: a solve that stops at ``max_sweeps`` or
fails the cut==flow certificate attaches a :class:`NonConvergence` report
(``MincutResult.diagnosis``) listing exactly which invariants the final
state violates, instead of dying on a bare assert.

Checkers return a list of :class:`Violation` records (empty = the
invariant holds), so callers choose between reporting and asserting:

* :func:`check_valid_preflow`   — residuals/excess non-negative.
* :func:`check_valid_labeling`  — d() is a valid distance labeling of the
  residual network: every residual arc (u, v) satisfies
  ``d(u) <= d(v) + w`` with w = 0 for ARD intra-region arcs, 1 for ARD
  cross arcs, 1 for every PRD arc; sink-residual vertices are bounded by
  the terminal distance (0 for ARD, 1 for PRD), all capped at d_inf.
* :func:`check_flow_conservation` — excess mass + delivered flow equals
  the conserved total of the entry state.
* :func:`invariant_report`      — all of the above in one list.

``CertificateError`` is the typed replacement for the historical bare
``assert cost == flow`` in the result assembly: it still IS an
``AssertionError`` (existing ``except AssertionError`` handlers keep
working) but carries the structured report on ``.diagnosis``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax.numpy as jnp

from repro.core.graph import intra_mask
from repro.core.labels import gather_ghost_labels


@dataclass
class Violation:
    """One broken invariant: which property, how many entries, evidence."""

    kind: str       # "negative_residual" | "intra_arc_validity" | ...
    count: int      # number of offending entries (0 for scalar properties)
    detail: str     # human-readable evidence (first offenders, totals)


def preflow_total(state) -> int:
    """The conserved quantity: live excess + flow already delivered to t."""
    return int(jnp.sum(jnp.where(state.vmask, state.excess, 0))) + \
        int(state.flow_to_t)


def _bad(kind: str, mask: np.ndarray, detail: str) -> list[Violation]:
    n = int(np.count_nonzero(mask))
    if n == 0:
        return []
    first = np.argwhere(mask)[:3].tolist()
    return [Violation(kind=kind, count=n, detail=f"{detail}; first at {first}")]


def check_valid_preflow(meta, state) -> list[Violation]:
    """Residuals and excess of a preflow are non-negative everywhere."""
    cf = np.asarray(state.cf)
    sink_cf = np.asarray(state.sink_cf)
    excess = np.asarray(state.excess)
    vm = np.asarray(state.vmask)
    out: list[Violation] = []
    out += _bad("negative_residual", cf < 0, "cf < 0")
    out += _bad("negative_sink_residual", sink_cf < 0, "sink_cf < 0")
    out += _bad("negative_excess", (excess < 0) & vm, "excess < 0")
    return out


def check_valid_labeling(meta, state, *, ard: bool) -> list[Violation]:
    """Paper eqs. (9)/(10): d() lower-bounds residual distance-to-sink.

    ARD labels count boundary crossings (intra arcs cost 0, cross arcs 1,
    the sink is at distance 0); PRD labels count hops (every arc costs 1,
    the sink is one hop away).  Vertices at the ceiling d_inf are exempt
    (they are declared unreachable), as are arcs into ghosts already at
    the ceiling — ``d(u) <= d_inf <= ghost`` holds trivially there.
    """
    ghost_d = gather_ghost_labels(state)
    intra = intra_mask(state)
    d_inf = meta.d_inf_ard if ard else meta.d_inf_prd
    d = state.d
    du = jnp.broadcast_to(d[:, :, None], state.cf.shape)
    resid = (state.cf > 0) & state.emask
    at_cap = du >= d_inf
    intra_w = 0 if ard else 1
    bad_intra = resid & intra & (du > ghost_d + intra_w) & ~at_cap
    cross = state.emask & ~intra
    bad_cross = resid & cross & (du > ghost_d + 1) & ~at_cap
    sink_w = 0 if ard else 1
    bad_sink = (state.sink_cf > 0) & (d > sink_w) & (d < d_inf) & state.vmask
    out: list[Violation] = []
    out += _bad("intra_arc_validity", np.asarray(bad_intra),
                f"residual intra arc with d(u) > d(v) + {intra_w}")
    out += _bad("cross_arc_validity", np.asarray(bad_cross),
                "residual cross arc with d(u) > ghost + 1")
    out += _bad("sink_validity", np.asarray(bad_sink),
                f"sink-residual vertex with d > {sink_w}")
    return out


def check_flow_conservation(meta, state, total0: int) -> list[Violation]:
    """No flow mass appears or vanishes: excess + flow_to_t == total0."""
    total = preflow_total(state)
    if total == total0:
        return []
    return [Violation(kind="flow_conservation", count=0,
                      detail=f"excess + flow_to_t = {total} != {total0}")]


def sweep_bound(meta, *, ard: bool) -> int:
    """The paper's worst-case sweep count: 2|B|^2 + 1 for ARD (Lemma 2 —
    each sweep after the first raises some boundary label, and boundary
    labels live in [0, 2|B|)), 2n^2 + 1 for PRD (labels in [0, 2n))."""
    base = max(1, meta.num_boundary) if ard else max(1, meta.num_vertices)
    return 2 * base * base + 1


def check_sweep_bound(meta, stats, *, ard: bool) -> list[Violation]:
    """A converged solve's sweep count respects the paper's bound.

    A violation here is not a wrong answer (convergence is certified
    separately) — it means the implementation lost the monotone-label
    argument the complexity analysis rests on, which the paper's
    streaming mode depends on for termination within bounded passes.
    """
    if not stats.converged:
        return []
    limit = sweep_bound(meta, ard=ard)
    if stats.sweeps <= limit:
        return []
    return [Violation(
        kind="sweep_bound", count=stats.sweeps,
        detail=f"{stats.sweeps} sweeps exceeds the "
               f"{'2|B|^2+1' if ard else '2n^2+1'} bound {limit} "
               f"(|B|={meta.num_boundary}, n={meta.num_vertices})")]


def invariant_report(meta, state, *, ard: bool,
                     total0: int | None = None) -> list[Violation]:
    """Every state-level invariant in one pass (empty list = all hold)."""
    out = check_valid_preflow(meta, state)
    out += check_valid_labeling(meta, state, ard=ard)
    if total0 is not None:
        out += check_flow_conservation(meta, state, total0)
    return out


# --------------------------------------------------------------------------
# structured solve diagnostics
# --------------------------------------------------------------------------

@dataclass
class NonConvergence:
    """Structured report attached to a solve that cannot certify optimality.

    ``reason`` — ``"max_sweeps"`` (the sweep budget ran out with active
    vertices left: the preflow is valid but possibly non-maximum) or
    ``"certificate"`` (the solve claims convergence but the independently
    computed cut cost differs from the flow value: an internal-consistency
    failure, e.g. state corrupted mid-solve).  ``violations`` lists which
    preflow/labeling invariants the final state breaks — an intact
    ``max_sweeps`` stop reports none; a corrupted state names the broken
    property.
    """

    reason: str                      # "max_sweeps" | "certificate"
    sweeps: int
    max_sweeps: int | None
    active_vertices: int
    flow_value: int
    cut_cost: int | None = None
    violations: list[Violation] = field(default_factory=list)

    def summary(self) -> str:
        head = (f"non-convergence ({self.reason}): sweeps={self.sweeps}"
                f"/{self.max_sweeps}, active={self.active_vertices}, "
                f"flow={self.flow_value}")
        if self.cut_cost is not None:
            head += f", cut_cost={self.cut_cost}"
        if self.violations:
            head += "; broken invariants: " + ", ".join(
                f"{v.kind} (x{v.count})" for v in self.violations)
        return head


class CertificateError(AssertionError):
    """The cut==flow certificate failed on a solve that claims convergence.

    Subclasses ``AssertionError`` (the historical raise of ``check=True``)
    so existing handlers keep working; carries the structured
    :class:`NonConvergence` report on ``.diagnosis``.
    """

    def __init__(self, message: str, diagnosis: NonConvergence):
        self.diagnosis = diagnosis
        super().__init__(f"{message}\n  {diagnosis.summary()}")


def diagnose(meta, state, *, ard: bool, reason: str, sweeps: int,
             max_sweeps: int | None, flow_value: int,
             cut_cost: int | None = None,
             total0: int | None = None) -> NonConvergence:
    """Assemble a :class:`NonConvergence` report for a finished state."""
    d_inf = meta.d_inf_ard if ard else meta.d_inf_prd
    active = int(jnp.asarray(state.active(d_inf)).sum())
    return NonConvergence(
        reason=reason, sweeps=sweeps, max_sweeps=max_sweeps,
        active_vertices=active, flow_value=flow_value, cut_cost=cut_cost,
        violations=invariant_report(meta, state, ard=ard, total0=total0))


__all__ = [
    "CertificateError", "NonConvergence", "Violation",
    "check_flow_conservation", "check_valid_labeling",
    "check_valid_preflow", "diagnose", "invariant_report", "preflow_total",
]
