"""Region reduction (Alg. 5, Sec. 8) — single-flow improvement of Kovtun's
auxiliary problems.

Kovtun's construction solves two auxiliary problems on the region network:
aux1 adds infinite links boundary -> sink (strong *source* detection), aux2
adds infinite links source -> boundary (strong *sink* detection).  Alg. 5
computes both with a single flow, exploiting that after Augment(s, t) the
s-reachable and t-reaching parts of the region are disjoint (Statement 11).

Key equivalence used here: because the added links are infinite, every aux
min cut places all boundary vertices on the auxiliary-terminal side, so each
aux network is *exactly* equivalent to the subnetwork induced by R alone
with cross-arc capacities folded into terminal capacities:

    aux1:  extra sink capacity  at u:  sum_w  c_f(u, w)   (residual out-arcs)
    aux2:  extra source mass    at u:  sum_w  c_f(w, u)   (residual in-arcs)

(Transit paths u -> w -> u' through a boundary vertex never help: flow
arriving at w can always exit into w's infinite terminal link instead.)
This removes any need to model ghost-hop paths on device; all reachability
and augmentation is strictly intra-region and therefore runs for every
region simultaneously on the [K, V, E] arrays.

The steps, matching Alg. 5 with the folding above:

  1. Augment(s, t)        — excess -> t-links inside the region;
  2. Augment(s, B^S)      — remaining excess -> residual out-arc exits
                            (maxflow only uses s-reachable exits = B^S);
  3. Augment(B^T, t)      — virtual excess = residual in-arc capacity,
                            pushed to t (only the t-reaching part moves
                            = B^T); leftover virtual excess is discarded;
  4. classify:  s -> v           => strong source  (v in C for every opt cut)
                v -> t           => strong sink    (v in C̄ for every opt cut)
                else v -/-> B^R  => weak source
                else B^R -/-> v  => weak sink

"Decided" = strong sink | weak source (paper Table 3).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import bfs_to_targets, push_relabel
from repro.core.graph import FlowState, GraphMeta, intra_mask

_I32 = jnp.int32


class ReductionResult(NamedTuple):
    strong_source: jax.Array   # bool[K,V]
    strong_sink: jax.Array     # bool[K,V]
    weak_source: jax.Array     # bool[K,V]
    weak_sink: jax.Array       # bool[K,V]
    decided: jax.Array         # bool[K,V]  strong sink | weak source


def _reach_forward(state: FlowState, seed: jax.Array, intra) -> jax.Array:
    """Vertices reachable from ``seed`` through intra residual arcs."""
    K, V, E = state.cf.shape

    def body(carry):
        reach, _ = carry
        hop = (state.cf > 0) & state.emask & intra & reach[:, :, None]
        rf = reach.reshape(-1).at[
            (state.nbr_region * V + state.nbr_local).reshape(-1)].max(
            hop.reshape(-1))
        new = (rf.reshape(K, V) | reach) & state.vmask
        return new, (new != reach).any()

    reach, _ = jax.lax.while_loop(lambda c: c[1], body,
                                  (seed & state.vmask, jnp.asarray(True)))
    return reach


def _reach_backward(state: FlowState, target: jax.Array, intra) -> jax.Array:
    """Vertices from which ``target`` is reachable through intra residuals."""
    def body(carry):
        reach, _ = carry
        nbr_reach = reach[state.nbr_region, state.nbr_local]
        ok = (state.cf > 0) & state.emask & intra & nbr_reach
        new = (reach | ok.any(axis=2)) & state.vmask
        return new, (new != reach).any()

    reach, _ = jax.lax.while_loop(lambda c: c[1], body,
                                  (target & state.vmask, jnp.asarray(True)))
    return reach


def _augment_all(meta: GraphMeta, state: FlowState, *, target_cross,
                 sink_open: bool, excess=None,
                 backend: str = "xla") -> FlowState:
    """Maxflow from excess to {sink?} ∪ cross-arc exits, in every region."""
    intra = intra_mask(state)
    V = meta.region_size
    exc = state.excess if excess is None else excess
    linf = V + 2

    def one(cf, sink_cf, e, tc, nl, rs, it, em, vm):
        lab0 = bfs_to_targets(cf, sink_cf, nbr_local=nl, intra=it, emask=em,
                              vmask=vm, target_cross=tc, linf=linf,
                              sink_open=sink_open)
        es = push_relabel(cf, sink_cf, e, lab0, nbr_local=nl, rev_slot=rs,
                          intra=it, emask=em, vmask=vm, cross_pushable=tc,
                          cross_lab=jnp.zeros_like(cf), d_inf=linf,
                          sink_open=sink_open, backend=backend)
        return es.cf, es.sink_cf, es.excess, es.sink_pushed

    cf, sink_cf, exc, sink_pushed = jax.vmap(one)(
        state.cf, state.sink_cf, exc, target_cross, state.nbr_local,
        state.rev_slot, intra, state.emask, state.vmask)
    return state.replace(cf=cf, sink_cf=sink_cf, excess=exc,
                         flow_to_t=state.flow_to_t + sink_pushed.sum())


def region_reduction(meta: GraphMeta, state: FlowState, *,
                     backend: str = "xla") -> ReductionResult:
    """Kovtun's two auxiliary maxflows (folded form) for all regions.

    ``backend`` selects the discharge engine's compute-phase implementation
    ("xla" or "pallas"), like ``SweepConfig.engine_backend`` for the sweeps.

    Faithfulness note (DESIGN.md): Alg. 5 computes both aux problems with a
    *single* flow per region by exploiting the disjointness of the
    s-reachable and t-reaching parts (Statement 11).  That sharing requires
    per-region reverse-arc bookkeeping on the cross arcs; in this
    all-regions-simultaneously layout neighbouring regions would corrupt
    each other's in-arc capacities (found by hypothesis testing), so the
    sound formulation here runs the two phases on separate scratch copies —
    Kovtun's original two flows, each still a single vectorized pass over
    every region at once.
    """
    K, V, E = state.cf.shape
    intra = intra_mask(state)
    cross = state.emask & ~intra
    src, dst = state.cross_src, state.cross_dst
    no_targets = jnp.zeros((K, V, E), bool)

    # ---- phase A (aux1: boundary -> sink flooded out) ----
    # step 1: Augment(s, t); step 2: Augment(s, B^S) — every residual
    # out-arc is an exit of capacity c_f(u, w); maxflow reaches exactly the
    # s-reachable exits = B^S.
    stA = _augment_all(meta, state, target_cross=no_targets, sink_open=True,
                       backend=backend)
    stA = _augment_all(meta, stA, target_cross=cross, sink_open=False,
                       backend=backend)

    # ---- phase B (aux2: source -> boundary flooded in) ----
    # fresh copy; sources = original excess + original in-arc capacities
    # injected as virtual excess at the entry vertices.
    arc_cf0 = state.cf[src[:, 0], src[:, 1], src[:, 2]]
    virt = jnp.zeros((K * V,), _I32).at[dst[:, 0] * V + dst[:, 1]].add(
        jnp.where(state.cross_valid, jnp.maximum(arc_cf0, 0), 0)
    ).reshape(K, V)
    stB = _augment_all(meta, state, target_cross=no_targets, sink_open=True,
                       excess=state.excess + virt, backend=backend)

    # ---- classification ----
    strong_source = _reach_forward(stA, stA.excess > 0, intra)
    strong_sink = _reach_backward(stB, stB.sink_cf > 0, intra)
    out_any = ((stA.cf > 0) & cross).any(axis=2)
    to_boundary = _reach_backward(stA, out_any, intra)
    in_any = jnp.zeros((K * V,), bool).at[dst[:, 0] * V + dst[:, 1]].max(
        (arc_cf0 > 0) & state.cross_valid).reshape(K, V)
    from_boundary = _reach_forward(stB, in_any, intra)
    rest = state.vmask & ~strong_source & ~strong_sink
    weak_source = rest & ~to_boundary
    weak_sink = rest & ~from_boundary
    decided = (strong_sink | weak_source) & state.vmask
    return ReductionResult(strong_source & state.vmask,
                           strong_sink & state.vmask,
                           weak_source, weak_sink, decided)
