"""Kernel dtype policy: narrow label/flow/mask storage when ranges allow.

The paper's working set is memory-bound, and both value families the
kernels carry are range-bounded by construction:

* **labels** never exceed ``d_inf`` (``n`` for PRD, ``|B|`` for ARD) nor
  the ARD stage ceiling ``V + 2`` — so whenever
  ``max(d_inf_prd, d_inf_ard, V + 2) + 2 < 2**14`` they fit int16 with a
  narrow infinity sentinel ``NARROW_INF_LABEL = 2**14`` standing in for
  the wide ``INF_LABEL = 2**30``;
* **residuals/excess** are conserved quantities bounded by the total
  capacity mass of the instance (sum of excess + sink capacities + arc
  pair totals), so when that mass is ``< 2**15`` every residual, every
  per-row cumulative sum, and every ``avail - cum_excl`` intermediate
  fits int16 without wraparound.

Under those bounds int16 arithmetic is *bit-exact* vs int32: min/max/
clamp/compare against the narrow sentinel order identically (all real
values sit strictly below it), and no additive path can overflow.
Scalar accumulators that cross regions or iterations (``flow_to_t``,
``relabel_sum``, ``engine_iters``, launch counters) always stay int32.

Policies:

* ``"int32"`` — the wide baseline (default everywhere).
* ``"auto"``  — per-problem range check; narrows each family
  independently, with an automatic int32 fallback when a bound fails.
* ``"narrow"`` — like auto, but a failed bound is a typed
  ``ProblemValidationError`` (raised by ``graph.validate_problem``)
  instead of a silent widening.

Masks ship to the kernels as int8 whenever either value family is
narrow, int32 otherwise (the portable-lowering baseline).
"""

from __future__ import annotations

import dataclasses

import numpy as np

INF_LABEL_WIDE = 2 ** 30        # mirrors graph.INF_LABEL (int32 sentinel)
NARROW_INF_LABEL = 2 ** 14      # int16 label sentinel
NARROW_FLOW_LIMIT = 2 ** 15     # total capacity mass must stay below this
NARROW_LABEL_LIMIT = NARROW_INF_LABEL - 2   # label values + 1 stay < inf

DTYPE_POLICIES = ("int32", "auto", "narrow")


@dataclasses.dataclass(frozen=True)
class KernelDtypes:
    """Storage dtypes for the three value families a region kernel holds.

    Hashable and string-keyed so it can sit inside frozen metadata
    (``GraphMeta``/``BatchMeta``) that keys the jit compile caches —
    a dtype change can never silently reuse a stale executable.
    """

    label: str = "int32"
    flow: str = "int32"
    mask: str = "int32"

    @property
    def label_np(self):
        return np.dtype(self.label)

    @property
    def flow_np(self):
        return np.dtype(self.flow)

    @property
    def mask_np(self):
        return np.dtype(self.mask)

    @property
    def inf_label(self) -> int:
        return inf_label_for(self.label)

    def as_dict(self) -> dict:
        return dict(label=self.label, flow=self.flow, mask=self.mask)


WIDE = KernelDtypes()
NARROW = KernelDtypes(label="int16", flow="int16", mask="int8")


def inf_label_for(dtype) -> int:
    """The label-infinity sentinel for a label dtype (2**30 / 2**14)."""
    return NARROW_INF_LABEL if np.dtype(dtype).itemsize < 4 \
        else INF_LABEL_WIDE


def flow_mass(problem) -> int:
    """Total capacity mass: the range bound for every residual quantity.

    int64 host-side sums (never wraps); excess, sink capacity and every
    residual pair total are all bounded by this one number for the whole
    solve — flow is conserved and updates only move it.
    """
    cf = np.asarray(problem.cap_fwd, dtype=np.int64)
    cb = np.asarray(problem.cap_bwd, dtype=np.int64)
    cs = np.asarray(problem.excess, dtype=np.int64)
    ct = np.asarray(problem.sink_cap, dtype=np.int64)
    return int(cf.sum() + cb.sum() + cs.sum() + ct.sum())


def label_bound(num_vertices: int, region_size: int) -> int:
    """Largest label any route can write: the PRD ceiling ``n`` vs the
    ARD stage ceiling ``V + 2`` (regional BFS labelings stay below it)."""
    return max(int(num_vertices), int(region_size) + 2)


def labels_fit_narrow(bound: int) -> bool:
    return bound <= NARROW_LABEL_LIMIT


def flows_fit_narrow(mass: int) -> bool:
    return mass < NARROW_FLOW_LIMIT


def select_dtypes(policy: str, *, mass: int, bound: int) -> KernelDtypes:
    """Resolve a policy name to concrete storage dtypes for one problem.

    ``"auto"`` and ``"narrow"`` resolve identically — the difference is
    that ``graph.validate_problem`` raises on a failed bound under
    ``"narrow"`` where ``"auto"`` silently falls back to int32.
    """
    if policy not in DTYPE_POLICIES:
        raise ValueError(
            f"unknown dtype policy {policy!r}; expected one of "
            f"{DTYPE_POLICIES}")
    if policy == "int32":
        return WIDE
    label = "int16" if labels_fit_narrow(bound) else "int32"
    flow = "int16" if flows_fit_narrow(mass) else "int32"
    mask = "int8" if (label == "int16" or flow == "int16") else "int32"
    return KernelDtypes(label=label, flow=flow, mask=mask)


def narrow_violations(policy: str, *, mass: int, bound: int) -> list:
    """(family, dtype, value, limit) rows for bounds a forced-narrow
    policy cannot satisfy; empty for int32/auto or when everything fits."""
    if policy != "narrow":
        return []
    out = []
    if not flows_fit_narrow(mass):
        out.append(("flow", "int16", mass, NARROW_FLOW_LIMIT))
    if not labels_fit_narrow(bound):
        out.append(("label", "int16", bound, NARROW_LABEL_LIMIT))
    return out
