"""Disk-backed region store: spill pool + LRU resident set + prefetch.

Each region's state lives on disk under its own pool directory, written
through the atomic snapshot machinery of ``core.resilience`` (write to a
temp dir, publish via ``os.rename`` — a crashed writer never corrupts
the pool, which is what makes kill-and-resume safe):

    <pool>/region_00007/topo/step_00000000/     immutable topology,
                                                written once per solve
    <pool>/region_00007/state/step_00000003/    mutable flow family at
                                                version 3

Writebacks are write-through (the new version is published before the
visit moves on), so eviction from the resident set is free — no dirty
pages, no flush ordering.  Versions only grow; ``protect`` pins the set
a checkpoint references and ``_prune`` deletes everything else, so disk
usage stays at O(current + one checkpoint) versions per region.

The prefetcher is one background thread staging the next region's files
into a side buffer while the current region discharges on device (the
host-side analogue of the fused engine's double-buffered DMA).  The
buffer is consumed only if its version is still current; writebacks
happen on the main thread and only ever touch the *current* region, so
the thread never races a writer.
"""

from __future__ import annotations

import shutil
import tempfile
import threading
from pathlib import Path

import numpy as np

from repro.core import resilience as _res

TOPO_FIELDS = ("nbr_region", "nbr_local", "rev_slot", "emask",
               "vmask", "is_boundary")
FLOW_FIELDS = ("cf", "sink_cf", "excess", "d")


def _nbytes(arrays: dict) -> int:
    return sum(int(np.asarray(a).nbytes) for a in arrays.values())


class StreamStore:
    """Spill pool for one solve: K regions, ``max_resident`` in memory."""

    def __init__(self, num_regions: int, directory: str | Path | None = None,
                 *, max_resident: int = 2, prefetch: bool = True):
        self.num_regions = num_regions
        self._own_dir = directory is None
        self.directory = Path(directory) if directory is not None \
            else Path(tempfile.mkdtemp(prefix="stream_pool_"))
        self.directory.mkdir(parents=True, exist_ok=True)
        self.max_resident = max(1, int(max_resident))
        self.prefetch_enabled = bool(prefetch)
        self.versions = np.zeros(num_regions, dtype=np.int64)
        self._protected = np.full(num_regions, -1, dtype=np.int64)
        self._resident: dict[int, dict] = {}     # insertion order == LRU
        # accounting (cumulative; the sweep driver reports per-sweep deltas)
        self.staged_in_bytes = 0
        self.staged_out_bytes = 0
        self.loads = 0
        self.disk_loads = 0
        self.evictions = 0
        self.prefetch_hits = 0
        self.prefetch_wasted = 0
        self._pf_thread: threading.Thread | None = None
        self._pf_slot: dict | None = None

    # -- pool layout --------------------------------------------------------

    def _region_dir(self, r: int) -> Path:
        return self.directory / f"region_{r:05d}"

    def region_exists(self, r: int) -> bool:
        return _res.snapshot_latest(self._region_dir(r) / "topo") is not None

    # -- population (initial spill / shard-wise build) ----------------------

    def put_region(self, r: int, topo: dict, flow: dict) -> None:
        """Publish region r's initial version (topology + flow v0).

        Setup cost, not sweep traffic: the per-sweep staged-bytes deltas
        the driver reports start from whatever the counters hold after
        population, so these writes never show up in ``SweepStats``.
        """
        _res.snapshot_save(self._region_dir(r) / "topo", 0,
                           {k: np.asarray(v) for k, v in topo.items()})
        _res.snapshot_save(self._region_dir(r) / "state", 0,
                           {k: np.asarray(v) for k, v in flow.items()})
        self.versions[r] = 0

    def attach(self, versions: np.ndarray) -> None:
        """Adopt an existing pool at the given per-region versions (the
        checkpoint-resume entry; newer orphan versions a dead process
        published after the checkpoint are pruned on the next writeback)."""
        self.versions = np.asarray(versions, dtype=np.int64).copy()
        self.protect(self.versions)
        self._resident.clear()
        self._drop_prefetch()

    # -- staging ------------------------------------------------------------

    def _read(self, r: int) -> dict:
        topo, _ = _res._snapshot_arrays(self._region_dir(r) / "topo", 0)
        flow, _ = _res._snapshot_arrays(self._region_dir(r) / "state",
                                        int(self.versions[r]))
        return {"topo": topo, "flow": flow, "version": int(self.versions[r]),
                "bytes": _nbytes(topo) + _nbytes(flow)}

    def load(self, r: int) -> tuple[dict, dict]:
        """Stage region r in; returns ``(topo, flow)`` host arrays.

        Resident hit: free.  Prefetch hit: the background read's bytes
        count as staged in (they crossed the disk boundary), but no
        foreground read happens.  Miss: synchronous read.
        """
        self.loads += 1
        ent = self._resident.pop(r, None)
        if ent is not None and ent["version"] == int(self.versions[r]):
            self._resident[r] = ent              # LRU refresh
            return ent["topo"], ent["flow"]
        ent = self._take_prefetch(r)
        if ent is None:
            ent = self._read(r)
            self.disk_loads += 1
            self.staged_in_bytes += ent["bytes"]
        self._insert(r, ent)
        return ent["topo"], ent["flow"]

    def writeback(self, r: int, flow: dict) -> int:
        """Publish region r's next version (write-through); returns the
        byte count staged out."""
        flow = {k: np.asarray(v) for k, v in flow.items()}
        self.versions[r] += 1
        _res.snapshot_save(self._region_dir(r) / "state",
                           int(self.versions[r]), flow)
        nb = _nbytes(flow)
        self.staged_out_bytes += nb
        ent = self._resident.get(r)
        if ent is not None:
            ent["flow"] = flow
            ent["version"] = int(self.versions[r])
        self._prune(r)
        return nb

    def _insert(self, r: int, ent: dict) -> None:
        self._resident[r] = ent
        while len(self._resident) > self.max_resident:
            lru = next(iter(self._resident))
            del self._resident[lru]              # write-through: no flush
            self.evictions += 1

    # -- prefetch -----------------------------------------------------------

    def prefetch(self, r: int | None) -> None:
        """Start staging region r in the background (no-op when disabled,
        already resident, or a prefetch is already in flight)."""
        if (r is None or not self.prefetch_enabled
                or r in self._resident or self._pf_thread is not None):
            return
        slot = {"r": r, "want_version": int(self.versions[r])}

        def work():
            try:
                slot["ent"] = self._read(r)
            except Exception as e:               # surfaced on consume
                slot["error"] = e

        self._pf_slot = slot
        self._pf_thread = threading.Thread(target=work, daemon=True)
        self._pf_thread.start()

    def _take_prefetch(self, r: int) -> dict | None:
        if self._pf_thread is None:
            return None
        self._pf_thread.join()
        slot, self._pf_slot, self._pf_thread = self._pf_slot, None, None
        if "error" in slot:
            raise slot["error"]
        ent = slot.get("ent")
        if ent is None:
            return None
        self.staged_in_bytes += ent["bytes"]     # the read happened
        self.disk_loads += 1
        if slot["r"] != r or ent["version"] != int(self.versions[r]):
            self.prefetch_wasted += 1
            return None
        self.prefetch_hits += 1
        return ent

    def _drop_prefetch(self) -> None:
        if self._pf_thread is not None:
            self._pf_thread.join()
            self._pf_thread = None
            self._pf_slot = None

    # -- retention ----------------------------------------------------------

    def protect(self, versions: np.ndarray) -> None:
        """Pin one version per region (the latest checkpoint's) against
        pruning, releasing the previously pinned set."""
        self._protected = np.asarray(versions, dtype=np.int64).copy()

    def _prune(self, r: int) -> None:
        keep = {int(self.versions[r]), int(self._protected[r])}
        state_dir = self._region_dir(r) / "state"
        if not state_dir.exists():
            return
        for p in state_dir.iterdir():
            if not p.name.startswith("step_") or p.name.endswith(".tmp"):
                continue
            if int(p.name[5:]) not in keep:
                shutil.rmtree(p, ignore_errors=True)

    def close(self) -> None:
        self._drop_prefetch()
        self._resident.clear()
        if self._own_dir:
            shutil.rmtree(self.directory, ignore_errors=True)
