"""Out-of-core streaming executor: regions staged one at a time.

The fourth executor route (``core.executor.StreamingExecutor``) solves
instances bigger than device memory by keeping at most
``max_resident_regions`` region states in memory, spilling the rest to a
disk pool and exchanging only |B|-sized boundary messages between region
visits — the paper's sequential sweep (Alg. 1) made out-of-core.

Modules:

* ``store``    — spill pool, LRU resident set, background prefetch
* ``boundary`` — |B|-sized boundary exchange layer + pending-flow ledger
* ``executor`` — staged sweep loop, solve driver, checkpoint/resume
* ``build``    — shard-wise build (never materializes [K, V, E])
"""

from repro.stream.boundary import BoundaryPlan, BoundaryState, make_plan
from repro.stream.build import build_stream
from repro.stream.executor import (StreamState, assemble_state, open_stream,
                                   solve_stream, stream_sweep, trace_count)
from repro.stream.store import StreamStore

__all__ = [
    "BoundaryPlan", "BoundaryState", "make_plan", "build_stream",
    "StreamState", "assemble_state", "open_stream", "solve_stream",
    "stream_sweep", "trace_count", "StreamStore",
]
