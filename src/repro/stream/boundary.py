"""Boundary exchange layer of the streaming executor.

Between region visits the ONLY state in memory is |B|-sized (plus the
O(|cross arcs|) pending-flow ledger): the boundary vertices' labels and
excess, and the flow pushed across the cut that the receiving region has
not staged in yet.  This is the paper's streaming invariant — "regions
are loaded into the memory one-by-one" — made literal: everything a
discharge needs about the rest of the graph is the ghost labels of its
cross arcs, and everything it tells the rest of the graph is the flow it
pushed over them.

Correctness relies on two facts about the sequential sweep (Alg. 1):

* cross-arc endpoints are boundary vertices by construction, so interior
  excess/labels of a region can only change while that region is being
  discharged — a per-region interior-active counter updated at writeback
  time stays exact between visits;
* a pushed boundary flow raises the receiver's excess immediately
  (``e_B``) while the arc-level residual update can be parked in ``pend``
  until the receiving region is staged in — applying it at load time is
  bit-identical to the resident sweep's immediate ``_apply_cross_flow``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class BoundaryPlan:
    """Static index tables tying the cross-arc table to boundary ids.

    Boundary vertices get global ids ("bids") in (region, local) order.
    Per region r: ``bnd_local[r]``/``bnd_bid[r]`` name its boundary
    vertices; the *out* tables index the valid cross arcs sourced in r
    (arc slot in r's [V,E] rows + the receiver's bid); the *in* tables
    index the arcs terminating in r (the slots ``pend`` flushes into at
    load time).  Everything is O(|B| + |cross arcs|) — never O(n).
    """

    num_regions: int
    num_boundary: int
    num_cross: int                      # valid cross arcs (pend length)
    bnd_local: list = field(default_factory=list)   # [K] i64[b_r]
    bnd_bid: list = field(default_factory=list)     # [K] i64[b_r]
    out_x: list = field(default_factory=list)       # [K] i64 -> pend index
    out_l: list = field(default_factory=list)       # [K] source local id
    out_s: list = field(default_factory=list)       # [K] source arc slot
    out_dst_bid: list = field(default_factory=list)  # [K] receiver bid
    in_x: list = field(default_factory=list)        # [K] i64 -> pend index
    in_l: list = field(default_factory=list)        # [K] receiver local id
    in_s: list = field(default_factory=list)        # [K] receiver arc slot


def make_plan(cross_src: np.ndarray, cross_dst: np.ndarray,
              cross_valid: np.ndarray, num_regions: int) -> BoundaryPlan:
    """Derive the boundary plan from the build-time cross tables."""
    cross_src = np.asarray(cross_src)
    cross_dst = np.asarray(cross_dst)
    xs = np.nonzero(np.asarray(cross_valid))[0]
    src = cross_src[xs].astype(np.int64)
    dst = cross_dst[xs].astype(np.int64)
    K = num_regions

    # bids in (region, local) order over the union of cross endpoints
    pairs = np.concatenate([src[:, :2], dst[:, :2]], axis=0)
    if len(pairs) == 0:
        uniq = np.zeros((0, 2), dtype=np.int64)
    else:
        flat = pairs[:, 0] * (pairs[:, 1].max() + 1) + pairs[:, 1]
        _, first = np.unique(flat, return_index=True)
        uniq = pairs[np.sort(first)]
        order = np.lexsort((uniq[:, 1], uniq[:, 0]))
        uniq = uniq[order]
    nb = len(uniq)
    region_of = uniq[:, 0]
    starts = np.searchsorted(region_of, np.arange(K + 1))

    def bid_of(region: np.ndarray, local: np.ndarray) -> np.ndarray:
        out = np.empty(len(region), dtype=np.int64)
        for r in range(K):
            sel = region == r
            if not sel.any():
                continue
            locals_r = uniq[starts[r]:starts[r + 1], 1]
            out[sel] = starts[r] + np.searchsorted(locals_r, local[sel])
        return out

    plan = BoundaryPlan(num_regions=K, num_boundary=nb, num_cross=len(xs))
    dst_bid_all = bid_of(dst[:, 0], dst[:, 1])
    for r in range(K):
        locals_r = uniq[starts[r]:starts[r + 1], 1]
        plan.bnd_local.append(locals_r.copy())
        plan.bnd_bid.append(np.arange(starts[r], starts[r + 1],
                                      dtype=np.int64))
        o = np.nonzero(src[:, 0] == r)[0]
        plan.out_x.append(o)
        plan.out_l.append(src[o, 1])
        plan.out_s.append(src[o, 2])
        plan.out_dst_bid.append(dst_bid_all[o])
        i = np.nonzero(dst[:, 0] == r)[0]
        plan.in_x.append(i)
        plan.in_l.append(dst[i, 1])
        plan.in_s.append(dst[i, 2])
    return plan


@dataclass
class BoundaryState:
    """The mutable between-visit state: |B| labels/excess + pending flow.

    ``e_B`` is authoritative for boundary excess (receivers' excess rises
    the moment a push happens); ``pend[x]`` holds the receiver-side
    residual increment of valid cross arc x until its region stages in.
    ``interior_active[r]`` counts active non-boundary vertices of r as of
    its last writeback — exact between visits (see module docstring).
    """

    d_B: np.ndarray              # label dtype [NB]
    e_B: np.ndarray              # flow dtype  [NB]
    pend: np.ndarray             # flow dtype  [num_cross]
    interior_active: np.ndarray  # i64 [K]
    flow_to_t: int = 0

    @classmethod
    def zeros(cls, plan: BoundaryPlan, label_np, flow_np) -> "BoundaryState":
        return cls(
            d_B=np.zeros(plan.num_boundary, dtype=label_np),
            e_B=np.zeros(plan.num_boundary, dtype=flow_np),
            pend=np.zeros(plan.num_cross, dtype=flow_np),
            interior_active=np.zeros(plan.num_regions, dtype=np.int64))

    def absorb_region(self, plan: BoundaryPlan, r: int, flow: dict,
                      is_boundary: np.ndarray, vmask: np.ndarray,
                      d_inf: int) -> None:
        """Refresh the boundary view of region r from its staged arrays
        (initial spill and post-discharge writeback share this)."""
        bl, bb = plan.bnd_local[r], plan.bnd_bid[r]
        self.d_B[bb] = flow["d"][bl]
        self.e_B[bb] = flow["excess"][bl]
        self.interior_active[r] = int(
            ((flow["excess"] > 0) & (flow["d"] < d_inf)
             & vmask & ~is_boundary).sum())

    def region_active(self, r: int, plan: BoundaryPlan, d_inf: int) -> bool:
        """The Alg. 1 skip test without staging the region in."""
        if self.interior_active[r] > 0:
            return True
        bb = plan.bnd_bid[r]
        return bool(((self.e_B[bb] > 0) & (self.d_B[bb] < d_inf)).any())

    def num_active(self, d_inf: int) -> int:
        return int(self.interior_active.sum()) + int(
            ((self.e_B > 0) & (self.d_B < d_inf)).sum())

    def payload(self) -> dict:
        """Checkpoint payload (everything but the spill pool itself)."""
        return {"d_B": self.d_B, "e_B": self.e_B, "pend": self.pend,
                "interior_active": self.interior_active,
                "flow_to_t": np.asarray(self.flow_to_t, np.int64)}

    def restore(self, payload: dict) -> None:
        self.d_B = np.asarray(payload["d_B"], dtype=self.d_B.dtype)
        self.e_B = np.asarray(payload["e_B"], dtype=self.e_B.dtype)
        self.pend = np.asarray(payload["pend"], dtype=self.pend.dtype)
        self.interior_active = np.asarray(payload["interior_active"],
                                          dtype=np.int64)
        self.flow_to_t = int(payload["flow_to_t"])
