"""Shard-wise build: a ``Problem`` -> spill pool, one region at a time.

``core.graph.build`` materializes the full ``[K, V, E]`` topology and
flow arrays — exactly what an out-of-core solve must avoid.  This build
produces the SAME layout region by region (bit-identical slabs: local
ids and arc slots come from the same stable-cumcount derivation, see
``graph._stable_cumcount``) while only ever holding

* O(n + m) 1-D index vectors (the problem description itself), and
* ONE region's [V, E] slabs at a time, written straight to the pool.

The returned ``GraphMeta`` is field-identical to ``build``'s, so solve
fingerprints, sweep bounds and dtype selection agree across the resident
and streaming entries.
"""

from __future__ import annotations

import numpy as np

from repro.core import dtypes as _dt
from repro.core.graph import GraphMeta, _check_problem, _stable_cumcount
from repro.stream.boundary import BoundaryState, make_plan
from repro.stream.store import StreamStore


def build_stream(problem, part, cfg, *, spill_dir=None,
                 max_resident_regions: int = 2, prefetch: bool = True,
                 dtype_policy: str = "int32"):
    """Block a flat problem straight into a spill pool.

    Returns a ready-to-solve ``stream.StreamState`` — hand it to
    ``stream.solve_stream``.  Layout-compatible with ``core.build``: the
    same partition yields byte-identical per-region slabs.
    """
    from repro.stream.executor import StreamState

    _check_problem(problem)
    n = problem.num_vertices
    part = np.asarray(part, dtype=np.int64)
    assert part.shape == (n,)
    K = int(part.max()) + 1 if n else 1
    local_id = _stable_cumcount(part)
    region_count = np.bincount(part, minlength=K)
    V = max(1, int(region_count.max()) if n else 0)

    u_arr = problem.edges[:, 0]
    v_arr = problem.edges[:, 1]
    m = len(problem.edges)
    deg = np.zeros(n, dtype=np.int64)
    np.add.at(deg, u_arr, 1)
    np.add.at(deg, v_arr, 1)
    E = max(1, int(deg.max()) if n else 1)
    del deg

    occ = np.empty(2 * m, dtype=np.int64)
    occ[0::2] = u_arr
    occ[1::2] = v_arr
    cc = _stable_cumcount(occ)
    del occ
    slot_u = cc[0::2].astype(np.int32)
    slot_v = cc[1::2].astype(np.int32)
    del cc

    ru = part[u_arr].astype(np.int32)
    rv = part[v_arr].astype(np.int32)

    # the flat cross-arc table (mutual-reverse pairs at (2i, 2i+1)) —
    # O(|cross arcs|), kept in memory like the boundary layer itself
    cross = np.nonzero(ru != rv)[0]
    nc = len(cross)
    X = max(1, 2 * nc)
    cross_src = np.zeros((X, 3), dtype=np.int32)
    cross_dst = np.zeros((X, 3), dtype=np.int32)
    cross_valid = np.zeros(X, dtype=bool)
    num_groups = 1
    if nc:
        a = np.column_stack([ru[cross], local_id[u_arr[cross]],
                             slot_u[cross]]).astype(np.int32)
        b = np.column_stack([rv[cross], local_id[v_arr[cross]],
                             slot_v[cross]]).astype(np.int32)
        cross_src[0:2 * nc:2] = a
        cross_src[1:2 * nc:2] = b
        cross_dst[0:2 * nc:2] = b
        cross_dst[1:2 * nc:2] = a
        cross_valid[:2 * nc] = True
        keys = (cross_src[:2 * nc, 0].astype(np.int64) * (K * V)
                + cross_dst[:2 * nc, 0].astype(np.int64) * V
                + cross_dst[:2 * nc, 1])
        num_groups = max(1, len(np.unique(keys)))
        del keys, a, b

    plan = make_plan(cross_src, cross_dst, cross_valid, K)
    num_boundary = plan.num_boundary

    mass = _dt.flow_mass(problem)
    bound = _dt.label_bound(n, V)
    kd = _dt.select_dtypes(dtype_policy, mass=mass, bound=bound)
    bad = _dt.narrow_violations(dtype_policy, mass=mass, bound=bound)
    if bad:
        from repro.core.graph import ProblemValidationError
        family, dt, value, limit = bad[0]
        raise ProblemValidationError(
            f"invalid build: {family} range {value} exceeds the {dt} "
            f"bound {limit} under dtype_policy='narrow'")

    meta = GraphMeta(
        num_regions=K, region_size=V, max_degree=E, num_vertices=n,
        num_boundary=num_boundary, num_cross_arcs=X,
        num_ghost_groups=num_groups, d_inf_ard=max(1, num_boundary),
        d_inf_prd=max(1, n), label_dtype=kd.label, flow_dtype=kd.flow,
        mask_dtype=kd.mask)

    store = StreamStore(K, spill_dir, max_resident=max_resident_regions,
                        prefetch=prefetch)
    bnd = BoundaryState.zeros(plan, kd.label_np, kd.flow_np)
    ss = StreamState(meta=meta, cfg=cfg, store=store, plan=plan, bnd=bnd)
    d_inf = ss.d_inf

    # directed-arc records in owner-region order: record 2i is u->v of
    # edge i (owner u's row), 2i+1 is v->u.  Only the sort permutation is
    # materialized; per-region columns are gathered from the 1-D problem
    # vectors through it, one region at a time.
    owner = np.empty(2 * m, dtype=np.int32)
    owner[0::2] = ru
    owner[1::2] = rv
    del ru, rv
    aorder = np.argsort(owner, kind="stable")
    astarts = np.searchsorted(owner[aorder], np.arange(K + 1))
    del owner
    vorder = np.argsort(part, kind="stable")
    vstarts = np.searchsorted(part[vorder], np.arange(K + 1))

    for r in range(K):
        sel = aorder[astarts[r]:astarts[r + 1]]
        e = sel >> 1
        fwd = (sel & 1) == 0                      # u->v records
        row = np.where(fwd, local_id[u_arr[e]], local_id[v_arr[e]])
        slot = np.where(fwd, slot_u[e], slot_v[e]).astype(np.int64)
        nbrr = np.where(fwd, part[v_arr[e]], part[u_arr[e]])
        nbrl = np.where(fwd, local_id[v_arr[e]], local_id[u_arr[e]])
        rslot = np.where(fwd, slot_v[e], slot_u[e])
        cap = np.where(fwd, problem.cap_fwd[e], problem.cap_bwd[e])

        nbr_region = np.zeros((V, E), dtype=np.int32)
        nbr_local = np.zeros((V, E), dtype=np.int32)
        rev_slot = np.zeros((V, E), dtype=np.int32)
        emask = np.zeros((V, E), dtype=bool)
        cf = np.zeros((V, E), dtype=kd.flow_np)
        nbr_region[row, slot] = nbrr.astype(np.int32)
        nbr_local[row, slot] = nbrl.astype(np.int32)
        rev_slot[row, slot] = rslot.astype(np.int32)
        emask[row, slot] = True
        cf[row, slot] = cap.astype(kd.flow_np)
        del e, fwd, row, slot, nbrr, nbrl, rslot, cap, sel

        vsel = vorder[vstarts[r]:vstarts[r + 1]]
        locs = local_id[vsel]
        vmask = np.zeros(V, dtype=bool)
        vmask[locs] = True
        sink_cf = np.zeros(V, dtype=kd.flow_np)
        sink_cf[locs] = problem.sink_cap[vsel].astype(kd.flow_np)
        excess = np.zeros(V, dtype=kd.flow_np)
        excess[locs] = problem.excess[vsel].astype(kd.flow_np)
        is_boundary = np.zeros(V, dtype=bool)
        is_boundary[plan.bnd_local[r]] = True
        d = np.zeros(V, dtype=kd.label_np)

        topo = {"nbr_region": nbr_region, "nbr_local": nbr_local,
                "rev_slot": rev_slot, "emask": emask, "vmask": vmask,
                "is_boundary": is_boundary}
        flow = {"cf": cf, "sink_cf": sink_cf, "excess": excess, "d": d}
        store.put_region(r, topo, flow)
        bnd.absorb_region(plan, r, flow, is_boundary, vmask, d_inf)

    return ss
