"""The streaming sweep: load -> discharge -> write back -> exchange.

One sweep visits regions 0..K-1 in order, exactly Alg. 1, except that a
region's [V,E] slabs live on disk between visits and the inter-visit
state is the |B|-sized boundary layer:

    for k in 0..K-1:
        if region k has no active vertex: continue        # zero I/O
        topo, flow = store.load(k)                        # staged in
        store.prefetch(next active region)                # overlaps ...
        apply pend (incoming cross flow) + e_B            #  ... compute
        ghost   = labels of k's neighbours (own d + d_B)
        result  = fused per-region discharge (device)     # same engine,
        flow_to_t += sink_pushed                          #  same dtypes,
        pend/e_B += out_push over k's out arcs            #  same chunking
        d_B/e_B[k's boundary] = new labels/excess
        store.writeback(k, new flow family)               # staged out

Bit-exactness vs the resident ``sequential_sweep`` holds because (a) the
per-region discharge is the SAME jitted operator on bit-identical
inputs — the ghost gather differs only at emask-invalid slots, which the
engine never reads; (b) boundary pushes apply to the receiver before its
visit, matching the immediate ``_apply_cross_flow``; (c) the skip test
``region_active`` equals the resident ``any(active)`` per region (see
``boundary.py``).  The conformance suite asserts this per state field
across ard/prd x engine backends.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core import executor as _executor
from repro.core import resilience as _res
from repro.core.ard import ard_discharge_one
from repro.core.prd import prd_discharge_one
from repro.core.sweep import (SweepStats, _page_and_msg_bytes, stats_from_dict,
                              stats_to_dict, sweep_bound)
from repro.stream.boundary import BoundaryPlan, BoundaryState
from repro.stream.store import FLOW_FIELDS, StreamStore

# traces of the jitted per-region discharge — one per (shape, dtypes,
# config); every staged region of every sweep reuses it.  Counted into
# ``Solver.cache_info`` with the other routes' programs.
_TRACE_COUNT = 0


def trace_count() -> int:
    return _TRACE_COUNT


def _bump_trace() -> None:
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def _make_discharge(meta, cfg):
    """One jitted [V,E] discharge shared by every region of the solve."""
    import jax
    import jax.numpy as jnp

    d_inf = meta.d_inf_ard if cfg.method == "ard" else meta.d_inf_prd

    def fn(cf, sink_cf, excess, d, ghost, stage_cap,
           nbr_local, rev_slot, intra, emask, vmask):
        _bump_trace()
        kw = dict(nbr_local=nbr_local, rev_slot=rev_slot, intra=intra,
                  emask=emask, vmask=vmask, d_inf=d_inf,
                  max_iters=cfg.engine_max_iters,
                  backend=cfg.engine_backend,
                  chunk_iters=cfg.engine_chunk_iters)
        if cfg.method == "ard":
            res = ard_discharge_one(cf, sink_cf, excess, ghost,
                                    stage_cap=stage_cap, **kw)
        else:
            res = prd_discharge_one(cf, sink_cf, excess, d, ghost, **kw)
        return (res.cf, res.sink_cf, res.excess, jnp.maximum(d, res.d),
                res.out_push, res.sink_pushed, res.engine_iters,
                res.engine_launches)

    return jax.jit(fn)


@dataclass
class StreamState:
    """Everything the host loop threads through a streaming solve.

    NOT a ``FlowState``: the resident footprint is the boundary layer
    plus the store's ``max_resident`` region slabs.  Duck-types the two
    surfaces the generic drivers touch (``num_active``; the state slot
    of ``executor.run_host``/the fault hook).
    """

    meta: Any
    cfg: Any
    store: StreamStore
    plan: BoundaryPlan
    bnd: BoundaryState
    _discharge: Any = None
    _sweep_stats: dict = field(default_factory=dict)

    def __post_init__(self):
        if self._discharge is None:
            self._discharge = _make_discharge(self.meta, self.cfg)

    @property
    def d_inf(self) -> int:
        return self.meta.d_inf_ard if self.cfg.method == "ard" \
            else self.meta.d_inf_prd

    def num_active(self) -> int:
        return self.bnd.num_active(self.d_inf)

    def payload(self) -> dict:
        """Checkpoint payload: boundary layer + the pool version vector.
        The region slabs themselves are already durable in the pool."""
        p = self.bnd.payload()
        p["versions"] = self.store.versions.copy()
        return p

    def restore(self, payload: dict) -> None:
        self.bnd.restore(payload)
        self.store.attach(payload["versions"])


def _materialize_region(ss: StreamState, k: int) -> tuple[dict, dict]:
    """Stage region k in with its pending cross flow applied.

    Returns ``(topo, flow)`` where ``flow`` is a fresh copy (the resident
    cache is never mutated in place): residuals get the parked ``pend``
    increments, boundary excess syncs from the authoritative ``e_B``.
    """
    topo, flow0 = ss.store.load(k)
    flow = {f: flow0[f].copy() for f in FLOW_FIELDS}
    plan = ss.plan
    ix = plan.in_x[k]
    if len(ix):
        np.add.at(flow["cf"], (plan.in_l[k], plan.in_s[k]), ss.bnd.pend[ix])
        ss.bnd.pend[ix] = 0
    bl = plan.bnd_local[k]
    if len(bl):
        flow["excess"][bl] = ss.bnd.e_B[plan.bnd_bid[k]]
    return topo, flow


def _next_active(ss: StreamState, k: int) -> int | None:
    """First region after k the sweep will visit (active regions only
    gain activity until discharged, so this prediction cannot go stale —
    at worst an intermediate region turns active first and the prefetch
    is consumed one visit later than planned)."""
    for j in range(k + 1, ss.meta.num_regions):
        if ss.bnd.region_active(j, ss.plan, ss.d_inf):
            return j
    return None


def stream_sweep(ss: StreamState, idx) -> tuple[StreamState, tuple]:
    """One full sweep over staged regions; the ``sweep_host`` body of
    ``StreamingExecutor``.  Returns ``(ss, obs)`` with obs =
    ``(n_active, flow_to_t, engine_iters, engine_launches,
    regions_discharged, staged_in_delta, staged_out_delta)`` — the first
    five exactly the resident host loop's observation tuple.
    """
    import jax

    meta, cfg, plan, bnd = ss.meta, ss.cfg, ss.plan, ss.bnd
    d_inf = ss.d_inf
    in0 = ss.store.staged_in_bytes
    out0 = ss.store.staged_out_bytes
    iters = launches = discharged = 0
    sweep_idx = int(idx)
    stage_cap = np.int32(max(sweep_idx - 1, -1)) if cfg.partial_discharge \
        else np.int32(meta.d_inf_ard)

    for k in range(meta.num_regions):
        if not bnd.region_active(k, plan, d_inf):
            continue
        topo, flow = _materialize_region(ss, k)
        ss.store.prefetch(_next_active(ss, k))
        own = topo["nbr_region"] == k
        intra = own & topo["emask"]
        # ghost labels: own region's labels through nbr_local (intra
        # slots), the boundary layer's labels on cross slots; invalid
        # slots are never read by the engine (emask-masked)
        ghost = flow["d"][topo["nbr_local"]]
        ol, os_, ox = plan.out_l[k], plan.out_s[k], plan.out_x[k]
        if len(ox):
            ghost[ol, os_] = bnd.d_B[plan.out_dst_bid[k]]
        out = ss._discharge(flow["cf"], flow["sink_cf"], flow["excess"],
                            flow["d"], ghost, stage_cap,
                            topo["nbr_local"], topo["rev_slot"], intra,
                            topo["emask"], topo["vmask"])
        (cf, sink_cf, excess, d, out_push, sink_pushed, it, ln) = (
            np.asarray(a) for a in jax.device_get(out))
        bnd.flow_to_t += int(sink_pushed)
        iters += int(it)
        launches += int(ln)
        discharged += 1
        if len(ox):
            deltas = out_push[ol, os_]
            np.add.at(bnd.pend, ox, deltas)
            np.add.at(bnd.e_B, plan.out_dst_bid[k], deltas)
        new_flow = {"cf": cf, "sink_cf": sink_cf, "excess": excess, "d": d}
        bnd.absorb_region(plan, k, new_flow, topo["is_boundary"],
                          topo["vmask"], d_inf)
        ss.store.writeback(k, new_flow)

    obs = (bnd.num_active(d_inf), bnd.flow_to_t, iters, launches,
           discharged, ss.store.staged_in_bytes - in0,
           ss.store.staged_out_bytes - out0)
    return ss, obs


# --------------------------------------------------------------------------
# opening a stream (spill) and closing one (assemble)
# --------------------------------------------------------------------------

def open_stream(meta, state, cfg, *, spill_dir=None, max_resident_regions=2,
                prefetch=True, cold_labels=True) -> StreamState:
    """Spill a built ``FlowState`` into a fresh pool, one region at a time.

    The session front-end's entry: the state is already resident there,
    so this is a staging pass, not a memory win — the win is every sweep
    after it.  For instances that never fit, build shard-wise instead
    (``repro.stream.build.build_stream``).  ``cold_labels`` zeroes ``d``
    during the spill (the cold-start ``Init``), saving the separate
    device-side zeroing pass.
    """
    from repro.core import graph as _graph
    from repro.stream.boundary import make_plan

    store = StreamStore(meta.num_regions, spill_dir,
                        max_resident=max_resident_regions, prefetch=prefetch)
    plan = make_plan(np.asarray(state.cross_src), np.asarray(state.cross_dst),
                     np.asarray(state.cross_valid), meta.num_regions)
    assert plan.num_boundary == meta.num_boundary, \
        (plan.num_boundary, meta.num_boundary)
    kd = meta.kernel_dtypes
    bnd = BoundaryState.zeros(plan, kd.label_np, kd.flow_np)
    ss = StreamState(meta=meta, cfg=cfg, store=store, plan=plan, bnd=bnd)
    flow_to_t = int(np.asarray(state.flow_to_t))
    d_inf = ss.d_inf
    for r in range(meta.num_regions):
        topo = _graph.extract_region(state, r, _graph.REGION_TOPO_FIELDS)
        flow = _graph.extract_region(state, r, _graph.REGION_FLOW_FIELDS)
        if cold_labels:
            flow["d"] = np.zeros_like(flow["d"])
        store.put_region(r, topo, flow)
        bnd.absorb_region(plan, r, flow, topo["is_boundary"], topo["vmask"],
                          d_inf)
    bnd.flow_to_t = flow_to_t
    return ss


def assemble_state(ss: StreamState, state):
    """Reassemble a resident ``FlowState`` from the streamed shards (cut
    extraction / certificate checks).  Pending cross flow is flushed into
    each region as it is staged, so the result is exact even when the
    solve stopped at the sweep cap."""
    import jax.numpy as jnp

    from repro.core import graph as _graph

    for r in range(ss.meta.num_regions):
        _, flow = _materialize_region(ss, r)
        state = _graph.insert_region(state, r, flow)
    return state.replace(flow_to_t=jnp.asarray(ss.bnd.flow_to_t,
                                               state.flow_to_t.dtype))


# --------------------------------------------------------------------------
# the solve driver (mirrors sweep._solve_host, 7-tuple observations)
# --------------------------------------------------------------------------

def solve_stream(ss: StreamState, *, on_sweep=None, checkpoint=None,
                 resume_from=None, salt: str = ""):
    """Run streamed sweeps to convergence; returns ``(ss, SweepStats)``.

    Checkpoints ride the existing ``CheckpointPolicy`` at sweep
    boundaries with route ``"stream"``: the payload is the |B|-sized
    boundary layer + the pool's per-region version vector — the region
    slabs are already durable in the pool (a streaming solve IS a
    sequence of region checkpoints), so capture cost is O(|B|), not
    O(n).  Resume re-attaches the pool at the checkpointed versions and
    is bit-exact, including across a SIGKILL mid-sweep (newer orphan
    versions the dead process published are pruned on the next
    writeback).
    """
    meta, cfg = ss.meta, ss.cfg
    _executor.StreamingExecutor.validate(cfg)
    ex = _executor.StreamingExecutor(meta, cfg)
    if checkpoint is not None:
        salt = checkpoint.salt
    fp = _res.solve_fingerprint(meta, cfg, salt)
    ckpt = _res.resolve_resume(resume_from, fp)
    bound = sweep_bound(meta, cfg)
    max_sweeps = cfg.max_sweeps if cfg.max_sweeps is not None else bound
    page_bytes, msg_bytes = _page_and_msg_bytes(meta)

    seed = None
    start = 0
    if ckpt is not None:
        ss.restore(ckpt.payload)
        seed = stats_from_dict(ckpt.stats)
        seed.active_curve = seed.active_curve[:len(seed.flow_curve)]
        start = ckpt.sweeps

    def build(trace, active_pre, syncs, sweeps):
        stats = SweepStats() if seed is None else stats_from_dict(
            stats_to_dict(seed))
        stats.host_syncs += syncs
        stats.sweeps = sweeps
        stats.active_curve = stats.active_curve + active_pre
        stats.flow_curve = list(stats.flow_curve)
        stats.degraded = list(stats.degraded)
        for n_act, flow, it, ln, dc, sin, sout in trace:
            stats.engine_iters += it
            stats.engine_launches += ln
            stats.regions_discharged += dc
            stats.page_bytes += dc * page_bytes
            stats.boundary_bytes += msg_bytes
            stats.staged_in_bytes += sin
            stats.staged_out_bytes += sout
            stats.flow_curve.append(flow)
        stats.num_boundary = meta.num_boundary
        return stats

    on_obs = None
    last_saved = [start]
    if checkpoint is not None:
        def on_obs(st, idx, trace, active_pre):
            if idx - last_saved[0] < checkpoint.every:
                return
            _save_ckpt(st, idx, trace, active_pre)

        def _save_ckpt(st, idx, trace, active_pre):
            stats = build(trace, active_pre, 1 + len(trace), idx)
            stats.converged = bool(trace and trace[-1][0] == 0)
            payload = st.payload()
            payload["n_act"] = np.asarray(
                trace[-1][0] if trace else 0, np.int32)
            _res.save_checkpoint(checkpoint.directory, _res.SolveCheckpoint(
                fingerprint=fp, route="stream", sweeps=idx, payload=payload,
                stats=stats_to_dict(stats),
                flow_offset=checkpoint.flow_offset))
            st.store.protect(payload["versions"])
            last_saved[0] = idx

    ss, trace, active_pre, syncs, sweeps = _executor.run_host(
        ex, ss, max_sweeps, on_sweep=on_sweep, start=start, on_obs=on_obs)
    stats = build(trace, active_pre, syncs, sweeps)
    if trace:
        stats.converged = trace[-1][0] == 0
    elif active_pre:
        stats.converged = active_pre[-1] == 0
    elif seed is not None:
        stats.converged = bool(seed.converged)
    if checkpoint is not None and sweeps > last_saved[0]:
        _save_ckpt(ss, sweeps, trace, active_pre)
    return ss, stats
