"""Serving steps: prefill and single-token decode (the serve_step the
decode_*/long_* dry-run shapes lower), plus a batched greedy-decode driver.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import shardings as shd
from repro.models import model as model_lib


def make_prefill_step(cfg: ArchConfig, dtype=jnp.bfloat16,
                      unroll: int | bool = 1, q_chunk: int | None = None,
                      act_sharding=None):
    def prefill(params, batch, cache):
        return model_lib.forward(cfg, params, batch, mode="prefill",
                                 cache=cache, dtype=dtype,
                                 scan_unroll=unroll, attn_q_chunk=q_chunk,
                                 attn_chunk_unroll=unroll,
                                 act_sharding=act_sharding)
    return prefill


def make_decode_step(cfg: ArchConfig, dtype=jnp.bfloat16,
                     unroll: int | bool = 1):
    def decode(params, tokens, cache):
        logits, cache = model_lib.forward(
            cfg, params, {"tokens": tokens}, mode="decode", cache=cache,
            dtype=dtype, scan_unroll=unroll)
        return logits, cache
    return decode


def decode_batch_specs(cfg: ArchConfig, batch: int):
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)


def cache_specs_struct(cfg: ArchConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, max_seq, dtype))


def make_sharded_decode_step(cfg: ArchConfig, mesh: Mesh, batch: int,
                             max_seq: int, dtype=jnp.bfloat16,
                             unroll: int | bool = 1):
    params_shape = jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    p_shard = shd.param_shardings(cfg, mesh, params_shape)
    cache_shape = cache_specs_struct(cfg, batch, max_seq, dtype)
    c_shard = shd.cache_specs(cfg, mesh, cache_shape)
    tok_shard = NamedSharding(mesh, shd.batch_pspec(mesh)
                              if batch % _dp(mesh) == 0
                              else P())
    step = make_decode_step(cfg, dtype, unroll=unroll)
    jit_step = jax.jit(step,
                       in_shardings=(p_shard, tok_shard, c_shard),
                       out_shardings=(None, c_shard),
                       donate_argnums=(2,))
    return jit_step, p_shard, c_shard, tok_shard


def _dp(mesh: Mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        if a in ("pod", "data"):
            n *= mesh.shape[a]
    return n


def greedy_generate(cfg: ArchConfig, params, prompt_tokens, steps: int,
                    max_seq: int, dtype=jnp.float32):
    """Small-scale greedy generation (examples / tests; single device)."""
    B, S = prompt_tokens.shape
    cache = model_lib.init_cache(cfg, B, max_seq, dtype)
    logits, cache = model_lib.forward(
        cfg, params, {"tokens": prompt_tokens}, mode="prefill", cache=cache,
        dtype=dtype)
    toks = [jnp.argmax(logits, -1)[:, None]]
    for _ in range(steps - 1):
        logits, cache = model_lib.forward(
            cfg, params, {"tokens": toks[-1]}, mode="decode", cache=cache,
            dtype=dtype)
        toks.append(jnp.argmax(logits, -1)[:, None])
    return jnp.concatenate(toks, axis=1)
