"""Gradient compression with error feedback.

At 1000+ node scale the cross-pod gradient all-reduce is the dominant
collective; int8 block-quantised gradients cut its bytes 4x (bf16) to 8x
(f32).  The compressor is a composable hook applied to the global gradient
before the optimizer update:

  * int8 symmetric block quantisation (block = last dim) with an f32 scale
    per block — quantise, (all-reduce happens on the quantised values in a
    real deployment; under GSPMD the reduction is already placed, so here
    the hook models the *quantisation error path*), dequantise;
  * error feedback (Seide et al.): the quantisation residual is carried in
    an f32 buffer and added to the next step's gradient, which restores
    convergence to the uncompressed trajectory.

Use ``make_error_feedback_compressor`` to get a (compress_fn, init_state)
pair; the train driver threads the EF state.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8(g: jax.Array) -> jax.Array:
    q, s = quantize_int8(g)
    return dequantize_int8(q, s)


def init_ef_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads, ef_state):
    """Error-feedback int8 compression: returns (compressed, new_ef)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        comp = compress_int8(corrected)
        return comp.astype(g.dtype), corrected - comp

    out = jax.tree.map(one, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef


def make_plain_compressor() -> Callable:
    """Stateless int8 compressor (no error feedback) for the optimizer hook."""
    return lambda grads: jax.tree.map(
        lambda g: compress_int8(g).astype(g.dtype), grads)
