"""Fault-tolerant training driver: checkpoint/restart, retry with backoff,
straggler detection, elastic resume.

Designed for the 1000+ node posture and exercised (with injected faults) in
tests/test_fault.py:

* every step runs under a watchdog budget — a step exceeding
  ``straggler_factor`` x the trailing median is recorded as a straggler
  event (on a real pod this triggers requeueing the step on the backup
  slice; here it is surfaced to the caller's policy hook);
* any exception inside a step triggers restore-from-latest + replay; the
  data pipeline is step-keyed so replays are exact;
* checkpoints are atomic (train/checkpoint.py) and elastic — a restart may
  come back on a different mesh and restores with the new shardings.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.train import checkpoint as ckpt


@dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_retries: int = 3
    straggler_factor: float = 3.0
    min_history: int = 5


@dataclass
class FaultStats:
    restarts: int = 0
    straggler_events: int = 0
    steps_replayed: int = 0
    step_times: list = field(default_factory=list)


def run_training(
    *,
    state: Any,
    state_shardings: Any,
    train_step: Callable,
    make_batch: Callable,            # step -> device batch
    num_steps: int,
    cfg: FaultConfig | None = None,
    on_metrics: Callable | None = None,
    inject_fault: Callable | None = None,   # step -> None | Exception
) -> tuple[Any, FaultStats]:
    """Drive training with checkpoint/restart + straggler accounting."""
    cfg = cfg or FaultConfig()
    stats = FaultStats()

    start = ckpt.latest_step(cfg.ckpt_dir)
    step = 0
    if start is not None:
        state = ckpt.restore(cfg.ckpt_dir, start, state, state_shardings)
        step = start
        stats.restarts += 1

    retries = 0
    while step < num_steps:
        t0 = time.time()
        try:
            if inject_fault is not None:
                err = inject_fault(step)
                if err is not None:
                    raise err
            batch = make_batch(step)
            state, metrics = train_step(state, batch)
            # block for real step time (straggler watch needs wall time)
            import jax
            jax.block_until_ready(
                jax.tree.leaves(metrics)[0] if metrics else state)
        except Exception:
            retries += 1
            stats.restarts += 1
            if retries > cfg.max_retries:
                raise
            last = ckpt.latest_step(cfg.ckpt_dir)
            if last is not None:
                state = ckpt.restore(cfg.ckpt_dir, last, state,
                                     state_shardings)
                stats.steps_replayed += step - last
                step = last
            continue
        retries = 0
        dt = time.time() - t0
        hist = stats.step_times
        if len(hist) >= cfg.min_history:
            med = sorted(hist[-20:])[len(hist[-20:]) // 2]
            if dt > cfg.straggler_factor * med:
                stats.straggler_events += 1
        hist.append(dt)

        step += 1
        if on_metrics is not None:
            on_metrics(step, metrics)
        if step % cfg.ckpt_every == 0 or step == num_steps:
            ckpt.save(cfg.ckpt_dir, step, state)
    return state, stats
