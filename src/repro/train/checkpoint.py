"""Sharded, manifest-based checkpointing — thin wrapper over the shared
atomic-snapshot utility in ``repro.core.resilience``.

Historically this module owned the write-to-temp-then-rename snapshot
implementation; the robustness PR promoted that machinery into
``core/resilience.py`` (where the solver's sweep-boundary checkpoints
also use it) and this module now delegates, keeping the training-side
API (``save``/``latest_step``/``restore``/``manifest_of``) stable for
the fault-tolerant training driver (train/fault.py).

Layout (one directory per step):

    <dir>/step_00000100.tmp/...      while writing
    <dir>/step_00000100/manifest.json
    <dir>/step_00000100/arrays.npz

Restore is *elastic*: arrays are re-laid-out onto the target mesh via
``jax.device_put`` with the new shardings, so a checkpoint taken on an
N-device mesh restores onto any other mesh whose axis sizes divide the
array dimensions.  The publish step is an atomic ``rename`` — a crashed
writer never corrupts the latest checkpoint.
"""

from __future__ import annotations

from pathlib import Path
from typing import Any

from repro.core.resilience import (
    MANIFEST,
    snapshot_latest,
    snapshot_manifest,
    snapshot_restore,
    snapshot_save,
)

__all__ = ["MANIFEST", "save", "latest_step", "restore", "manifest_of"]


def save(directory: str | Path, step: int, state: Any,
         extra: dict | None = None) -> Path:
    return snapshot_save(directory, step, state, extra=extra)


def latest_step(directory: str | Path) -> int | None:
    return snapshot_latest(directory)


def restore(directory: str | Path, step: int, like: Any,
            shardings: Any | None = None) -> Any:
    return snapshot_restore(directory, step, like, shardings=shardings)


def manifest_of(directory: str | Path, step: int) -> dict:
    return snapshot_manifest(directory, step)
