"""Sharded, manifest-based checkpointing with atomic publish and elastic
restore.

Layout (one directory per step):

    <dir>/step_000100.tmp/...      while writing
    <dir>/step_000100/manifest.json
    <dir>/step_000100/arr_00000.npz ...

Every leaf of the state pytree is saved as float/int arrays in .npz chunks
together with a manifest recording tree structure, dtypes, shapes and the
mesh it was saved under.  Restore is *elastic*: arrays are re-laid-out onto
the target mesh via ``jax.device_put`` with the new shardings, so a
checkpoint taken on an N-device mesh restores onto any other mesh whose
axis sizes divide the array dimensions (scale up, scale down, or reshape
the mesh).  The publish step is an atomic ``rename`` — a crashed writer
never corrupts the latest checkpoint, which is the property the
fault-tolerant driver (train/fault.py) relies on.

In a true multi-host deployment each host writes only the shards it owns
(addressable_shards) with the same manifest/rename protocol; this container
is single-process so arrays are fully addressable.
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out.append((path, leaf))
    return out


def save(directory: str | Path, step: int, state: Any,
         extra: dict | None = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i:05d}"
        # bf16 has no numpy dtype: store raw uint16 view + dtype tag
        dtype = str(leaf.dtype)
        if dtype == "bfloat16":
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": path, "key": key, "dtype": dtype,
             "shape": list(arr.shape)})
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    return final


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and not p.name.endswith(".tmp") \
                and (p / MANIFEST).exists():
            steps.append(int(p.name[5:]))
    return max(steps) if steps else None


def restore(directory: str | Path, step: int, like: Any,
            shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) re-lays the arrays
    onto the *current* mesh — the elastic path.
    """
    import ml_dtypes

    path = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((path / MANIFEST).read_text())
    data = np.load(path / "arrays.npz")
    by_path = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if leaf["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        by_path[leaf["path"]] = arr

    like_leaves = _flatten_with_paths(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(like_leaves))
    out = []
    for (lpath, lleaf), sh in zip(like_leaves, shard_leaves):
        if lpath not in by_path:
            raise KeyError(f"checkpoint missing leaf {lpath!r}")
        arr = by_path[lpath]
        if tuple(arr.shape) != tuple(lleaf.shape):
            raise ValueError(
                f"shape mismatch for {lpath}: ckpt {arr.shape} "
                f"vs state {lleaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def manifest_of(directory: str | Path, step: int) -> dict:
    return json.loads(
        (Path(directory) / f"step_{step:08d}" / MANIFEST).read_text())
