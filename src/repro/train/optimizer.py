"""AdamW with ZeRO-1 sharded moments, gradient clipping, LR schedules.

No optax dependency — the optimizer is ~80 lines and owning it lets the
moment shardings be chosen explicitly: each moment takes its parameter's
PartitionSpec with the "data" axis added on the first divisible unsharded
dimension (ZeRO-1), so optimizer memory scales with the full mesh even for
TP-only parameter layouts.  Supports a gradient-compression hook
(train/compression.py) applied to the global gradient before the update.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params, grads, opt_state,
                 compress: Callable | None = None):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    if compress is not None:
        grads = compress(grads)
    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def zero1_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Add the 'data' axis to the first divisible unsharded dim (ZeRO-1)."""
    if "data" not in mesh.axis_names:
        return spec
    dsize = mesh.shape["data"]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for p in parts:
        for a in (p if isinstance(p, tuple) else (p,)):
            if a:
                used.add(a)
    if "data" in used:
        return spec
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is None and dim % dsize == 0:
            parts[i] = "data"
            return P(*parts)
    return spec


def opt_state_shardings(mesh: Mesh, params_shape, params_shardings):
    """ZeRO-1 shardings for the optimizer moments."""
    def mom(ps, x):
        return NamedSharding(mesh, zero1_spec(ps.spec, x.shape, mesh))

    m = jax.tree.map(mom, params_shardings, params_shape)
    return {"m": m, "v": m, "step": NamedSharding(mesh, P())}
