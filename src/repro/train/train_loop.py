"""Sharded training step: chunked cross-entropy, remat, ZeRO-1 AdamW.

The LM head is applied inside the loss in sequence chunks (the full
[B, S, vocab] logits tensor is never materialised — with 262k vocabularies
it would dominate activation memory).  Loss is computed in f32 with the
log-sum-exp over the (model-sharded) vocab dimension; GSPMD turns the
per-chunk reductions into a single all-reduce per chunk.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import shardings as shd
from repro.models import model as model_lib
from repro.train import optimizer as opt_lib

CE_CHUNK = 512
AUX_WEIGHT = 0.01


def chunked_ce_loss(cfg: ArchConfig, params, hidden, labels, mask,
                    unroll: int | bool = 1):
    """hidden [B,S,D], labels [B,S] (next-token ids), mask [B,S]."""
    B, S, D = hidden.shape
    head = params.get("head")
    table = head if head is not None else params["embed"]

    c = min(CE_CHUNK, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // c
    hs = hidden.reshape(B, nc, c, D).swapaxes(0, 1)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)
    ms = mask.reshape(B, nc, c).swapaxes(0, 1)

    def chunk_body(carry, inp):
        tot, cnt = carry
        h, l, m = inp
        if head is not None:
            logits = jnp.einsum("bcd,dv->bcv", h, table)
        else:
            logits = jnp.einsum("bcd,vd->bcv", h, table)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * m
        return (tot + ce.sum(), cnt + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        chunk_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ArchConfig, params, batch, dtype=jnp.bfloat16,
            act_sharding=None, unroll: int | bool = 1,
            q_chunk: int | None = None):
    hidden, aux = model_lib.forward(cfg, params, batch, mode="train",
                                    dtype=dtype, return_hidden=True,
                                    act_sharding=act_sharding,
                                    scan_unroll=unroll,
                                    attn_q_chunk=q_chunk,
                                    attn_chunk_unroll=unroll)
    S_h = hidden.shape[1]
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    if labels.shape[1] != S_h:            # vlm: patches prepended
        pad = S_h - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)))
        mask = jnp.pad(mask, ((0, 0), (pad, 0)))
    ce = chunked_ce_loss(cfg, params, hidden, labels,
                         mask.astype(jnp.float32), unroll=unroll)
    return ce + AUX_WEIGHT * aux, (ce, aux)


@dataclass
class TrainState:
    params: Any
    opt: Any

    def tree_flatten(self):
        return (self.params, self.opt), None


jax.tree_util.register_pytree_node(
    TrainState, lambda s: ((s.params, s.opt), None),
    lambda _, c: TrainState(params=c[0], opt=c[1]))


def make_train_step(cfg: ArchConfig, opt_cfg: opt_lib.AdamWConfig,
                    dtype=jnp.bfloat16,
                    compress: Callable | None = None,
                    act_sharding=None, unroll: int | bool = 1,
                    q_chunk: int | None = None,
                    microbatches: int = 1):
    """``microbatches`` > 1: gradient accumulation — the global batch is
    split into G sequential microbatches whose grads accumulate in f32,
    dividing live activation memory by G (the standard lever for fitting
    large-model training steps into HBM; see EXPERIMENTS.md §Perf)."""

    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, dtype, act_sharding, unroll,
                              q_chunk), has_aux=True)(params)

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            G = microbatches
            mb = jax.tree.map(
                lambda x: x.reshape(G, x.shape[0] // G, *x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(carry, b):
                gsum, ls, cs, as_ = carry
                (loss, (ce, aux)), g = grad_of(state.params, b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, ls + loss, cs + ce, as_ + aux), None

            (gsum, loss, ce, aux), _ = jax.lax.scan(
                body, (zeros, 0.0, 0.0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / G, gsum)
            loss, ce, aux = loss / G, ce / G, aux / G
        else:
            (loss, (ce, aux)), grads = grad_of(state.params, batch)
        new_params, new_opt, metrics = opt_lib.adamw_update(
            opt_cfg, state.params, grads, state.opt, compress=compress)
        metrics.update(loss=loss, ce=ce, aux=aux)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_sharded_train_step(cfg: ArchConfig, mesh: Mesh,
                            opt_cfg: opt_lib.AdamWConfig | None = None,
                            dtype=jnp.bfloat16,
                            compress: Callable | None = None,
                            donate: bool = True,
                            seq_len: int | None = None,
                            unroll: int | bool = 1,
                            q_chunk: int | None = None,
                            global_batch: int | None = None,
                            microbatches: int = 1):
    """jit the train step with full in/out shardings for the given mesh.

    When ``seq_len`` divides the model axis, the residual stream is
    sequence-sharded over "model" (Megatron sequence parallelism) so remat
    activation memory scales with the full mesh.
    """
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    params_shape = jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    p_shard = shd.param_shardings(cfg, mesh, params_shape)
    opt_shape = jax.eval_shape(opt_lib.init_opt_state, params_shape)
    o_shard = opt_lib.opt_state_shardings(mesh, params_shape, p_shard)
    state_shardings = TrainState(params=p_shard, opt=o_shard)
    bspec = NamedSharding(mesh, shd.batch_pspec(mesh, cfg, global_batch))
    act_sharding = None
    if cfg.sharding != "dp" and seq_len is not None \
            and seq_len % mesh.shape["model"] == 0:
        dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        act_sharding = NamedSharding(mesh, P(dp, "model", None))
    step = make_train_step(cfg, opt_cfg, dtype, compress,
                           act_sharding=act_sharding, unroll=unroll,
                           q_chunk=q_chunk, microbatches=microbatches)
    jit_step = jax.jit(
        step,
        in_shardings=(state_shardings, bspec),
        out_shardings=(state_shardings, None),
        donate_argnums=(0,) if donate else ())
    return jit_step, state_shardings, bspec


def train_batch_specs(cfg: ArchConfig, seq_len: int, global_batch: int,
                      dtype=jnp.bfloat16):
    """ShapeDtypeStructs of one training batch (for AOT lowering)."""
    f = jax.ShapeDtypeStruct
    if cfg.frontend == "audio_frames":
        return {
            "frames": f((global_batch, seq_len, cfg.frontend_dim),
                        jnp.bfloat16),
            "labels": f((global_batch, seq_len), jnp.int32),
            "mask": f((global_batch, seq_len), jnp.float32),
        }
    if cfg.frontend == "vision_patches":
        s_text = seq_len - cfg.num_patches
        return {
            "tokens": f((global_batch, s_text), jnp.int32),
            "patches": f((global_batch, cfg.num_patches, cfg.frontend_dim),
                         jnp.bfloat16),
            "labels": f((global_batch, s_text), jnp.int32),
            "mask": f((global_batch, s_text), jnp.float32),
        }
    return {
        "tokens": f((global_batch, seq_len), jnp.int32),
        "labels": f((global_batch, seq_len), jnp.int32),
    }
