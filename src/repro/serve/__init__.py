"""The serving tier: a robust continuous-batching maxflow service.

Public surface:

* :class:`~repro.serve.service.MaxflowService` — the admission-controlled,
  continuously batched, circuit-broken service loop;
* :class:`~repro.serve.service.SolveRequest` /
  :class:`~repro.serve.service.Ticket` /
  :class:`~repro.serve.service.ServiceConfig` — its request surface;
* :func:`~repro.serve.service.solve_with_deadline` — the single-handle
  deadline route;
* :func:`~repro.serve.service.replay_stream` — the bench/CLI driver;
* the typed error taxonomy (:mod:`repro.serve.errors`) and the
  :class:`~repro.serve.stats.ServiceStats` report.

See the "Serving tier" section of docs/ARCHITECTURE.md.
"""

from .breaker import BreakerBoard, CircuitBreaker
from .errors import (ERROR_TAXONOMY, DeadlineExceeded, RequestFailed,
                     ServiceClosed, ServiceError, ServiceOverloaded)
from .service import (MaxflowService, ServiceConfig, SolveRequest, Ticket,
                      replay_stream, solve_with_deadline)
from .stats import ServiceStats

__all__ = [
    "BreakerBoard", "CircuitBreaker", "DeadlineExceeded", "ERROR_TAXONOMY",
    "MaxflowService", "RequestFailed", "ServiceClosed", "ServiceConfig",
    "ServiceError", "ServiceOverloaded", "ServiceStats", "SolveRequest",
    "Ticket", "replay_stream", "solve_with_deadline",
]
