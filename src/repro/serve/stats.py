"""Service observability: counters, latency quantiles, health probes.

``ServiceStats`` is a plain mutable aggregate the service mutates inline
(no locks needed — the service loop is single-threaded by design, see
``service.py``).  It answers the two operational questions the ISSUE's
acceptance test asks: *is the service up and bounded* (health/readiness
probes, queue-depth gauge vs its bound) and *where did every request go*
(completed + the four typed-error counters sum back to submissions).

Latencies are kept in a bounded ring so a long-lived service reports
recent p50/p99, not lifetime averages diluted by startup.
"""

from __future__ import annotations

import dataclasses
from collections import deque


def _quantile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank quantile of an already-sorted list (0 if empty)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return sorted_vals[idx]


@dataclasses.dataclass
class ServiceStats:
    """Mutable counters + gauges of one ``MaxflowService`` instance."""

    # -- request lifecycle counters --
    submitted: int = 0
    admitted: int = 0          # entered a batch slot (swaps == admissions)
    completed: int = 0         # resolved with a MincutResult
    deadline_misses: int = 0   # resolved with DeadlineExceeded
    sheds: int = 0             # resolved with ServiceOverloaded
    failed: int = 0            # resolved with RequestFailed
    # -- robustness-layer counters --
    evictions: int = 0         # prepared handles checkpointed off device
    warm_resumes: int = 0      # evicted handles restored from checkpoint
    retries: int = 0           # supervisor re-runs of a faulted chunk
    faults: int = 0            # chunk executions that raised
    degradations: int = 0      # ladder steps taken after kernel failures
    breaker_trips: int = 0     # rungs that crossed the failure threshold
    breaker_skips: int = 0     # chunk entries that avoided an open rung
    swaps: int = 0             # slot-swap admissions into live batches
    # -- gauges --
    queue_depth: int = 0
    max_queue_depth: int = 0
    in_flight: int = 0
    resident_bytes: int = 0    # device bytes held by cached handles
    # -- per-tenant shed accounting --
    sheds_by_tenant: dict[str, int] = dataclasses.field(default_factory=dict)

    latency_window: int = 1024

    def __post_init__(self):
        self._latencies: deque[float] = deque(maxlen=self.latency_window)
        self._elapsed = 0.0  # clock time spanned by completed requests

    # -- recording ----------------------------------------------------------

    def observe_queue(self, depth: int) -> None:
        self.queue_depth = depth
        self.max_queue_depth = max(self.max_queue_depth, depth)

    def record_latency(self, seconds: float) -> None:
        self._latencies.append(seconds)

    def record_shed(self, tenant: str) -> None:
        self.sheds += 1
        self.sheds_by_tenant[tenant] = self.sheds_by_tenant.get(tenant, 0) + 1

    def note_elapsed(self, seconds: float) -> None:
        self._elapsed = seconds

    # -- derived ------------------------------------------------------------

    @property
    def resolved(self) -> int:
        """Requests that reached a terminal outcome (result or typed err)."""
        return (self.completed + self.deadline_misses + self.sheds
                + self.failed)

    def latency_quantiles(self) -> dict[str, float]:
        vals = sorted(self._latencies)
        return {"p50": _quantile(vals, 0.50), "p99": _quantile(vals, 0.99)}

    def throughput(self) -> float:
        """Completed requests per second over the service's lifetime."""
        return self.completed / self._elapsed if self._elapsed > 0 else 0.0

    # -- probes -------------------------------------------------------------

    def healthy(self) -> bool:
        """Liveness: no request has vanished without a terminal outcome.

        ``submitted == resolved + queued + in-flight`` is the invariant the
        acceptance test leans on; a leak (a request neither resolved nor
        tracked) breaks it.
        """
        return self.resolved + self.queue_depth + self.in_flight \
            == self.submitted

    def ready(self, queue_bound: int) -> bool:
        """Readiness: accepting work (queue has headroom)."""
        return self.queue_depth < queue_bound

    # -- reporting ----------------------------------------------------------

    def report(self, breaker_state: dict[str, str] | None = None) -> dict:
        """One JSON-able snapshot of everything above."""
        out = {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "completed": self.completed,
            "deadline_misses": self.deadline_misses,
            "sheds": self.sheds,
            "sheds_by_tenant": dict(self.sheds_by_tenant),
            "failed": self.failed,
            "evictions": self.evictions,
            "warm_resumes": self.warm_resumes,
            "retries": self.retries,
            "faults": self.faults,
            "degradations": self.degradations,
            "breaker_trips": self.breaker_trips,
            "breaker_skips": self.breaker_skips,
            "swaps": self.swaps,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "in_flight": self.in_flight,
            "resident_bytes": self.resident_bytes,
            "latency": self.latency_quantiles(),
            "throughput": self.throughput(),
            "healthy": self.healthy(),
        }
        if breaker_state is not None:
            out["breaker"] = breaker_state
        return out


__all__ = ["ServiceStats"]
