"""Typed error taxonomy of the serving tier.

Robustness-first serving means every request resolves to either a
``MincutResult`` or ONE of these typed outcomes — never a bare exception
escaping the service loop, never a silently dropped request.  The
taxonomy is deliberately small and machine-readable: each error carries a
stable ``code`` (the wire/metric label), the ``request_id`` it resolves,
and the structured fields a client needs to react (retry-after on
overload, sweeps-completed diagnostics on a missed deadline).

``ERROR_TAXONOMY`` is the table the docs render and the tests assert
against; ``ServiceError.retriable`` tells a client whether resubmitting
the same request can succeed (overload: yes, after ``retry_after``;
a missed deadline with the same budget: no).
"""

from __future__ import annotations


class ServiceError(Exception):
    """Base of every typed service outcome (never raised bare)."""

    code = "service_error"
    retriable = False

    def __init__(self, request_id: str, message: str):
        self.request_id = request_id
        super().__init__(message)


class DeadlineExceeded(ServiceError):
    """The request's deadline expired before its solve converged.

    Enforced at sweep boundaries only (the ``on_sweep`` hook on the host
    route, ``host_sync_every`` chunk boundaries on the device routes), so
    the solve is abandoned at a consistent preflow — ``partial_flow`` is
    the value of that valid preflow (a LOWER bound on the maxflow, already
    net of any warm-start offset) and ``sweeps_completed`` says how far
    the solve got.  ``stage`` is ``"queued"`` (expired before admission,
    zero sweeps run) or ``"running"`` (expired mid-solve).
    """

    code = "deadline_exceeded"
    retriable = False

    def __init__(self, request_id: str, *, deadline: float, elapsed: float,
                 sweeps_completed: int = 0, partial_flow: int | None = None,
                 stage: str = "running"):
        self.deadline = deadline
        self.elapsed = elapsed
        self.sweeps_completed = sweeps_completed
        self.partial_flow = partial_flow
        self.stage = stage
        super().__init__(
            request_id,
            f"request {request_id} missed its deadline after "
            f"{elapsed:.3f}s ({stage}, {sweeps_completed} sweeps"
            + (f", partial flow {partial_flow}" if partial_flow is not None
               else "") + ")")


class ServiceOverloaded(ServiceError):
    """Admission control shed the request: the bounded queue is full.

    ``retry_after`` estimates when capacity frees up (seconds); the shed
    is counted per tenant in ``ServiceStats.sheds_by_tenant``.
    """

    code = "overloaded"
    retriable = True

    def __init__(self, request_id: str, *, retry_after: float,
                 queue_depth: int, bound: int, tenant: str = "default"):
        self.retry_after = retry_after
        self.queue_depth = queue_depth
        self.bound = bound
        self.tenant = tenant
        super().__init__(
            request_id,
            f"request {request_id} shed: queue full ({queue_depth}/{bound});"
            f" retry after {retry_after:.2f}s")


class ServiceClosed(ServiceError):
    """The service is shutting down and no longer accepts requests."""

    code = "closed"
    retriable = False

    def __init__(self, request_id: str):
        super().__init__(request_id,
                         f"request {request_id} rejected: service closed")


class RequestFailed(ServiceError):
    """The solve faulted and exhausted the supervisor's retries.

    Only reached after the degradation ladder bottomed out (kernel-class
    failures) or ``max_retries`` re-runs from the intact sweep boundary
    (everything else) — the terminal rung of the robustness layer.
    """

    code = "failed"
    retriable = True

    def __init__(self, request_id: str, *, cause: str, attempts: int):
        self.cause = cause
        self.attempts = attempts
        super().__init__(
            request_id,
            f"request {request_id} failed after {attempts} attempts: "
            f"{cause}")


ERROR_TAXONOMY = {
    DeadlineExceeded.code: DeadlineExceeded,
    ServiceOverloaded.code: ServiceOverloaded,
    ServiceClosed.code: ServiceClosed,
    RequestFailed.code: RequestFailed,
}

__all__ = ["ERROR_TAXONOMY", "DeadlineExceeded", "RequestFailed",
           "ServiceClosed", "ServiceError", "ServiceOverloaded"]
