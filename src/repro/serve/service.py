"""Continuous-batching maxflow service with a robustness layer.

``MaxflowService`` turns the solver session layer into a *service*: an
admission-controlled request queue feeding shape-bucketed, continuously
batched solve loops.  Each power-of-two bucket shape owns ONE live batch
of ``max_batch`` slots driven chunk-by-chunk through the generic
``executor._device_chunk`` program; a slot whose instance converged (or
died) is freed and the next queued request of that shape is swapped in
via ``BatchedExecutor.swap_slot`` — admission into a *running* batch,
no repack, no retrace (one compiled swap program per bucket).

The service is deliberately **step-driven and single-threaded**: every
externally observable action happens inside ``submit`` or ``step``, the
clock is injected, and device work happens in bounded chunks
(``sync_every`` sweeps per bucket per step).  That makes the whole
robustness matrix deterministic under a fake clock — which is how the
test suite drives deadline expiry mid-solve, breaker cooldowns and
eviction without wall time — while a real deployment just calls
``step()`` in a loop (``run_until_idle``, ``replay_stream``, or the
``launch/maxflow_serve.py`` CLI).

The robustness layer, each with its typed outcome and counter:

* **deadlines** — enforced at sweep boundaries only (the chunk
  boundaries of the bucket loop; ``solve_with_deadline`` does the same
  through the ``on_sweep`` hook of the single-handle routes), so an
  expired request dies at a consistent preflow and its
  ``DeadlineExceeded`` carries sweeps-completed and partial-flow
  diagnostics;
* **admission control** — a bounded queue; overflow is shed immediately
  with ``ServiceOverloaded`` (retry-after, per-tenant shed accounting)
  instead of queueing unboundedly;
* **handle eviction** — named sessions keep prepared handles warm on
  device under an LRU with a byte budget; evicted handles are
  checkpointed (``resilience.snapshot_save``) and transparently resumed
  warm on their next request;
* **circuit breaker** — kernel-class chunk failures walk the
  pallas -> xla-fused -> xla-unfused ladder as usual, but a rung that
  keeps failing is *opened* and skipped at chunk entry for a cooldown
  (``serve.breaker``), so a wedged backend stops costing a failed launch
  per chunk;
* **supervised retries** — non-kernel chunk faults re-run the chunk from
  the intact pre-chunk state up to ``max_retries`` times before the
  batch's in-flight requests resolve to ``RequestFailed``.

Everything lands in ``ServiceStats`` (``service.report()``), including
the liveness invariant the acceptance test asserts: every submitted
request is exactly one of resolved / queued / in-flight.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..core import executor as _executor
from ..core import graph as _graph
from ..core import resilience as _res
from ..core import sweep as _sweep
from ..core.solver import (MincutResult, ProblemHandle, Solver,
                           SolverOptions, _finish)
from .breaker import BreakerBoard
from .errors import (DeadlineExceeded, RequestFailed, ServiceClosed,
                     ServiceOverloaded)
from .stats import ServiceStats

_I32 = jnp.int32


# --------------------------------------------------------------------------
# requests and tickets
# --------------------------------------------------------------------------

@dataclass
class SolveRequest:
    """One unit of service work.

    ``problem`` — the network to cut (required unless ``session`` names a
    live prepared session and ``update`` re-cuts it).  ``session`` — a
    client-chosen key: the prepared handle is cached under it, so later
    requests with the same key warm-start (and may carry ``update``, a
    dict of ``ProblemHandle.update`` kwargs applied before the re-solve).
    ``timeout`` — seconds from submission to the deadline (None: the
    service default).  ``tenant`` — shed-accounting bucket.
    """

    problem: object | None = None
    part: np.ndarray | None = None
    session: str | None = None
    update: dict | None = None
    timeout: float | None = None
    tenant: str = "default"
    request_id: str = ""


@dataclass
class Ticket:
    """The service's promise for one submitted request.

    Exactly one of ``result``/``error`` is set once ``done``; ``error``
    is always a typed ``serve.errors.ServiceError``.
    """

    request: SolveRequest
    submitted_at: float
    deadline_at: float | None
    done: bool = False
    result: MincutResult | None = None
    error: Exception | None = None
    _handle: ProblemHandle | None = field(default=None, repr=False)
    _inst: object | None = field(default=None, repr=False)

    def outcome(self):
        """The result, or raises the typed error (once resolved)."""
        assert self.done, "request not resolved yet — step the service"
        if self.error is not None:
            raise self.error
        return self.result


@dataclass(frozen=True)
class ServiceConfig:
    """Service-level knobs (solver knobs stay in ``SolverOptions``)."""

    max_queue: int = 64            # admission bound; beyond: shed
    max_batch: int = 4             # slots per shape bucket
    sync_every: int = 1            # sweeps per bucket per step (the
    #                                deadline-enforcement granularity)
    default_timeout: float | None = None
    retry_after: float = 0.5       # hint stamped on sheds
    max_retries: int = 2           # chunk re-runs before RequestFailed
    handle_budget_bytes: int | None = None   # session LRU byte budget
    eviction_dir: str | None = None          # where evicted handles go
    breaker_threshold: int = 3
    breaker_window: float = 60.0
    breaker_cooldown: float = 30.0

    def __post_init__(self):
        assert self.max_queue >= 1 and self.max_batch >= 1
        assert self.sync_every >= 1 and self.max_retries >= 0


@dataclass
class _Slot:
    ticket: Ticket
    handle: ProblemHandle
    session: str | None


class _Bucket:
    """One live batch: ``max_batch`` slots of one power-of-two shape."""

    def __init__(self, bmeta, state, carry, ex):
        self.bmeta = bmeta
        self.state = state
        self.carry = carry
        self.ex = ex                      # base-config executor (swaps)
        B = bmeta.num_instances
        self.slots: list[_Slot | None] = [None] * B
        self.limits = np.zeros(B, np.int32)
        self.sweeps_host = np.zeros(B, np.int32)
        self.syncs = 0

    @property
    def occupied(self) -> bool:
        return any(s is not None for s in self.slots)

    def free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None


# --------------------------------------------------------------------------
# the service
# --------------------------------------------------------------------------

class MaxflowService:
    """See the module docstring.  ``clock`` is injectable (tests pass a
    fake); the default is ``time.monotonic``."""

    def __init__(self, options: SolverOptions | None = None,
                 config: ServiceConfig | None = None, clock=None):
        self.options = options if options is not None else SolverOptions()
        self.config = config if config is not None else ServiceConfig()
        self._clock = clock if clock is not None else time.monotonic
        self._cfg = self.options.sweep_config()
        _executor.BatchedExecutor.validate(self._cfg)
        self.solver = Solver(self.options)
        self.stats = ServiceStats()
        self.board = BreakerBoard(
            threshold=self.config.breaker_threshold,
            window=self.config.breaker_window,
            cooldown=self.config.breaker_cooldown, clock=self._clock)
        self._queue: deque[Ticket] = deque()
        self._buckets: dict[tuple, _Bucket] = {}
        self._sessions: "OrderedDict[str, ProblemHandle]" = OrderedDict()
        self._evicted: dict[str, dict] = {}
        self._seq = 0
        self._evict_seq = 0
        self._closed = False
        self._started_at = self._clock()

    # -- submission ---------------------------------------------------------

    def submit(self, request: SolveRequest | None = None, **kw) -> Ticket:
        """Admit (or shed) one request; returns its ``Ticket``.

        Never blocks and never raises for per-request conditions: a full
        queue resolves the ticket immediately with ``ServiceOverloaded``,
        a closed service with ``ServiceClosed`` (closed rejections are
        not counted as submissions — the request never entered).
        """
        if request is None:
            request = SolveRequest(**kw)
        if not request.request_id:
            request.request_id = f"r{self._seq:06d}"
        self._seq += 1
        now = self._clock()
        timeout = request.timeout if request.timeout is not None \
            else self.config.default_timeout
        ticket = Ticket(request, submitted_at=now,
                        deadline_at=None if timeout is None
                        else now + timeout)
        if self._closed:
            ticket.done = True
            ticket.error = ServiceClosed(request.request_id)
            return ticket
        self.stats.submitted += 1
        if len(self._queue) >= self.config.max_queue:
            self.stats.record_shed(request.tenant)
            ticket.done = True
            ticket.error = ServiceOverloaded(
                request.request_id, retry_after=self.config.retry_after,
                queue_depth=len(self._queue), bound=self.config.max_queue,
                tenant=request.tenant)
            return ticket
        self._queue.append(ticket)
        self.stats.observe_queue(len(self._queue))
        return ticket

    # -- the service loop ---------------------------------------------------

    def step(self) -> int:
        """One service round: expire queued deadlines, admit into free
        slots, advance every occupied bucket by ``sync_every`` sweeps,
        harvest/expire slots, enforce the session byte budget.  Returns
        the number of requests resolved this round."""
        before = self.stats.resolved
        self._expire_queued()
        self._admit_from_queue()
        for bucket in list(self._buckets.values()):
            if bucket.occupied:
                self._pump_bucket(bucket)
        self._enforce_budget()
        self._refresh_gauges()
        return self.stats.resolved - before

    @property
    def pending(self) -> int:
        return len(self._queue) + sum(
            1 for b in self._buckets.values()
            for s in b.slots if s is not None)

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        steps = 0
        while self.pending:
            self.step()
            steps += 1
            assert steps < max_steps, "service failed to drain"

    def close(self, drain: bool = True) -> None:
        """Stop accepting work; by default drain what is already in."""
        if drain:
            self.run_until_idle()
        else:
            self._expire_queued()
        self._closed = True

    # -- probes + reporting -------------------------------------------------

    def healthy(self) -> bool:
        self._refresh_gauges()
        return self.stats.healthy()

    def ready(self) -> bool:
        return not self._closed \
            and self.stats.ready(self.config.max_queue)

    def report(self) -> dict:
        self._refresh_gauges()
        self.stats.note_elapsed(self._clock() - self._started_at)
        out = self.stats.report(breaker_state=self.board.snapshot())
        out["ready"] = self.ready()
        return out

    # -- queue-side deadline + admission ------------------------------------

    def _resolve_error(self, ticket: Ticket, err: Exception) -> None:
        ticket.done = True
        ticket.error = err

    def _resolve_result(self, ticket: Ticket, res: MincutResult) -> None:
        ticket.done = True
        ticket.result = res
        self.stats.completed += 1
        self.stats.record_latency(self._clock() - ticket.submitted_at)

    def _expire_queued(self) -> None:
        now = self._clock()
        keep: deque[Ticket] = deque()
        for t in self._queue:
            if t.deadline_at is not None and now >= t.deadline_at:
                self.stats.deadline_misses += 1
                self._resolve_error(t, DeadlineExceeded(
                    t.request.request_id,
                    deadline=t.deadline_at - t.submitted_at,
                    elapsed=now - t.submitted_at, sweeps_completed=0,
                    stage="queued"))
            else:
                keep.append(t)
        self._queue = keep
        self.stats.observe_queue(len(self._queue))

    def _inflight_sessions(self) -> set:
        return {s.session for b in self._buckets.values()
                for s in b.slots if s is not None and s.session}

    def _resolve_handle(self, req: SolveRequest) -> ProblemHandle:
        """The prepared handle of a request: session cache hit, warm
        resume of an evicted session, or a fresh ``prepare`` — then any
        ``update`` delta applied (exactly once per request)."""
        if req.session is not None:
            h = self._sessions.get(req.session)
            if h is None and req.session in self._evicted:
                h = self._restore_session(req.session)
            if h is not None:
                self._sessions.move_to_end(req.session)
                if req.update:
                    h.update(**req.update)
                return h
            if req.problem is None:
                raise KeyError(
                    f"session {req.session!r} unknown and the request "
                    f"carries no problem to prepare it from")
        h = self.solver.prepare(req.problem, req.part)
        if req.session is not None:
            self._sessions[req.session] = h
        if req.update:
            h.update(**req.update)
        return h

    def _admit_from_queue(self) -> None:
        """Scan the queue in order, swapping each request into a free
        slot of its shape bucket (FIFO per bucket; a request whose bucket
        is full — or whose session is already in flight — waits without
        blocking other shapes)."""
        inflight = self._inflight_sessions()
        keep: deque[Ticket] = deque()
        for t in self._queue:
            if t.request.session is not None \
                    and t.request.session in inflight:
                keep.append(t)
                continue
            if self._admit_one(t):
                if t.request.session is not None:
                    inflight.add(t.request.session)
            else:
                keep.append(t)
        self._queue = keep
        self.stats.observe_queue(len(self._queue))

    def _admit_one(self, ticket: Ticket) -> bool:
        req = ticket.request
        if ticket._handle is None:
            try:
                ticket._handle = self._resolve_handle(req)
            except Exception as exc:
                # malformed request (unknown session, bad update delta,
                # unbuildable problem): fail THIS request typed — the
                # loop must survive any single request
                self.stats.failed += 1
                self._resolve_error(ticket, RequestFailed(
                    req.request_id,
                    cause=f"{type(exc).__name__}: {exc}", attempts=0))
                return True               # resolved: drop from the queue
        h = ticket._handle
        # dtype strings join the shape key: a narrowed handle must never
        # share a batched executable with a wide one of the same dims
        key = _graph.bucket_shape_for(h.meta) + (
            h.meta.label_dtype, h.meta.flow_dtype, h.meta.mask_dtype)
        bucket = self._buckets.get(key)
        if bucket is not None and bucket.free_slot() is None:
            return False
        if ticket._inst is None:
            # B == 1 pack of the entry state: the swap-in payload
            ticket._inst = _graph.pack_built(
                [(0, h.meta, h._entry_state(), h.layout, h.state0)],
                pad_batch=False)[0]
        pack1 = ticket._inst
        if bucket is None:
            bucket = self._new_bucket(pack1)
            self._buckets[key] = bucket
        slot = bucket.free_slot()
        bucket.state, bucket.carry = bucket.ex.swap_slot(
            bucket.state, bucket.carry, slot, pack1.state)
        bound = _sweep.sweep_bound(h.meta, self._cfg)
        if self._cfg.max_sweeps is not None:
            bound = min(bound, self._cfg.max_sweeps)
        bucket.limits[slot] = min(bound, np.iinfo(np.int32).max)
        bucket.sweeps_host[slot] = 0
        bucket.slots[slot] = _Slot(ticket, h, req.session)
        self.stats.admitted += 1
        self.stats.swaps += 1
        ticket._inst = None               # the batch owns the state now
        return True

    def _new_bucket(self, pack1) -> _Bucket:
        """An empty ``max_batch``-slot batch of ``pack1``'s bucket shape
        (all-zero slots are inert: masked off, zero excess, converged at
        entry — exactly ``pack_built``'s batch padding)."""
        B = self.config.max_batch
        bmeta = dataclasses.replace(pack1.meta, num_instances=B)
        state = jax.tree_util.tree_map(
            lambda x: jnp.zeros((B,) + x.shape[1:], x.dtype), pack1.state)
        ex = _executor.BatchedExecutor(bmeta, self._cfg)
        return _Bucket(bmeta, state, ex.init_carry(state), ex)

    # -- chunk execution: breaker + ladder + retries -------------------------

    def _run_chunk(self, bucket: _Bucket):
        """Advance one bucket by up to ``sync_every`` sweeps per slot.

        Returns ``(host_carry, None)`` on success or ``(None, (exc,
        attempts))`` once retries are exhausted.  Kernel-class failures
        are recorded on the rung's breaker and degraded down the ladder
        (the pre-chunk state is intact, so the re-run is bit-exact);
        everything else is retried up to ``max_retries`` times.
        """
        cap = np.minimum(bucket.limits,
                         bucket.sweeps_host + self.config.sync_every)
        cfg, skips = self.board.entry_config(self._cfg)
        self.stats.breaker_skips += skips
        attempts = 0
        while True:
            rung = _res.config_rung(cfg)
            ex = _executor.BatchedExecutor(bucket.bmeta, cfg)
            try:
                state, carry = _executor._device_chunk(
                    ex, bucket.state, bucket.carry, jnp.asarray(cap, _I32))
                host = jax.device_get(carry)
                done = int(np.asarray(host[0]).max(initial=0))
                state = _executor._fire_fault_hook("device", state, done)
            except Exception as exc:       # noqa: BLE001 — every chunk
                #   fault maps to a typed outcome; nothing leaks upward
                self.stats.faults += 1
                attempts += 1
                if _res.is_kernel_failure(exc):
                    self.board.record(rung, ok=False)
                    self.stats.breaker_trips = self.board.trips
                    down = _res.degrade_config(cfg)
                    if down is not None:
                        self.stats.degradations += 1
                        cfg = down
                        continue
                if attempts <= self.config.max_retries:
                    self.stats.retries += 1
                    continue
                return None, (exc, attempts)
            self.board.record(rung, ok=True)
            bucket.state, bucket.carry = state, carry
            return host, None

    def _pump_bucket(self, bucket: _Bucket) -> None:
        host, failure = self._run_chunk(bucket)
        if host is None:
            exc, attempts = failure
            for b, slot in enumerate(bucket.slots):
                if slot is not None:
                    self._fail_slot(bucket, b, exc, attempts)
            return
        bucket.syncs += 1
        # np.array (not asarray): device_get buffers are read-only and
        # sweeps_host is written on swap-in
        sweeps, iters, launches, n_act = (np.array(x) for x in host)
        now = self._clock()
        for b, slot in enumerate(bucket.slots):
            if slot is None:
                continue
            if n_act[b] == 0 or sweeps[b] >= bucket.limits[b]:
                self._harvest(bucket, b, sweeps, iters, int(launches),
                              n_act)
            elif slot.ticket.deadline_at is not None \
                    and now >= slot.ticket.deadline_at:
                self._expire_slot(bucket, b, sweeps, now)
        bucket.sweeps_host = sweeps

    # -- slot resolution -----------------------------------------------------

    def _release(self, bucket: _Bucket, b: int) -> None:
        bucket.slots[b] = None
        bucket.limits[b] = 0   # run flag off until the next swap-in

    def _harvest(self, bucket: _Bucket, b: int, sweeps, iters,
                 launches: int, n_act) -> None:
        """Unpack slot ``b`` into a ``MincutResult`` (the ``solve_many``
        unpacking, per slot) and leave the session handle warm."""
        slot = bucket.slots[b]
        h = slot.handle
        meta = h.meta
        K, V, E = meta.num_regions, meta.region_size, meta.max_degree
        bstate = bucket.state
        st = h.state0.replace(
            cf=bstate.cf[b, :K, :V, :E], sink_cf=bstate.sink_cf[b, :K, :V],
            excess=bstate.excess[b, :K, :V], d=bstate.d[b, :K, :V],
            flow_to_t=bstate.flow_to_t[b])
        sw = int(sweeps[b])
        converged = bool(n_act[b] == 0)
        page_bytes, msg_bytes = _sweep._page_and_msg_bytes(meta)
        stats = _sweep.SweepStats(
            sweeps=sw, engine_iters=int(iters[b]),
            engine_launches=launches, host_syncs=bucket.syncs,
            boundary_bytes=sw * msg_bytes,
            page_bytes=sw * meta.num_regions * page_bytes,
            regions_discharged=sw * meta.num_regions,
            scope="batch", converged=converged)
        h.state = st
        h.warm = True
        h._dirty = False
        h._grew = jnp.zeros((), bool)
        try:
            res = _finish(meta, h.state0, st, h.layout, stats,
                          self.options.check, offset=int(h._flow_offset),
                          converged=converged,
                          ard=self.options.method == "ard",
                          max_sweeps=self._cfg.max_sweeps)
        except AssertionError as exc:   # CertificateError: a wrong answer
            #   must not crash the loop; it fails THIS request, typed
            self.stats.failed += 1
            self._resolve_error(slot.ticket, RequestFailed(
                slot.ticket.request.request_id,
                cause=f"{type(exc).__name__}: {exc}", attempts=1))
            self._release(bucket, b)
            return
        self._resolve_result(slot.ticket, res)
        self._release(bucket, b)

    def _expire_slot(self, bucket: _Bucket, b: int, sweeps,
                     now: float) -> None:
        slot = bucket.slots[b]
        t = slot.ticket
        partial = int(jax.device_get(bucket.state.flow_to_t[b])) \
            - int(slot.handle._flow_offset)
        self.stats.deadline_misses += 1
        self._resolve_error(t, DeadlineExceeded(
            t.request.request_id, deadline=t.deadline_at - t.submitted_at,
            elapsed=now - t.submitted_at, sweeps_completed=int(sweeps[b]),
            partial_flow=partial, stage="running"))
        self._release(bucket, b)

    def _fail_slot(self, bucket: _Bucket, b: int, exc: Exception,
                   attempts: int) -> None:
        slot = bucket.slots[b]
        self.stats.failed += 1
        self._resolve_error(slot.ticket, RequestFailed(
            slot.ticket.request.request_id,
            cause=f"{type(exc).__name__}: {exc}", attempts=attempts))
        self._release(bucket, b)

    # -- session LRU + eviction ----------------------------------------------

    @staticmethod
    def _handle_bytes(h: ProblemHandle) -> int:
        seen: set[int] = set()
        total = 0
        for leaf in jax.tree_util.tree_leaves((h.state, h.state0)):
            if id(leaf) in seen:
                continue   # state/state0 share topology buffers
            seen.add(id(leaf))
            total += getattr(leaf, "nbytes", 0)
        return total

    def _resident_bytes(self) -> int:
        return sum(self._handle_bytes(h) for h in self._sessions.values())

    def _enforce_budget(self) -> None:
        budget = self.config.handle_budget_bytes
        if budget is None or self.config.eviction_dir is None:
            return
        inflight = self._inflight_sessions()
        queued = {t.request.session for t in self._queue
                  if t.request.session}
        while self._resident_bytes() > budget:
            victim = next((k for k in self._sessions
                           if k not in inflight and k not in queued), None)
            if victim is None:
                break   # everything resident is busy; over budget for now
            self._evict_session(victim)

    def _evict_session(self, key: str) -> None:
        h = self._sessions.pop(key)
        d = Path(self.config.eviction_dir) / key
        step = self._evict_seq
        self._evict_seq += 1
        _res.snapshot_save(
            d, step,
            {"state": _res.state_payload(h.state),
             "state0": _res.state_payload(h.state0)},
            extra={"kind": "evicted_session", "session": key,
                   "flow_offset": int(h._flow_offset),
                   "warm": bool(h.warm), "dirty": bool(h._dirty),
                   "grew": bool(h._grew)})
        self._evicted[key] = {"problem": h.problem, "part": h.part,
                              "dir": str(d), "step": step}
        self.stats.evictions += 1

    def _restore_session(self, key: str) -> ProblemHandle:
        """Re-prepare an evicted session and pour its checkpointed state
        back in — the next solve runs warm, as if never evicted."""
        info = self._evicted.pop(key)
        h = self.solver.prepare(info["problem"], info["part"])
        like = {"state": _res.state_payload(h.state),
                "state0": _res.state_payload(h.state0)}
        payload = _res.snapshot_restore(info["dir"], info["step"], like)
        h.state = _res.restore_state(h.state, payload["state"])
        h.state0 = _res.restore_state(h.state0, payload["state0"])
        extra = _res.snapshot_manifest(info["dir"], info["step"])["extra"]
        h.warm = bool(extra["warm"])
        h._dirty = bool(extra["dirty"])
        h._grew = jnp.asarray(bool(extra["grew"]))
        h._flow_offset = jnp.asarray(int(extra["flow_offset"]), _I32)
        self._sessions[key] = h
        self.stats.warm_resumes += 1
        return h

    def _refresh_gauges(self) -> None:
        self.stats.observe_queue(len(self._queue))
        self.stats.in_flight = sum(
            1 for b in self._buckets.values()
            for s in b.slots if s is not None)
        self.stats.resident_bytes = self._resident_bytes()


# --------------------------------------------------------------------------
# single-handle deadline route + stream replay
# --------------------------------------------------------------------------

class _DeadlineAbort(Exception):
    """Internal control-flow signal of ``solve_with_deadline``."""


def solve_with_deadline(handle: ProblemHandle, *, timeout: float,
                        clock=None, mesh=None,
                        axes=("regions",)) -> MincutResult:
    """``handle.solve()`` with a deadline enforced at sweep boundaries.

    The same enforcement points as the service's bucket loop, through the
    single-handle routes' ``on_sweep`` hook: every boundary on the host
    loop, the ``host_sync_every`` boundaries on the device-resident and
    sharded drivers (which therefore need ``host_sync_every`` set).
    Raises :class:`~repro.serve.errors.DeadlineExceeded` with
    sweeps-completed and partial-flow diagnostics; the handle's resident
    state is left untouched by an aborted solve.
    """
    clock = clock if clock is not None else time.monotonic
    t0 = clock()
    deadline = t0 + timeout
    seen: dict = {"sweeps": 0, "flow": None}

    def on_sweep(state, sweeps_done):
        seen["sweeps"] = sweeps_done
        seen["flow"] = state.flow_to_t
        if clock() >= deadline:
            raise _DeadlineAbort()

    try:
        return handle.solve(mesh=mesh, axes=axes, on_sweep=on_sweep)
    except _DeadlineAbort:
        partial = None
        if seen["flow"] is not None:
            partial = int(jax.device_get(seen["flow"])) \
                - int(handle._flow_offset)
        raise DeadlineExceeded(
            "solve", deadline=timeout, elapsed=clock() - t0,
            sweeps_completed=seen["sweeps"], partial_flow=partial,
            stage="running") from None


def replay_stream(service: MaxflowService, requests, *,
                  rate: float | None = None) -> list[Ticket]:
    """Feed ``requests`` into ``service`` at ``rate`` req/s (None: one
    burst), stepping the service while pacing, then drain.  Returns the
    tickets in submission order — the bench/CLI driver.

    Pacing honors the offered rate even when a single ``step()`` takes
    several intervals: every request whose scheduled time has already
    passed is submitted before the next step, so a slow service sees the
    backlog (and sheds) instead of silently throttling the stream.  Rate
    pacing needs a real (advancing) clock; with ``rate=None`` the whole
    stream is one burst and any clock works."""
    tickets = []
    reqs = list(requests)
    interval = 0.0 if not rate else 1.0 / rate
    start = service._clock()
    i = 0
    while i < len(reqs):
        if not rate or service._clock() >= start + i * interval:
            tickets.append(service.submit(reqs[i]))
            i += 1
            continue
        service.step()
        if not service.pending:
            # idle and ahead of schedule: wait out the gap (stepping an
            # idle service burns CPU without advancing the stream)
            gap = (start + i * interval) - service._clock()
            if gap > 0:
                time.sleep(min(gap, 0.01))
    service.run_until_idle()
    return tickets


__all__ = ["MaxflowService", "ServiceConfig", "SolveRequest", "Ticket",
           "replay_stream", "solve_with_deadline"]
