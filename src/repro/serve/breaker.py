"""Circuit breakers over the kernel degradation ladder.

``core.resilience.run_with_degradation`` steps pallas -> xla-fused ->
xla-unfused *within one solve* when a kernel faults.  A service replays
that discovery on every request: a rung that is persistently broken (a
driver wedged, VMEM exhausted by a cotenant) keeps faulting, and each
fault costs a failed chunk launch before the ladder steps down.  The
breaker remembers: a rung that trips ``threshold`` times inside
``window`` seconds is *open* — skipped outright at chunk entry for
``cooldown`` seconds, after which a single probe (*half-open*) is let
through; success closes the breaker, another failure re-opens it.

``BreakerBoard`` holds one breaker per ladder rung and answers the only
question the service loop asks: *given the configured entry rung, which
rung should this chunk actually run on right now?*
"""

from __future__ import annotations

from ..core import resilience as _res

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


class CircuitBreaker:
    """Failure-count breaker with a sliding window and cooldown probe."""

    def __init__(self, *, threshold: int = 3, window: float = 60.0,
                 cooldown: float = 30.0, clock=None):
        import time
        self.threshold = threshold
        self.window = window
        self.cooldown = cooldown
        self._clock = clock if clock is not None else time.monotonic
        self._failures: list[float] = []   # timestamps inside the window
        self._opened_at: float | None = None
        self._probing = False
        self.trips = 0

    @property
    def state(self) -> str:
        if self._opened_at is None:
            return CLOSED
        if self._clock() - self._opened_at >= self.cooldown:
            return HALF_OPEN
        return OPEN

    def allows(self) -> bool:
        """May a call go through right now?

        In half-open, the first caller becomes the probe; concurrent
        callers are still refused until the probe reports back.
        """
        st = self.state
        if st == CLOSED:
            return True
        if st == HALF_OPEN and not self._probing:
            self._probing = True
            return True
        return False

    def record_success(self) -> None:
        self._failures.clear()
        self._opened_at = None
        self._probing = False

    def record_failure(self) -> None:
        now = self._clock()
        if self._opened_at is not None:
            # a failed half-open probe: restart the cooldown
            self._opened_at = now
            self._probing = False
            return
        self._failures = [t for t in self._failures
                          if now - t < self.window] + [now]
        if len(self._failures) >= self.threshold:
            self._opened_at = now
            self._probing = False
            self.trips += 1


class BreakerBoard:
    """One ``CircuitBreaker`` per kernel-ladder rung."""

    def __init__(self, *, threshold: int = 3, window: float = 60.0,
                 cooldown: float = 30.0, clock=None):
        self._breakers = {
            rung: CircuitBreaker(threshold=threshold, window=window,
                                 cooldown=cooldown, clock=clock)
            for rung in _res.KERNEL_LADDER
        }

    def __getitem__(self, rung: str) -> CircuitBreaker:
        return self._breakers[rung]

    def entry_config(self, cfg):
        """Walk ``cfg`` down the ladder past rungs whose breaker refuses.

        Returns ``(entry_cfg, skips)`` where ``skips`` counts the open
        rungs stepped over.  The bottom rung always runs (a fully-open
        board must not deadlock the service — the last rung's failures
        surface as request faults, which is the honest outcome).
        """
        skips = 0
        while True:
            rung = _res.config_rung(cfg)
            down = _res.degrade_config(cfg)
            if down is None or self._breakers[rung].allows():
                return cfg, skips
            skips += 1
            cfg = down

    def record(self, rung: str, ok: bool) -> None:
        br = self._breakers[rung]
        br.record_success() if ok else br.record_failure()

    @property
    def trips(self) -> int:
        return sum(b.trips for b in self._breakers.values())

    def snapshot(self) -> dict[str, str]:
        return {rung: b.state for rung, b in self._breakers.items()}


__all__ = ["BreakerBoard", "CircuitBreaker", "CLOSED", "HALF_OPEN", "OPEN"]
