"""DIMACS ``.max`` maxflow instance reader/writer.

The standard interchange format of the maxflow benchmark families the
paper evaluates (BVZ/KZ2/LB07 stereo, segmentation, the Univ. of Western
Ontario archives):

    c <comment>
    p max <num_nodes> <num_arcs>
    n <node_id> s          # source designator (1-based ids)
    n <node_id> t          # sink designator
    a <from> <to> <cap>    # directed arc

Mapping to the solver's terminal-capacity ``Problem`` representation is
the paper's ``Init``: source arcs (s, v) become per-vertex ``excess``
(the source is eliminated by saturating them), arcs (v, t) become
``sink_cap``, and the remaining directed arcs pair up into undirected
edges with independent forward/backward capacities.  Arcs INTO the source
and OUT of the sink carry no flow in any maxflow and are dropped (a note
is standard practice — cf. the BK reader).  Parallel arcs accumulate.

``write_dimacs`` emits the inverse, so ``read_dimacs(write_dimacs(p))``
reproduces the problem up to edge order and zero-capacity edges
(tests/test_dimacs.py asserts the canonical roundtrip and oracle-flow
equality).
"""

from __future__ import annotations

import shutil
import tempfile
from pathlib import Path

import numpy as np

from repro.core.graph import Problem, validate_problem


def read_dimacs(source) -> Problem:
    """Parse a DIMACS ``.max`` file into a ``Problem``.

    ``source`` — path, file-like object, or the text itself.
    """
    if hasattr(source, "read"):
        text = source.read()
    else:
        s = str(source)
        if "\n" in s:
            text = s                  # raw DIMACS text (always multi-line)
        else:
            text = Path(s).read_text()   # a path; missing file raises
    n_decl = None
    src_id = sink_id = None
    arcs: list[tuple[int, int, int]] = []
    for ln, line in enumerate(text.splitlines(), 1):
        tok = line.split()
        if not tok or tok[0] == "c":
            continue
        if tok[0] == "p":
            assert len(tok) == 4 and tok[1] == "max", \
                f"line {ln}: expected 'p max <n> <m>', got {line!r}"
            n_decl = int(tok[2])
        elif tok[0] == "n":
            assert len(tok) == 3, f"line {ln}: bad node designator {line!r}"
            if tok[2] == "s":
                src_id = int(tok[1])
            elif tok[2] == "t":
                sink_id = int(tok[1])
            else:
                raise ValueError(f"line {ln}: unknown designator {tok[2]!r}")
        elif tok[0] == "a":
            assert len(tok) == 4, f"line {ln}: bad arc {line!r}"
            arcs.append((int(tok[1]), int(tok[2]), int(tok[3])))
        else:
            raise ValueError(f"line {ln}: unknown record {tok[0]!r}")
    assert n_decl is not None, "missing 'p max' problem line"
    assert src_id is not None and sink_id is not None, \
        "missing source/sink designators"
    assert src_id != sink_id

    # map non-terminal 1-based file ids -> dense 0-based vertex ids
    vid = {}
    for u in range(1, n_decl + 1):
        if u != src_id and u != sink_id:
            vid[u] = len(vid)
    n = len(vid)
    excess = np.zeros(n, np.int64)
    sink_cap = np.zeros(n, np.int64)
    directed: dict[tuple[int, int], int] = {}
    for u, v, c in arcs:
        assert c >= 0, f"negative capacity on arc ({u}, {v})"
        assert 1 <= u <= n_decl and 1 <= v <= n_decl, \
            f"arc ({u}, {v}) outside the declared node range"
        if u == v or v == src_id or u == sink_id:
            continue          # self loops, arcs into s / out of t: no flow
        if u == src_id and v == sink_id:
            # a direct (s, t) arc adds a constant c to every maxflow; the
            # terminal-capacity representation has no slot for it
            raise NotImplementedError(
                "direct source->sink arcs are not representable in the "
                "excess/sink_cap form")
        if u == src_id:
            excess[vid[v]] += c
        elif v == sink_id:
            sink_cap[vid[u]] += c
        else:
            directed[(vid[u], vid[v])] = \
                directed.get((vid[u], vid[v]), 0) + c

    pairs = sorted({(min(u, v), max(u, v)) for u, v in directed})
    edges = np.asarray(pairs, np.int64).reshape(-1, 2)
    cap_fwd = np.asarray([directed.get((u, v), 0) for u, v in pairs],
                         np.int64)
    cap_bwd = np.asarray([directed.get((v, u), 0) for u, v in pairs],
                         np.int64)
    for name, a in (("arc", cap_fwd), ("arc", cap_bwd),
                    ("source-arc", excess), ("sink-arc", sink_cap)):
        assert a.size == 0 or a.max(initial=0) <= np.iinfo(np.int32).max, \
            f"{name} capacity overflows int32"
    problem = Problem(num_vertices=n, edges=edges,
                      cap_fwd=cap_fwd.astype(np.int32),
                      cap_bwd=cap_bwd.astype(np.int32),
                      excess=excess.astype(np.int32),
                      sink_cap=sink_cap.astype(np.int32))
    # structured rejection of overflow-risk inputs (capacity sums nearing
    # INF_CAP would corrupt the solver's int32 arithmetic mid-solve)
    validate_problem(problem, context="DIMACS input")
    return problem


# --------------------------------------------------------------------------
# streaming sharded reader: DIMACS -> per-region shards, single pass
# --------------------------------------------------------------------------

# one directed arc record as staged on disk during the sharded parse
_REC_FIELDS = 7   # row_local, slot, nbr_region, nbr_local, rev_slot, cap,
#                   is_tail (1 on the record carrying the arc's capacity)


class ShardedDimacs:
    """A DIMACS instance parsed straight into per-region shards.

    Produced by :func:`read_dimacs_sharded`; never holds the full edge
    list — per-region directed-arc records are spilled to disk as the
    single parse pass emits them, and only O(n) terminal/degree vectors
    plus the O(|cross|) cross-arc tables stay in memory.

    ``to_stream(cfg)`` assembles the spill-pool ``StreamState`` one
    region at a time (the out-of-core ingest path);  ``to_problem()``
    reconstructs the canonical flat ``Problem`` — bit-identical to
    ``read_dimacs`` on the same file (the small-file round-trip oracle:
    it *does* materialize the edge list, so use it only to verify).

    Unlike ``read_dimacs``, mutually-reverse and parallel directed arcs
    are NOT merged into shared undirected edges on the streaming path
    (merging needs the whole edge list at once); each file arc becomes
    its own edge with a zero-capacity reverse side.  The residual
    network — hence every flow value — is identical either way.
    """

    def __init__(self, num_regions: int, part: np.ndarray,
                 local_id: np.ndarray, directory: Path, own_dir: bool):
        self.num_regions = num_regions
        self.part = part
        self.local_id = local_id
        self.directory = directory
        self._own_dir = own_dir
        n = len(part)
        self.num_vertices = n
        self.excess = np.zeros(n, np.int64)
        self.sink_cap = np.zeros(n, np.int64)
        self.slot_ctr = np.zeros(n, np.int64)     # per-vertex next arc slot
        self.mass = 0                             # running flow_mass
        self.cross_src: list = []                 # build-order (2i, 2i+1)
        self.cross_dst: list = []
        self.num_arcs = 0                         # kept edge records / 2
        self._buf: list[list] = [[] for _ in range(num_regions)]
        self._counts = np.zeros(num_regions, np.int64)

    # -- spill plumbing -----------------------------------------------------

    def _shard_path(self, r: int) -> Path:
        return self.directory / f"shard_{r:05d}.rec"

    def _push(self, r: int, rec: tuple) -> None:
        self._buf[r].append(rec)
        self._counts[r] += 1
        if len(self._buf[r]) >= 65536:
            self._flush(r)

    def _flush(self, r: int) -> None:
        if self._buf[r]:
            with open(self._shard_path(r), "ab") as f:
                f.write(np.asarray(self._buf[r], np.int32).tobytes())
            self._buf[r] = []

    def _records(self, r: int) -> np.ndarray:
        self._flush(r)
        path = self._shard_path(r)
        raw = path.read_bytes() if path.exists() else b""
        return np.frombuffer(raw, np.int32).reshape(-1, _REC_FIELDS)

    def close(self) -> None:
        if self._own_dir:
            shutil.rmtree(self.directory, ignore_errors=True)

    # -- assembly -----------------------------------------------------------

    def _tables(self):
        X = max(1, len(self.cross_src))
        cs = np.zeros((X, 3), np.int32)
        cd = np.zeros((X, 3), np.int32)
        cv = np.zeros(X, bool)
        if self.cross_src:
            cs[: len(self.cross_src)] = np.asarray(self.cross_src, np.int32)
            cd[: len(self.cross_dst)] = np.asarray(self.cross_dst, np.int32)
            cv[: len(self.cross_src)] = True
        return cs, cd, cv

    def to_stream(self, cfg, *, spill_dir=None, max_resident_regions: int = 2,
                  prefetch: bool = True, dtype_policy: str = "int32"):
        """Assemble the spill-pool ``stream.StreamState``, one region's
        [V, E] slabs in memory at a time."""
        from repro.core import dtypes as _dt
        from repro.core.graph import GraphMeta
        from repro.stream.boundary import BoundaryState, make_plan
        from repro.stream.executor import StreamState
        from repro.stream.store import StreamStore

        n, K = self.num_vertices, self.num_regions
        region_count = np.bincount(self.part, minlength=K)
        V = max(1, int(region_count.max()) if n else 0)
        E = max(1, int(self.slot_ctr.max()) if n else 1)
        cs, cd, cv = self._tables()
        plan = make_plan(cs, cd, cv, K)
        kd = _dt.select_dtypes(dtype_policy, mass=self.mass,
                               bound=_dt.label_bound(n, V))
        keys = {(int(cs[x, 0]), int(cd[x, 0]), int(cd[x, 1]))
                for x in range(len(self.cross_src))}
        meta = GraphMeta(
            num_regions=K, region_size=V, max_degree=E, num_vertices=n,
            num_boundary=plan.num_boundary, num_cross_arcs=len(cv),
            num_ghost_groups=max(1, len(keys)),
            d_inf_ard=max(1, plan.num_boundary), d_inf_prd=max(1, n),
            label_dtype=kd.label, flow_dtype=kd.flow, mask_dtype=kd.mask)

        store = StreamStore(K, spill_dir, max_resident=max_resident_regions,
                            prefetch=prefetch)
        bnd = BoundaryState.zeros(plan, kd.label_np, kd.flow_np)
        ss = StreamState(meta=meta, cfg=cfg, store=store, plan=plan, bnd=bnd)
        for r in range(K):
            rec = self._records(r)
            nbr_region = np.zeros((V, E), np.int32)
            nbr_local = np.zeros((V, E), np.int32)
            rev_slot = np.zeros((V, E), np.int32)
            emask = np.zeros((V, E), bool)
            cf = np.zeros((V, E), kd.flow_np)
            row, slot = rec[:, 0], rec[:, 1]
            nbr_region[row, slot] = rec[:, 2]
            nbr_local[row, slot] = rec[:, 3]
            rev_slot[row, slot] = rec[:, 4]
            emask[row, slot] = True
            cf[row, slot] = rec[:, 5].astype(kd.flow_np)
            sel = np.nonzero(self.part == r)[0]
            locs = self.local_id[sel]
            vmask = np.zeros(V, bool)
            vmask[locs] = True
            sink_cf = np.zeros(V, kd.flow_np)
            sink_cf[locs] = self.sink_cap[sel].astype(kd.flow_np)
            excess = np.zeros(V, kd.flow_np)
            excess[locs] = self.excess[sel].astype(kd.flow_np)
            is_boundary = np.zeros(V, bool)
            is_boundary[plan.bnd_local[r]] = True
            topo = {"nbr_region": nbr_region, "nbr_local": nbr_local,
                    "rev_slot": rev_slot, "emask": emask, "vmask": vmask,
                    "is_boundary": is_boundary}
            flow = {"cf": cf, "sink_cf": sink_cf, "excess": excess,
                    "d": np.zeros(V, kd.label_np)}
            store.put_region(r, topo, flow)
            bnd.absorb_region(plan, r, flow, is_boundary, vmask, ss.d_inf)
        return ss

    def to_problem(self) -> Problem:
        """Reconstruct the canonical flat ``Problem`` — bit-identical to
        ``read_dimacs`` of the same file (materializes the edge list:
        the small-file verification path, not the out-of-core one)."""
        n, K = self.num_vertices, self.num_regions
        V = max(1, int(np.bincount(self.part, minlength=K).max()) if n else 0)
        lut = np.full(K * V, -1, np.int64)
        lut[self.part * V + self.local_id] = np.arange(n)
        directed: dict[tuple[int, int], int] = {}
        for r in range(K):
            rec = self._records(r)
            tails = rec[rec[:, 6] == 1]
            gu = lut[r * V + tails[:, 0].astype(np.int64)]
            gv = lut[tails[:, 2].astype(np.int64) * V + tails[:, 3]]
            for u, v, c in zip(gu, gv, tails[:, 5]):
                directed[(int(u), int(v))] = \
                    directed.get((int(u), int(v)), 0) + int(c)
        pairs = sorted({(min(u, v), max(u, v)) for u, v in directed})
        edges = np.asarray(pairs, np.int64).reshape(-1, 2)
        cap_fwd = np.asarray([directed.get((u, v), 0) for u, v in pairs],
                             np.int64)
        cap_bwd = np.asarray([directed.get((v, u), 0) for u, v in pairs],
                             np.int64)
        problem = Problem(num_vertices=n, edges=edges,
                          cap_fwd=cap_fwd.astype(np.int32),
                          cap_bwd=cap_bwd.astype(np.int32),
                          excess=self.excess.astype(np.int32),
                          sink_cap=self.sink_cap.astype(np.int32))
        validate_problem(problem, context="DIMACS input")
        return problem


def _iter_dimacs_lines(source):
    if hasattr(source, "read"):
        yield from source
        return
    s = str(source)
    if "\n" in s:
        yield from s.splitlines()
        return
    with open(s, "r") as f:         # a path: stream, never read_text
        yield from f


def read_dimacs_sharded(source, part, *, directory=None) -> ShardedDimacs:
    """Single-pass chunked DIMACS parse into per-region shards.

    ``part`` — region id per dense vertex: an array of length
    ``n_declared - 2``, a callable ``part(n) -> array`` (the vertex count
    is only known once the ``p max`` line is read), or an int K (the
    node-number fallback partitioner).  ``directory`` — where the shard
    record files go (a temp dir deleted by ``close()`` when omitted).

    Terminal designators must precede the first arc line (true of every
    DIMACS writer in the benchmark families).  Memory stays at O(n)
    vectors + O(|cross arcs|) tables + one bounded flush buffer per
    region, independent of the arc count.
    """
    from repro.core.graph import _stable_cumcount
    from repro.core.partition import block_partition

    own_dir = directory is None
    directory = Path(tempfile.mkdtemp(prefix="dimacs_shards_")) \
        if own_dir else Path(directory)
    directory.mkdir(parents=True, exist_ok=True)

    n_decl = None
    src_id = sink_id = None
    sd: ShardedDimacs | None = None
    part_arr = local = None

    for ln, line in enumerate(_iter_dimacs_lines(source), 1):
        tok = line.split()
        if not tok or tok[0] == "c":
            continue
        if tok[0] == "p":
            assert len(tok) == 4 and tok[1] == "max", \
                f"line {ln}: expected 'p max <n> <m>', got {line!r}"
            n_decl = int(tok[2])
        elif tok[0] == "n":
            assert len(tok) == 3, f"line {ln}: bad node designator {line!r}"
            assert sd is None, \
                f"line {ln}: designator after the first arc (the sharded " \
                f"reader needs terminals up front)"
            if tok[2] == "s":
                src_id = int(tok[1])
            elif tok[2] == "t":
                sink_id = int(tok[1])
            else:
                raise ValueError(f"line {ln}: unknown designator {tok[2]!r}")
        elif tok[0] == "a":
            assert len(tok) == 4, f"line {ln}: bad arc {line!r}"
            if sd is None:
                assert n_decl is not None, "missing 'p max' problem line"
                assert src_id is not None and sink_id is not None, \
                    "missing source/sink designators before the first arc"
                assert src_id != sink_id
                n = n_decl - 2
                if callable(part):
                    part_arr = np.asarray(part(n), np.int64)
                elif np.ndim(part) == 0:
                    part_arr = block_partition(n, int(part)).astype(np.int64)
                else:
                    part_arr = np.asarray(part, np.int64)
                assert part_arr.shape == (n,)
                local = _stable_cumcount(part_arr)
                K = int(part_arr.max()) + 1 if n else 1
                sd = ShardedDimacs(K, part_arr, local, directory, own_dir)
            u, v, c = int(tok[1]), int(tok[2]), int(tok[3])
            assert c >= 0, f"negative capacity on arc ({u}, {v})"
            assert 1 <= u <= n_decl and 1 <= v <= n_decl, \
                f"arc ({u}, {v}) outside the declared node range"
            if u == v or v == src_id or u == sink_id:
                continue
            if u == src_id and v == sink_id:
                raise NotImplementedError(
                    "direct source->sink arcs are not representable in "
                    "the excess/sink_cap form")
            sd.mass += c
            if u == src_id:
                sd.excess[_dense_id(v, src_id, sink_id)] += c
                continue
            if v == sink_id:
                sd.sink_cap[_dense_id(u, src_id, sink_id)] += c
                continue
            du = _dense_id(u, src_id, sink_id)
            dv = _dense_id(v, src_id, sink_id)
            ru, rv = int(part_arr[du]), int(part_arr[dv])
            lu, lv = int(local[du]), int(local[dv])
            su = int(sd.slot_ctr[du])
            sv = int(sd.slot_ctr[dv])
            sd.slot_ctr[du] += 1
            sd.slot_ctr[dv] += 1
            sd._push(ru, (lu, su, rv, lv, sv, c, 1))
            sd._push(rv, (lv, sv, ru, lu, su, 0, 0))
            if ru != rv:
                a = (ru, lu, su)
                b = (rv, lv, sv)
                sd.cross_src += [a, b]
                sd.cross_dst += [b, a]
            sd.num_arcs += 1
        else:
            raise ValueError(f"line {ln}: unknown record {tok[0]!r}")

    assert n_decl is not None, "missing 'p max' problem line"
    if sd is None:                       # arcless instance
        assert src_id is not None and sink_id is not None, \
            "missing source/sink designators"
        n = n_decl - 2
        if callable(part):
            part_arr = np.asarray(part(n), np.int64)
        elif np.ndim(part) == 0:
            part_arr = block_partition(n, int(part)).astype(np.int64)
        else:
            part_arr = np.asarray(part, np.int64)
        local = _stable_cumcount(part_arr)
        K = int(part_arr.max()) + 1 if n else 1
        sd = ShardedDimacs(K, part_arr, local, directory, own_dir)
    for r in range(sd.num_regions):
        sd._flush(r)
    return sd


def _dense_id(u: int, src_id: int, sink_id: int) -> int:
    """1-based file id -> dense 0-based vertex id with terminals removed
    (matches ``read_dimacs``'s increasing-id mapping)."""
    return u - 1 - (u > src_id) - (u > sink_id)


def write_dimacs(problem: Problem, dest=None) -> str:
    """Serialize a ``Problem`` as DIMACS ``.max`` text.

    Terminals are appended as nodes n+1 (source) and n+2 (sink);
    zero-capacity arcs are omitted (they constrain nothing).  Writes to
    ``dest`` (path or file-like) when given; always returns the text.
    """
    n = problem.num_vertices
    s, t = n + 1, n + 2
    lines = []
    for v in range(n):
        if problem.excess[v]:
            lines.append(f"a {s} {v + 1} {int(problem.excess[v])}")
        if problem.sink_cap[v]:
            lines.append(f"a {v + 1} {t} {int(problem.sink_cap[v])}")
    for (u, v), cf, cb in zip(problem.edges, problem.cap_fwd,
                              problem.cap_bwd):
        if cf:
            lines.append(f"a {int(u) + 1} {int(v) + 1} {int(cf)}")
        if cb:
            lines.append(f"a {int(v) + 1} {int(u) + 1} {int(cb)}")
    text = "\n".join(
        ["c generated by repro.data.dimacs",
         f"p max {n + 2} {len(lines)}", f"n {s} s", f"n {t} t"]
        + lines) + "\n"
    if dest is not None:
        if hasattr(dest, "write"):
            dest.write(text)
        else:
            Path(dest).write_text(text)
    return text
