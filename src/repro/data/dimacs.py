"""DIMACS ``.max`` maxflow instance reader/writer.

The standard interchange format of the maxflow benchmark families the
paper evaluates (BVZ/KZ2/LB07 stereo, segmentation, the Univ. of Western
Ontario archives):

    c <comment>
    p max <num_nodes> <num_arcs>
    n <node_id> s          # source designator (1-based ids)
    n <node_id> t          # sink designator
    a <from> <to> <cap>    # directed arc

Mapping to the solver's terminal-capacity ``Problem`` representation is
the paper's ``Init``: source arcs (s, v) become per-vertex ``excess``
(the source is eliminated by saturating them), arcs (v, t) become
``sink_cap``, and the remaining directed arcs pair up into undirected
edges with independent forward/backward capacities.  Arcs INTO the source
and OUT of the sink carry no flow in any maxflow and are dropped (a note
is standard practice — cf. the BK reader).  Parallel arcs accumulate.

``write_dimacs`` emits the inverse, so ``read_dimacs(write_dimacs(p))``
reproduces the problem up to edge order and zero-capacity edges
(tests/test_dimacs.py asserts the canonical roundtrip and oracle-flow
equality).
"""

from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.core.graph import Problem, validate_problem


def read_dimacs(source) -> Problem:
    """Parse a DIMACS ``.max`` file into a ``Problem``.

    ``source`` — path, file-like object, or the text itself.
    """
    if hasattr(source, "read"):
        text = source.read()
    else:
        s = str(source)
        if "\n" in s:
            text = s                  # raw DIMACS text (always multi-line)
        else:
            text = Path(s).read_text()   # a path; missing file raises
    n_decl = None
    src_id = sink_id = None
    arcs: list[tuple[int, int, int]] = []
    for ln, line in enumerate(text.splitlines(), 1):
        tok = line.split()
        if not tok or tok[0] == "c":
            continue
        if tok[0] == "p":
            assert len(tok) == 4 and tok[1] == "max", \
                f"line {ln}: expected 'p max <n> <m>', got {line!r}"
            n_decl = int(tok[2])
        elif tok[0] == "n":
            assert len(tok) == 3, f"line {ln}: bad node designator {line!r}"
            if tok[2] == "s":
                src_id = int(tok[1])
            elif tok[2] == "t":
                sink_id = int(tok[1])
            else:
                raise ValueError(f"line {ln}: unknown designator {tok[2]!r}")
        elif tok[0] == "a":
            assert len(tok) == 4, f"line {ln}: bad arc {line!r}"
            arcs.append((int(tok[1]), int(tok[2]), int(tok[3])))
        else:
            raise ValueError(f"line {ln}: unknown record {tok[0]!r}")
    assert n_decl is not None, "missing 'p max' problem line"
    assert src_id is not None and sink_id is not None, \
        "missing source/sink designators"
    assert src_id != sink_id

    # map non-terminal 1-based file ids -> dense 0-based vertex ids
    vid = {}
    for u in range(1, n_decl + 1):
        if u != src_id and u != sink_id:
            vid[u] = len(vid)
    n = len(vid)
    excess = np.zeros(n, np.int64)
    sink_cap = np.zeros(n, np.int64)
    directed: dict[tuple[int, int], int] = {}
    for u, v, c in arcs:
        assert c >= 0, f"negative capacity on arc ({u}, {v})"
        assert 1 <= u <= n_decl and 1 <= v <= n_decl, \
            f"arc ({u}, {v}) outside the declared node range"
        if u == v or v == src_id or u == sink_id:
            continue          # self loops, arcs into s / out of t: no flow
        if u == src_id and v == sink_id:
            # a direct (s, t) arc adds a constant c to every maxflow; the
            # terminal-capacity representation has no slot for it
            raise NotImplementedError(
                "direct source->sink arcs are not representable in the "
                "excess/sink_cap form")
        if u == src_id:
            excess[vid[v]] += c
        elif v == sink_id:
            sink_cap[vid[u]] += c
        else:
            directed[(vid[u], vid[v])] = \
                directed.get((vid[u], vid[v]), 0) + c

    pairs = sorted({(min(u, v), max(u, v)) for u, v in directed})
    edges = np.asarray(pairs, np.int64).reshape(-1, 2)
    cap_fwd = np.asarray([directed.get((u, v), 0) for u, v in pairs],
                         np.int64)
    cap_bwd = np.asarray([directed.get((v, u), 0) for u, v in pairs],
                         np.int64)
    for name, a in (("arc", cap_fwd), ("arc", cap_bwd),
                    ("source-arc", excess), ("sink-arc", sink_cap)):
        assert a.size == 0 or a.max(initial=0) <= np.iinfo(np.int32).max, \
            f"{name} capacity overflows int32"
    problem = Problem(num_vertices=n, edges=edges,
                      cap_fwd=cap_fwd.astype(np.int32),
                      cap_bwd=cap_bwd.astype(np.int32),
                      excess=excess.astype(np.int32),
                      sink_cap=sink_cap.astype(np.int32))
    # structured rejection of overflow-risk inputs (capacity sums nearing
    # INF_CAP would corrupt the solver's int32 arithmetic mid-solve)
    validate_problem(problem, context="DIMACS input")
    return problem


def write_dimacs(problem: Problem, dest=None) -> str:
    """Serialize a ``Problem`` as DIMACS ``.max`` text.

    Terminals are appended as nodes n+1 (source) and n+2 (sink);
    zero-capacity arcs are omitted (they constrain nothing).  Writes to
    ``dest`` (path or file-like) when given; always returns the text.
    """
    n = problem.num_vertices
    s, t = n + 1, n + 2
    lines = []
    for v in range(n):
        if problem.excess[v]:
            lines.append(f"a {s} {v + 1} {int(problem.excess[v])}")
        if problem.sink_cap[v]:
            lines.append(f"a {v + 1} {t} {int(problem.sink_cap[v])}")
    for (u, v), cf, cb in zip(problem.edges, problem.cap_fwd,
                              problem.cap_bwd):
        if cf:
            lines.append(f"a {int(u) + 1} {int(v) + 1} {int(cf)}")
        if cb:
            lines.append(f"a {int(v) + 1} {int(u) + 1} {int(cb)}")
    text = "\n".join(
        ["c generated by repro.data.dimacs",
         f"p max {n + 2} {len(lines)}", f"n {s} s", f"n {t} t"]
        + lines) + "\n"
    if dest is not None:
        if hasattr(dest, "write"):
            dest.write(text)
        else:
            Path(dest).write_text(text)
    return text
