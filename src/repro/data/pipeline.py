"""Deterministic sharded synthetic data pipeline.

Production posture: every (step, host) pair maps to a unique, reproducible
slice of the stream — restart-safe (a restored checkpoint resumes at the
same batch), elastic (re-sharding by host count changes nothing about the
global stream), with no inter-host coordination.

Two generators:

* ``markov_batch`` — order-1 Markov chain over the vocabulary with a fixed
  random transition structure; its per-token entropy is controllable, so
  training-loss curves have a known floor (examples/train_lm.py checks the
  loss approaches it);
* ``frame_batch`` / ``patch_batch`` — gaussian frame/patch embeddings for
  the audio/vlm stub frontends with cluster-id labels.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class MarkovSpec:
    vocab: int = 256
    branching: int = 4          # out-degree per state => entropy = log(b)
    seed: int = 7

    def entropy_floor(self) -> float:
        return float(np.log(self.branching))


def _transition_table(spec: MarkovSpec) -> np.ndarray:
    rng = np.random.RandomState(spec.seed)
    return rng.randint(0, spec.vocab,
                       size=(spec.vocab, spec.branching)).astype(np.int32)


def markov_batch(spec: MarkovSpec, step: int, batch: int, seq_len: int,
                 host_id: int = 0, num_hosts: int = 1):
    """Global batch slice for this host at this step (numpy, determinstic)."""
    assert batch % num_hosts == 0
    local = batch // num_hosts
    table = _transition_table(spec)
    rng = np.random.RandomState(
        ((spec.seed * 1_000_003 + step) * 65_537 + host_id) % (2**32 - 1))
    toks = np.zeros((local, seq_len + 1), np.int32)
    toks[:, 0] = rng.randint(0, spec.vocab, size=local)
    choices = rng.randint(0, spec.branching, size=(local, seq_len))
    for t in range(seq_len):
        toks[:, t + 1] = table[toks[:, t], choices[:, t]]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def frame_batch(spec_dim: int, vocab: int, step: int, batch: int,
                seq_len: int, host_id: int = 0, num_hosts: int = 1):
    local = batch // num_hosts
    rng = np.random.RandomState(step * 65_537 + host_id + 13)
    centers = np.random.RandomState(5).randn(vocab, spec_dim) * 0.5
    labels = rng.randint(0, vocab, size=(local, seq_len))
    frames = centers[labels] + rng.randn(local, seq_len, spec_dim) * 0.1
    mask = (rng.rand(local, seq_len) < 0.5).astype(np.float32)
    return {"frames": frames.astype(np.float32), "labels": labels,
            "mask": mask}


def patch_batch(cfg, spec: MarkovSpec, step: int, batch: int, seq_len: int,
                host_id: int = 0, num_hosts: int = 1):
    text = markov_batch(spec, step, batch, seq_len - cfg.num_patches,
                        host_id, num_hosts)
    rng = np.random.RandomState(step * 31 + host_id)
    local = batch // num_hosts
    patches = rng.randn(local, cfg.num_patches,
                        cfg.frontend_dim).astype(np.float32) * 0.2
    return {"tokens": text["tokens"], "labels": text["labels"],
            "patches": patches,
            "mask": np.ones_like(text["labels"], np.float32)}


def batch_for(cfg, spec: MarkovSpec, step: int, batch: int, seq_len: int,
              host_id: int = 0, num_hosts: int = 1):
    """Dispatch by architecture frontend."""
    if cfg.frontend == "audio_frames":
        return frame_batch(cfg.frontend_dim, cfg.vocab_size, step, batch,
                           seq_len, host_id, num_hosts)
    if cfg.frontend == "vision_patches":
        return patch_batch(cfg, spec, step, batch, seq_len, host_id,
                           num_hosts)
    return markov_batch(spec, step, batch, seq_len, host_id, num_hosts)
