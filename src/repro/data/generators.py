"""Hard DIMACS-family instance generators: GENRMF and Washington RLG.

The synthetic grids of ``data.grids`` converge in a handful of sweeps —
fine for conformance, useless for exercising the sweep loop, the
partial-discharge ladder, or the streaming executor's staged passes.
The two classic maxflow generator families below produce the opposite
regime: long augmenting paths and flow that must percolate through many
regions, so sweep counts grow with instance depth (the inputs the
paper's sweep-bound analysis is about).

Both express the classic source/sink construction in this repo's
terminal form: the designated source vertex carries ``excess`` equal to
the total capacity of its incident arcs (an inexhaustible supply for the
rest of the graph), the sink vertex a ``sink_cap`` equal to its incident
capacity — exactly the reduction DIMACS ``n s``/``n t`` lines get in
``data.dimacs.read_dimacs``, so maxflow values match the classical
statement of each family.
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Problem


def _dedup_directed(u: np.ndarray, w: np.ndarray,
                    cap: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Accumulate parallel directed arcs (u, w) into one edge row each."""
    key = u.astype(np.int64) * (w.max() + 1 if len(w) else 1) + w
    uniq, inv = np.unique(key, return_inverse=True)
    cap_sum = np.zeros(len(uniq), dtype=np.int64)
    np.add.at(cap_sum, inv, cap)
    first = np.zeros(len(uniq), dtype=np.int64)
    first[inv[::-1]] = np.arange(len(u) - 1, -1, -1)
    edges = np.stack([u[first], w[first]], axis=1).astype(np.int64)
    return edges, cap_sum.astype(np.int32)


def _terminal_caps(n: int, edges: np.ndarray, cap_fwd: np.ndarray,
                   cap_bwd: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-vertex total outgoing / incoming arc capacity."""
    out_cap = np.zeros(n, dtype=np.int64)
    in_cap = np.zeros(n, dtype=np.int64)
    np.add.at(out_cap, edges[:, 0], cap_fwd)
    np.add.at(out_cap, edges[:, 1], cap_bwd)
    np.add.at(in_cap, edges[:, 1], cap_fwd)
    np.add.at(in_cap, edges[:, 0], cap_bwd)
    return out_cap, in_cap


def genrmf(a: int = 6, b: int = 6, *, c1: int = 1, c2: int = 100,
           seed: int = 0) -> Problem:
    """GENRMF (Goldfarb & Grigoriadis): b frames of an a x a grid.

    In-frame 4-neighbor edges carry the saturating capacity ``c2 * a^2``
    in both directions; each vertex of frame z sends one arc of random
    capacity in ``[c1, c2]`` to a uniformly random vertex of frame z+1.
    Source: corner of the first frame; sink: opposite corner of the last.
    All flow must thread the b-1 narrow random inter-frame cuts, so
    augmenting paths are long and sweep counts grow with ``b`` — the
    standard hard case for push-relabel orderings.
    """
    assert a >= 2 and b >= 2 and 0 <= c1 <= c2
    rng = np.random.RandomState(seed)
    n = a * a * b
    vid = np.arange(n).reshape(b, a, a)
    big = np.int32(c2 * a * a)

    e_u, e_w, e_fwd, e_bwd = [], [], [], []
    for dy, dx in ((0, 1), (1, 0)):
        u = vid[:, : a - dy, : a - dx].reshape(-1)
        w = vid[:, dy:, dx:].reshape(-1)
        e_u.append(u)
        e_w.append(w)
        e_fwd.append(np.full(len(u), big, dtype=np.int32))
        e_bwd.append(np.full(len(u), big, dtype=np.int32))
    for z in range(b - 1):
        u = vid[z].reshape(-1)
        w = vid[z + 1].reshape(-1)[rng.randint(0, a * a, size=a * a)]
        e_u.append(u)
        e_w.append(w)
        e_fwd.append(rng.randint(c1, c2 + 1, size=a * a).astype(np.int32))
        e_bwd.append(np.zeros(a * a, dtype=np.int32))

    edges = np.stack([np.concatenate(e_u), np.concatenate(e_w)],
                     axis=1).astype(np.int64)
    cap_fwd = np.concatenate(e_fwd)
    cap_bwd = np.concatenate(e_bwd)

    src = int(vid[0, 0, 0])
    snk = int(vid[b - 1, a - 1, a - 1])
    out_cap, in_cap = _terminal_caps(n, edges, cap_fwd, cap_bwd)
    excess = np.zeros(n, dtype=np.int32)
    sink_cap = np.zeros(n, dtype=np.int32)
    excess[src] = out_cap[src]
    sink_cap[snk] = in_cap[snk]
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap_fwd,
                   cap_bwd=cap_bwd, excess=excess, sink_cap=sink_cap)


def pipeline_levels(rows: int = 64, levels: int = 16, *, pipe_cap: int = 114,
                    mix_cap: int = 2, supply: int = 100) -> Problem:
    """Absorbing level pipeline: big, deterministic, fast-converging.

    ``levels`` columns of ``rows`` vertices; every vertex of level l
    sends a ``pipe_cap`` arc straight ahead to (l+1, same row) and seven
    ``mix_cap`` arcs to the next level's rows shifted by 1..7 (cyclic) —
    eight distinct targets, in-degree exactly eight.  Every vertex's
    out-capacity (``pipe_cap + 7*mix_cap``) covers its worst-case inflow
    (``pipe_cap`` from the pipe + ``7*mix_cap`` mixed), and the last
    level's ``sink_cap`` covers everything, so NO excess is ever stuck:
    labels stay near zero, the sequential sweep drains the instance in a
    handful of passes, and the maxflow equals the injected supply
    (``supply * rows``) exactly.

    This is the scaling instance of the out-of-core benchmark
    (``benchmarks/bench_streaming.py``): solve cost grows linearly with
    ``rows`` while sweep and engine-iteration counts stay flat — the
    GENRMF/RLG families above stress the algorithm, this one stresses
    the memory system.  Edges are emitted in sorted ``(u, v)`` order, so
    a DIMACS round trip through ``read_dimacs`` (which sorts) and the
    file-order ``read_dimacs_sharded`` ingest reproduce the exact same
    arc slots — the resident and streamed solves are bit-identical
    sweep for sweep.
    """
    assert rows >= 8 and levels >= 2
    assert supply <= pipe_cap and pipe_cap <= pipe_cap + 7 * mix_cap
    n = rows * levels
    vid = np.arange(n).reshape(levels, rows)

    r = np.arange(rows)
    # eight next-level targets per vertex: shift 0 (the pipe) carries
    # pipe_cap, shifts 1..7 carry mix_cap; sorted per source vertex so
    # the global edge list is lexicographically ordered
    shifts = np.arange(8)
    tgt_row = (r[:, None] + shifts[None, :]) % rows          # [rows, 8]
    cap_row = np.where(shifts == 0, pipe_cap,
                       mix_cap)[None, :].repeat(rows, 0)     # [rows, 8]
    order = np.argsort(tgt_row, axis=1, kind="stable")
    tgt_row = np.take_along_axis(tgt_row, order, axis=1)
    cap_row = np.take_along_axis(cap_row, order, axis=1)

    us, ws, caps = [], [], []
    for l in range(levels - 1):
        us.append(np.repeat(vid[l], 8))
        ws.append((vid[l + 1][0] + tgt_row).reshape(-1))
        caps.append(cap_row.reshape(-1))
    edges = np.stack([np.concatenate(us), np.concatenate(ws)],
                     axis=1).astype(np.int64)
    cap_fwd = np.concatenate(caps).astype(np.int32)
    cap_bwd = np.zeros(len(edges), dtype=np.int32)

    excess = np.zeros(n, dtype=np.int32)
    sink_cap = np.zeros(n, dtype=np.int32)
    excess[vid[0]] = supply
    sink_cap[vid[-1]] = pipe_cap + 7 * mix_cap
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap_fwd,
                   cap_bwd=cap_bwd, excess=excess, sink_cap=sink_cap)


def washington_rlg(rows: int = 8, levels: int = 12, *, degree: int = 3,
                   max_cap: int = 100, seed: int = 0) -> Problem:
    """Washington random level graph (RLG).

    ``levels`` columns of ``rows`` vertices; every vertex sends ``degree``
    arcs of random capacity in ``[1, max_cap]`` to random vertices of the
    next column (parallel draws accumulate).  The source feeds the whole
    first column, the last column drains to the sink.  Flow has to cross
    every level, so the solve needs at least ~``levels`` region visits
    when columns are partitioned across regions.
    """
    assert rows >= 1 and levels >= 2 and degree >= 1 and max_cap >= 1
    rng = np.random.RandomState(seed)
    n = rows * levels
    vid = np.arange(n).reshape(levels, rows)

    us, ws, caps = [], [], []
    for j in range(levels - 1):
        us.append(np.repeat(vid[j], degree))
        ws.append(vid[j + 1][rng.randint(0, rows, size=rows * degree)])
        caps.append(rng.randint(1, max_cap + 1, size=rows * degree))
    edges, cap_fwd = _dedup_directed(
        np.concatenate(us), np.concatenate(ws), np.concatenate(caps))
    cap_bwd = np.zeros(len(edges), dtype=np.int32)

    out_cap, in_cap = _terminal_caps(n, edges, cap_fwd, cap_bwd)
    excess = np.zeros(n, dtype=np.int32)
    sink_cap = np.zeros(n, dtype=np.int32)
    excess[vid[0]] = out_cap[vid[0]]
    sink_cap[vid[-1]] = in_cap[vid[-1]]
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap_fwd,
                   cap_bwd=cap_bwd, excess=excess, sink_cap=sink_cap)
