"""Synthetic maxflow instance generators (paper Sec. 7.1).

The paper's synthetic family: an N-D grid with a regular connectivity
structure, integer excess/deficit per node uniform in [-mag, mag] (positive
=> source link, negative => sink link), and constant edge capacity
("strength").  ``connectivity_offsets`` reproduces the displacement list of
Sec. 7.1: (0,1),(1,0) -> 4-connected, first 8 -> 8-connected, etc.
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Problem

# paper Sec. 7.1 displacement list (pairs added symmetrically)
_DISPLACEMENTS = [
    (0, 1), (1, 0), (1, 2), (2, 1), (1, 3), (3, 1), (2, 3), (3, 2),
    (0, 2), (2, 0), (2, 2), (3, 3), (3, 4), (4, 2),
]


def connectivity_offsets(connectivity: int) -> list[tuple[int, int]]:
    assert connectivity % 2 == 0 and connectivity <= 2 * len(_DISPLACEMENTS)
    return _DISPLACEMENTS[: connectivity // 2]


def synthetic_grid(height: int, width: int, *, connectivity: int = 8,
                   strength: int = 150, excess_mag: int = 500,
                   seed: int = 0) -> Problem:
    """Paper Sec. 7.1 synthetic 2D problem."""
    rng = np.random.RandomState(seed)
    n = height * width
    vid = np.arange(n).reshape(height, width)
    edges = []
    for dy, dx in connectivity_offsets(connectivity):
        dst = vid[dy:, dx:]
        edges.append(np.stack(
            [vid[: height - dy, : width - dx].reshape(-1),
             dst.reshape(-1)], axis=1))
    edges = np.concatenate(edges, axis=0).astype(np.int64)
    m = len(edges)
    cap = np.full(m, strength, dtype=np.int32)
    term = rng.randint(-excess_mag, excess_mag + 1, size=n)
    excess = np.where(term > 0, term, 0).astype(np.int32)
    sink_cap = np.where(term < 0, -term, 0).astype(np.int32)
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap.copy(),
                   cap_bwd=cap.copy(), excess=excess, sink_cap=sink_cap)


def segmentation_grid(height: int, width: int, *, seed: int = 0,
                      smoothness: int = 20, depth: int = 1) -> Problem:
    """Vision-style segmentation instance: noisy foreground disk unaries +
    contrast-modulated pairwise terms (stands in for the BJ01/BF06 family of
    Table 1)."""
    rng = np.random.RandomState(seed)
    n = height * width * depth
    yy, xx = np.mgrid[:height, :width]
    cy, cx, r = height / 2, width / 2, min(height, width) / 3
    fg = ((yy - cy) ** 2 + (xx - cx) ** 2 < r * r)
    noise = rng.randint(0, 15, size=(height, width))
    exc2d = np.where(fg, 30 + noise, 0)
    snk2d = np.where(~fg, 30 + noise, 0)
    vid = np.arange(n).reshape(depth, height, width)
    edges = []
    for dz, dy, dx in [(0, 0, 1), (0, 1, 0), (1, 0, 0)][: (3 if depth > 1 else 2)]:
        a = vid[: depth - dz or None, : height - dy or None, : width - dx or None]
        b = vid[dz:, dy:, dx:]
        edges.append(np.stack([a.reshape(-1), b.reshape(-1)], axis=1))
    edges = np.concatenate(edges, axis=0).astype(np.int64)
    cap = rng.randint(1, smoothness + 1, size=len(edges)).astype(np.int32)
    excess = np.tile(exc2d.reshape(-1), depth).astype(np.int32)
    sink_cap = np.tile(snk2d.reshape(-1), depth).astype(np.int32)
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap.copy(),
                   cap_bwd=cap.copy(), excess=excess, sink_cap=sink_cap)


def segmentation_seeds_grid(height: int, width: int, *, seed: int = 0,
                            smoothness: int = 20,
                            seed_strength: int = 200) -> Problem:
    """Interactive-segmentation instance: SPARSE scribble terminals.

    Unlike ``segmentation_grid`` (dense unaries — every pixel has a
    terminal link, so every region touches the sink and solves are very
    local), this is the paper's interactive BJ01 shape: a foreground
    scribble (small disk at the center) carries source mass, a background
    scribble (the image border frame) carries sink capacity, and ALL flow
    must travel across the 4-connected grid between them — crossing many
    region boundaries, which is what makes sweep counts (and warm-start
    re-solves) interesting.
    """
    rng = np.random.RandomState(seed)
    n = height * width
    yy, xx = np.mgrid[:height, :width]
    cy, cx, r = height / 2, width / 2, min(height, width) / 3
    fg_seed = ((yy - cy) ** 2 + (xx - cx) ** 2 < (r / 3) ** 2)
    bg_seed = (yy < 2) | (yy >= height - 2) | (xx < 2) | (xx >= width - 2)
    exc2d = np.where(fg_seed & ~bg_seed,
                     seed_strength + rng.randint(0, 15, size=(height, width)),
                     0)
    snk2d = np.where(bg_seed,
                     seed_strength + rng.randint(0, 15, size=(height, width)),
                     0)
    vid = np.arange(n).reshape(height, width)
    edges = []
    for dy, dx in [(0, 1), (1, 0)]:
        a = vid[: height - dy or None, : width - dx or None]
        b = vid[dy:, dx:]
        edges.append(np.stack([a.reshape(-1), b.reshape(-1)], axis=1))
    edges = np.concatenate(edges, axis=0).astype(np.int64)
    cap = rng.randint(1, smoothness + 1, size=len(edges)).astype(np.int32)
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap.copy(),
                   cap_bwd=cap.copy(),
                   excess=exc2d.reshape(-1).astype(np.int32),
                   sink_cap=snk2d.reshape(-1).astype(np.int32))


def random_sparse(n: int, m: int, *, cap_mag: int = 100, term_mag: int = 50,
                  seed: int = 0) -> Problem:
    """Random sparse instance (property-test fodder)."""
    rng = np.random.RandomState(seed)
    if n < 2:
        raise ValueError("need n >= 2")
    pairs = set()
    edges = []
    while len(edges) < m:
        u, v = rng.randint(0, n, size=2)
        if u == v or (u, v) in pairs or (v, u) in pairs:
            continue
        pairs.add((u, v))
        edges.append((u, v))
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    cap_f = rng.randint(0, cap_mag + 1, size=len(edges)).astype(np.int32)
    cap_b = rng.randint(0, cap_mag + 1, size=len(edges)).astype(np.int32)
    excess = rng.randint(0, term_mag + 1, size=n).astype(np.int32)
    sink_cap = rng.randint(0, term_mag + 1, size=n).astype(np.int32)
    return Problem(num_vertices=n, edges=edges, cap_fwd=cap_f, cap_bwd=cap_b,
                   excess=excess, sink_cap=sink_cap)
