"""Pallas TPU kernel: fused push/relabel compute phase on an ELL block.

The hot spot of every region discharge is the per-vertex row scan over the
padded adjacency: gather neighbour labels, test admissibility, split the
vertex's excess over admissible arcs (exclusive cumsum), and compute the
relabel minimum.  On TPU this is one VMEM-resident pass per vertex block:

  * grid tiles the vertex dimension (rows); each program instance loads a
    (BV, E) tile of cf/nbr/masks plus the full label vector (labels are
    4B * V — a 64k-vertex region's labels are 256 KiB, VMEM-resident);
  * the label gather, admissibility mask, cumsum split and relabel min all
    happen in registers/VMEM — the only HBM traffic is the tile streams,
    which is what makes the discharge memory-bound rather than gather-bound;
  * scatter application of the deltas (reverse arcs, receiver excess) stays
    outside the kernel in XLA — scatters are global (cross-tile) and XLA's
    sort-based scatter on TPU handles them well.

Block shapes: BV = 256 rows/tile by default (rows * (3 arcs arrays + 2
outputs) * E * 4B ≈ 2.6 MiB at E = 256 — fits VMEM with double buffering);
E is padded to the lane width (128) by the wrapper.

Validated against kernels/ref.py in interpret mode over a shape/dtype sweep
(tests/test_kernels.py); on this CPU-only container the kernel always runs
with interpret=True.

``push_relabel_phase`` is the raw tiled kernel; ``engine_phase`` is the
engine-facing adapter that accepts core/engine.py's mask semantics
(``cross_pushable``/``emask``/``vmask``/``sink_open``) and is what the
``backend="pallas"`` path of ``repro.core.engine.push_relabel`` calls twice
per iteration (pre-push for the deltas, post-push for the relabels).

Region-resident fused mode
--------------------------
``fused_engine_run`` is the single-launch alternative: one ``pallas_call``
whose block is the *whole region* (``block_v = V`` — regions are sized to
fit memory, paper Sec. 5.3) advances up to ``iter_limit`` complete engine
iterations with all state resident in VMEM.  Each in-kernel iteration does
the push split, the intra-region scatter (reverse arcs via ``rev_slot``,
receiver excess via ``nbr_local``) and the post-push relabel, accumulating
``out_push``/``sink_pushed``/``relabel_sum`` in-kernel, with an early exit
as soon as no vertex is active.  HBM traffic drops from four round trips of
the ``[V, E]`` state per iteration (two phase launches + two scatters) to
one per *k* iterations — the paper's "intra-region work is cheap because it
stays local" premise, honored on the accelerator.  ``core.engine`` falls
back to the blocked two-phase path when the region exceeds the VMEM budget
(``fused_region_fits_vmem``).

``fused_engine_run_batched`` is the grid-over-regions form: the same
in-kernel loop as a ``grid=(K,)`` program, one launch discharging *all*
regions of a parallel sweep — each program instance owns one region's
``[V, E]`` tile, takes its own iteration budget from a per-region limit
vector, and early-exits independently, so idle regions cost O(1) inside
the shared launch.  ``core.engine.push_relabel_batched`` drives it.

With ``[B, K, V, E]`` inputs the same entry point lowers to a
``grid=(B, K)`` program — one launch advancing *every region of every
instance of a solve batch*.  ``d_inf`` and ``iter_limit`` broadcast
against the ``(B, K)`` lead, so each instance keeps its own label ceiling
(mixed problem sizes share one bucket-shaped executable) and the driver's
per-instance convergence flags arrive as zeroed iteration budgets; a
converged instance's regions all take the O(1) early exit, exactly like
idle regions of a single solve.  ``core.batch`` drives this form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import dtypes as _dt

INF_LABEL = 2**30
DEFAULT_BLOCK_V = 256


def _inf_for(dtype) -> int:
    """Label-infinity sentinel for the label dtype in play: 2**30 for
    int32, 2**14 for int16 (``repro.core.dtypes``).  Every real label is
    strictly below either sentinel, so comparisons/min/max order
    identically — the narrow path stays bit-exact."""
    return _dt.inf_label_for(dtype)


def _pr_kernel(lab_ref, cf_ref, sink_cf_ref, excess_ref, nbr_ref, intra_ref,
               pushable_ref, cross_lab_ref, d_inf_ref,
               delta_ref, new_lab_ref, *, mode: str):
    """One vertex-block: push deltas (sink col 0) and/or relabel candidates.

    ``mode`` ("both" | "push" | "relabel") statically drops the unneeded
    output's compute — pallas_call is opaque to XLA DCE, and the engine
    consumes only one output per call (deltas pre-push, relabels post-push).
    The admissibility mask is shared; only the cumsum excess split resp. the
    relabel min-reduction is skipped.  A skipped output ref is still written
    (zero deltas / unchanged labels) so it stays well-defined.
    """
    lab_full = lab_ref[...]                      # [V] whole-region labels
    cf = cf_ref[...]                             # [BV, E]
    nbr = nbr_ref[...]
    intra = intra_ref[...] != 0
    pushable = pushable_ref[...] != 0
    cross_lab = cross_lab_ref[...]
    excess = excess_ref[...]
    sink_cf = sink_cf_ref[...]
    inf = _inf_for(lab_full.dtype)
    d_inf = d_inf_ref[0].astype(lab_full.dtype)  # ceiling fits the dtype

    lab_rows = lab_full[nbr]                     # gather [BV, E]
    nlab = jnp.where(intra, lab_rows, cross_lab)
    nlab = jnp.where(pushable, nlab, inf)

    bv = cf.shape[0]
    row0 = pl.program_id(0) * bv
    my_lab = jax.lax.dynamic_slice(lab_full, (row0,), (bv,))
    act = (excess > 0) & (my_lab < d_inf)

    adm = (cf > 0) & (my_lab[:, None] == nlab + 1) & act[:, None]
    sink_adm = (sink_cf > 0) & (my_lab == 1) & act

    if mode in ("both", "push"):
        sink_cap = jnp.where(sink_adm, sink_cf, 0)
        arc_cap = jnp.where(adm, cf, 0)
        caps = jnp.concatenate([sink_cap[:, None], arc_cap], axis=1)
        avail = jnp.where(act, excess, 0)
        # cumsum/sum must not promote (jnp defaults widen sub-int32 ints);
        # the narrow range check bounds every partial sum
        cum_excl = jnp.cumsum(caps, axis=1, dtype=caps.dtype) - caps
        delta_ref[...] = jnp.clip(avail[:, None] - cum_excl, 0, caps)
    else:
        delta_ref[...] = jnp.zeros(delta_ref.shape, delta_ref.dtype)

    if mode in ("both", "relabel"):
        no_adm = act & ~adm.any(axis=1) & ~sink_adm
        cand = jnp.where(cf > 0, nlab + 1, inf).min(axis=1)
        cand = jnp.where(sink_cf > 0, jnp.minimum(cand, 1), cand)
        new_lab_ref[...] = jnp.where(
            no_adm, jnp.maximum(jnp.minimum(cand, d_inf), my_lab), my_lab)
    else:
        new_lab_ref[...] = my_lab


@functools.partial(jax.jit, static_argnames=("block_v", "interpret", "mode"))
def push_relabel_phase(lab, cf, sink_cf, excess, nbr, intra, pushable,
                       cross_lab, d_inf, *, block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool = True, mode: str = "both"):
    """Pallas-tiled push/relabel compute phase.

    Returns (delta [V, 1+E] with the sink in column 0, new_lab [V]).
    Masks are 0/1 integers (int32, or int8 under a narrow dtype policy) for
    portable Pallas lowering; value dtypes follow the inputs.  ``mode``
    statically prunes the unused output's compute ("push": zero new_lab
    changes, "relabel": zero deltas); "both" computes everything.
    """
    assert mode in ("both", "push", "relabel"), mode
    V, E = cf.shape
    bv = min(block_v, V)
    if V % bv:                       # pad rows to a whole number of tiles
        pad = bv - V % bv
        padv = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        out_d, out_l = push_relabel_phase(
            jnp.pad(lab, (0, pad), constant_values=_inf_for(lab.dtype)),
            padv(cf), padv(sink_cf), padv(excess), padv(nbr), padv(intra),
            padv(pushable), padv(cross_lab), d_inf, block_v=bv,
            interpret=interpret, mode=mode)
        return out_d[:V], out_l[:V]

    grid = (V // bv,)
    kernel = pl.pallas_call(
        functools.partial(_pr_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((V,), lambda i: (0,)),            # lab (full)
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # cf
            pl.BlockSpec((bv,), lambda i: (i,)),           # sink_cf
            pl.BlockSpec((bv,), lambda i: (i,)),           # excess
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # nbr
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # intra
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # pushable
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # cross_lab
            pl.BlockSpec((1,), lambda i: (0,)),            # d_inf
        ],
        out_specs=[
            pl.BlockSpec((bv, 1 + E), lambda i: (i, 0)),   # delta
            pl.BlockSpec((bv,), lambda i: (i,)),           # new_lab
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, 1 + E), cf.dtype),
            jax.ShapeDtypeStruct((V,), lab.dtype),
        ],
        interpret=interpret,
    )
    d_inf_arr = jnp.reshape(jnp.asarray(d_inf, jnp.int32), (1,))
    return kernel(lab, cf, sink_cf, excess, nbr, intra, pushable, cross_lab,
                  d_inf_arr)


# --------------------------------------------------------------------------
# Region-resident fused discharge: k full iterations per kernel launch.
# --------------------------------------------------------------------------

# VMEM working set of one fused iteration, per value family.  [V, E]
# arrays: cf, out_push, d_arc, d_intra carry flow values; nbr, rev_slot
# are int32 indices; intra, pushable are masks; cross_lab carries labels.
# caps/delta are flow-valued [V, 1+E].  [V] vectors: sink_cf, excess,
# avail carry flow; lab, new_lab carry labels; vmask is a mask; plus two
# int32 scalar/misc words per row.  The budget leaves headroom under the
# ~16 MiB/core of TPU VMEM for double buffering and the scalar plumbing.
FUSED_VMEM_BUDGET_BYTES = 12 * 2**20


def fused_region_vmem_bytes(V: int, E: int,
                            dtypes: _dt.KernelDtypes | None = None) -> int:
    """Estimated VMEM bytes of the region-resident fused kernel's state.

    Dtype-aware: each value family is costed at its own itemsize (the old
    formula hard-coded 4-byte words for everything, so it over-estimated
    the narrow configurations and would have kept them on the blocked
    path).  With all-int32 dtypes this is exactly the historical
    ``4 * (9*V*E + 2*V*(E+1) + 8*V)``.
    """
    kd = _dt.WIDE if dtypes is None else dtypes
    fb = np.dtype(kd.flow).itemsize
    lb = np.dtype(kd.label).itemsize
    mb = np.dtype(kd.mask).itemsize
    return (fb * (4 * V * E + 2 * V * (E + 1) + 3 * V)   # flow values
            + 4 * (2 * V * E + 2 * V)                    # int32 indices/misc
            + mb * (2 * V * E + V)                       # masks
            + lb * (V * E + 2 * V))                      # labels


def fused_region_fits_vmem(V: int, E: int,
                           budget_bytes: int | None = None,
                           dtypes: _dt.KernelDtypes | None = None) -> bool:
    budget = FUSED_VMEM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    return fused_region_vmem_bytes(V, E, dtypes) <= budget


def make_fused_iteration(*, nbr, rev_slot, intra, pushable, cross_lab, vmask,
                         d_inf, sink_open: bool):
    """Build the pure fused-iteration function shared by both backends.

    ``iteration(cf, sink_cf, excess, lab) -> (cf, sink_cf, excess, new_lab,
    d_cross, d_sink_total, relabel_inc)`` performs push compute (labels
    frozen), intra-region scatter application (reverse arcs via
    ``rev_slot``, receiver excess via ``nbr``) and the post-push relabel in
    one function — the per-step unit of the region-resident kernel and of
    the fused XLA engine body.  Defining it once is what makes the two
    fused backends bit-exact by construction; ``kernels.ref.
    fused_iteration_ref`` stays the independent oracle.  ``intra``/
    ``pushable``/``vmask`` are bool, ``d_inf`` an i32 scalar.
    """
    V, E = nbr.shape
    flat_n = V * E
    flat_idx = (nbr * E + rev_slot).reshape(flat_n)
    recv_idx = nbr.reshape(flat_n)
    inf = _inf_for(cross_lab.dtype)

    def iteration(cf, sink_cf, excess, lab):
        # label ceiling arrives int32 (scalar plumbing); every real label
        # fits the narrow dtype by the build-time range check
        dinf = jnp.asarray(d_inf).astype(lab.dtype)
        # ---- push compute (labels frozen) ----
        act = (excess > 0) & (lab < dinf) & vmask
        nlab = jnp.where(intra, lab[nbr], cross_lab)
        nlab = jnp.where(pushable, nlab, inf)
        adm = (cf > 0) & (lab[:, None] == nlab + 1) & act[:, None]
        sink = sink_cf if sink_open else jnp.zeros_like(sink_cf)
        sink_adm = (sink > 0) & (lab == 1) & act
        sink_cap = jnp.where(sink_adm, sink, 0)
        arc_cap = jnp.where(adm, cf, 0)
        caps = jnp.concatenate([sink_cap[:, None], arc_cap], axis=1)
        avail = jnp.where(act, excess, 0)
        cum_excl = jnp.cumsum(caps, axis=1, dtype=caps.dtype) - caps
        delta = jnp.clip(avail[:, None] - cum_excl, 0, caps)
        d_sink = delta[:, 0]
        d_arc = delta[:, 1:]
        # ---- scatter application (intra reverse arcs + receiver excess) ----
        excess = excess - d_sink - jnp.sum(d_arc, axis=1, dtype=d_arc.dtype)
        sink_cf = sink_cf - d_sink
        cf = cf - d_arc
        d_intra = jnp.where(intra, d_arc, 0)
        cf = (cf.reshape(flat_n).at[flat_idx]
              .add(d_intra.reshape(flat_n), mode="drop").reshape(V, E))
        excess = excess + jnp.zeros((V,), excess.dtype).at[recv_idx].add(
            d_intra.reshape(flat_n), mode="drop")
        d_cross = d_arc - d_intra
        # ---- relabel (on the post-push residual graph) ----
        act2 = (excess > 0) & (lab < dinf) & vmask
        adm2 = (cf > 0) & (lab[:, None] == nlab + 1) & act2[:, None]
        sink2 = sink_cf if sink_open else jnp.zeros_like(sink_cf)
        sink_adm2 = (sink2 > 0) & (lab == 1) & act2
        no_adm = act2 & ~adm2.any(axis=1) & ~sink_adm2
        cand = jnp.where(cf > 0, nlab + 1, inf).min(axis=1)
        cand = jnp.where(sink2 > 0, jnp.minimum(cand, 1), cand)
        new_lab = jnp.where(
            no_adm, jnp.maximum(jnp.minimum(cand, dinf), lab), lab)
        # accumulators cross iterations and regions: always int32
        relabel_inc = jnp.sum(jnp.where(vmask, new_lab - lab, 0),
                              dtype=jnp.int32)
        return (cf, sink_cf, excess, new_lab, d_cross,
                jnp.sum(d_sink, dtype=jnp.int32), relabel_inc)

    return iteration


def _fused_region_loop(lab, cf, sink_cf, excess, nbr, rev_slot, intra,
                       pushable, cross_lab, vmask, d_inf, limit, *,
                       sink_open: bool):
    """Up to ``limit`` fused engine iterations on one region's arrays.

    One iteration is bit-identical to one trip of the unfused engine loop
    (push compute -> intra scatter -> post-push relabel); the while_loop
    exits early once no vertex is active, so idle regions cost O(1).  This
    is the shared in-kernel body of the single-region (``grid=()``) and the
    grid-over-regions (``grid=(K,)``) fused kernels.
    """
    V, E = cf.shape
    vmask = vmask != 0
    d_inf = jnp.asarray(d_inf).astype(lab.dtype)
    iteration = make_fused_iteration(
        nbr=nbr, rev_slot=rev_slot, intra=intra != 0,
        pushable=pushable != 0, cross_lab=cross_lab,
        vmask=vmask, d_inf=d_inf, sink_open=sink_open)

    def body(carry):
        cf, sink_cf, excess, lab, out_push, sinkp, rls, it = carry
        cf, sink_cf, excess, lab, d_cross, d_sink, rinc = iteration(
            cf, sink_cf, excess, lab)
        return (cf, sink_cf, excess, lab, out_push + d_cross,
                sinkp + d_sink, rls + rinc, it + 1)

    def cond(carry):
        cf, sink_cf, excess, lab, out_push, sinkp, rls, it = carry
        return (it < limit) & ((excess > 0) & (lab < d_inf) & vmask).any()

    z = jnp.zeros((), jnp.int32)
    init = (cf, sink_cf, excess, lab, jnp.zeros((V, E), cf.dtype), z, z, z)
    return jax.lax.while_loop(cond, body, init)


def _fused_kernel_grid(lab_ref, cf_ref, sink_cf_ref, excess_ref, nbr_ref,
                       rev_ref, intra_ref, pushable_ref, cross_lab_ref,
                       vmask_ref, scal_ref, cf_out, sink_out, exc_out,
                       lab_out, push_out, sinkp_out, rls_out, it_out, *,
                       sink_open: bool, nlead: int):
    """Grid program instance: region ``pl.program_id(0)`` (``grid=(K,)``)
    or region (``pl.program_id(0)``, ``pl.program_id(1)``) of a solve batch
    (``grid=(B, K)``).

    Every ref carries ``nlead`` leading block dimensions of 1 (one region's
    tile); ``scal_ref`` is this region's (d_inf, iter_limit) row.  The
    in-kernel early exit makes an idle or already-converged region cost
    O(1), so one launch can mix hot and idle regions — and converged and
    running instances — freely.
    """
    z = (0,) * nlead
    scal = scal_ref[z]
    cf, sink_cf, excess, lab, out_push, sinkp, rls, it = _fused_region_loop(
        lab_ref[z], cf_ref[z], sink_cf_ref[z], excess_ref[z],
        nbr_ref[z], rev_ref[z], intra_ref[z], pushable_ref[z],
        cross_lab_ref[z], vmask_ref[z], scal[0], scal[1],
        sink_open=sink_open)
    cf_out[z] = cf
    sink_out[z] = sink_cf
    exc_out[z] = excess
    lab_out[z] = lab
    push_out[z] = out_push
    sinkp_out[z] = sinkp
    rls_out[z] = rls
    it_out[z] = it


@functools.partial(jax.jit, static_argnames=("sink_open", "interpret"))
def fused_engine_run(lab, cf, sink_cf, excess, nbr, rev_slot, intra, pushable,
                     cross_lab, vmask, d_inf, iter_limit, *,
                     sink_open: bool = True, interpret: bool = True):
    """Run up to ``iter_limit`` fused engine iterations in one kernel launch.

    Region-resident mode: ``block_v = V`` (the caller guarantees
    ``fused_region_fits_vmem``).  Masks are int32 (0/1) for portable Pallas
    lowering; ``iter_limit`` is dynamic so the driver can clamp the last
    chunk to a ``max_iters`` cap.  The single-region convenience form of
    ``fused_engine_run_batched`` (K = 1 grid, same kernel body).  Returns
    the post-chunk region state plus this launch's accumulators:
    ``(cf, sink_cf, excess, lab, out_push, sink_pushed, relabel_sum, iters)``.
    """
    one = lambda a: a[None]
    outs = fused_engine_run_batched(
        one(lab), one(cf), one(sink_cf), one(excess), one(nbr),
        one(rev_slot), one(intra), one(pushable), one(cross_lab), one(vmask),
        d_inf, jnp.reshape(jnp.asarray(iter_limit, jnp.int32), (1,)),
        sink_open=sink_open, interpret=interpret)
    return tuple(o[0] for o in outs)


@functools.partial(jax.jit, static_argnames=("sink_open", "interpret",
                                             "double_buffer"))
def fused_engine_run_batched(lab, cf, sink_cf, excess, nbr, rev_slot, intra,
                             pushable, cross_lab, vmask, d_inf, iter_limit, *,
                             sink_open: bool = True, interpret: bool = True,
                             double_buffer: bool | None = None):
    """All regions of a sweep — or of a solve batch — in ONE kernel launch.

    The grid-over-regions variant of ``fused_engine_run``: with
    ``[K, V, E]`` inputs the program is ``grid=(K,)`` and instance k owns
    region k's ``[V, E]`` tile; with ``[B, K, V, E]`` inputs it is
    ``grid=(B, K)`` and instance (b, k) owns region k of solve-batch
    instance b.  Each advances its tile up to ``iter_limit[...]`` complete
    fused engine iterations with per-region in-kernel early exit — an idle
    region (or every region of a converged instance) costs O(1).
    ``d_inf`` and ``iter_limit`` broadcast against the lead shape, so each
    batch instance keeps its own label ceiling and iteration budget (the
    driver's per-instance convergence flag is a zeroed budget).
    Per-region results are bit-identical to separate ``fused_engine_run``
    calls; what changes is the dispatch count: one launch instead of K
    (resp. B*K).

    ``double_buffer`` selects the DMA-streamed variant on real TPUs
    (regions staged HBM->VMEM one at a time with region k+1's copy in
    flight while region k computes — ``None`` auto-selects it whenever
    ``dma_overlap_supported()``); the grid form is the interpret-mode /
    non-TPU fallback.  Both variants are bit-identical and count as one
    launch.

    Returns ``(cf, sink_cf, excess, lab, out_push, sink_pushed [lead],
    relabel_sum [lead], iters [lead])`` where ``lead`` = ``(K,)`` or
    ``(B, K)``.
    """
    lead = cf.shape[:-2]
    V, E = cf.shape[-2:]
    nlead = len(lead)
    assert nlead in (1, 2), cf.shape
    scal = jnp.stack(
        [jnp.broadcast_to(jnp.asarray(d_inf, jnp.int32), lead),
         jnp.broadcast_to(jnp.asarray(iter_limit, jnp.int32), lead)],
        axis=-1)                                           # [*lead, 2]
    args = (lab, cf, sink_cf, excess, nbr, rev_slot, intra, pushable,
            cross_lab, vmask)
    if double_buffer is None:
        double_buffer = dma_overlap_supported() and not interpret
    if double_buffer and nlead == 1:
        return _fused_streamed_call(args, scal, sink_open=sink_open)
    blk = lambda *tail: pl.BlockSpec(
        (1,) * nlead + tail, lambda *ids: ids + (0,) * len(tail))
    vec = lambda: blk(V)
    mat = lambda w: blk(V, w)
    one = lambda: pl.BlockSpec((1,) * nlead, lambda *ids: ids)
    outs = pl.pallas_call(
        functools.partial(_fused_kernel_grid, sink_open=sink_open,
                          nlead=nlead),
        grid=lead,
        in_specs=[vec(), mat(E), vec(), vec(), mat(E), mat(E), mat(E),
                  mat(E), mat(E), vec(), blk(2)],
        out_specs=[mat(E), vec(), vec(), vec(), mat(E), one(), one(), one()],
        out_shape=[
            jax.ShapeDtypeStruct(lead + (V, E), cf.dtype),    # cf
            jax.ShapeDtypeStruct(lead + (V,), sink_cf.dtype),  # sink_cf
            jax.ShapeDtypeStruct(lead + (V,), excess.dtype),  # excess
            jax.ShapeDtypeStruct(lead + (V,), lab.dtype),     # lab
            jax.ShapeDtypeStruct(lead + (V, E), cf.dtype),    # out_push
            jax.ShapeDtypeStruct(lead, jnp.int32),            # sink_pushed
            jax.ShapeDtypeStruct(lead, jnp.int32),            # relabel_sum
            jax.ShapeDtypeStruct(lead, jnp.int32),            # iters
        ],
        interpret=interpret,
    )(*args, scal)
    return outs


# --------------------------------------------------------------------------
# DMA-streamed fused discharge: double-buffered region staging (TPU only).
# --------------------------------------------------------------------------

def dma_overlap_supported() -> bool:
    """True when the DMA-streamed (double-buffered) fused variant can run:
    manual ``pltpu.make_async_copy`` pipelines need a real TPU backend —
    plain interpret mode executes the grid variant instead (bit-identical,
    serial region staging)."""
    return jax.default_backend() == "tpu"


def _fused_kernel_streamed(lab_hbm, cf_hbm, sink_hbm, exc_hbm, nbr_hbm,
                           rev_hbm, intra_hbm, push_hbm, clab_hbm, vmask_hbm,
                           scal_smem, cf_out, sink_out, exc_out, lab_out,
                           op_out, sinkp_out, rls_out, it_out, *,
                           sink_open: bool, num_regions: int):
    """Single-program streamed form of the grid kernel (pallas guide
    "Double Buffering"): inputs stay in HBM/ANY; region k's ten blocks are
    DMA'd into one of two VMEM slots while region k-1 computes, and each
    region's results are DMA'd back out while the next region runs.  The
    compute body is the same ``_fused_region_loop`` as the grid variant,
    so results are bit-identical; what changes is that the K-region launch
    no longer serializes loads with compute — the kernel-level
    prerequisite for streaming regions that don't fit VMEM together.
    """
    from jax.experimental.pallas import tpu as pltpu

    K = num_regions
    ins = (cf_hbm, lab_hbm, sink_hbm, exc_hbm, nbr_hbm, rev_hbm, intra_hbm,
           push_hbm, clab_hbm, vmask_hbm)

    def scoped(in_s, out_s, in_sems, out_sems):
        def in_dmas(slot, k):
            return [pltpu.make_async_copy(src.at[k], dst.at[slot],
                                          in_sems.at[slot, i])
                    for i, (src, dst) in enumerate(zip(ins, in_s))]

        outs_hbm = (cf_out, sink_out, exc_out, lab_out, op_out)

        def out_dmas(slot, k):
            return [pltpu.make_async_copy(src.at[slot], dst.at[k],
                                          out_sems.at[slot, i])
                    for i, (src, dst) in enumerate(zip(out_s, outs_hbm))]

        for dma in in_dmas(0, 0):
            dma.start()

        def body(k, _):
            slot = k % 2

            @pl.when(k + 1 < K)
            def _prefetch():            # stage region k+1 while k computes
                for dma in in_dmas((k + 1) % 2, k + 1):
                    dma.start()

            for dma in in_dmas(slot, k):
                dma.wait()

            @pl.when(k >= 2)
            def _drain():               # slot's previous writeback done?
                for dma in out_dmas(slot, k - 2):
                    dma.wait()

            cf_s, lab_s, sink_s, exc_s, nbr_s, rev_s, intra_s, push_s, \
                clab_s, vm_s = in_s
            cf, sink_cf, excess, lab, out_push, sinkp, rls, it = \
                _fused_region_loop(
                    lab_s[slot], cf_s[slot], sink_s[slot], exc_s[slot],
                    nbr_s[slot], rev_s[slot], intra_s[slot], push_s[slot],
                    clab_s[slot], vm_s[slot], scal_smem[k, 0],
                    scal_smem[k, 1], sink_open=sink_open)
            cfo_s, sino_s, exco_s, labo_s, opo_s = out_s
            cfo_s[slot] = cf
            sino_s[slot] = sink_cf
            exco_s[slot] = excess
            labo_s[slot] = lab
            opo_s[slot] = out_push
            sinkp_out[k] = sinkp        # scalar accumulators live in SMEM
            rls_out[k] = rls
            it_out[k] = it
            for dma in out_dmas(slot, k):
                dma.start()
            return 0

        jax.lax.fori_loop(0, K, body, 0)

        @pl.when(K >= 2)
        def _():
            for dma in out_dmas((K - 2) % 2, K - 2):
                dma.wait()
        for dma in out_dmas((K - 1) % 2, K - 1):
            dma.wait()

    V, E = cf_hbm.shape[-2:]
    dbl = lambda ref, *tail: pltpu.VMEM((2,) + tail, ref.dtype)
    pl.run_scoped(
        scoped,
        in_s=tuple(dbl(r, V, E) if r.ndim == 3 else dbl(r, V)
                   for r in ins),
        out_s=(dbl(cf_hbm, V, E), dbl(sink_hbm, V), dbl(exc_hbm, V),
               dbl(lab_hbm, V), dbl(cf_hbm, V, E)),
        in_sems=pltpu.SemaphoreType.DMA((2, 10)),
        out_sems=pltpu.SemaphoreType.DMA((2, 5)),
    )


def _fused_streamed_call(args, scal, *, sink_open: bool):
    from jax.experimental.pallas import tpu as pltpu

    lab, cf, sink_cf, excess = args[0], args[1], args[2], args[3]
    K, V, E = cf.shape
    anyspec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    smem = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.SMEM)
    return pl.pallas_call(
        functools.partial(_fused_kernel_streamed, sink_open=sink_open,
                          num_regions=K),
        in_specs=[anyspec] * 10 + [smem],
        out_specs=[anyspec] * 5 + [smem] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((K, V, E), cf.dtype),    # cf
            jax.ShapeDtypeStruct((K, V), sink_cf.dtype),  # sink_cf
            jax.ShapeDtypeStruct((K, V), excess.dtype),   # excess
            jax.ShapeDtypeStruct((K, V), lab.dtype),      # lab
            jax.ShapeDtypeStruct((K, V, E), cf.dtype),    # out_push
            jax.ShapeDtypeStruct((K,), jnp.int32),        # sink_pushed
            jax.ShapeDtypeStruct((K,), jnp.int32),        # relabel_sum
            jax.ShapeDtypeStruct((K,), jnp.int32),        # iters
        ],
    )(*args, scal)


def engine_phase(lab, cf, sink_cf, excess, *, nbr_local, intra, emask, vmask,
                 cross_pushable, cross_lab, d_inf, sink_open: bool = True,
                 block_v: int = DEFAULT_BLOCK_V, interpret: bool = True,
                 mode: str = "both", mask_dtype=jnp.int32):
    """Engine-semantics adapter over ``push_relabel_phase``.

    Folds the engine's masks into the kernel's inputs: arcs are pushable iff
    intra or cross-enabled (and real, per ``emask``); vertices outside
    ``vmask`` are made inactive by zeroing their excess; a closed sink is a
    zero sink capacity.  Returns (delta [V, 1+E] with sink column 0, new_lab
    [V]) — exactly what one compute phase of ``core.engine.push_relabel``
    consumes.  ``mode`` prunes the output the caller discards ("push" /
    "relabel" / "both").
    """
    pushable = ((cross_pushable | intra) & emask).astype(mask_dtype)
    excess = jnp.where(vmask, excess, 0)
    sink = sink_cf if sink_open else jnp.zeros_like(sink_cf)
    return push_relabel_phase(lab, cf, sink, excess, nbr_local,
                              intra.astype(mask_dtype), pushable, cross_lab,
                              d_inf, block_v=block_v, interpret=interpret,
                              mode=mode)
