"""Pallas TPU kernel: fused push/relabel compute phase on an ELL block.

The hot spot of every region discharge is the per-vertex row scan over the
padded adjacency: gather neighbour labels, test admissibility, split the
vertex's excess over admissible arcs (exclusive cumsum), and compute the
relabel minimum.  On TPU this is one VMEM-resident pass per vertex block:

  * grid tiles the vertex dimension (rows); each program instance loads a
    (BV, E) tile of cf/nbr/masks plus the full label vector (labels are
    4B * V — a 64k-vertex region's labels are 256 KiB, VMEM-resident);
  * the label gather, admissibility mask, cumsum split and relabel min all
    happen in registers/VMEM — the only HBM traffic is the tile streams,
    which is what makes the discharge memory-bound rather than gather-bound;
  * scatter application of the deltas (reverse arcs, receiver excess) stays
    outside the kernel in XLA — scatters are global (cross-tile) and XLA's
    sort-based scatter on TPU handles them well.

Block shapes: BV = 256 rows/tile by default (rows * (3 arcs arrays + 2
outputs) * E * 4B ≈ 2.6 MiB at E = 256 — fits VMEM with double buffering);
E is padded to the lane width (128) by the wrapper.

Validated against kernels/ref.py in interpret mode over a shape/dtype sweep
(tests/test_kernels.py); on this CPU-only container the kernel always runs
with interpret=True.

``push_relabel_phase`` is the raw tiled kernel; ``engine_phase`` is the
engine-facing adapter that accepts core/engine.py's mask semantics
(``cross_pushable``/``emask``/``vmask``/``sink_open``) and is what the
``backend="pallas"`` path of ``repro.core.engine.push_relabel`` calls twice
per iteration (pre-push for the deltas, post-push for the relabels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF_LABEL = 2**30
DEFAULT_BLOCK_V = 256


def _pr_kernel(lab_ref, cf_ref, sink_cf_ref, excess_ref, nbr_ref, intra_ref,
               pushable_ref, cross_lab_ref, d_inf_ref,
               delta_ref, new_lab_ref, *, mode: str):
    """One vertex-block: push deltas (sink col 0) and/or relabel candidates.

    ``mode`` ("both" | "push" | "relabel") statically drops the unneeded
    output's compute — pallas_call is opaque to XLA DCE, and the engine
    consumes only one output per call (deltas pre-push, relabels post-push).
    The admissibility mask is shared; only the cumsum excess split resp. the
    relabel min-reduction is skipped.  A skipped output ref is still written
    (zero deltas / unchanged labels) so it stays well-defined.
    """
    lab_full = lab_ref[...]                      # [V] whole-region labels
    cf = cf_ref[...]                             # [BV, E]
    nbr = nbr_ref[...]
    intra = intra_ref[...] != 0
    pushable = pushable_ref[...] != 0
    cross_lab = cross_lab_ref[...]
    excess = excess_ref[...]
    sink_cf = sink_cf_ref[...]
    d_inf = d_inf_ref[0]

    lab_rows = lab_full[nbr]                     # gather [BV, E]
    nlab = jnp.where(intra, lab_rows, cross_lab)
    nlab = jnp.where(pushable, nlab, INF_LABEL)

    bv = cf.shape[0]
    row0 = pl.program_id(0) * bv
    my_lab = jax.lax.dynamic_slice(lab_full, (row0,), (bv,))
    act = (excess > 0) & (my_lab < d_inf)

    adm = (cf > 0) & (my_lab[:, None] == nlab + 1) & act[:, None]
    sink_adm = (sink_cf > 0) & (my_lab == 1) & act

    if mode in ("both", "push"):
        sink_cap = jnp.where(sink_adm, sink_cf, 0)
        arc_cap = jnp.where(adm, cf, 0)
        caps = jnp.concatenate([sink_cap[:, None], arc_cap], axis=1)
        avail = jnp.where(act, excess, 0)
        cum_excl = jnp.cumsum(caps, axis=1) - caps
        delta_ref[...] = jnp.clip(avail[:, None] - cum_excl, 0, caps)
    else:
        delta_ref[...] = jnp.zeros(delta_ref.shape, delta_ref.dtype)

    if mode in ("both", "relabel"):
        no_adm = act & ~adm.any(axis=1) & ~sink_adm
        cand = jnp.where(cf > 0, nlab + 1, INF_LABEL).min(axis=1)
        cand = jnp.where(sink_cf > 0, jnp.minimum(cand, 1), cand)
        new_lab_ref[...] = jnp.where(
            no_adm, jnp.maximum(jnp.minimum(cand, d_inf), my_lab), my_lab)
    else:
        new_lab_ref[...] = my_lab


@functools.partial(jax.jit, static_argnames=("block_v", "interpret", "mode"))
def push_relabel_phase(lab, cf, sink_cf, excess, nbr, intra, pushable,
                       cross_lab, d_inf, *, block_v: int = DEFAULT_BLOCK_V,
                       interpret: bool = True, mode: str = "both"):
    """Pallas-tiled push/relabel compute phase.

    Returns (delta [V, 1+E] with the sink in column 0, new_lab [V]).
    Masks are int32 (0/1) for portable Pallas lowering.  ``mode`` statically
    prunes the unused output's compute ("push": zero new_lab changes,
    "relabel": zero deltas); "both" computes everything.
    """
    assert mode in ("both", "push", "relabel"), mode
    V, E = cf.shape
    bv = min(block_v, V)
    if V % bv:                       # pad rows to a whole number of tiles
        pad = bv - V % bv
        padv = lambda a: jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        out_d, out_l = push_relabel_phase(
            jnp.pad(lab, (0, pad), constant_values=INF_LABEL), padv(cf),
            padv(sink_cf), padv(excess), padv(nbr), padv(intra),
            padv(pushable), padv(cross_lab), d_inf, block_v=bv,
            interpret=interpret, mode=mode)
        return out_d[:V], out_l[:V]

    grid = (V // bv,)
    kernel = pl.pallas_call(
        functools.partial(_pr_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((V,), lambda i: (0,)),            # lab (full)
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # cf
            pl.BlockSpec((bv,), lambda i: (i,)),           # sink_cf
            pl.BlockSpec((bv,), lambda i: (i,)),           # excess
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # nbr
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # intra
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # pushable
            pl.BlockSpec((bv, E), lambda i: (i, 0)),       # cross_lab
            pl.BlockSpec((1,), lambda i: (0,)),            # d_inf
        ],
        out_specs=[
            pl.BlockSpec((bv, 1 + E), lambda i: (i, 0)),   # delta
            pl.BlockSpec((bv,), lambda i: (i,)),           # new_lab
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, 1 + E), jnp.int32),
            jax.ShapeDtypeStruct((V,), jnp.int32),
        ],
        interpret=interpret,
    )
    d_inf_arr = jnp.reshape(jnp.asarray(d_inf, jnp.int32), (1,))
    return kernel(lab, cf, sink_cf, excess, nbr, intra, pushable, cross_lab,
                  d_inf_arr)


def engine_phase(lab, cf, sink_cf, excess, *, nbr_local, intra, emask, vmask,
                 cross_pushable, cross_lab, d_inf, sink_open: bool = True,
                 block_v: int = DEFAULT_BLOCK_V, interpret: bool = True,
                 mode: str = "both"):
    """Engine-semantics adapter over ``push_relabel_phase``.

    Folds the engine's masks into the kernel's inputs: arcs are pushable iff
    intra or cross-enabled (and real, per ``emask``); vertices outside
    ``vmask`` are made inactive by zeroing their excess; a closed sink is a
    zero sink capacity.  Returns (delta [V, 1+E] with sink column 0, new_lab
    [V]) — exactly what one compute phase of ``core.engine.push_relabel``
    consumes.  ``mode`` prunes the output the caller discards ("push" /
    "relabel" / "both").
    """
    pushable = ((cross_pushable | intra) & emask).astype(jnp.int32)
    excess = jnp.where(vmask, excess, 0)
    sink = sink_cf if sink_open else jnp.zeros_like(sink_cf)
    return push_relabel_phase(lab, cf, sink, excess, nbr_local,
                              intra.astype(jnp.int32), pushable, cross_lab,
                              d_inf, block_v=block_v, interpret=interpret,
                              mode=mode)
