"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (this container) and False when
a TPU is attached — the kernels are written for TPU BlockSpec tiling and
validated on CPU via the Pallas interpreter against kernels/ref.py.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.push_relabel import push_relabel_phase as _pr_phase


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 512, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=interpret)


def push_relabel_phase(lab, cf, sink_cf, excess, nbr, intra, pushable,
                       cross_lab, d_inf, *, block_v: int = 256,
                       interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _pr_phase(lab, cf, sink_cf, excess, nbr, intra, pushable,
                     cross_lab, d_inf, block_v=block_v, interpret=interpret)
