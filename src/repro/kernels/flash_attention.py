"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

The LM-side compute hot spot.  Standard TPU tiling:

  * grid = (batch*heads, q_blocks, kv_blocks), kv fastest — the VMEM scratch
    accumulator (acc, m, l) persists across the kv dimension and the output
    block is written once at the last kv step;
  * block shapes default to (Bq, D) = (256, head_dim) and Bk = 512: with
    f32 scratch acc 256x128 = 128 KiB plus the q/k/v tiles, comfortably
    inside VMEM with double buffering, and the 128-wide lane dimension on D
    keeps the MXU fed;
  * causal masking happens on global positions with a query offset so the
    same kernel serves prefill (Sq = Sk) and decode (Sq = 1, Sk = cache).

Validated against kernels/ref.py attention_ref in interpret mode across a
shape/dtype sweep; used by the model stack when cfg.use_flash_attention is
set (the dry-run default keeps the pure-jnp path so cost_analysis sees the
attention FLOPs — Pallas custom calls are opaque to XLA cost analysis; see
EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, q_off: int, sk_real: int):
    iq, ik = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [Bq, D]
    k = k_ref[0].astype(jnp.float32)                  # [Bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Bq, Bk]
    bq, bk = s.shape
    kj = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kj < sk_real                               # padded keys are dead
    if causal:
        qp = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        mask &= kj <= qp + q_off
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        out = jnp.where(l[:, None] > 0, acc_ref[...] / l[:, None], 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 512, interpret: bool = True):
    """q [B,H,Sq,D], k/v [B,Hkv,Sk,D] (GQA folded by repeat), same dtype out."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    if Hkv != H:
        assert H % Hkv == 0
        k = jnp.repeat(k, H // Hkv, axis=1)
        v = jnp.repeat(v, H // Hkv, axis=1)
    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    # queries pad at the FRONT so real queries keep their causal offsets;
    # keys pad at the back and are masked via sk_real.
    qf = jnp.pad(qf, ((0, 0), (pad_q, 0), (0, 0)))
    kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
    vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    # padded query index p maps to real position p - pad_q; causal bound:
    # kj <= (p - pad_q) + (Sk - Sq)
    q_off = Sk - Sq - pad_q

    BH, Sqp, _ = qf.shape
    Skp = kf.shape[1]
    grid = (BH, Sqp // bq, Skp // bk)
    kernel = functools.partial(_flash_kernel, scale=1.0 / (D ** 0.5),
                               causal=causal, q_off=q_off, sk_real=Sk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, D), qf.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, pad_q:, :].reshape(B, H, Sq, D)
