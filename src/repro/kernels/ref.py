"""Pure reference oracles.

* ``maxflow_oracle`` — plain numpy BFS augmenting-path (Edmonds-Karp)
  maxflow on the excess/sink-cap problem representation.  Ground truth for
  every solver and kernel test.
* ``push_relabel_iteration_ref`` — pure-jnp oracle for the Pallas
  push-relabel kernel (kernels/push_relabel.py).
* ``fused_iteration_ref`` — pure-jnp oracle for one *complete* fused engine
  iteration (push compute + intra-region scatter + post-push relabel), the
  unit the region-resident fused kernel advances per in-kernel step.
* ``attention_ref`` — pure-jnp oracle for the Pallas flash-attention kernel.
"""

from __future__ import annotations

from collections import deque

import jax.numpy as jnp
import numpy as np

INF_LABEL = 2**30


def maxflow_oracle(problem) -> tuple[int, np.ndarray]:
    """Edmonds-Karp on the terminal-capacity representation.

    Returns (maxflow value, source_side bool[n]) where source_side is the
    minimal source set {v : s -> v in G_f} complement of T.
    """
    n = problem.num_vertices
    # explicit s = n, t = n + 1
    s, t = n, n + 1
    cap = {}

    def add(u, v, c):
        if c:
            cap[(u, v)] = cap.get((u, v), 0) + int(c)

    for (u, v), cf_, cb_ in zip(problem.edges, problem.cap_fwd,
                                problem.cap_bwd):
        add(int(u), int(v), cf_)
        add(int(v), int(u), cb_)
    for v in range(n):
        add(s, v, problem.excess[v])
        add(v, t, problem.sink_cap[v])

    adj = [[] for _ in range(n + 2)]
    for (u, v) in list(cap.keys()):
        adj[u].append(v)
        adj[v].append(u)
        cap.setdefault((v, u), 0)
    adj = [sorted(set(a)) for a in adj]

    flow = 0
    while True:
        parent = {s: s}
        q = deque([s])
        while q and t not in parent:
            u = q.popleft()
            for v in adj[u]:
                if v not in parent and cap.get((u, v), 0) > 0:
                    parent[v] = u
                    q.append(v)
        if t not in parent:
            break
        # bottleneck
        path = []
        v = t
        while v != s:
            path.append((parent[v], v))
            v = parent[v]
        aug = min(cap[(u, v)] for u, v in path)
        for u, v in path:
            cap[(u, v)] -= aug
            cap[(v, u)] += aug
        flow += aug
    # source side of the min cut
    seen = {s}
    q = deque([s])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if v not in seen and cap.get((u, v), 0) > 0:
                seen.add(v)
                q.append(v)
    side = np.zeros(n, dtype=bool)
    for v in range(n):
        side[v] = v in seen
    return flow, side


def push_relabel_iteration_ref(cf, sink_cf, excess, lab, nbr, rev_slot,
                               intra, emask, vmask, cross_lab, cross_pushable,
                               d_inf):
    """One synchronous push+relabel iteration — pure jnp, mirrors engine.body.

    This is the oracle for the Pallas kernel, which computes the push deltas
    and relabel values for a block of vertices.
    """
    V, E = cf.shape
    act = (excess > 0) & (lab < d_inf) & vmask
    nlab = jnp.where(intra, lab[nbr], cross_lab)
    nlab = jnp.where((cross_pushable | intra) & emask, nlab, INF_LABEL)
    adm = (cf > 0) & (lab[:, None] == nlab + 1) & act[:, None]
    sink_adm = (sink_cf > 0) & (lab == 1) & act
    sink_cap = jnp.where(sink_adm, sink_cf, 0)
    arc_cap = jnp.where(adm, cf, 0)
    caps = jnp.concatenate([sink_cap[:, None], arc_cap], axis=1)
    avail = jnp.where(act, excess, 0)
    cum_excl = jnp.cumsum(caps, axis=1) - caps
    delta = jnp.clip(avail[:, None] - cum_excl, 0, caps)
    # relabel candidates on the *post push* residual state are computed by
    # the caller; the kernel itself emits deltas + the relabel min on the
    # pre-push state for vertices with no admissible arc.
    no_adm = act & ~adm.any(axis=1) & ~sink_adm
    cand = jnp.where(cf > 0, nlab + 1, INF_LABEL).min(axis=1)
    cand = jnp.where(sink_cf > 0, jnp.minimum(cand, 1), cand)
    new_lab = jnp.where(no_adm, jnp.maximum(jnp.minimum(cand, d_inf), lab),
                        lab)
    return delta, new_lab


def fused_iteration_ref(cf, sink_cf, excess, lab, nbr, rev_slot, intra,
                        emask, vmask, cross_lab, cross_pushable, d_inf,
                        sink_open: bool = True):
    """One complete fused engine iteration — pure jnp oracle.

    push compute (labels frozen) -> scatter application of the deltas
    (reverse arcs + receiver excess for intra arcs; cross flow accumulated
    into ``out_push``) -> relabel on the post-push residual graph.  This is
    the per-step unit of the region-resident fused kernel and of the fused
    XLA engine body; both are tested bit-equal against it.

    Returns ``(cf, sink_cf, excess, new_lab, out_push, sink_pushed,
    relabel_sum)``.
    """
    V, E = cf.shape
    sink = sink_cf if sink_open else jnp.zeros_like(sink_cf)
    delta, _ = push_relabel_iteration_ref(
        cf, sink, excess, lab, nbr, rev_slot, intra, emask, vmask, cross_lab,
        cross_pushable, d_inf)
    d_sink = delta[:, 0]
    d_arc = delta[:, 1:]
    excess = excess - d_sink - jnp.sum(d_arc, axis=1, dtype=d_arc.dtype)
    sink_cf = sink_cf - d_sink
    cf = cf - d_arc
    d_intra = jnp.where(intra, d_arc, 0)
    flat_n = V * E
    flat_idx = (nbr * E + rev_slot).reshape(flat_n)
    cf = (cf.reshape(flat_n).at[flat_idx]
          .add(d_intra.reshape(flat_n), mode="drop").reshape(V, E))
    excess = excess + jnp.zeros((V,), excess.dtype).at[nbr.reshape(flat_n)] \
        .add(d_intra.reshape(flat_n), mode="drop")
    out_push = d_arc - d_intra
    sink2 = sink_cf if sink_open else jnp.zeros_like(sink_cf)
    _, new_lab = push_relabel_iteration_ref(
        cf, sink2, excess, lab, nbr, rev_slot, intra, emask, vmask, cross_lab,
        cross_pushable, d_inf)
    relabel_sum = jnp.sum(jnp.where(vmask, new_lab - lab, 0))
    return (cf, sink_cf, excess, new_lab, out_push, d_sink.sum(),
            relabel_sum)


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Numerically-stable reference attention (f32 accumulation)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    if scale is None:
        scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("...qd,...kd->...qk", qf, kf) * scale
    if causal:
        Tq, Tk = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((Tq, Tk), bool), k=Tk - Tq)
        logits = jnp.where(mask, logits, -1e30)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", probs, vf).astype(q.dtype)
