import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell:

1. the FULL-DEPTH step program (train_step / prefill / decode serve_step) is
   lowered with ShapeDtypeStruct inputs and compiled for the production mesh
   with scan-over-layers (compact HLO) — this proves the sharding config is
   coherent and yields the realistic memory_analysis();
2. two PROBE programs at depth = 1 and 2 block-pattern periods, with every
   scan fully unrolled, give exact per-period FLOPs / bytes / collective
   bytes (XLA cost analysis counts while bodies once, so the full program
   undercounts by the trip count).  Totals are the affine extrapolation
       total = probe1 + (num_layers/period - 1) * (probe2 - probe1),
   exact for homogeneous stacks and accurate to the partial final period
   otherwise.

Results are written as JSON per cell for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape train_4k --mesh single --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The maxflow solver itself is dry-run with --arch maxflow (region = chip).
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, shape_skip_reason
from repro.configs.registry import ARCHS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as roof

Q_CHUNK_THRESHOLD = 2048      # chunk whenever S exceeds this
Q_CHUNK = 1024
MICROBATCHES = 1              # grad-accumulation factor (hillclimb knob)


def _mesh_tag(multi_pod: bool) -> str:
    return "multi" if multi_pod else "single"


def _probe_depth(cfg) -> int:
    if cfg.block_kind == "xlstm":
        return 2
    if cfg.block_kind == "rglru":
        return 3
    if cfg.pattern_local:
        return cfg.pattern_local + cfg.pattern_global
    return 1


def _lower_cell(cfg, shape, mesh, *, unroll):
    """Build + lower the step program for one cell; returns lowered."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import shardings as shd
    from repro.models import model as model_lib
    from repro.train import optimizer as opt_lib
    from repro.train import serve as serve_lib
    from repro.train import train_loop as tl

    q_chunk = Q_CHUNK if shape.seq_len > Q_CHUNK_THRESHOLD else None
    params_shape = jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k, jnp.bfloat16),
        jax.random.PRNGKey(0))

    if shape.kind == "train":
        step, state_sh, bspec = tl.make_sharded_train_step(
            cfg, mesh, opt_lib.AdamWConfig(), donate=False,
            seq_len=shape.seq_len, unroll=unroll, q_chunk=q_chunk,
            global_batch=shape.global_batch, microbatches=MICROBATCHES)
        opt_shape = jax.eval_shape(
            __import__("repro.train.optimizer", fromlist=["x"])
            .init_opt_state, params_shape)
        state = tl.TrainState(params=params_shape, opt=opt_shape)
        batch = tl.train_batch_specs(cfg, shape.seq_len, shape.global_batch)
        return step.lower(state, batch)

    if shape.kind == "prefill":
        p_sh = shd.param_shardings(cfg, mesh, params_shape)
        cache_shape = serve_lib.cache_specs_struct(
            cfg, shape.global_batch, shape.seq_len)
        c_sh = shd.cache_specs(cfg, mesh, cache_shape)
        dp = 1
        for a in mesh.axis_names:
            if a in ("pod", "data"):
                dp *= mesh.shape[a]
        bspec = NamedSharding(mesh, shd.batch_pspec(mesh)
                              if shape.global_batch % dp == 0 else P())
        act_sh = None
        if shape.seq_len % mesh.shape["model"] == 0:
            dpa = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            act_sh = NamedSharding(mesh, P(
                dpa if shape.global_batch % dp == 0 else None,
                "model", None))
        batch = _prefill_batch_specs(cfg, shape)

        def bsh(x):
            if x.ndim >= 1 and x.shape[0] == shape.global_batch \
                    and shape.global_batch % dp == 0:
                return bspec
            return NamedSharding(mesh, P())

        batch_sh = jax.tree.map(bsh, batch)
        fn = serve_lib.make_prefill_step(cfg, unroll=unroll, q_chunk=q_chunk,
                                         act_sharding=act_sh)
        step = jax.jit(fn, in_shardings=(p_sh, batch_sh, c_sh),
                       out_shardings=(None, c_sh))
        cache_struct = cache_shape
        return step.lower(params_shape, batch, cache_struct)

    # decode
    step, p_sh, c_sh, t_sh = serve_lib.make_sharded_decode_step(
        cfg, mesh, shape.global_batch, shape.seq_len, unroll=unroll)
    cache_shape = serve_lib.cache_specs_struct(
        cfg, shape.global_batch, shape.seq_len)
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return step.lower(params_shape, toks, cache_shape)


def _cost_triple(compiled, hlo=None):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    text = hlo if hlo is not None else compiled.as_text()
    coll = roof.collective_bytes(text)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]), coll)


def dryrun_lm_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
                   probes: bool = True, cfg_override=None) -> dict:
    from repro.models import model as model_lib

    cfg = cfg_override if cfg_override is not None else get_arch(arch_name)
    shape = SHAPES[shape_name]
    skip = shape_skip_reason(cfg, shape)
    if skip:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": _mesh_tag(multi_pod), "status": "skip",
                "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    t0 = time.time()
    with mesh:
        lowered = _lower_cell(cfg, shape, mesh, unroll=1)
        t_lower = round(time.time() - t0, 1)
        compiled = lowered.compile()
        t_compile = round(time.time() - t0 - t_lower, 1)
    mem = roof.memory_summary(compiled)
    raw_flops, raw_bytes, raw_coll, _ = _cost_triple(compiled)

    # ---- probes: exact per-period cost ----
    flops = nbytes = coll = None
    coll_detail = {}
    if probes:
        base = _probe_depth(cfg)
        vals = []
        for depth in (base, 2 * base):
            pcfg = dataclasses.replace(cfg, num_layers=depth)
            with mesh:
                pl = _lower_cell(pcfg, shape, mesh, unroll=True)
                pc = pl.compile()
            vals.append(_cost_triple(pc))
        n = cfg.num_layers / base
        f1, b1, c1, d1 = vals[0]
        f2, b2, c2, d2 = vals[1]
        # per-period slopes; clamped at 0 — XLA occasionally optimises the
        # 2-period probe below the 1-period one (fusion differences), and a
        # negative per-layer cost is non-physical.
        flops = f1 + (n - 1) * max(f2 - f1, 0.0)
        nbytes = b1 + (n - 1) * max(b2 - b1, 0.0)
        coll = c1 + (n - 1) * max(c2 - c1, 0.0)
        coll_detail = {
            "probe1": d1["per_kind"], "probe2": d2["per_kind"],
            "counts_probe2": d2["counts"],
        }

    n_params = model_lib.param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 6.0 * n_params * tokens
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_params * shape.seq_len * shape.global_batch
    else:
        model_flops = 2.0 * n_params * shape.global_batch

    use_f = flops if flops is not None else raw_flops
    use_b = nbytes if nbytes is not None else raw_bytes
    use_c = coll if coll is not None else raw_coll
    compute_s = use_f / roof.PEAK_FLOPS
    memory_s = use_b / roof.HBM_BW
    collective_s = use_c / roof.LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    rec = {
        "arch": arch_name, "shape": shape_name,
        "mesh": _mesh_tag(multi_pod), "status": "ok", "n_chips": n_chips,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": mem,
        "raw_cost": {"flops": raw_flops, "bytes": raw_bytes,
                     "coll_bytes": raw_coll,
                     "note": "scan bodies counted once (see probes)"},
        "roofline": {
            "flops": use_f, "bytes_accessed": use_b, "coll_bytes": use_c,
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "bottleneck": max(terms, key=terms.get),
            "model_flops": model_flops,
            "useful_ratio": (model_flops / (use_f * n_chips)
                             if use_f else 0.0),
            "coll_detail": coll_detail,
        },
        "n_params": n_params,
    }
    return rec


def _prefill_batch_specs(cfg, shape):
    f = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio_frames":
        return {"frames": f((B, S, cfg.frontend_dim), jnp.bfloat16)}
    if cfg.frontend == "vision_patches":
        return {"tokens": f((B, S - cfg.num_patches), jnp.int32),
                "patches": f((B, cfg.num_patches, cfg.frontend_dim),
                             jnp.bfloat16)}
    return {"tokens": f((B, S), jnp.int32)}


def dryrun_maxflow(*, multi_pod: bool, region_size: int = 4096,
                   degree: int = 8, exchange: str = "full") -> dict:
    """Dry-run the distributed P-ARD sweep: one region per chip."""
    from repro.core.distributed import (make_sharded_sweep,
                                        maxflow_input_specs)
    from repro.core.graph import GraphMeta
    from repro.core.sweep import SweepConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    K = n_chips
    V, E = region_size, degree
    X = int(4 * (V ** 0.5)) * K
    meta = GraphMeta(num_regions=K, region_size=V, max_degree=E,
                     num_vertices=K * V, num_boundary=X // 2,
                     num_cross_arcs=X, num_ghost_groups=X,
                     d_inf_ard=X // 2, d_inf_prd=K * V)
    axes = tuple(mesh.axis_names)
    t0 = time.time()
    with mesh:
        fn = make_sharded_sweep(meta, mesh, SweepConfig(method="ard"),
                                axes=axes, exchange=exchange)
        specs = maxflow_input_specs(meta)
        lowered = fn.lower(specs, jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = round(time.time() - t0, 1)
        compiled = lowered.compile()
        t_compile = round(time.time() - t0 - t_lower, 1)
    flops, nbytes, coll, coll_d = _cost_triple(compiled)
    terms = {"compute": flops / roof.PEAK_FLOPS,
             "memory": nbytes / roof.HBM_BW,
             "collective": coll / roof.LINK_BW}
    return {
        "arch": f"maxflow-pard-{exchange}", "shape": f"V{V}xE{E}",
        "mesh": _mesh_tag(multi_pod), "status": "ok", "n_chips": n_chips,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": roof.memory_summary(compiled),
        "roofline": {
            "flops": flops, "bytes_accessed": nbytes, "coll_bytes": coll,
            "compute_s": terms["compute"], "memory_s": terms["memory"],
            "collective_s": terms["collective"],
            "bottleneck": max(terms, key=terms.get),
            "note": ("per-sweep cost; engine while-loops counted once per "
                     "discharge iteration — see benchmarks for measured "
                     "iteration counts"),
            "coll_detail": coll_d["per_kind"],
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
        cells.append(("maxflow", None))
    else:
        assert args.arch
        if args.arch == "maxflow":
            cells = [("maxflow", None)]
        else:
            shapes = [args.shape] if args.shape else list(SHAPES)
            cells = [(args.arch, s) for s in shapes]

    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape or 'sweep'}__{_mesh_tag(mp)}"
            path = out / f"{tag}.json"
            if path.exists():
                print(f"[dryrun] {tag}: cached", flush=True)
                continue
            print(f"[dryrun] {tag}: running...", flush=True)
            t0 = time.time()
            try:
                if arch == "maxflow":
                    rec = dryrun_maxflow(multi_pod=mp)
                else:
                    rec = dryrun_lm_cell(arch, shape, multi_pod=mp,
                                         probes=not args.no_probes)
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": _mesh_tag(mp), "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            rec["wall_s"] = round(time.time() - t0, 1)
            path.write_text(json.dumps(rec, indent=2))
            print(f"[dryrun] {tag}: {rec['status']} ({rec['wall_s']}s)",
                  flush=True)


if __name__ == "__main__":
    main()
