"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism (pod-local FSDP, cross-pod gradient
all-reduce only), matching a v5e-256 x 2 deployment where cross-pod links
are the scarce resource.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run forces 512 host devices via
XLA_FLAGS *before* any jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 forced host devices)."""
    n = 1
    for s in shape:
        n *= s
    import numpy as np
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def data_axes(mesh) -> tuple:
    """Axes carrying data parallelism (includes 'pod' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
