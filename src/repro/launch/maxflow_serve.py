"""Maxflow serving launcher: replay a mixed request stream through the
continuous-batching service (repro.serve).

    PYTHONPATH=src python -m repro.launch.maxflow_serve \
        --stream 6x6,8x8,10x10 --requests 24 --rate 8 \
        --tight-frac 0.25 --tight-timeout 0.05

Each spec is an HxW synthetic grid or a DIMACS ``.max`` path; requests
cycle through the specs and are paced at ``--rate`` req/s (omit for one
burst).  A ``--tight-frac`` fraction carries a ``--tight-timeout``
deadline, enforced at sweep boundaries (misses come back as typed
``DeadlineExceeded`` partial results, not hangs).  The bounded queue
sheds overflow with ``ServiceOverloaded`` + retry-after.

Large warm re-cut sessions ride along with ``--sessions``:

    PYTHONPATH=src python -m repro.launch.maxflow_serve \
        --requests 16 --rate 4 --sessions 2 --recuts 3 \
        --session-grid 24x24 --handle-budget-mb 8 --eviction-dir /tmp/ev

Each session first solves a ``--session-grid`` instance, then submits
``--recuts`` incremental capacity-perturbation re-cuts against the warm
handle (evicted-to-checkpoint handles resume warm when the
``--handle-budget-mb`` LRU budget forces them out).

Prints one line per resolved request and the final ``service.report()``
(p50/p99, throughput, sheds, evictions, deadline misses, breaker state);
``--report PATH`` also writes it as JSON.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _build_requests(ap, args):
    import re
    from pathlib import Path

    from repro.core import grid_partition
    from repro.data.grids import synthetic_grid
    from repro.serve import SolveRequest

    ry, rx = (int(v) for v in args.regions.split("x"))

    def spec_problem(spec, seed):
        grid = re.fullmatch(r"(\d+)x(\d+)", spec)
        if grid and not Path(spec).exists():    # a file named HxW wins
            h, w = int(grid[1]), int(grid[2])
            return (synthetic_grid(h, w, connectivity=args.connectivity,
                                   strength=args.strength, seed=seed),
                    grid_partition((h, w), (ry, rx)))
        if Path(spec).is_file():
            from repro.data.dimacs import read_dimacs
            return read_dimacs(spec), None
        ap.error(f"stream spec {spec!r} is neither HxW nor an existing "
                 "DIMACS file")

    specs = args.stream.split(",")
    tight_every = (0 if args.tight_frac <= 0
                   else max(1, round(1 / args.tight_frac)))
    reqs = []
    for i in range(args.requests):
        prob, part = spec_problem(specs[i % len(specs)], args.seed + i)
        timeout = (args.tight_timeout
                   if tight_every and i % tight_every == 0
                   else args.timeout)
        reqs.append(SolveRequest(problem=prob, part=part, timeout=timeout,
                                 tenant=f"t{i % 2}"))

    # warm re-cut sessions: one create + --recuts updates each, spread
    # evenly through the stream so re-cuts land on warm (possibly
    # evicted-and-restored) handles
    rng = np.random.RandomState(args.seed)
    sh, sw = (int(v) for v in args.session_grid.split("x"))
    spart = grid_partition((sh, sw), (ry, rx))
    session_reqs = []
    for s in range(args.sessions):
        prob = synthetic_grid(sh, sw, connectivity=args.connectivity,
                              strength=args.strength, seed=args.seed + 97 + s)
        m = len(prob.edges)
        session_reqs.append(SolveRequest(problem=prob, part=spart,
                                         session=f"s{s}",
                                         timeout=args.timeout))
        k = max(1, int(round(args.perturb * m)))
        hi = 2 * args.strength + 1
        for _ in range(args.recuts):
            session_reqs.append(SolveRequest(
                session=f"s{s}", timeout=args.timeout,
                update=dict(arcs=rng.choice(m, size=k, replace=False),
                            cap_fwd=rng.randint(0, hi, size=k)
                            .astype(np.int32))))
    if session_reqs:
        stride = max(1, len(reqs) // len(session_reqs) or 1)
        for j, r in enumerate(session_reqs):    # order preserves
            reqs.insert(min(len(reqs), (j + 1) * stride + j), r)  # create
        #                                         before that session's
        #                                         re-cuts (FIFO per session)
    return reqs


def main():
    from repro.core.engine import ENGINE_BACKENDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--stream", default="6x6,8x8,10x10",
                    metavar="SPEC[,SPEC...]",
                    help="request mix: HxW synthetic grids and/or DIMACS "
                         ".max paths, cycled --requests times")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=None, metavar="R",
                    help="offered load in req/s (default: one burst)")
    ap.add_argument("--timeout", type=float, default=None,
                    help="default per-request deadline in seconds")
    ap.add_argument("--tight-frac", type=float, default=0.0, metavar="F",
                    help="fraction of stream requests given the tight "
                         "deadline (deadline-miss pressure)")
    ap.add_argument("--tight-timeout", type=float, default=0.05)
    ap.add_argument("--sessions", type=int, default=0, metavar="S",
                    help="warm re-cut sessions interleaved into the stream")
    ap.add_argument("--recuts", type=int, default=2, metavar="M",
                    help="incremental re-cuts per session")
    ap.add_argument("--session-grid", default="16x16")
    ap.add_argument("--perturb", type=float, default=0.02,
                    help="fraction of session edges re-randomized per re-cut")
    ap.add_argument("--regions", default="2x2")
    ap.add_argument("--method", choices=["ard", "prd"], default="ard")
    ap.add_argument("--engine-backend", choices=list(ENGINE_BACKENDS),
                    default="xla")
    ap.add_argument("--engine-chunk-iters", type=int, default=None)
    ap.add_argument("--connectivity", type=int, default=8)
    ap.add_argument("--strength", type=int, default=150)
    ap.add_argument("--max-queue", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=2,
                    help="sweeps between deadline/harvest checks")
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--handle-budget-mb", type=float, default=None,
                    help="device-memory budget for resident prepared "
                         "handles; LRU overflow is evicted to checkpoint")
    ap.add_argument("--eviction-dir", default=None,
                    help="snapshot directory for evicted sessions "
                         "(required with --handle-budget-mb)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="also write the final service report as JSON")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if (args.handle_budget_mb is None) != (args.eviction_dir is None):
        ap.error("--handle-budget-mb and --eviction-dir go together")

    from repro.core import SolverOptions
    from repro.serve import (MaxflowService, ServiceConfig, replay_stream)

    ry, rx = (int(v) for v in args.regions.split("x"))
    opts = SolverOptions(method=args.method, num_regions=ry * rx,
                         engine_backend=args.engine_backend,
                         engine_chunk_iters=args.engine_chunk_iters)
    cfg = ServiceConfig(
        max_queue=args.max_queue, max_batch=args.max_batch,
        sync_every=args.sync_every, max_retries=args.max_retries,
        default_timeout=args.timeout,
        handle_budget_bytes=None if args.handle_budget_mb is None
        else int(args.handle_budget_mb * 2**20),
        eviction_dir=args.eviction_dir)
    service = MaxflowService(opts, cfg)
    reqs = _build_requests(ap, args)

    t0 = time.time()
    tickets = replay_stream(service, reqs, rate=args.rate)
    dt = time.time() - t0
    for t in tickets:
        req = t.request
        what = (f"session={req.session}" if req.session
                else f"problem<{len(req.problem.edges)} edges>")
        if t.error is None:
            print(f"[serve] {req.request_id} {what}: "
                  f"flow={t.result.flow_value} "
                  f"sweeps={t.result.stats.sweeps}")
        else:
            print(f"[serve] {req.request_id} {what}: "
                  f"{t.error.code}: {t.error}")
    service.close()
    report = service.report()
    print(f"[serve] {len(tickets)} requests in {dt:.2f}s "
          f"({len(tickets) / max(dt, 1e-9):.1f} offered/s): "
          f"{json.dumps(report, indent=2, default=str)}")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"[serve] report written to {args.report}")


if __name__ == "__main__":
    main()
