"""Training launcher: --arch <id> [--smoke] end-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
        --steps 50 --batch 8 --seq 128

Full-size configs on real hardware use the same entry point without
--smoke; on this CPU container smoke configs train in seconds and the
examples (examples/train_lm.py) demonstrate loss convergence to the
synthetic stream's entropy floor.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.data.pipeline import MarkovSpec, batch_for
from repro.models import model as model_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import fault as fault_lib
from repro.train import optimizer as opt_lib
from repro.train import train_loop as tl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    dtype = jnp.float32 if args.smoke else jnp.bfloat16

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key, dtype=dtype)
    opt_cfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
    state = tl.TrainState(params=params,
                          opt=opt_lib.init_opt_state(params))
    step_fn = jax.jit(tl.make_train_step(cfg, opt_cfg, dtype))

    spec = MarkovSpec(vocab=cfg.vocab_size)
    n_params = model_lib.param_count(cfg)
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"floor={spec.entropy_floor():.3f}")

    def make_batch(step):
        b = batch_for(cfg, spec, step, args.batch, args.seq)
        return jax.tree.map(jnp.asarray, b)

    def on_metrics(step, metrics):
        if step % 10 == 0 or step == 1:
            print(f"  step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)

    if args.ckpt_dir:
        fcfg = fault_lib.FaultConfig(ckpt_dir=args.ckpt_dir,
                                     ckpt_every=args.ckpt_every)
        state, stats = fault_lib.run_training(
            state=state, state_shardings=None, train_step=step_fn,
            make_batch=make_batch, num_steps=args.steps, cfg=fcfg,
            on_metrics=on_metrics)
        print(f"[train] done; restarts={stats.restarts} "
              f"stragglers={stats.straggler_events}")
    else:
        t0 = time.time()
        for step in range(1, args.steps + 1):
            state, metrics = step_fn(state, make_batch(step))
            on_metrics(step, metrics)
        print(f"[train] done in {time.time()-t0:.1f}s; "
              f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
