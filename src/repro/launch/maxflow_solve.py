"""Distributed mincut launcher.

    PYTHONPATH=src python -m repro.launch.maxflow_solve \
        --height 64 --width 64 --regions 2x2 --method ard [--sharded]

Solves a synthetic instance (paper Sec. 7.1) with the region-discharge
solver and verifies flow value == independently-computed cut cost.  With
--sharded the parallel sweep runs under shard_map across however many
devices are available (regions per device = K / n_devices).

Batched throughput mode solves a fleet of instances through the
shape-bucketed batched driver (one grid=(B,K) device program per bucket):

    PYTHONPATH=src python -m repro.launch.maxflow_solve \
        --batch 64x64,64x64,48x48 --regions 2x2 \
        --engine-backend pallas --engine-chunk-iters 8

Each HxW entry becomes one synthetic instance (seeds --seed, --seed+1,
...); per-instance results are bit-identical to single solves.  DIMACS
``.max`` files (see repro.data.dimacs) can be mixed in by path:
``--batch instance.max,64x64``.

Warm-start serving mode re-solves the prepared instance N times through
ONE ``Solver`` session, perturbing a P-fraction of the edge capacities
before each re-solve (``handle.update`` reparameterizes the residual
network on device; the solve continues from the warm preflow):

    PYTHONPATH=src python -m repro.launch.maxflow_solve \
        --height 64 --width 64 --regions 4x4 --resolve 5 --perturb 0.01

Prints per-re-solve sweeps/launches and the session's compile-cache
hits/misses (steady state: zero retraces per cycle).

Out-of-core streaming mode stages regions one at a time from a disk
spill pool, so instances bigger than device memory solve with at most
``--max-resident-regions`` region states in memory (bit-identical to the
sequential in-memory sweep):

    PYTHONPATH=src python -m repro.launch.maxflow_solve \
        --height 1024 --width 1024 --regions 4x4 --streaming \
        --max-resident-regions 2 [--spill-dir /scratch/pool]

Fault tolerance: ``--checkpoint-dir DIR [--checkpoint-every N]`` captures
resumable sweep-boundary checkpoints during the solve; ``--resume``
continues bit-exactly from the latest one after a kill/preemption
(``repro.core.resilience``; exercised end-to-end by
tools/kill_resume_smoke.py).
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    from repro.core.engine import ENGINE_BACKENDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--height", type=int, default=64)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--connectivity", type=int, default=8)
    ap.add_argument("--strength", type=int, default=150)
    ap.add_argument("--regions", default="2x2")
    ap.add_argument("--method", choices=["ard", "prd"], default="ard")
    ap.add_argument("--sequential", action="store_true")
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--streaming", action="store_true",
                    help="out-of-core route (repro.stream): stage regions "
                         "one at a time from a disk spill pool, keeping at "
                         "most --max-resident-regions region states in "
                         "memory and only the |B|-sized boundary layer "
                         "between visits; implies the sequential sweep "
                         "without the global gap heuristic")
    ap.add_argument("--max-resident-regions", type=int, default=2,
                    metavar="R",
                    help="streaming route: LRU resident-set size in regions "
                         "(default 2: the discharging region + the "
                         "prefetched next)")
    ap.add_argument("--spill-dir", default=None, metavar="DIR",
                    help="streaming route: durable spill-pool directory "
                         "(kill-resume needs the pool to outlive the "
                         "process); default: a temp dir deleted after the "
                         "solve")
    ap.add_argument("--engine-backend", choices=list(ENGINE_BACKENDS),
                    default="xla",
                    help="discharge-engine compute phase: dense XLA rows or "
                         "the fused Pallas kernel (interpret mode off-TPU)")
    ap.add_argument("--engine-chunk-iters", type=int, default=None,
                    metavar="K",
                    help="region-resident fused engine: K complete "
                         "iterations per compute-program launch (in-kernel "
                         "early exit; falls back to the blocked path when "
                         "the region exceeds the VMEM budget); default: "
                         "unfused two-phase engine")
    ap.add_argument("--device-resident", action="store_true",
                    help="run the whole sweep loop in one lax.while_loop "
                         "on device: one host sync per solve instead of "
                         "one per sweep (bit-identical results)")
    ap.add_argument("--host-sync-every", type=int, default=None, metavar="M",
                    help="device-resident escape hatch: return to the host "
                         "every M sweeps (default: only at convergence)")
    ap.add_argument("--batch", default=None, metavar="SPEC[,SPEC...]",
                    help="batched throughput mode: comma-separated instance "
                         "specs (HxW synthetic grid or a DIMACS .max path) "
                         "solved together through solve_mincut_batch — one "
                         "shape-bucketed grid=(B,K) device program per "
                         "bucket, compiled solve cached per bucket shape")
    ap.add_argument("--dtype-policy", choices=["int32", "auto", "narrow"],
                    default="int32",
                    help="kernel storage dtypes: int32 baseline (default), "
                         "auto (narrow labels/residuals to int16 and masks "
                         "to int8 when this instance's range bounds allow, "
                         "per-family int32 fallback), or narrow (forced; a "
                         "failed bound is a ProblemValidationError)")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve engine_chunk_iters through the "
                         "VMEM-budget autotuner (core.autotune; JSON-cached "
                         "per bucket dims/backend/dtypes — repeat keys cost "
                         "zero search and zero retrace); an explicit "
                         "--engine-chunk-iters wins over the tuner")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the host-side cut-cost == flow assertion "
                         "(an extra device fetch + O(n*E) host reduction "
                         "per solve) — the serving-path setting")
    ap.add_argument("--resolve", type=int, default=0, metavar="N",
                    help="warm-start serving mode: N incremental re-solves "
                         "through one Solver session, perturbing a "
                         "--perturb fraction of edge capacities before "
                         "each (handle.update + warm handle.solve)")
    ap.add_argument("--perturb", type=float, default=0.01, metavar="P",
                    help="fraction of edges re-randomized per re-solve "
                         "(default 0.01)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="capture resumable sweep-boundary checkpoints "
                         "under DIR (atomic write-then-rename snapshots; "
                         "see repro.core.resilience)")
    ap.add_argument("--checkpoint-every", type=int, default=5, metavar="N",
                    help="checkpoint cadence in sweeps (default 5; the "
                         "device-resident routes capture at their "
                         "--host-sync-every boundaries)")
    ap.add_argument("--resume", action="store_true",
                    help="continue bit-exactly from the latest checkpoint "
                         "in --checkpoint-dir when one exists (the "
                         "restart-after-preemption path)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.core import SweepConfig, grid_partition
    from repro.data.grids import synthetic_grid

    ry, rx = (int(v) for v in args.regions.split("x"))
    if args.streaming:
        if args.sharded:
            ap.error("--streaming and --sharded are mutually exclusive "
                     "routes")
        if not args.sequential:
            print("[maxflow] --streaming implies the sequential sweep "
                  "without the global gap heuristic (Alg. 1 staged order)")
    cfg = SweepConfig(method=args.method,
                      parallel=not (args.sequential or args.streaming),
                      use_global_gap=not args.streaming,
                      engine_backend=args.engine_backend,
                      engine_chunk_iters=args.engine_chunk_iters,
                      device_resident=args.device_resident,
                      host_sync_every=args.host_sync_every)

    checkpoint = resume_from = None
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume needs --checkpoint-dir")
    if args.checkpoint_dir:
        from repro.core import resilience as _res

        checkpoint = _res.CheckpointPolicy(directory=args.checkpoint_dir,
                                           every=args.checkpoint_every)
        if args.resume and _res.snapshot_latest(args.checkpoint_dir) \
                is not None:
            resume_from = args.checkpoint_dir
            print(f"[maxflow] resuming from checkpoint sweep "
                  f"{_res.snapshot_latest(args.checkpoint_dir)} "
                  f"under {args.checkpoint_dir}")

    if args.batch:
        if args.resolve:
            ap.error("--resolve works on a single prepared instance; "
                     "it cannot be combined with --batch")
        if args.checkpoint_dir:
            ap.error("--checkpoint-dir on the batch route goes through "
                     "Solver.solve_many(checkpoint=...); the CLI wires "
                     "the single-instance routes only")
        import re
        from pathlib import Path

        from repro.data.dimacs import read_dimacs

        probs, parts = [], []
        for i, spec in enumerate(args.batch.split(",")):
            grid = re.fullmatch(r"(\d+)x(\d+)", spec)
            if grid and not Path(spec).exists():   # a file named HxW wins
                h, w = int(grid[1]), int(grid[2])
                probs.append(synthetic_grid(
                    h, w, connectivity=args.connectivity,
                    strength=args.strength, seed=args.seed + i))
                parts.append(grid_partition((h, w), (ry, rx)))
            elif Path(spec).is_file():
                probs.append(read_dimacs(spec))
                parts.append(None)     # node-number fallback partitioner
            else:
                ap.error(f"--batch spec {spec!r} is neither HxW nor an "
                         "existing DIMACS file")
        from repro.core import Solver, SolverOptions

        solver = Solver(SolverOptions.from_sweep_config(
            cfg, num_regions=ry * rx, check=not args.no_check,
            dtype_policy=args.dtype_policy, autotune=args.autotune))
        t0 = time.time()
        results = solver.solve_many(probs, parts)
        dt = time.time() - t0
        for i, res in enumerate(results):
            print(f"[maxflow]   instance {i}: flow={res.flow_value} "
                  f"sweeps={res.stats.sweeps} "
                  f"engine_iters={res.stats.engine_iters}")
        launches = sum(bs.engine_launches for bs in solver.last_batch_stats)
        syncs = sum(bs.host_syncs for bs in solver.last_batch_stats)
        print(f"[maxflow] batch of {len(results)} ({args.method}, "
              f"{args.engine_backend}, "
              f"{len(solver.last_batch_stats)} bucket(s)): "
              f"launches={launches} host_syncs={syncs} t={dt:.2f}s "
              f"({len(results) / max(dt, 1e-9):.1f} instances/s)")
        return

    prob = synthetic_grid(args.height, args.width,
                          connectivity=args.connectivity,
                          strength=args.strength, seed=args.seed)
    part = grid_partition((args.height, args.width), (ry, rx))

    # one Solver session for the cold solve and every warm re-solve: the
    # build/Layout and every compiled program are reused across the loop
    from repro.core import Solver, SolverOptions

    solver = Solver(SolverOptions.from_sweep_config(
        cfg, num_regions=ry * rx, check=not args.no_check,
        dtype_policy=args.dtype_policy, autotune=args.autotune,
        streaming=args.streaming,
        max_resident_regions=args.max_resident_regions,
        spill_dir=args.spill_dir))
    handle = solver.prepare(prob, part)

    mesh = None
    if args.sharded:
        n_dev = len(jax.devices())
        assert handle.meta.num_regions % n_dev == 0, \
            f"K={handle.meta.num_regions} must divide over {n_dev} devices"
        mesh = jax.make_mesh((n_dev,), ("regions",))

    t0 = time.time()
    res = handle.solve(mesh=mesh, checkpoint=checkpoint,
                       resume_from=resume_from)
    route = (f"sharded x{len(jax.devices())}" if args.sharded
             else f"streaming(resident={args.max_resident_regions})"
             if args.streaming
             else f"device_resident={cfg.device_resident}")
    kd = handle.meta.kernel_dtypes
    print(f"[maxflow] {args.method} parallel={cfg.parallel} {route} "
          f"dtypes={kd.label}/{kd.flow}/{kd.mask}: "
          f"flow={res.flow_value} sweeps={res.stats.sweeps} "
          f"launches={res.stats.engine_launches} "
          f"host_syncs={res.stats.host_syncs} "
          f"boundary_bytes={res.stats.boundary_bytes} "
          f"page_bytes={res.stats.page_bytes} "
          f"t={time.time()-t0:.2f}s")
    if args.streaming:
        print(f"[maxflow]   staged_in={res.stats.staged_in_bytes} "
              f"staged_out={res.stats.staged_out_bytes} "
              f"|B|={res.stats.num_boundary}")

    rng = np.random.RandomState(args.seed + 1)
    m = len(handle.problem.edges)
    for i in range(args.resolve):
        k = max(1, int(round(args.perturb * m)))
        idx = rng.choice(m, size=k, replace=False)
        hi = 2 * args.strength + 1
        handle.update(
            arcs=idx,
            cap_fwd=rng.randint(0, hi, size=k).astype(np.int32),
            cap_bwd=rng.randint(0, hi, size=k).astype(np.int32))
        t0 = time.time()
        res = handle.solve(mesh=mesh)
        info = solver.cache_info()
        print(f"[maxflow] re-solve {i + 1}/{args.resolve} "
              f"(perturbed {k}/{m} edges): flow={res.flow_value} "
              f"sweeps={res.stats.sweeps} "
              f"launches={res.stats.engine_launches} "
              f"host_syncs={res.stats.host_syncs} t={time.time()-t0:.2f}s "
              f"cache_hits={info.hits} cache_misses={info.misses}")


if __name__ == "__main__":
    main()
