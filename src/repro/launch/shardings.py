"""GSPMD sharding rules for every architecture family.

Policy (MaxText-style, adapted per arch):

* TP over "model": attention heads / kv heads / d_ff / experts / vocab —
  whichever dimension is divisible by the axis size; when a head count is
  not divisible (qwen 40H, command-r kv=8 on a 16-way axis) the rule falls
  back to sharding d_model (row/col-parallel) for the projection and, for
  KV caches, to sharding the *sequence* dimension (sequence-parallel decode:
  GSPMD inserts the flash-decode softmax-merge collectives).
* FSDP over "data" (cfg.sharding == "fsdp_tp"): parameters additionally
  sharded over the data axis on a divisible non-TP dimension; the "pod"
  axis stays pure DP (pod-local FSDP, cross-pod all-reduce only).
* ZeRO-1: optimizer moments always take the param spec *plus* "data" on a
  divisible dimension (train/optimizer.py).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _axsize(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return dim % _axsize(mesh, axis) == 0


def _spec(*parts):
    return P(*parts)


def param_spec(cfg: ArchConfig, mesh: Mesh, path: str, shape: tuple,
               fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf.  ``path`` is a '/'-joined name;
    stacked block params carry a leading period dimension (never sharded).
    """
    if cfg.sharding == "dp":
        # pure data parallelism: params replicated, batch over every axis —
        # the right policy for sub-1B archs where TP all-reduces dominate
        # (EXPERIMENTS.md §Perf, xlstm pair)
        return P(*([None] * len(shape)))
    tp = "model"
    dp = "data"
    name = path.split("/")[-1]
    stacked = path.startswith("blocks/")
    L = (None,) if stacked else ()
    dims = shape[1:] if stacked else shape

    def ok(i, ax):
        return _fits(dims[i], mesh, ax)

    # ---- embeddings / head ----
    if name == "embed":
        v_ax = tp if _fits(shape[0], mesh, tp) else None
        d_ax = dp if fsdp and _fits(shape[1], mesh, dp) and v_ax != dp \
            else None
        return P(v_ax, d_ax)
    if name == "head":
        v_ax = tp if _fits(shape[1], mesh, tp) else None
        d_ax = dp if fsdp and _fits(shape[0], mesh, dp) else None
        return P(d_ax, v_ax)
    if name.startswith("ln") or name in ("final_norm", "lam"):
        return P(*([None] * len(shape)))

    # ---- attention ----
    if name in ("wq", "wk", "wv") and len(dims) == 3:
        d, h, hd = dims
        if ok(1, tp):
            return P(*L, dp if fsdp and ok(0, dp) else None, tp, None)
        # fallback: row-parallel on d_model
        return P(*L, tp, None, dp if fsdp and ok(2, dp) else None)
    if name == "wo" and len(dims) == 3:
        h, hd, d = dims
        if ok(0, tp):
            return P(*L, tp, None, dp if fsdp and ok(2, dp) else None)
        return P(*L, None, None, tp)
    if name in ("bq", "bk", "bv"):
        h = dims[0]
        return P(*L, tp if ok(0, tp) else None, None)

    # ---- dense mlp ----
    if name in ("w_gate", "w_up") and len(dims) == 2:
        return P(*L, dp if fsdp and ok(0, dp) else None,
                 tp if ok(1, tp) else None)
    if name == "w_down" and len(dims) == 2:
        return P(*L, tp if ok(0, tp) else None,
                 dp if fsdp and ok(1, dp) else None)

    # ---- moe ----
    if name == "router":
        return P(*L, None, tp if ok(1, tp) else None)
    if name in ("w_gate", "w_up") and len(dims) == 3:      # [E, D, Fe]
        return P(*L, tp if ok(0, tp) else None,
                 dp if fsdp and ok(1, dp) else None, None)
    if name == "w_down" and len(dims) == 3:                # [E, Fe, D]
        return P(*L, tp if ok(0, tp) else None, None,
                 dp if fsdp and ok(2, dp) else None)

    # ---- xlstm ----
    if name in ("wi", "wf"):                               # [D, H]
        return P(*L, None, tp if ok(1, tp) else None)
    if name == "w_in":                                     # [D, H, 4dh]
        return P(*L, None, tp if ok(1, tp) else None, None)
    if name == "r":                                        # [H, dh, 4dh]
        return P(*L, tp if ok(0, tp) else None, None, None)
    if name in ("wg",):
        return P(*L, None, tp if ok(1, tp) else None)

    # ---- rglru / generic square projections ----
    if name in ("w_x", "w_r", "w_i"):
        return P(*L, None, tp if ok(1, tp) else None)
    if name == "w_out" or name == "wo":
        return P(*L, tp if ok(0, tp) else None,
                 dp if fsdp and len(dims) > 1 and ok(1, dp) else None)
    if name == "conv":                                     # [4, Dr]
        return P(*L, None, tp if ok(1, tp) else None)

    # ---- frontends ----
    if name in ("proj", "proj1", "proj2"):
        return P(None, tp if _fits(shape[1], mesh, tp) else None)

    return P(*([None] * len(shape)))


def _tree_paths(tree) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp), tree)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shape) -> Any:
    """NamedShardings for a params pytree (from jax.eval_shape)."""
    fsdp = cfg.sharding == "fsdp_tp" and "data" in mesh.axis_names
    paths = _tree_paths(params_shape)
    return jax.tree.map(
        lambda p, x: NamedSharding(
            mesh, param_spec(cfg, mesh, p, x.shape, fsdp)),
        paths, params_shape)


def batch_pspec(mesh: Mesh, cfg: ArchConfig | None = None,
                global_batch: int | None = None) -> P:
    if cfg is not None and cfg.sharding == "dp" and global_batch \
            and global_batch % mesh.size == 0:
        return P(tuple(mesh.axis_names))
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return P(dp)


def cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shape) -> Any:
    """PartitionSpecs for the serving cache.

    KV stacks [Lx, B, T, Kv, Dh]: batch over DP axes when divisible; kv
    heads over "model" when divisible, otherwise the sequence dim goes over
    "model" (sequence-parallel decode).  Recurrent states shard batch only.
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_n = _axsize(mesh, dp)
    tp_n = mesh.shape["model"]
    paths = _tree_paths(cache_shape)

    def spec_for(path, x):
        shape = x.shape
        name = path.split("/")[-1].split("_")[0] if "/" in path else path
        base = path.split("/")[0]
        if base in ("gk", "gv", "lk", "lv"):
            Lx, B, T, Kv, Dh = shape
            b_ax = dp if B % dp_n == 0 else None
            if Kv % tp_n == 0:
                return P(None, b_ax, None, "model", None)
            if T % tp_n == 0:
                return P(None, b_ax, "model", None, None)
            return P(None, b_ax, None, None, None)
        if base in ("gpos", "lpos"):
            B = shape[0]
            return P(dp if B % dp_n == 0 else None, None)
        if base == "pos":
            return P()
        # recurrent states: [n, B, ...]
        if len(shape) >= 2:
            B = shape[1]
            parts = [None, dp if B % dp_n == 0 else None]
            parts += [None] * (len(shape) - 2)
            return P(*parts)
        return P(*([None] * len(shape)))

    return jax.tree.map(lambda p, x: NamedSharding(mesh, spec_for(p, x)),
                        paths, cache_shape)
