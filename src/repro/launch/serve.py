"""Serving launcher: batched prefill + greedy decode on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --smoke --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import model as model_lib
from repro.train.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    dtype = jnp.float32 if args.smoke else jnp.bfloat16

    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key, dtype=dtype)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = greedy_generate(cfg, params, prompts, args.gen,
                          args.prompt_len + args.gen + 8, dtype=dtype)
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"generated {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
