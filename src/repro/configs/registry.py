"""Architecture registry: --arch <id> resolution for every launcher."""

from repro.configs.base import ArchConfig

from repro.configs.command_r_plus_104b import CONFIG as _command_r
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.phi3_mini_38b import CONFIG as _phi3
from repro.configs.qwen15_32b import CONFIG as _qwen
from repro.configs.recurrentgemma_9b import CONFIG as _rg
from repro.configs.xlstm_350m import CONFIG as _xlstm

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    _gemma3, _qwen, _command_r, _phi3, _llava, _llama4, _deepseek, _xlstm,
    _hubert, _rg,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
