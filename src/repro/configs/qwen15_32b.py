"""qwen1.5-32b [dense] — MHA (kv=40) with QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=40, d_ff=27392, vocab_size=152064,
    qkv_bias=True, tie_embeddings=False, sharding="fsdp_tp")
