"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` in its own module under
``repro.configs``; ``repro.configs.registry`` exposes them by id for the
``--arch`` flag of every launcher.  Each config also provides a ``smoke()``
reduction (same family, tiny dims) used by per-arch CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_expert: int = 0              # 0 => use arch d_ff
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # attention pattern: a period of layers with `local` sliding-window
    # layers followed by `global` full-attention layers (gemma3: 5:1)
    local_window: int = 0          # 0 => all layers global
    pattern_local: int = 0
    pattern_global: int = 1
    # recurrent/hybrid block pattern (recurrentgemma: 2 recurrent : 1 attn)
    block_kind: str = "attn"       # attn | xlstm | rglru
    pattern_recurrent: int = 0
    # ssm/xlstm
    mlstm_chunk: int = 256
    conv_width: int = 4
    # moe
    moe: MoEConfig | None = None
    # modality frontend stub (audio frames / vision patches)
    frontend: str = "none"         # none | audio_frames | vision_patches
    frontend_dim: int = 0
    num_patches: int = 0           # vlm: patches prepended to the sequence
    encoder_only: bool = False
    # distribution policy
    sharding: str = "tp"           # tp | fsdp_tp
    remat: bool = True
    use_flash_attention: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def smoke(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = MoEConfig(num_experts=min(4, self.moe.num_experts),
                            top_k=min(2, self.moe.top_k),
                            num_shared=min(1, self.moe.num_shared),
                            d_expert=32, capacity_factor=2.0)
        period = max(1, self.pattern_local + self.pattern_global,
                     self.pattern_recurrent + (1 if self.pattern_recurrent
                                               else 0))
        layers = max(2, 2 * period)
        return dataclasses.replace(
            self, num_layers=layers, d_model=64,
            num_heads=4, num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            d_ff=128, vocab_size=256, head_dim=16,
            local_window=min(self.local_window, 16) if self.local_window
            else 0,
            mlstm_chunk=16, moe=moe, frontend_dim=32 if self.frontend != "none"
            else 0, num_patches=8 if self.frontend == "vision_patches" else 0,
            sharding="tp", remat=False)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_skip_reason(arch: ArchConfig, shape: ShapeConfig) -> str | None:
    """None if the (arch, shape) cell runs; otherwise the documented skip."""
    if arch.encoder_only and shape.kind == "decode":
        return "encoder-only architecture has no autoregressive decode step"
    if shape.name == "long_500k":
        sub_quadratic = arch.family in ("ssm", "hybrid")
        if not sub_quadratic:
            return ("500k decode needs sub-quadratic attention; this arch "
                    "carries full/periodically-global attention (see "
                    "DESIGN.md §Arch-applicability)")
    return None


def live_cells(archs) -> list[tuple[ArchConfig, ShapeConfig]]:
    cells = []
    for a in archs:
        for s in SHAPES.values():
            if shape_skip_reason(a, s) is None:
                cells.append((a, s))
    return cells
