"""xlstm-350m [ssm] — alternating mLSTM (chunkwise-parallel matrix memory)
and sLSTM (recurrent scan) blocks.  [arXiv:2405.04517; unverified]

d_ff = 0: xLSTM blocks carry their own projections; no separate MLP.
Runs the long_500k shape (O(1) recurrent state, no KV growth).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm", num_layers=24, d_model=1024,
    num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
    block_kind="xlstm", mlstm_chunk=256, tie_embeddings=True, sharding="tp")
