"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense", num_layers=62, d_model=5376,
    num_heads=32, num_kv_heads=16, d_ff=21504, vocab_size=262144,
    head_dim=128, rope_theta=1_000_000.0, local_window=1024,
    pattern_local=5, pattern_global=1, tie_embeddings=True, sharding="fsdp_tp")
