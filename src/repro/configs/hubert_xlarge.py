"""hubert-xlarge [audio] — encoder-only; masked-prediction over 504 cluster
ids.  [arXiv:2106.07447; unverified]

Modality frontend is a STUB: input_specs provides precomputed conv-feature
frames [B, S, 512]; decode shapes are skipped (no autoregressive step).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio", num_layers=48, d_model=1280,
    num_heads=16, num_kv_heads=16, d_ff=5120, vocab_size=504,
    encoder_only=True, frontend="audio_frames", frontend_dim=512,
    tie_embeddings=False, sharding="tp")
