from repro.configs.base import SHAPES, ArchConfig, MoEConfig, ShapeConfig, shape_skip_reason
from repro.configs.registry import ARCHS, get_arch

__all__ = ["ARCHS", "ArchConfig", "MoEConfig", "SHAPES", "ShapeConfig",
           "get_arch", "shape_skip_reason"]
