"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6,
expert dim 1408.  [arXiv:2401.06066; hf]

Deviation note (DESIGN.md): the reference model keeps layer 0 dense; here
every layer is MoE for a homogeneous scan stack — parameter count differs
by < 1%.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=102400,
    tie_embeddings=False, sharding="tp",
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408,
                  capacity_factor=1.25))
