"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern,
MQA kv=1, window 2048.  [arXiv:2402.19427; unverified]

Runs the long_500k shape: recurrent state is O(1), attention KV is a
2048-slot ring buffer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, d_ff=12288, vocab_size=256000,
    block_kind="rglru", local_window=2048, tie_embeddings=True,
    sharding="fsdp_tp")
