"""llama4-scout-17b-a16e [moe] — 16 routed experts top-1 + 1 shared, every
layer MoE.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=8192, vocab_size=202048,
    tie_embeddings=False, sharding="fsdp_tp",
    moe=MoEConfig(num_experts=16, top_k=1, num_shared=1, d_expert=8192,
                  capacity_factor=1.25))
