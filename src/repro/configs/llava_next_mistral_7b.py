"""llava-next-mistral-7b [vlm] — mistral backbone + anyres patch stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The modality frontend is a STUB per the assignment: input_specs provides
precomputed patch embeddings [B, 2880, 1024] (5 anyres tiles x 576 patches)
projected by a 2-layer MLP and prepended to the token sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    tie_embeddings=False, frontend="vision_patches", frontend_dim=1024,
    num_patches=2880, sharding="tp")
