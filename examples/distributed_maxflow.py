"""Distributed P-ARD under shard_map across (simulated) devices: regions
are sharded over the mesh; all cross-device traffic is the paper's boundary
label/flow exchange.

    python examples/distributed_maxflow.py     # forces 8 host devices
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.core import SweepConfig, grid_partition, init_labels
from repro.core.distributed import solve_sharded
from repro.core.graph import build
from repro.core.sweep import cut_value, extract_cut
from repro.data.grids import synthetic_grid

H = W = 40
problem = synthetic_grid(H, W, connectivity=8, strength=150, seed=0)
part = grid_partition((H, W), (2, 4))          # 8 regions, 1 per device
meta, state, layout = build(problem, part)
state0 = state
state = init_labels(meta, state)

mesh = jax.make_mesh((len(jax.devices()),), ("regions",))
print(f"devices: {len(jax.devices())}, regions: {meta.num_regions}, "
      f"|B|={meta.num_boundary}")
st, sweeps = solve_sharded(meta, state, mesh, SweepConfig(method="ard"))
flow = int(st.flow_to_t)
side = extract_cut(meta, st)
cost = int(cut_value(meta, state0, side))
print(f"flow={flow} cut={cost} sweeps={sweeps} "
      f"(bound {2 * meta.num_boundary**2 + 1})")
assert flow == cost
