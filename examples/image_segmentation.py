"""Vision use-case: binary image segmentation via distributed mincut —
the paper's motivating application family (BJ01/BF06 instances).

Builds a contrast-weighted grid graph over a noisy synthetic image with a
planted foreground disk, solves it with S-ARD, and prints ASCII output.

    PYTHONPATH=src python examples/image_segmentation.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import SweepConfig, grid_partition, solve_mincut
from repro.data.grids import segmentation_grid

H = W = 32
problem = segmentation_grid(H, W, seed=0)
part = grid_partition((H, W), (2, 2))
res = solve_mincut(problem, part=part, config=SweepConfig(method="ard"))

seg = res.source_side.reshape(H, W)      # source side = foreground
print(f"flow={res.flow_value} sweeps={res.stats.sweeps}")
for row in seg[::2]:
    print("".join("#" if v else "." for v in row))
