"""End-to-end driver: train a ~100M-parameter transformer for a few hundred
steps on the deterministic Markov stream and watch the loss approach the
stream's entropy floor.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

--tiny trains a few-M-param model instead (seconds on this CPU container);
the default ~100M config is sized for a real accelerator.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import MarkovSpec, markov_batch
from repro.models.model import init_params, param_count
from repro.train import optimizer as opt_lib
from repro.train import train_loop as tl

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

if args.tiny:
    cfg = ArchConfig(name="tiny-lm", family="dense", num_layers=4,
                     d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
                     vocab_size=512, remat=False)
else:
    # ~100M params: 12L x 768 with a 32k vocab
    cfg = ArchConfig(name="lm-100m", family="dense", num_layers=12,
                     d_model=768, num_heads=12, num_kv_heads=12, d_ff=3072,
                     vocab_size=32768, remat=False)

spec = MarkovSpec(vocab=cfg.vocab_size, branching=4, seed=11)
params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
print(f"params: {param_count(cfg) / 1e6:.1f}M  "
      f"entropy floor: {spec.entropy_floor():.4f}")

state = tl.TrainState(params=params, opt=opt_lib.init_opt_state(params))
step = jax.jit(tl.make_train_step(
    cfg, opt_lib.AdamWConfig(lr=1e-3, warmup_steps=30,
                             total_steps=args.steps), jnp.float32))

t0 = time.time()
for i in range(1, args.steps + 1):
    batch = jax.tree.map(jnp.asarray,
                         markov_batch(spec, i, args.batch, args.seq))
    state, m = step(state, batch)
    if i % 20 == 0 or i == 1:
        print(f"step {i:4d}  ce={float(m['ce']):.4f}  "
              f"lr={float(m['lr']):.2e}  "
              f"({args.batch * args.seq * i / (time.time() - t0):.0f} tok/s)",
              flush=True)
final = float(m["ce"])
print(f"final ce {final:.4f} vs floor {spec.entropy_floor():.4f}")
