"""Interactive image segmentation on a ``Solver`` session — the paper's
motivating dynamic-cuts workload: the user scribbles, the solver re-cuts.

A sparse-seed segmentation instance (foreground scribble at the center,
background scribble on the border, contrast-weighted 4-connected grid) is
prepared ONCE; the first solve is cold.  Each simulated "brush stroke"
then edits terminal capacities through ``handle.update`` — the residual
network is reparameterized on device — and ``handle.solve()`` re-cuts
from the warm preflow in a fraction of the cold solve's sweeps.

    PYTHONPATH=src python examples/interactive_segmentation.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import Solver, SolverOptions, grid_partition
from repro.data.grids import segmentation_seeds_grid

H = W = 32


def show(res, title):
    seg = res.source_side.reshape(H, W)      # source side = foreground
    print(f"--- {title}: flow={res.flow_value} "
          f"sweeps={res.stats.sweeps} launches={res.stats.engine_launches}")
    for row in seg[::2]:
        print("".join("#" if v else "." for v in row))


problem = segmentation_seeds_grid(H, W, seed=0)
solver = Solver(SolverOptions(method="ard", num_regions=4))
handle = solver.prepare(problem, grid_partition((H, W), (2, 2)))

cold = handle.solve()
show(cold, "initial segmentation (cold solve)")

# The user scribbles FOREGROUND over a block in the upper-left quadrant:
# those pixels get strong source mass (and any sink capacity removed).
yy, xx = np.mgrid[:H, :W]
stroke = ((yy - H // 4) ** 2 + (xx - W // 4) ** 2
          < (H // 8) ** 2).reshape(-1)
exc = handle.problem.excess.copy()
snk = handle.problem.sink_cap.copy()
exc[stroke] = 300                  # strong source mass under the brush
snk[stroke] = 0                    # ... and no competing sink link
handle.update(excess=exc, sink_cap=snk)

warm = handle.solve()
show(warm, "after foreground scribble (warm re-solve)")

# the warm result is exactly what a from-scratch solve of the edited
# problem computes — the session just got there from the previous optimum
cold_ref = Solver(SolverOptions(method="ard", num_regions=4)).solve(
    handle.problem, handle.part)
assert warm.flow_value == cold_ref.flow_value
print(f"warm re-solve: {warm.stats.sweeps} sweep(s) / "
      f"{warm.stats.engine_launches} launches vs cold re-solve "
      f"{cold_ref.stats.sweeps} / {cold_ref.stats.engine_launches}; "
      f"session cache: {solver.cache_info()}")

# a second stroke with the same brush shows the steady-state win: the
# edit lands in the same (power-of-two) update-size bucket and the
# re-solve reuses every compiled program — zero retraces
traces = solver.cache_info().traces
touch = ((yy - H // 4) ** 2 + (xx - 3 * W // 4) ** 2
         < (H // 8) ** 2).reshape(-1)
exc2 = handle.problem.excess.copy()
exc2[touch] = 300
handle.update(excess=exc2)
warm2 = handle.solve()
show(warm2, "after touch-up stroke (warm re-solve)")
assert solver.cache_info().traces == traces, "steady state must not retrace"
print(f"touch-up re-solved in {warm2.stats.sweeps} sweep(s), "
      f"zero retraces")
