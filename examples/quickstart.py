"""Quickstart: solve a distributed MINCUT with the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import Problem, SweepConfig, solve_mincut

# A tiny hand-built network: 6 vertices, terminal masses, symmetric edges.
problem = Problem(
    num_vertices=6,
    edges=np.array([[0, 1], [1, 2], [2, 3], [3, 4], [4, 5], [0, 3]]),
    cap_fwd=np.array([4, 3, 2, 5, 6, 1], np.int32),
    cap_bwd=np.array([4, 3, 2, 5, 6, 1], np.int32),
    excess=np.array([9, 0, 0, 0, 0, 0], np.int32),     # source mass at v0
    sink_cap=np.array([0, 0, 0, 0, 0, 9], np.int32),   # sink drain at v5
)

# Solve with the paper's S/P-ARD (augmented-path region discharge).
result = solve_mincut(problem, num_regions=2,
                      config=SweepConfig(method="ard", parallel=True))
print(f"max-flow / min-cut value : {result.flow_value}")
print(f"source side              : {np.nonzero(result.source_side)[0]}")
print(f"sweeps                   : {result.stats.sweeps} "
      f"(bound {2 * result.meta.num_boundary**2 + 1})")
print(f"boundary message bytes   : {result.stats.boundary_bytes}")

# Compare against the push-relabel region discharge baseline (Delong-Boykov)
baseline = solve_mincut(problem, num_regions=2,
                        config=SweepConfig(method="prd"))
assert baseline.flow_value == result.flow_value
print(f"PRD baseline sweeps      : {baseline.stats.sweeps}")
