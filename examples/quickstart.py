"""Quickstart: solve a distributed MINCUT through a ``Solver`` session.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import Problem, Solver, SolverOptions

# A tiny hand-built network: 6 vertices, terminal masses, symmetric edges.
problem = Problem(
    num_vertices=6,
    edges=np.array([[0, 1], [1, 2], [2, 3], [3, 4], [4, 5], [0, 3]]),
    cap_fwd=np.array([4, 3, 2, 5, 6, 1], np.int32),
    cap_bwd=np.array([4, 3, 2, 5, 6, 1], np.int32),
    excess=np.array([9, 0, 0, 0, 0, 0], np.int32),     # source mass at v0
    sink_cap=np.array([0, 0, 0, 0, 0, 9], np.int32),   # sink drain at v5
)

# A session holds the options and the compile cache; prepare() blocks the
# problem into regions ONCE and returns a reusable handle.
solver = Solver(SolverOptions(method="ard", parallel=True, num_regions=2))
handle = solver.prepare(problem)

result = handle.solve()          # the paper's S/P-ARD
print(f"max-flow / min-cut value : {result.flow_value}")
print(f"source side              : {np.nonzero(result.source_side)[0]}")
print(f"sweeps                   : {result.stats.sweeps} "
      f"(bound {2 * result.meta.num_boundary**2 + 1})")
print(f"boundary message bytes   : {result.stats.boundary_bytes}")

# The handle is now WARM: edit capacities in place and re-solve — the
# update reparameterizes the residual network on device and the solve
# continues from the previous optimum instead of from zero.  Edge (2, 3)
# crosses the mincut, so widening it raises the flow.
handle.update(arcs=np.array([2]),                 # edge (2, 3): 2 -> 6
              cap_fwd=np.array([6], np.int32),
              cap_bwd=np.array([6], np.int32))
warm = handle.solve()
print(f"after widening edge (2,3): flow {result.flow_value} -> "
      f"{warm.flow_value} in {warm.stats.sweeps} warm sweep(s)")
assert warm.flow_value > result.flow_value

# Compare against the push-relabel region discharge baseline (Delong-Boykov)
baseline = Solver(SolverOptions(method="prd", num_regions=2)).solve(problem)
assert baseline.flow_value == result.flow_value
print(f"PRD baseline sweeps      : {baseline.stats.sweeps}")

# Legacy one-shot front-end (thin wrapper over a throwaway session):
from repro.core import SweepConfig, solve_mincut

legacy = solve_mincut(problem, num_regions=2,
                      config=SweepConfig(method="ard", parallel=True))
assert legacy.flow_value == result.flow_value
